"""The pre-PR-2 call pattern, kept as the deprecation-shim demonstration.

    PYTHONPATH=src python examples/legacy_quickstart.py [--budget 8]

Runs the historical ``Scenario`` + ``tune_scenario`` path, asserts that the
shims emit ``DeprecationWarning`` pointing at the Study replacement, and
asserts the numbers match the typed API exactly.
"""
import argparse
import sys, os
import warnings
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="gups")
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--scale", type=float, default=0.1)
    args = ap.parse_args()

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        from repro.core.simulator import Scenario
        from repro.core.bo.tuner import tune_scenario
        sc = Scenario(args.workload, scale=args.scale)
        legacy = tune_scenario("hemem", sc, budget=args.budget, seed=0)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)
           and str(w.message).startswith("repro.")]
    assert dep, "legacy path must emit DeprecationWarning"
    print("deprecation warnings emitted by the legacy path:")
    for w in {str(d.message).split(" is deprecated")[0] for d in dep}:
        print(f"  {w}")

    res = Study(ExperimentSpec(
        engine="hemem", workload=WorkloadSpec(args.workload, scale=args.scale),
        options=SimOptions(sampler="elementwise"))).tune(
            budget=args.budget, seed=0)
    assert [o.value for o in res.history] == \
        [o.value for o in legacy.history], "shim numerics must match"
    print(f"\nlegacy best {legacy.best_value:.1f}s == Study best "
          f"{res.best_value:.1f}s (identical numerics, budget "
          f"{args.budget})")
    print("migrate: Scenario+tune_scenario -> "
          "Study(ExperimentSpec(...)).tune(...)")


if __name__ == "__main__":
    main()
