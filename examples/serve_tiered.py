"""Serve a small model with batched requests over a TieredKVCache, with the
tiering engine migrating KV pages between HBM and host tiers — the paper's
technique running in the real decode path.

By default this runs the COMPILED serving path: ``decode_step`` is one
jitted call (append + paged-attention + read-recording fused over the whole
batch) and engine epochs batch their page moves through ``page_migrate``.
``--python-loop`` runs the per-page reference loop instead — same residency
decisions (both modes share one jitted engine executable), ~100x slower.

    PYTHONPATH=src python examples/serve_tiered.py [--steps 128] [--tuned]
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.tiered_kv import KVSpec, TieredKVCache

TUNED = dict(read_hot_threshold=2, sampling_period=500,
             cooling_pages=65536, migration_period=10)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--hbm-pages", type=int, default=24)
    ap.add_argument("--tuned", action="store_true")
    ap.add_argument("--python-loop", action="store_true",
                    help="use the per-page reference loop instead of the "
                         "fused compiled step")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    spec = KVSpec(n_layers=4, kv_heads=2, head_dim=32, page_tokens=8)
    cache = TieredKVCache(spec, batch=args.batch, max_pages_per_seq=64,
                          hbm_pages=args.hbm_pages,
                          config=TUNED if args.tuned else None,
                          compiled=not args.python_loop)
    t0 = time.time()
    for step in range(args.steps):
        k = rng.normal(size=(args.batch, spec.n_layers, spec.kv_heads,
                             spec.head_dim))
        q = rng.normal(size=(args.batch, spec.kv_heads, spec.head_dim))
        out = cache.decode_step(k, k, q)   # fused append+attend+record
        if step % 8 == 7:
            cache.step_engine(50.0)
        if step % 32 == 31:
            print(f"step {step+1:4d}  recall={cache.recall():.3f}  "
                  f"migrations={cache.migrations:4d}  "
                  f"hbm_util={cache.hbm_utilization():.2f}")
    mode = "python-loop" if args.python_loop else "compiled"
    print(f"\n{'tuned' if args.tuned else 'default'} config [{mode}]: "
          f"recall={cache.recall():.3f} migrations={cache.migrations} "
          f"({(time.time()-t0)/args.steps*1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()
