"""End-to-end training driver: train a language model with the full
production stack (sharded train_step, grad accumulation, async checkpoints,
straggler detection, deterministic resume).

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is a ~100M-param llama-style config (takes a while on CPU;
it is the TPU-ready path).
"""
import argparse
import dataclasses
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.mesh import make_local_mesh
from repro.models.config import ModelConfig
from repro.train.trainer import Trainer

PRESETS = {
    "tiny": ModelConfig(arch="tiny-lm", family="lm", n_layers=4, d_model=128,
                        n_heads=4, n_kv_heads=2, d_ff=512, vocab=2048,
                        remat=False),
    "100m": ModelConfig(arch="lm-100m", family="lm", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab=32000, remat=False),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workdir", default="/tmp/repro_train")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"{cfg.arch}: {cfg.param_count()/1e6:.1f}M params")
    mesh = make_local_mesh()
    tr = Trainer(cfg, mesh, args.workdir, global_batch=args.batch,
                 seq_len=args.seq, total_steps=args.steps, ckpt_every=50,
                 lr=3e-4)
    out = tr.run()
    for m in out["metrics"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  {m['dt']*1e3:.0f}ms")
    print(f"stragglers: {len(out['stragglers'])}")


if __name__ == "__main__":
    main()
