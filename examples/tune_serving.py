"""Tune the TieredKVCache knobs against the REAL serving path through the
typed Study API: ``Study.tune(objective=...)`` drives the Table-2 HeMem
knob space while the objective replays an embedded, JSON-round-trippable
:class:`~repro.core.traffic.TrafficSpec` through the compiled decode loop
(fused append + paged-attention + read-recording jit) and scores
p99 latency / recall.

    PYTHONPATH=src python examples/tune_serving.py [--budget 20]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import ExperimentSpec, Study
from repro.core.knobs import HEMEM_SPACE
from repro.core.traffic import TrafficSpec

from benchmarks.serving_tiered_kv import replay, serving_objective


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=20)
    ap.add_argument("--pattern", choices=("poisson", "bursty-diurnal"),
                    default="bursty-diurnal")
    args = ap.parse_args()

    traffic = TrafficSpec(pattern=args.pattern, arrival_rate=32 / 24,
                          steps=96)
    print(f"traffic: {traffic.to_json()}\n")

    def objective(config) -> float:
        stats = replay(config, traffic, batch=32, max_pages=8, seed=5)
        return serving_objective(stats)

    # the spec names the engine whose knob space is tuned; the serving
    # replay above replaces the simulator objective
    study = Study(ExperimentSpec(engine="kv-hemem", workload="kv-poisson"))
    res = study.tune(budget=args.budget, seed=0, n_init=8,
                     objective=objective, verbose=True)
    print(f"\ndefault objective: {res.default_value:.2f}")
    print(f"tuned   objective: {res.best_value:.2f} "
          f"({res.improvement:.2f}x better)")
    dflt = HEMEM_SPACE.default_config()
    for k, v in res.best.config.items():
        if v != dflt[k]:
            print(f"  {k:28s} {dflt[k]:>8} -> {v}")


if __name__ == "__main__":
    main()
