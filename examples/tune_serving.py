"""Tune the TieredKVCache knobs with SMAC against the REAL serving path
(the JaxBackend of DESIGN.md): the objective is attention-mass recall
shortfall + migration cost on an actual decode loop.

    PYTHONPATH=src python examples/tune_serving.py [--budget 20]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.knobs import HEMEM_SPACE
from repro.core.bo.tuner import TuningSession
from repro.core.tiered_kv import KVSpec, TieredKVCache


def serving_objective(config) -> float:
    rng = np.random.default_rng(7)
    spec = KVSpec(n_layers=2, kv_heads=2, head_dim=16, page_tokens=8)
    cache = TieredKVCache(spec, batch=2, max_pages_per_seq=48, hbm_pages=12,
                          config=config)
    for step in range(96):
        k = rng.normal(size=(2, spec.n_layers, spec.kv_heads, spec.head_dim))
        cache.append(k, k)
        cache._record_reads()
        if step % 8 == 7:
            cache.step_engine(50.0)
    # cost = missed attention mass + migration bandwidth penalty
    miss = 1.0 - cache.recall()
    return 100.0 * miss + 0.05 * cache.migrations


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=20)
    args = ap.parse_args()
    session = TuningSession("hemem", serving_objective,
                            scenario_key="tiered-kv-serving",
                            budget=args.budget, seed=0, n_init=8)
    res = session.run(verbose=True)
    print(f"\ndefault objective: {res.default_value:.2f}")
    print(f"tuned   objective: {res.best_value:.2f} "
          f"({res.improvement:.2f}x better)")
    dflt = HEMEM_SPACE.default_config()
    for k, v in res.best.config.items():
        if v != dflt[k]:
            print(f"  {k:28s} {dflt[k]:>8} -> {v}")


if __name__ == "__main__":
    main()
