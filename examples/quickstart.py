"""Quickstart: tune HeMem's knobs for a workload with SMAC-BO (the paper's
pipeline, §3.1) and print the before/after table.

    PYTHONPATH=src python examples/quickstart.py [--workload gups] [--budget 40]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.simulator import Scenario
from repro.core.knobs import HEMEM_SPACE
from repro.core.bo.tuner import tune_scenario
from repro.core.bo.importance import knob_importance


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="gups")
    ap.add_argument("--input", default="")
    ap.add_argument("--machine", default="pmem-large")
    ap.add_argument("--budget", type=int, default=40)
    args = ap.parse_args()

    sc = Scenario(args.workload, args.input, machine=args.machine)
    print(f"Tuning HeMem for {sc.key} (budget {args.budget})...")
    res = tune_scenario("hemem", sc, budget=args.budget, seed=0,
                        verbose=True)
    print(f"\ndefault: {res.default_value:8.1f}s")
    print(f"best:    {res.best_value:8.1f}s   ({res.improvement:.2f}x)")
    print("\nbest config (changes vs default):")
    dflt = HEMEM_SPACE.default_config()
    for k, v in res.best.config.items():
        if v != dflt[k]:
            print(f"  {k:28s} {dflt[k]:>8} -> {v}")
    print("\nknob importance (surrogate-based, §3.1):")
    for k, v in list(knob_importance(HEMEM_SPACE, res.history).items())[:5]:
        print(f"  {k:28s} {v:.2f}")


if __name__ == "__main__":
    main()
