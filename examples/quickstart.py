"""Quickstart: tune HeMem's knobs for a workload with SMAC-BO (the paper's
pipeline, §3.1) through the typed Study API, and print the before/after
table.

    PYTHONPATH=src python examples/quickstart.py [--workload gups] [--budget 40]

Pass ``--batch-size 8`` to evaluate whole candidate batches per tuning
iteration through the vectorized simulator, and ``--workers auto`` to
additionally shard each batch over a process pool.  With jax installed,
``--batch-size 8 --backend jax`` compiles the whole epoch loop (engines +
samplers + cost model) into one jitted ``lax.scan`` and adds ``--crn``
common-random-number evaluation, so every candidate batch is compared under
identical monitoring noise::

    PYTHONPATH=src python examples/quickstart.py --batch-size 8 \\
        --backend jax --crn

The jax backend plans migrations with the **exact** top-k selection kernel
(``repro.kernels.select_topk``; bit-identical page sets to the numpy
reference's stable sorts) — ``SimOptions(exact_select=False)`` keeps the
historical log-quantized approximation for ablations, and
``python -m benchmarks.batched_tuning --backend jax --select
{pallas,quantized,ref}`` measures what exactness costs.

The experiment is fully described by one JSON-round-trippable
``ExperimentSpec``; see ``examples/legacy_quickstart.py`` for the
deprecated pre-PR-2 call pattern.

The same Study API also drives the REAL serving path (PR 6): engines that
implement the lifted protocol (``repro.core.engine_jax.register_jax_engine``;
``kv-hemem`` ships) compile end-to-end under ``backend="jax"``, and
``TieredKVCache(compiled=True)`` runs decode as ONE fused jit (append +
paged attention + read recording) with batched ``page_migrate`` epochs —
bit-identically to the per-page Python loop.  ``Study.tune(objective=...)``
accepts a custom objective, e.g. a p99-latency/recall score over a
replayable ``TrafficSpec`` arrival trace; see ``examples/tune_serving.py``
and ``python -m benchmarks.serving_tiered_kv``.

**Async tuning & resume** (PR 7): ``--executor async`` hands the study to
the asynchronous trial-executor service — ``--slots N`` evaluation slots
stay saturated with trials (no per-round barrier), ``--scheduler asha``
adds successive-halving early stopping over ¼/½/full-epoch rungs (on the
jax backend promoted trials resume mid-run from the epoch-loop
checkpoint), and ``--journal study.jsonl`` records every ask/eval/rung/
tell decision as replayable JSON lines.  A killed study picks up exactly
where it died::

    PYTHONPATH=src python examples/quickstart.py --backend jax \\
        --executor async --slots 8 --scheduler asha --journal study.jsonl
    # ... SIGKILL it mid-run, then:
    PYTHONPATH=src python examples/quickstart.py --backend jax \\
        --executor async --slots 8 --scheduler asha --journal study.jsonl \\
        --resume

The control loop is deterministic (every decision happens at canonical
commit order, not wall-clock arrival), so the resumed journal, trial
table and incumbent are byte/bit-identical to an uninterrupted run —
and ``--executor async --slots 1`` reproduces the synchronous path's
incumbent bit-identically.  Receipts: ``python -m benchmarks.study_async``
-> ``BENCH_study.json``; journal schema: ``tools/journal_schema.py``.

**Fault-tolerant fleets** (PR 8): ``--executor fleet`` puts the same study
behind the lease-and-commit coordinator — ``--fleet-workers N`` worker
*processes* (or remote hosts via ``pool="socket"`` and ``python -m
repro.core.tune_service.worker --connect HOST:PORT``) drain one shared
work-unit queue.  Every dispatched unit carries a heartbeat-monitored
lease: a worker that dies, wedges or loses its result message has its
lease expired and the unit re-issued to another worker (duplicate
execution is safe — results are deterministic, the first commit wins and
any late twin is asserted bitwise equal), and at zero live workers the
coordinator degrades to its local slot rather than wedging.  The journal
gains ``lease``/``expire``/``reissue`` events, recorded at commit order,
so a SIGKILLed coordinator resumes byte-identically even mid-re-issue::

    PYTHONPATH=src python examples/quickstart.py --executor fleet \\
        --fleet-workers 4 --journal study.jsonl

Receipts (injected 1-in-8 worker kills, utilization, re-issue overhead):
``python -m benchmarks.study_fleet`` -> ``BENCH_study.json["fleet"]``;
fault injectors for tests live in ``repro.core.tune_service.faults``.

**Hardened multi-host fleets** (PR 10): ``--fleet-spec FLEET.json``
deploys the coordinator against a frozen
:class:`~repro.core.tune_service.FleetSpec` — ONE artifact holding the
bind address, worker count/hosts, heartbeat + lease parameters and the
shared ``auth_key`` that every socket frame is HMAC-signed with
(length-capped before allocation, replay-protected, bounded reads;
workers greet with a signed hello before any unit is leased, so
reachability no longer implies trust).  Mint a spec and bring up its
workers with the launcher, then point the study at it::

    python tools/fleet_launch.py --init fleet.json --workers 4
    python tools/fleet_launch.py fleet.json &      # or --print for the
                                                   # per-host commands
    PYTHONPATH=src python examples/quickstart.py --executor fleet \\
        --fleet-spec fleet.json --scheduler asha --journal study.jsonl

Workers re-dial with backoff when the link drops and the coordinator
re-attaches the live lease (``reconnect`` in the journal); invalid
frames are journaled as ``reject`` events and the connection is dropped.
``--scheduler asha`` now composes with the fleet: rung segments
re-derive their epoch prefix from scratch, so early stopping survives
lease expiry and re-issue bitwise.  The auth key is a secret — it rides
the spec file or the ``REPRO_FLEET_KEY`` environment variable, never
argv or the journal; keep spec files out of version control.

**Online re-tuning under drift** (PR 9): ``--drift`` swaps the workload
for a registered phase-shifting trace (:mod:`repro.core.drift`) and
``--online`` runs the sliding-window online tuner instead of a one-shot
search: every ``--window`` epochs ONE compiled CRN segment evaluates the
deployed config (row 0 — the system's actual trajectory) next to
``--batch-size`` SMAC candidates as paired what-if-we-switched
counterfactuals; a detected phase change (sampled-histogram divergence or
surrogate-residual blowup) warm-restarts the optimizer from the prior
elites, and switches apply only past a hysteresis margin + dwell period,
so the config can never thrash.  Worked hotspot-rotation example (the hot
set moves every 20 epochs; watch the tuner detect each rotation and
re-adapt)::

    PYTHONPATH=src python examples/quickstart.py --backend jax --crn \\
        --drift drift-hotspot --online --window 10 --batch-size 6 \\
        --budget 36

``--drift drift-splice`` replays a gups -> silo/ycsb-c wholesale change
instead, and custom drifts are one-liners (``DriftSpec.splice(...)``,
``.hotspot(...)``, ``.wset(...)`` — ``spec.register()`` makes them plain
workload names).  Receipts (time-to-readapt, cumulative slowdown vs the
default and per-phase-oracle arms, zero-thrash assertion):
``python -m benchmarks.drift`` -> ``BENCH_drift.json``.

The optimizer itself runs its compiled hot path by default (PR 5): the
random-forest surrogate is grown level-synchronously into flat arrays and
EI acquisition is one fused vectorized pass (jitted on TPU hosts) ending in
the exact ``select_topk`` top-q kernel — ask/tell costs a few percent of
evaluation wall clock (receipts: ``python -m benchmarks.bo_overhead`` ->
``BENCH_bo.json``).  ``Study.tune(surrogate="reference")`` pins the
recursive reference forest (bit-identical suggestions, for debugging) and
``acquisition="legacy"`` replays the pre-PR-5 scoring pipeline.
"""
import argparse
import json
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.knobs import HEMEM_SPACE
from repro.core.bo.importance import knob_importance


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="gups")
    ap.add_argument("--input", default="")
    ap.add_argument("--machine", default="pmem-large")
    ap.add_argument("--budget", type=int, default=40)
    ap.add_argument("--batch-size", type=int, default=1,
                    help="evaluate q candidates per iteration in one "
                         "vectorized simulator pass (1 = sequential)")
    ap.add_argument("--workers", default=1,
                    help="process-pool size for batch sharding (int or auto)")
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="'jax' compiles the whole epoch loop (one jitted "
                         "lax.scan per engine/workload shape)")
    ap.add_argument("--crn", action="store_true",
                    help="common random numbers: all candidates of a batch "
                         "see identical monitoring noise (requires "
                         "--backend jax)")
    ap.add_argument("--executor", choices=("sync", "async", "fleet"),
                    default="sync",
                    help="'async' = slot-saturating trial executor; "
                         "'fleet' = lease-and-commit coordinator over "
                         "worker processes (repro.core.tune_service)")
    ap.add_argument("--slots", type=int, default=1,
                    help="async evaluation slots (--executor async)")
    ap.add_argument("--fleet-workers", type=int, default=2,
                    help="fleet worker processes (--executor fleet)")
    ap.add_argument("--fleet-spec", metavar="SPEC.json", default=None,
                    help="frozen FleetSpec JSON from tools/fleet_launch.py "
                         "--init; switches the fleet to the authenticated "
                         "socket transport and supplies workers/heartbeat/"
                         "auth key (--executor fleet; overrides "
                         "--fleet-workers)")
    ap.add_argument("--scheduler", choices=("asha",), default=None,
                    help="ASHA successive-halving early stopping "
                         "(--executor async)")
    ap.add_argument("--journal", default=None,
                    help="JSON-lines study journal path (--executor async)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed study from --journal")
    ap.add_argument("--drift", default=None,
                    help="phase-shifting workload name (drift-hotspot, "
                         "drift-wset, drift-splice, or a registered "
                         "DriftSpec); overrides --workload")
    ap.add_argument("--online", action="store_true",
                    help="sliding-window online re-tuning (requires "
                         "--backend jax --crn; see repro.core.tune_online)")
    ap.add_argument("--window", type=int, default=10,
                    help="online re-tuning window length in epochs")
    args = ap.parse_args()
    workers = args.workers if args.workers == "auto" else int(args.workers)

    workload = WorkloadSpec(args.drift, scale=0.05) if args.drift \
        else WorkloadSpec(args.workload, args.input)
    spec = ExperimentSpec(
        engine="hemem",
        workload=workload,
        machine=args.machine,
        options=SimOptions(sampler="sparse" if args.batch_size > 1
                           else "elementwise", workers=workers,
                           backend=args.backend, crn=args.crn))
    study = Study(spec)
    if args.online:
        print(f"Online re-tuning of HeMem for {study.key} "
              f"(window {args.window} epochs, q={args.batch_size}, "
              f"budget {args.budget})...")
        print(f"spec: {json.dumps(spec.to_dict())}\n")
        res = study.tune(online=True, window_epochs=args.window,
                         batch_size=args.batch_size, budget=args.budget,
                         seed=0, journal=args.journal, resume=args.resume,
                         verbose=True)
        print(f"\ndeployed cumulative wall: {res.total_wall_ms:12.1f} ms "
              f"over {len(res.windows)} windows")
        print(f"switches: {res.switches} (windows {res.switch_windows}) | "
              f"detections: {res.detections} | guard-blocked: "
              f"{res.guard_blocks} | thrash: {res.thrash_events}")
        print("final config (changes vs default):")
        dflt = HEMEM_SPACE.default_config()
        for k, v in res.final_config.items():
            if v != dflt[k]:
                print(f"  {k:28s} {dflt[k]:>8} -> {v}")
        return
    if args.executor == "fleet":
        mode = f"fleet spec={args.fleet_spec}" if args.fleet_spec \
            else f"fleet workers={args.fleet_workers}"
    elif args.executor == "async":
        mode = f"async slots={args.slots}" + \
            (f" +{args.scheduler}" if args.scheduler else "")
    elif args.batch_size > 1:
        mode = f"batch q={args.batch_size}"
    else:
        mode = "sequential"
    print(f"Tuning HeMem for {study.key} (budget {args.budget}, {mode})...")
    print(f"spec: {json.dumps(spec.to_dict())}\n")
    if args.executor in ("async", "fleet"):
        fleet_kw = {}
        if args.executor == "fleet":
            if args.fleet_spec:
                from repro.core.tune_service import FleetSpec
                # the spec supplies workers/heartbeat/lease/auth key
                fleet_kw = {"fleet_spec": FleetSpec.load(args.fleet_spec)}
            else:
                fleet_kw = {"workers": args.fleet_workers}
        res = study.tune(budget=args.budget, seed=0, verbose=True,
                         executor=args.executor, slots=args.slots,
                         scheduler=args.scheduler, journal=args.journal,
                         resume=args.resume, **fleet_kw)
        print(f"\ntrials: {len(res.trials)} "
              f"({res.n_stopped_early} stopped early, "
              f"{res.n_failed} failed) | slot utilization "
              f"{res.utilization:.2f}"
              + (f" | journal: {args.journal}" if args.journal else ""))
        if res.fleet is not None:
            fs = res.fleet
            print(f"fleet: {fs['workers']} {fs['pool']} workers | "
                  f"{fs['n_worker_deaths']} deaths, "
                  f"{fs['n_respawns']} respawns, "
                  f"{fs['n_reissues']} re-issues"
                  + (" | degraded to local slot" if fs["degraded"] else ""))
    else:
        res = study.tune(budget=args.budget, batch_size=args.batch_size,
                         seed=0, verbose=True)
    print(f"\ndefault: {res.default_value:8.1f}s")
    print(f"best:    {res.best_value:8.1f}s   ({res.improvement:.2f}x)")
    print("\nbest config (changes vs default):")
    dflt = HEMEM_SPACE.default_config()
    for k, v in res.best.config.items():
        if v != dflt[k]:
            print(f"  {k:28s} {dflt[k]:>8} -> {v}")
    print("\nknob importance (surrogate-based, §3.1):")
    for k, v in list(knob_importance(HEMEM_SPACE, res.history).items())[:5]:
        print(f"  {k:28s} {v:.2f}")


if __name__ == "__main__":
    main()
