"""Batched evaluation pipeline benchmark.

Measures the wall-clock win of the batched tuning stack
(``TuningSession(batch_size=q)`` -> ``SMACOptimizer.ask_batch`` ->
``run_simulation_batch``) against the paper-faithful sequential SMAC loop at
equal budget, and validates two correctness claims:

* **equivalence** — ``run_simulation_batch`` with B configs returns exactly
  the same per-config results as B sequential ``run_simulation`` calls with
  matched seeds;
* **parity** — batched tuning reaches a best_value close to sequential
  SMAC's at equal budget (the search trajectories differ — top-q EI vs
  strictly sequential EI — so a small tolerance applies).

Speedup sources: one shared workload trace per batch, ``(B, n_pages)``
vectorized engine state, the sparse event-driven Poisson sampler, vectorized
EI scoring, and (``--workers``) sharding the batch over a process pool.  The
sampling work itself is irreducible per config, so the achievable speedup
scales with core count; run with ``--workers auto`` on a multicore box.

Usage::

    PYTHONPATH=src python -m benchmarks.batched_tuning [--quick]
        [--budget N] [--batch-size Q] [--workers N|auto] [--seed S]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.knobs import get_space
from repro.core.simulator import (Scenario, run_simulation,
                                  run_simulation_batch)
from repro.core.bo.tuner import tune_scenario
from repro.core.workloads import make_workload

from .common import claim, print_claims, save


def _check_equivalence(scale: float) -> bool:
    """Batch results must equal matched sequential runs, every engine."""
    wl = make_workload("gups", "8GiB-hot", threads=8, scale=scale, seed=3)
    rng = np.random.default_rng(5)
    for engine in ("hemem", "hmsdk", "memtis", "static", "oracle"):
        if engine in ("hemem", "hmsdk", "memtis"):
            cfgs = [get_space(engine).default_config(),
                    get_space(engine).sample(rng)]
        else:
            cfgs = [{}, {}]
        batch = run_simulation_batch(wl, engine, cfgs, "pmem-large", seeds=7)
        for cfg, b in zip(cfgs, batch):
            s = run_simulation(wl, engine, cfg, "pmem-large", seed=7,
                               sampler="sparse")
            if b.total_s != s.total_s or \
                    not np.array_equal(b.epoch_wall_ms, s.epoch_wall_ms):
                return False
    return True


def run(quick: bool = False, budget: int = None, batch_size: int = None,
        workers="auto", seed: int = 0) -> dict:
    budget = budget if budget is not None else (12 if quick else 32)
    batch_size = batch_size if batch_size is not None else (4 if quick else 8)
    sc = Scenario(workload="gups", input_name="8GiB-hot",
                  machine="pmem-large", seed=seed)

    print(f"GUPS/hemem, budget={budget}, batch_size={batch_size}, "
          f"workers={workers}", flush=True)

    # warm the persistent shard pool (one-time process spinup) so the timed
    # comparison measures steady-state throughput
    from repro.core.simulator import _get_pool, _resolve_workers
    n_workers = _resolve_workers(workers, batch_size)
    if n_workers > 1:
        list(_get_pool(n_workers).map(int, range(n_workers)))

    t0 = time.time()
    seq = tune_scenario("hemem", sc, budget=budget, seed=seed)
    t_seq = time.time() - t0
    print(f"  sequential SMAC: {t_seq:6.2f}s  best={seq.best_value:8.3f}s  "
          f"improvement={seq.improvement:.2f}x", flush=True)

    t0 = time.time()
    bat = tune_scenario("hemem", sc, budget=budget, seed=seed,
                        batch_size=batch_size, workers=workers)
    t_bat = time.time() - t0
    speedup = t_seq / t_bat
    parity = abs(bat.best_value - seq.best_value) / seq.best_value
    print(f"  batched  q={batch_size}:   {t_bat:6.2f}s  "
          f"best={bat.best_value:8.3f}s  improvement={bat.improvement:.2f}x",
          flush=True)
    print(f"  speedup {speedup:.2f}x | best_value delta {parity * 100:.2f}%",
          flush=True)

    equiv = _check_equivalence(scale=0.04 if quick else 0.1)

    out = {
        "budget": budget, "batch_size": batch_size, "workers": str(workers),
        "wall_sequential_s": t_seq, "wall_batched_s": t_bat,
        "speedup_x": speedup,
        "best_sequential_s": seq.best_value, "best_batched_s": bat.best_value,
        "best_value_delta_pct": parity * 100,
        "improvement_sequential_x": seq.improvement,
        "improvement_batched_x": bat.improvement,
    }
    claims = [
        claim("batch == sequential (matched seeds, every engine)", equiv,
              "run_simulation_batch numerically equals sequential runs"),
        claim("batched tuning matches sequential best_value",
              parity <= (0.05 if quick else 0.03),
              f"delta {parity * 100:.2f}% at equal budget {budget}"),
        claim("batched tuning is faster than sequential SMAC",
              speedup >= 1.0,
              f"{speedup:.2f}x with {workers} workers "
              "(scales with core count; sampling is irreducible per config)"),
    ]
    out["claims"] = claims
    print_claims(claims)
    save("batched_tuning", out)
    return out


def _workers_arg(value: str):
    if value == "auto":
        return value
    try:
        return int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--workers must be an integer or 'auto', got {value!r}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--budget", type=int, default=None)
    p.add_argument("--batch-size", type=int, default=None)
    p.add_argument("--workers", type=_workers_arg, default="auto",
                   help="process-pool size for batch sharding (int or auto)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    run(quick=args.quick, budget=args.budget, batch_size=args.batch_size,
        workers=args.workers, seed=args.seed)


if __name__ == "__main__":
    main()
