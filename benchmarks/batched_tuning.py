"""Batched evaluation pipeline benchmark (+ numpy-vs-jax backend report).

Measures the wall-clock win of the batched tuning stack
(``Study.tune(batch_size=q)`` -> ``SMACOptimizer.ask_batch`` ->
``run_simulation_batch``) against the paper-faithful sequential SMAC loop at
equal budget, and validates two correctness claims:

* **equivalence** — ``run_simulation_batch`` with B configs returns exactly
  the same per-config results as B single-config batches with matched
  seeds;
* **parity** — batched tuning reaches a best_value close to sequential
  SMAC's at equal budget (the search trajectories differ — top-q EI vs
  strictly sequential EI — so a small tolerance applies).

``--backend jax`` additionally benchmarks the **compiled epoch loop**
(:mod:`repro.core.engine_jax`) against the numpy reference for a batch-8
HeMem evaluation on GUPS — one-time compile excluded — and records the
numbers (plus a CRN bitwise check) in ``BENCH_backend.json`` (repo root and
``benchmarks/results/``).  The same backend is then used for the batched
tuning run.  ``--smoke`` runs only a tiny jitted HeMem evaluation + parity
check (the CI fail-fast job).

``--backend jax`` also runs the **selection ablation**: migration-plan
top-k selection via the exact Pallas kernel (``pallas``, interpret mode on
CPU), its pure-jnp ref (``ref``, the CPU default), and the historical
8-bit log-quantized approximation (``quantized``), recording per-call
selection wall-clock, end-to-end evaluation wall-clock and cross-mode
parity under ``select_ablation`` in ``BENCH_backend.json`` — the receipts
for what exact selection costs.  ``--select MODE`` additionally pins that
implementation for the batched tuning run itself.

Usage::

    PYTHONPATH=src python -m benchmarks.batched_tuning [--quick]
        [--budget N] [--batch-size Q] [--workers N|auto] [--seed S]
        [--backend numpy|jax] [--select pallas|ref|quantized] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time


def _default_xla_flags():
    """Split the host into one XLA device per core (max 8) so the compiled
    jax epoch loop can shard a batch across cores.  Must run before jax
    initializes; an explicit XLA_FLAGS always wins."""
    ncpu = os.cpu_count() or 1
    if "XLA_FLAGS" not in os.environ and ncpu > 1:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={min(ncpu, 8)}"


_default_xla_flags()  # before any (transitive) jax import

import numpy as np  # noqa: E402

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec  # noqa: E402
from repro.core.knobs import get_space  # noqa: E402
from repro.core.simulator import run_simulation_batch  # noqa: E402
from repro.core.workloads import make_workload  # noqa: E402

from .common import claim, print_claims, save  # noqa: E402


def _check_equivalence(scale: float) -> bool:
    """Batch results must equal matched single-config runs, every engine."""
    wl = make_workload("gups", "8GiB-hot", threads=8, scale=scale, seed=3)
    rng = np.random.default_rng(5)
    for engine in ("hemem", "hmsdk", "memtis", "static", "oracle"):
        if engine in ("hemem", "hmsdk", "memtis"):
            cfgs = [get_space(engine).default_config(),
                    get_space(engine).sample(rng)]
        else:
            cfgs = [{}, {}]
        batch = run_simulation_batch(wl, engine, cfgs, "pmem-large", seeds=7)
        for cfg, b in zip(cfgs, batch):
            s = run_simulation_batch(wl, engine, [cfg], "pmem-large",
                                     seeds=7)[0]
            if b.total_s != s.total_s or \
                    not np.array_equal(b.epoch_wall_ms, s.epoch_wall_ms):
                return False
    return True


def _hemem_batch(n_configs: int, seed: int = 5):
    space = get_space("hemem")
    rng = np.random.default_rng(seed)
    return [space.default_config()] + [space.sample(rng)
                                       for _ in range(n_configs - 1)]


def _time_pair(wl, cfgs, reps: int):
    """Interleaved min wall times of numpy and jax batch evaluations: both
    backends sample the same throttle windows, and min-of-N is robust
    against noisy-neighbour slowdowns on shared hosts."""
    t_np, t_jx = [], []
    for _ in range(reps):
        t0 = time.time()
        run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0,
                             sampler="sparse", backend="numpy")
        t_np.append(time.time() - t0)
        t0 = time.time()
        run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0,
                             sampler="sparse", backend="jax")
        t_jx.append(time.time() - t0)
    return float(min(t_np)), float(min(t_jx))


def _select_mode_env(mode: str):
    """(kernels FORCE value, exact_select flag) pinning one selection
    implementation."""
    return (None, False) if mode == "quantized" else (mode, True)


def _select_microbench(modes, n_pages: int, reps: int, B: int = 8):
    """Per-call wall-clock (ms) of one jitted migration-plan selection at
    the evaluation's (B, n_pages) shape, per mode.  Reps are interleaved
    across modes (same throttle windows) and min-of-N (robust to noisy
    neighbours), like :func:`_time_pair`."""
    import functools
    import jax
    from repro.core import engine_jax
    engine_jax.have_jax()
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    heat = jnp.asarray(rng.gamma(0.3, 50.0, (B, n_pages)).astype(np.float32))
    p_mask = jnp.asarray(rng.uniform(size=(B, n_pages)) < 0.2)
    d_mask = jnp.asarray(rng.uniform(size=(B, n_pages)) < 0.5)
    k = jnp.asarray(np.full(B, n_pages // 20, np.float32))
    fns = {m: jax.jit(functools.partial(engine_jax.select_top, mode=m))
           for m in modes}
    for fn in fns.values():  # compile outside the timed region
        jax.block_until_ready(fn(p_mask, heat, d_mask, heat, k, k))
    times = {m: [] for m in modes}
    for _ in range(max(reps, 5)):
        for m, fn in fns.items():
            t0 = time.time()
            jax.block_until_ready(fn(p_mask, heat, d_mask, heat, k, k))
            times[m].append(time.time() - t0)
    return {m: float(min(t)) * 1e3 for m, t in times.items()}


def select_ablation(quick: bool = False) -> dict:
    """--select ablation: exact Pallas kernel vs its pure-jnp ref vs the
    log-quantized approximation, on the batch-8 HeMem/GUPS headline —
    selection-only and end-to-end wall clock plus cross-mode parity.
    All modes are timed interleaved rep by rep so they sample the same
    throttle windows (see :func:`_time_pair`)."""
    from repro.kernels import ops as kernel_ops
    cfgs = _hemem_batch(8)
    scale = 0.25
    wl = make_workload("gups", "8GiB-hot", threads=12, scale=scale, seed=0)
    reps = 3 if quick else 6
    modes = ("quantized", "ref", "pallas")
    totals = {}
    compiles = {}
    walls = {m: [] for m in modes}
    old_force = kernel_ops.FORCE

    def _eval(mode):
        force, exact = _select_mode_env(mode)
        kernel_ops.FORCE = force
        return run_simulation_batch(
            wl, "hemem", cfgs, "pmem-large", seeds=0, sampler="sparse",
            backend="jax", exact_select=exact)

    try:
        for mode in modes:  # compile each mode outside the timed region
            t0 = time.time()
            totals[mode] = np.array([r.total_s for r in _eval(mode)])
            compiles[mode] = time.time() - t0
        for _ in range(reps):  # interleaved: same throttle windows
            for mode in modes:
                t0 = time.time()
                _eval(mode)
                walls[mode].append(time.time() - t0)
        sel_ms = _select_microbench(modes, wl.n_pages, reps)
    finally:
        kernel_ops.FORCE = old_force
    rows = {mode: {"wall_s": float(min(walls[mode])),
                   "compile_s": float(compiles[mode]),
                   "select_ms_per_call": sel_ms[mode]}
            for mode in modes}
    for mode in modes:
        print(f"  select={mode:9s}: eval {rows[mode]['wall_s']:.3f}s | "
              f"selection {rows[mode]['select_ms_per_call']:.2f} ms/call",
              flush=True)
    exact_bitwise = bool(np.array_equal(totals["ref"], totals["pallas"]))
    quant_rel = float(np.max(np.abs(totals["quantized"] - totals["ref"])
                             / totals["ref"]))
    overhead = rows["ref"]["wall_s"] / rows["quantized"]["wall_s"] - 1.0
    out = {
        "scale": scale, "n_pages": wl.n_pages, "batch": len(cfgs),
        "modes": rows,
        "exact_pallas_vs_ref_bitwise": exact_bitwise,
        "quantized_vs_exact_total_s_rel": quant_rel,
        "exact_end_to_end_overhead_pct": overhead * 100.0,
        "claims": [
            claim("exact selection: pallas and ref dispatch agree bitwise "
                  "(batch-8 HeMem end-to-end)", exact_bitwise,
                  f"total_s identical across {len(cfgs)} configs"),
            claim("exact selection end-to-end cost is recorded and small",
                  overhead < 0.25,
                  f"exact(ref) is {overhead * 100:+.1f}% vs quantized; "
                  f"selection {rows['ref']['select_ms_per_call']:.2f} vs "
                  f"{rows['quantized']['select_ms_per_call']:.2f} ms/call "
                  f"(pallas-interpret: "
                  f"{rows['pallas']['select_ms_per_call']:.2f})"),
        ],
    }
    print_claims(out["claims"])
    return out


def backend_bench(quick: bool = False) -> dict:
    """Numpy-vs-jax wall clock for a batch-8 HeMem evaluation on GUPS,
    recorded in BENCH_backend.json (acceptance target: >= 3x post-compile).
    """
    cfgs = _hemem_batch(8)
    reps = 3 if quick else 6
    scales = (0.25,) if quick else (0.25, 0.5)
    rows = []
    for scale in scales:
        wl = make_workload("gups", "8GiB-hot", threads=12, scale=scale,
                           seed=0)
        t0 = time.time()
        run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0,
                             sampler="sparse", backend="jax")
        t_compile = time.time() - t0
        t_np, t_jax = _time_pair(wl, cfgs, reps)
        rows.append({"scale": scale, "n_pages": wl.n_pages,
                     "batch": len(cfgs),
                     "wall_numpy_s": t_np, "wall_jax_s": t_jax,
                     "jax_compile_s": t_compile,
                     "speedup_x": t_np / t_jax})
        print(f"  GUPS@{scale} hemem batch-{len(cfgs)}: numpy {t_np:.3f}s | "
              f"jax {t_jax:.3f}s (compile {t_compile:.1f}s) | "
              f"{t_np / t_jax:.2f}x", flush=True)

    # CRN sanity: identical configs under crn=True draw identical noise
    wl_s = make_workload("gups", "8GiB-hot", threads=8, scale=0.04, seed=3)
    cfg = get_space("hemem").default_config()
    crn = run_simulation_batch(wl_s, "hemem", [cfg] * 3, "pmem-large",
                               seeds=0, backend="jax", crn=True)
    crn_ok = all(np.array_equal(crn[0].epoch_wall_ms, r.epoch_wall_ms)
                 for r in crn[1:])

    print("selection ablation (--select: pallas | ref | quantized):",
          flush=True)
    ablation = select_ablation(quick=quick)

    best = max(r["speedup_x"] for r in rows)
    out = {
        "engine": "hemem", "workload": "gups:8GiB-hot",
        "sampler": "sparse", "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "evaluations": rows,
        "best_speedup_x": best,
        "crn_bitwise_identical": bool(crn_ok),
        "select_ablation": ablation,
    }
    out["claims"] = [
        claim("jax backend >= 3x over numpy (batch-8 HeMem on GUPS, "
              "post-compile, exact selection)", best >= 3.0,
              f"best {best:.2f}x across scales "
              f"{[r['scale'] for r in rows]}"),
        claim("crn=True draws are bitwise-identical across the batch",
              crn_ok, "epoch walls equal across 3 identical configs"),
    ] + ablation["claims"]
    print_claims(out["claims"])
    save("BENCH_backend", out)
    # the acceptance artifact also lives at the repo root
    root = os.path.join(os.path.dirname(__file__), "..", "BENCH_backend.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=2)
    return out


def smoke() -> dict:
    """CI fail-fast: one jitted HeMem evaluation on CPU + numpy parity."""
    wl = make_workload("gups", "8GiB-hot", threads=8, scale=0.04, seed=3)
    cfgs = _hemem_batch(2)
    t0 = time.time()
    jx = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0,
                              backend="jax")
    t_first = time.time() - t0
    npr = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0)
    rel = max(abs(a.total_s - b.total_s) / a.total_s
              for a, b in zip(npr, jx))
    ok = rel < 0.25 and all(np.isfinite(r.total_s) for r in jx)
    claims = [claim("jax smoke: jitted HeMem evaluation runs and tracks "
                    "numpy", ok,
                    f"compile+run {t_first:.1f}s, max rel diff {rel:.3f}")]
    print_claims(claims)
    if not ok:
        raise SystemExit("jax backend smoke failed")
    return {"rel": rel, "claims": claims}


def run(quick: bool = False, budget: int = None, batch_size: int = None,
        workers="auto", seed: int = 0, backend: str = "numpy",
        select: str = None) -> dict:
    budget = budget if budget is not None else (12 if quick else 32)
    batch_size = batch_size if batch_size is not None else (4 if quick else 8)
    exact_select = True
    if select is not None and backend != "jax":
        raise SystemExit("--select only applies to --backend jax "
                         "(the numpy reference is always exact)")

    print(f"GUPS/hemem, budget={budget}, batch_size={batch_size}, "
          f"workers={workers}, backend={backend}"
          + (f", select={select}" if select else ""), flush=True)

    out = {}
    if backend == "jax":
        print("backend benchmark (numpy vs compiled jax epoch loop):",
              flush=True)
        out["backend_bench"] = backend_bench(quick=quick)

    if select is not None:
        # pin the selection implementation for the TUNING run only — the
        # backend benchmark + ablation above always measure the default
        # dispatch and all three modes respectively
        force, exact_select = _select_mode_env(select)
        from repro.kernels import ops as kernel_ops
        kernel_ops.FORCE = force

    wspec = WorkloadSpec("gups", "8GiB-hot")

    def _study(sampler, wk, be):
        return Study(ExperimentSpec(
            engine="hemem", workload=wspec, machine="pmem-large",
            options=SimOptions(seed=seed, sampler=sampler, workers=wk,
                               backend=be, exact_select=exact_select)))

    # warm the persistent shard pool (one-time process spinup) so the timed
    # comparison measures steady-state throughput
    from repro.core.simulator import _get_pool, _resolve_workers
    n_workers = _resolve_workers(workers, batch_size)
    if backend == "numpy" and n_workers > 1:
        list(_get_pool(n_workers).map(int, range(n_workers)))
    if backend == "jax":
        # compile the epoch loops used by the tuning run (B=1 for the
        # default evaluation, B=batch_size + any partial final round)
        # outside the timed region, mirroring the pool warm-up above
        warm = _study("sparse", 1, "jax")
        cfg = get_space("hemem").default_config()
        for b in {1, batch_size, budget % batch_size or batch_size}:
            warm.run(configs=[cfg] * b)

    t0 = time.time()
    seq = _study("elementwise", 1, "numpy").tune(budget=budget, seed=seed)
    t_seq = time.time() - t0
    print(f"  sequential SMAC: {t_seq:6.2f}s  best={seq.best_value:8.3f}s  "
          f"improvement={seq.improvement:.2f}x", flush=True)

    # the jax backend parallelizes inside one process (XLA device
    # sharding); process-pool workers only apply to the numpy path
    eff_workers = workers if backend == "numpy" else 1
    t0 = time.time()
    bat = _study("sparse", eff_workers, backend).tune(
        budget=budget, seed=seed, batch_size=batch_size)
    t_bat = time.time() - t0
    speedup = t_seq / t_bat
    parity = abs(bat.best_value - seq.best_value) / seq.best_value
    print(f"  batched  q={batch_size}:   {t_bat:6.2f}s  "
          f"best={bat.best_value:8.3f}s  improvement={bat.improvement:.2f}x",
          flush=True)
    print(f"  speedup {speedup:.2f}x | best_value delta {parity * 100:.2f}%",
          flush=True)

    equiv = _check_equivalence(scale=0.04 if quick else 0.1)

    out.update({
        "budget": budget, "batch_size": batch_size,
        "workers": str(eff_workers), "backend": backend,
        "select": select or ("exact" if backend == "jax" else "n/a"),
        "wall_sequential_s": t_seq, "wall_batched_s": t_bat,
        "speedup_x": speedup,
        "best_sequential_s": seq.best_value, "best_batched_s": bat.best_value,
        "best_value_delta_pct": parity * 100,
        "improvement_sequential_x": seq.improvement,
        "improvement_batched_x": bat.improvement,
    })
    # the jax backend draws different (equal-in-distribution) monitoring
    # noise than the numpy reference, so best-value parity is statistical
    parity_tol = (0.05 if quick else 0.03) + (0.05 if backend == "jax" else 0)
    claims = [
        claim("batch == sequential (matched seeds, every engine)", equiv,
              "run_simulation_batch numerically equals per-config runs"),
        claim("batched tuning matches sequential best_value",
              parity <= parity_tol,
              f"delta {parity * 100:.2f}% at equal budget {budget}"),
        claim("batched tuning is faster than sequential SMAC",
              speedup >= 1.0,
              f"{speedup:.2f}x with {eff_workers} workers / {backend} "
              "backend"),
    ]
    # surface the backend-bench claims (if that section ran) at the top
    # level alongside the tuning claims
    out["claims"] = out.get("backend_bench", {}).get("claims", []) + claims
    print_claims(claims)
    save("batched_tuning", out)
    return out


def _workers_arg(value: str):
    if value == "auto":
        return value
    try:
        return int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--workers must be an integer or 'auto', got {value!r}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--budget", type=int, default=None)
    p.add_argument("--batch-size", type=int, default=None)
    p.add_argument("--workers", type=_workers_arg, default="auto",
                   help="process-pool size for batch sharding (int or auto)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                   help="evaluation backend for the batched tuning run; "
                   "'jax' also runs the backend comparison + selection "
                   "ablation and writes BENCH_backend.json")
    p.add_argument("--select", choices=("pallas", "ref", "quantized"),
                   default=None,
                   help="pin the migration-plan selection implementation "
                   "for the jax tuning run (the ablation section always "
                   "measures all three)")
    p.add_argument("--smoke", action="store_true",
                   help="CI fail-fast: one jitted HeMem evaluation only")
    args = p.parse_args()
    if args.smoke:
        smoke()
        return
    run(quick=args.quick, budget=args.budget, batch_size=args.batch_size,
        workers=args.workers, seed=args.seed, backend=args.backend,
        select=args.select)


if __name__ == "__main__":
    main()
