"""Fig. 10 — tuning on the NUMA (CXL-emulation) machine + cross-machine
config transfer.

Paper claims: gains are mostly modest on NUMA (tiers are close in
latency/bandwidth, migrations nearly free) and pmem-large best configs
mostly perform well when transferred to NUMA.
"""

from __future__ import annotations

from repro.core.simulator import Scenario
from repro.core.bo.tuner import tune_scenario

from .common import SUITE, budget, claim, print_claims, save


def run(quick: bool = False) -> dict:
    b = budget(quick)
    out = {"workloads": {}}
    claims = []
    numa_imps, transfer_ok = {}, []
    suite = SUITE if not quick else [("silo", "ycsb-c"), ("xsbench", ""),
                                     ("gups", "8GiB-hot")]
    for wname, inp in suite:
        sc_numa = Scenario(wname, inp, machine="numa")
        res_numa = tune_scenario("hemem", sc_numa, budget=b, seed=19)
        numa_imps[sc_numa.key] = res_numa.improvement

        # transfer the pmem-large best config onto the NUMA machine
        sc_pmem = Scenario(wname, inp, machine="pmem-large")
        res_pmem = tune_scenario("hemem", sc_pmem, budget=b, seed=19)
        f_numa = sc_numa.objective("hemem")
        transfer_s = f_numa(res_pmem.best.config)
        rel = transfer_s / res_numa.best_value
        transfer_ok.append(rel <= 1.15)
        out["workloads"][sc_numa.key] = {
            "numa_improvement": res_numa.improvement,
            "pmem_config_on_numa_vs_numa_best": rel,
        }
        print(f"  {wname:12s} numa-gain={res_numa.improvement:.2f}x "
              f"pmem-cfg-transfer={rel:.2f}x of numa best", flush=True)

    claims.append(claim(
        "fig10: NUMA gains are mostly modest (smaller than pmem)",
        sorted(numa_imps.values())[len(numa_imps) // 2] <= 1.35,
        ", ".join(f"{k.split(':')[0]}={v:.2f}x" for k, v in numa_imps.items())))
    claims.append(claim(
        "fig10: pmem-large best configs mostly transfer to NUMA",
        sum(transfer_ok) >= max(1, int(0.6 * len(transfer_ok))),
        f"{sum(transfer_ok)}/{len(transfer_ok)} within 15% of NUMA-native best"))
    out["claims"] = claims
    print_claims(claims)
    save("fig10_numa", out)
    return out


if __name__ == "__main__":
    run()
