"""Fig. 10 — tuning on the NUMA (CXL-emulation) machine + cross-machine
config transfer.

Paper claims: gains are mostly modest on NUMA (tiers are close in
latency/bandwidth, migrations nearly free) and pmem-large best configs
mostly perform well when transferred to NUMA.

Ported to the typed Study API (completing the PR 2 migration): one
``ExperimentSpec`` per (workload, machine), tuned with batched SMAC rounds
(``batch_size=4``, process-pool sharded) instead of the deprecated
``Scenario``/``tune_scenario`` shims; the transfer evaluation reuses the
NUMA study's cached workload trace.  Result payloads embed the replayable
spec.
"""

from __future__ import annotations

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec

from .common import SUITE, budget, claim, print_claims, save

BATCH_SIZE = 4


def _study(wname: str, inp: str, machine: str) -> Study:
    return Study(ExperimentSpec(
        engine="hemem", workload=WorkloadSpec(wname, inp), machine=machine,
        options=SimOptions(sampler="sparse", workers="auto")))


def run(quick: bool = False) -> dict:
    b = budget(quick)
    out = {"workloads": {}}
    claims = []
    numa_imps, transfer_ok = {}, []
    suite = SUITE if not quick else [("silo", "ycsb-c"), ("xsbench", ""),
                                     ("gups", "8GiB-hot")]
    for wname, inp in suite:
        study_numa = _study(wname, inp, "numa")
        res_numa = study_numa.tune(budget=b, batch_size=BATCH_SIZE, seed=19)
        numa_imps[study_numa.key] = res_numa.improvement

        # transfer the pmem-large best config onto the NUMA machine
        res_pmem = _study(wname, inp, "pmem-large").tune(
            budget=b, batch_size=BATCH_SIZE, seed=19)
        transfer_s = study_numa.run(
            configs=[res_pmem.best.config])[0].total_s
        rel = transfer_s / res_numa.best_value
        transfer_ok.append(rel <= 1.15)
        out["workloads"][study_numa.key] = {
            "spec": study_numa.spec.to_dict(),
            "numa_improvement": res_numa.improvement,
            "pmem_config_on_numa_vs_numa_best": rel,
        }
        print(f"  {wname:12s} numa-gain={res_numa.improvement:.2f}x "
              f"pmem-cfg-transfer={rel:.2f}x of numa best", flush=True)

    claims.append(claim(
        "fig10: NUMA gains are mostly modest (smaller than pmem)",
        sorted(numa_imps.values())[len(numa_imps) // 2] <= 1.35,
        ", ".join(f"{k.split(':')[0]}={v:.2f}x" for k, v in numa_imps.items())))
    claims.append(claim(
        "fig10: pmem-large best configs mostly transfer to NUMA",
        sum(transfer_ok) >= max(1, int(0.6 * len(transfer_ok))),
        f"{sum(transfer_ok)}/{len(transfer_ok)} within 15% of NUMA-native best"))
    out["claims"] = claims
    print_claims(claims)
    save("fig10_numa", out)
    return out


if __name__ == "__main__":
    run()
