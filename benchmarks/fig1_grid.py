"""Fig. 1 — grid search over (read_hot_threshold x cooling_threshold) for
GUPS and Silo, all other knobs at default.

Paper claims: large performance variation across cells; best cell beats the
default by >= 29 % (GUPS) and >= 36 % (Silo).

Runs through the typed :class:`~repro.core.study.Study` API: every grid
cell is a validated config and the whole grid evaluates as ONE batched
``Study.run(configs=...)`` pass over a shared workload trace (numerically
identical to the historical sequential grid loop with matched seeds).
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import ExperimentSpec, Study, WorkloadSpec
from repro.core.knobs import HEMEM_SPACE

from .common import claim, print_claims, save

RH_GRID = [1, 2, 4, 6, 8, 12, 16, 20, 26, 30]
CT_GRID = [4, 8, 12, 18, 24, 32, 40]


def run(quick: bool = False) -> dict:
    rh = RH_GRID[::2] if quick else RH_GRID
    ct = CT_GRID[::2] if quick else CT_GRID
    out = {"rh_grid": rh, "ct_grid": ct, "workloads": {}}
    claims = []
    base = HEMEM_SPACE.default_config()
    combos = list(itertools.product(rh, ct))
    grid_cfgs = [HEMEM_SPACE.validate(dict(base, read_hot_threshold=r,
                                           cooling_threshold=c))
                 for r, c in combos]
    for wname, inp, floor in [("gups", "8GiB-hot", 1.29),
                              ("silo", "ycsb-c", 1.36)]:
        study = Study(ExperimentSpec(engine="hemem",
                                     workload=WorkloadSpec(wname, inp)))
        # one batched pass evaluates every grid cell plus the default
        results = study.run(configs=grid_cfgs + [base])
        vals = [r.total_s for r in results]
        cells = dict(zip(combos, vals[:-1]))
        default_val = vals[-1]
        best_idx = int(np.argmin(vals[:-1]))
        best_cfg, best_val = grid_cfgs[best_idx], vals[best_idx]
        grid = np.array([[cells[(r, c)] for c in ct] for r in rh])
        imp = default_val / best_val
        out["workloads"][study.workload().key] = {
            "default_s": default_val, "best_s": best_val,
            "improvement": imp,
            "best_rh": best_cfg["read_hot_threshold"],
            "best_ct": best_cfg["cooling_threshold"],
            "grid_s": grid,
        }
        claims.append(claim(
            f"fig1/{wname}: grid headroom >= {floor}x",
            imp >= floor * 0.93,   # reproduction tolerance
            f"default={default_val:.1f}s best={best_val:.1f}s "
            f"({imp:.2f}x vs paper {floor}x)"))
        claims.append(claim(
            f"fig1/{wname}: large variation across cells",
            grid.max() / grid.min() >= 1.25,
            f"max/min cell = {grid.max() / grid.min():.2f}x"))
    out["claims"] = claims
    print_claims(claims)
    save("fig1_grid", out)
    return out


if __name__ == "__main__":
    run()
