"""Fault-tolerant fleet-tuning benchmark -> BENCH_study.json["fleet"].

Exercises ``Study.tune(executor="fleet", workers=N)`` — the
lease-and-commit coordinator serving one shared work-unit queue to N
worker processes — and records the robustness receipts the fleet PR gates
on:

* **determinism across placements**: the fleet incumbent (1 worker, N
  workers, process transport) is bitwise identical to the local async
  executor's at equal study parameters;
* **determinism under faults**: a run with 1-in-8 injected worker kills
  (``FaultPlan(kill_every=8)`` — the worker process SIGKILLs itself
  mid-unit, the coordinator detects the death, respawns a replacement and
  re-issues the lease) still matches the fault-free incumbent bitwise;
* **slot utilization** stays near 1.0 as workers are added AND under the
  injected kills (lost leases cost re-issue overhead, not idle slots) —
  acceptance gate >= 0.8 at full size;
* **re-issue overhead + time-to-recover** columns: wall clock burned by
  duplicate/aborted executions, and the fault-to-reissue latency per
  expired lease;
* the faulty run's journal — including its ``lease``/``expire``/
  ``reissue`` lifecycle events — validates against
  ``tools/journal_schema.py``;
* **socket transport under latency** (hardened-fleet PR): the same study
  over the authenticated frame codec with injected per-frame link
  latency (``FaultPlan(net_delay_s=...)``) stays bitwise identical —
  slower frames, same decisions;
* **ASHA over the fleet** (ROADMAP 3a): ``scheduler="asha"`` on the
  socket fleet under combined kills + latency matches the local async
  ASHA incumbent bitwise, with early stopping actually saving epochs.

The numpy backend keeps worker processes fork-cheap (no per-respawn jax
import/compile), which is what makes a kill-every-8-units fault schedule
affordable; determinism is backend-independent, so the bitwise claims
carry over unchanged.

Usage::

    PYTHONPATH=src python -m benchmarks.study_fleet [--quick]
        [--budget N] [--workers N] [--scale S] [--seed S] [--kill-every K]
        [--net-delay S]
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.tune_service import FaultPlan

from .common import claim, print_claims, save


def _study(scale: float, seed: int) -> Study:
    return Study(ExperimentSpec(
        engine="hemem", workload=WorkloadSpec("gups", scale=scale),
        machine="pmem-large",
        options=SimOptions(seed=seed, sampler="sparse", backend="numpy")))


def run(quick: bool = False, budget: int = None, workers: int = 2,
        scale: float = None, seed: int = 0, kill_every: int = 8,
        net_delay: float = 0.002) -> dict:
    budget = budget if budget is not None else (48 if quick else 512)
    scale = scale if scale is not None else (0.1 if quick else 0.5)
    n_init = min(20, max(4, budget // 8))
    window = 4 * workers
    results_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(results_dir, exist_ok=True)
    journal = os.path.join(results_dir, "study_fleet_journal.jsonl")
    if os.path.exists(journal):
        os.remove(journal)

    wl = _study(scale, seed).workload()
    print(f"GUPS@{scale}/hemem (E={wl.n_epochs}, n_pages={wl.n_pages}), "
          f"budget={budget}, fleet workers={workers} window={window}, "
          f"1-in-{kill_every} injected worker kills", flush=True)

    kw = dict(budget=budget, seed=seed, n_init=n_init, window=window)

    t0 = time.time()
    r_async = _study(scale, seed).tune(executor="async", slots=workers, **kw)
    t_async = time.time() - t0
    print(f"  async slots={workers} (local):   {t_async:7.2f}s  "
          f"best={r_async.best_value:8.3f}s  "
          f"util={r_async.utilization:.2f}", flush=True)

    t0 = time.time()
    r_f1 = _study(scale, seed).tune(executor="fleet", workers=1,
                                    budget=budget, seed=seed, n_init=n_init,
                                    window=window)
    t_f1 = time.time() - t0
    print(f"  fleet workers=1:         {t_f1:7.2f}s  "
          f"best={r_f1.best_value:8.3f}s  util={r_f1.utilization:.2f}",
          flush=True)

    t0 = time.time()
    r_fw = _study(scale, seed).tune(executor="fleet", workers=workers, **kw)
    t_fw = time.time() - t0
    print(f"  fleet workers={workers}:         {t_fw:7.2f}s  "
          f"best={r_fw.best_value:8.3f}s  util={r_fw.utilization:.2f}",
          flush=True)

    plan = FaultPlan(kill_every=kill_every)
    t0 = time.time()
    r_fault = _study(scale, seed).tune(
        executor="fleet", workers=workers, faults=plan, journal=journal,
        max_respawns=budget, **kw)
    t_fault = time.time() - t0
    fs = r_fault.fleet
    recover = fs["time_to_recover_s"]
    print(f"  fleet workers={workers} +kills:  {t_fault:7.2f}s  "
          f"best={r_fault.best_value:8.3f}s  "
          f"util={r_fault.utilization:.2f}  "
          f"deaths={fs['n_worker_deaths']} respawns={fs['n_respawns']} "
          f"reissues={fs['n_reissues']}", flush=True)

    # socket transport + injected per-frame link latency: the hardened
    # codec (HMAC-signed, capped, replay-protected frames) under a slow
    # link — frames arrive late, decisions do not change
    t0 = time.time()
    r_sock = _study(scale, seed).tune(
        executor="fleet", workers=workers, pool="socket",
        faults=FaultPlan(net_delay_s=net_delay), **kw)
    t_sock = time.time() - t0
    sfs = r_sock.fleet
    print(f"  fleet workers={workers} socket+{net_delay * 1e3:.0f}ms: "
          f"{t_sock:7.2f}s  best={r_sock.best_value:8.3f}s  "
          f"util={r_sock.utilization:.2f}  "
          f"reconnects={sfs['n_reconnects']} "
          f"rejects={sfs['n_rejected_frames']}", flush=True)

    # ASHA over the fleet (ROADMAP 3a), under kills AND link latency at
    # once: rung segments re-derive [0, hi) from scratch, so promote/
    # early-stop composes with lease expiry + straggler re-issue
    t0 = time.time()
    r_asha_async = _study(scale, seed).tune(
        executor="async", slots=workers, scheduler="asha", **kw)
    t_asha_async = time.time() - t0
    t0 = time.time()
    r_asha_fleet = _study(scale, seed).tune(
        executor="fleet", workers=workers, pool="socket",
        scheduler="asha",
        faults=FaultPlan(kill_every=kill_every, net_delay_s=net_delay),
        max_respawns=budget, **kw)
    t_asha_fleet = time.time() - t0
    afs = r_asha_fleet.fleet
    print(f"  fleet workers={workers} asha+kills+lat: {t_asha_fleet:7.2f}s  "
          f"best={r_asha_fleet.best_value:8.3f}s  "
          f"util={r_asha_fleet.utilization:.2f}  "
          f"saved={r_asha_fleet.asha_epochs_saved_frac:.2f} "
          f"(async asha: {t_asha_async:.2f}s "
          f"best={r_asha_async.best_value:.3f}s)", flush=True)

    # determinism receipt: the faulty journal (with its lease lifecycle
    # events) must validate against the standalone schema checker
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "tools"))
    import journal_schema
    journal_problems = journal_schema.validate_file(journal)
    with open(journal, "r", encoding="utf-8") as fh:
        kinds = [json.loads(line)["event"] for line in fh if line.strip()]
    n_expire = kinds.count("expire")
    n_reissue = kinds.count("reissue")

    def _arm(r, wall):
        out = {
            "wall_s": float(wall), "best_value_s": float(r.best_value),
            "utilization": float(r.utilization),
            "makespan_s": float(r.makespan_s), "busy_s": float(r.busy_s),
        }
        if r.fleet is not None:
            out["fleet"] = r.fleet
        return out

    util_gate = 0.8 if not quick else 0.4
    out = {
        "engine": "hemem", "workload": f"gups:8GiB-hot@{scale}",
        "n_epochs": wl.n_epochs, "n_pages": wl.n_pages,
        "budget": budget, "n_init": n_init, "seed": seed,
        "workers": workers, "window": window, "kill_every": kill_every,
        "net_delay_s": net_delay,
        "cpu_count": os.cpu_count(),
        "arms": {
            "async_local": _arm(r_async, t_async),
            "fleet_w1": _arm(r_f1, t_f1),
            f"fleet_w{workers}": _arm(r_fw, t_fw),
            f"fleet_w{workers}_kills": _arm(r_fault, t_fault),
            f"fleet_w{workers}_socket_latency": _arm(r_sock, t_sock),
            "asha_async": _arm(r_asha_async, t_asha_async),
            f"asha_fleet_w{workers}_kills_latency":
                _arm(r_asha_fleet, t_asha_fleet),
        },
        "reissue_overhead_s": float(fs["reissue_overhead_s"]),
        "time_to_recover_s": {
            "n": len(recover),
            "mean": float(sum(recover) / len(recover)) if recover else None,
            "max": float(max(recover)) if recover else None,
        },
        "journal": os.path.relpath(journal,
                                   os.path.join(os.path.dirname(__file__),
                                                os.pardir)),
        "journal_valid": not journal_problems,
        "journal_lease_events": {"expire": n_expire, "reissue": n_reissue},
    }
    out["claims"] = [
        claim("fleet incumbent is bitwise identical to the local async "
              "executor's at equal study shape",
              r_fw.best_value == r_async.best_value,
              f"async slots={workers} {r_async.best_value!r} == fleet "
              f"workers={workers} {r_fw.best_value!r} (w1 is a different "
              f"study shape: {r_f1.best_value!r})"),
        claim(f"1-in-{kill_every} injected worker kills do not change the "
              f"incumbent (bitwise)",
              r_fault.best_value == r_fw.best_value,
              f"{fs['n_worker_deaths']} worker deaths, "
              f"{fs['n_respawns']} respawns, {fs['n_reissues']} re-issues "
              f"-> best {r_fault.best_value!r}"),
        claim(f"slot utilization >= {util_gate} under injected kills",
              r_fault.utilization >= util_gate,
              f"{r_fault.utilization:.2f} with kills vs "
              f"{r_fw.utilization:.2f} fault-free at workers={workers}, "
              f"{r_f1.utilization:.2f} at workers=1"),
        claim("re-issue overhead and time-to-recover are reported",
              fs["n_worker_deaths"] > 0 and len(recover) > 0,
              f"reissue overhead {fs['reissue_overhead_s']:.2f}s; "
              f"recover mean "
              f"{(sum(recover) / max(len(recover), 1)):.3f}s over "
              f"{len(recover)} expiries"),
        claim("faulty-run journal validates (lease lifecycle included)",
              not journal_problems and n_expire > 0 and n_reissue > 0,
              f"tools/journal_schema.py: "
              f"{'ok' if not journal_problems else '; '.join(journal_problems[:3])}; "
              f"{n_expire} expire / {n_reissue} reissue events"),
        claim(f"authenticated socket transport under {net_delay * 1e3:.0f}ms "
              f"per-frame latency is bitwise identical",
              r_sock.best_value == r_fw.best_value,
              f"socket+latency best {r_sock.best_value!r} == process-pool "
              f"{r_fw.best_value!r}; {sfs['n_rejected_frames']} rejected "
              f"frames, {sfs['n_reconnects']} reconnects"),
        claim("ASHA over the fleet under kills + latency matches async "
              "ASHA bitwise, with real early stopping",
              r_asha_fleet.best_value == r_asha_async.best_value
              and r_asha_fleet.trials == r_asha_async.trials
              and r_asha_fleet.asha_epochs_saved_frac > 0,
              f"fleet asha best {r_asha_fleet.best_value!r} == async asha "
              f"{r_asha_async.best_value!r}; "
              f"{r_asha_fleet.asha_epochs_saved_frac:.1%} epochs saved, "
              f"{afs['n_worker_deaths']} deaths, "
              f"{afs['n_reissues']} re-issues"),
    ]
    print_claims(out["claims"])
    save("BENCH_study_fleet", out)
    # merge into the root BENCH_study.json next to the async receipts —
    # never clobber them
    root = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_study.json")
    payload = {}
    if os.path.exists(root):
        try:
            with open(root) as f:
                payload = json.load(f)
        except ValueError:
            payload = {}
    payload["fleet"] = out
    with open(root, "w") as f:
        json.dump(payload, f, indent=2)
    return out


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="tiny budget/scale: wiring check, not a perf gate")
    p.add_argument("--budget", type=int, default=None)
    p.add_argument("--workers", type=int, default=2)
    p.add_argument("--scale", type=float, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--kill-every", type=int, default=8,
                   help="kill the worker holding every K-th unit")
    p.add_argument("--net-delay", type=float, default=0.002,
                   help="injected per-frame link latency (socket arms)")
    args = p.parse_args()
    run(quick=args.quick, budget=args.budget, workers=args.workers,
        scale=args.scale, seed=args.seed, kill_every=args.kill_every,
        net_delay=args.net_delay)


if __name__ == "__main__":
    main()
