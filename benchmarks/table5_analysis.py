"""Table 5 — why the best configurations win: knob diffs, importance scores,
and the per-workload mechanism evidence (migration counts, hit rates).

Paper claims validated here:
  * PR/CC best configs eliminate (nearly all) migrations vs default.
  * XSBench best config eliminates warm/bulk-page migrations.
  * Btree best config reduces write-driven init-phase migrations.
  * Silo's important knobs include the *hidden* cooling_pages.
  * GUPS best config increases sampling accuracy (lower sampling_period)
    or otherwise stabilizes hot classification, reducing shuffling.
"""

from __future__ import annotations

import numpy as np

from repro.core.simulator import Scenario, run_simulation, PMEM_LARGE
from repro.core.workloads import make_workload
from repro.core.knobs import HEMEM_SPACE
from repro.core.bo.tuner import tune_scenario
from repro.core.bo.importance import knob_importance

from .common import budget, claim, print_claims, save


def _sim(wname, inp, cfg):
    wl = make_workload(wname, inp, threads=12, scale=0.25, seed=0)
    return run_simulation(wl, "hemem", cfg, PMEM_LARGE, seed=0)


def run(quick: bool = False) -> dict:
    out = {"workloads": {}}
    claims = []
    b = budget(quick)
    default_cfg = HEMEM_SPACE.default_config()

    for wname, inp in [("gapbs-pr", "kron"), ("xsbench", ""), ("btree", ""),
                       ("silo", "ycsb-c"), ("gups", "8GiB-hot")]:
        sc = Scenario(wname, inp)
        res = tune_scenario("hemem", sc, budget=b, seed=5)
        best_cfg = res.best.config
        r_def = _sim(wname, inp, default_cfg)
        r_best = _sim(wname, inp, best_cfg)
        imp = knob_importance(HEMEM_SPACE, res.history)
        diff = {k: (default_cfg[k], best_cfg[k]) for k in best_cfg
                if best_cfg[k] != default_cfg[k]}
        out["workloads"][sc.key] = {
            "improvement": res.improvement,
            "migrations_default": r_def.total_migrations,
            "migrations_best": r_best.total_migrations,
            "hit_default": float(r_def.fast_hit_rate.mean()),
            "hit_best": float(r_best.fast_hit_rate.mean()),
            "knob_diff": diff,
            "importance": imp,
        }
        print(f"  {sc.key:22s} {res.improvement:.2f}x  migs {r_def.total_migrations}"
              f" -> {r_best.total_migrations}  top-knobs: "
              f"{list(imp)[:3]}", flush=True)

        if wname in ("gapbs-pr", "xsbench"):
            claims.append(claim(
                f"table5/{wname}: best config eliminates unnecessary migrations",
                r_best.total_migrations <= max(0.25 * r_def.total_migrations, 50),
                f"{r_def.total_migrations} -> {r_best.total_migrations}"))
        if wname == "btree":
            claims.append(claim(
                "table5/btree: best config reduces init write migrations",
                r_best.total_migrations <= 0.7 * r_def.total_migrations,
                f"{r_def.total_migrations} -> {r_best.total_migrations}"))
        if wname == "silo":
            claims.append(claim(
                "table5/silo: hidden knob cooling_pages among important knobs",
                list(imp).index("cooling_pages") < 5
                if "cooling_pages" in imp else False,
                f"importance ranking: {list(imp)[:5]}"))
        if wname == "gups":
            claims.append(claim(
                "table5/gups: best config stabilizes hot classification "
                "(better hit rate, fewer wasteful migrations)",
                r_best.fast_hit_rate.mean() > r_def.fast_hit_rate.mean(),
                f"hit {r_def.fast_hit_rate.mean():.3f} -> "
                f"{r_best.fast_hit_rate.mean():.3f}"))

    out["claims"] = claims
    print_claims(claims)
    save("table5_analysis", out)
    return out


if __name__ == "__main__":
    run()
