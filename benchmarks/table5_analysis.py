"""Table 5 — why the best configurations win: knob diffs, importance scores,
and the per-workload mechanism evidence (migration counts, hit rates).

Paper claims validated here:
  * PR/CC best configs eliminate (nearly all) migrations vs default.
  * XSBench best config eliminates warm/bulk-page migrations.
  * Btree best config reduces write-driven init-phase migrations.
  * Silo's important knobs include the *hidden* cooling_pages.
  * GUPS best config increases sampling accuracy (lower sampling_period)
    or otherwise stabilizes hot classification, reducing shuffling.

Ported to the typed Study API (completing the PR 2 migration): tuning runs
as batched SMAC rounds and the default-vs-best mechanism evidence comes
from ONE batched ``Study.run(configs=[default, best])`` pass over the
shared workload trace — no ``Scenario``/``tune_scenario``/
``run_simulation`` shims.  The knob-importance sweep rides the flat-forest
``predict_batch`` fast path (one descent over all knob sweeps).
"""

from __future__ import annotations

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.bo.importance import knob_importance
from repro.core.knobs import HEMEM_SPACE

from .common import budget, claim, print_claims, save

BATCH_SIZE = 4


def run(quick: bool = False) -> dict:
    out = {"workloads": {}}
    claims = []
    b = budget(quick)
    default_cfg = HEMEM_SPACE.default_config()

    for wname, inp in [("gapbs-pr", "kron"), ("xsbench", ""), ("btree", ""),
                       ("silo", "ycsb-c"), ("gups", "8GiB-hot")]:
        study = Study(ExperimentSpec(
            engine="hemem", workload=WorkloadSpec(wname, inp, threads=12),
            options=SimOptions(sampler="sparse", workers="auto")))
        res = study.tune(budget=b, batch_size=BATCH_SIZE, seed=5)
        best_cfg = res.best.config
        # default and best mechanisms from one shared-trace batched pass
        r_def, r_best = study.run(configs=[default_cfg, best_cfg])
        imp = knob_importance(HEMEM_SPACE, res.history)
        diff = {k: (default_cfg[k], best_cfg[k]) for k in best_cfg
                if best_cfg[k] != default_cfg[k]}
        out["workloads"][study.key] = {
            "spec": study.spec.to_dict(),
            "improvement": res.improvement,
            "migrations_default": r_def.total_migrations,
            "migrations_best": r_best.total_migrations,
            "hit_default": float(r_def.fast_hit_rate.mean()),
            "hit_best": float(r_best.fast_hit_rate.mean()),
            "knob_diff": diff,
            "importance": imp,
        }
        print(f"  {study.key:22s} {res.improvement:.2f}x  migs "
              f"{r_def.total_migrations} -> {r_best.total_migrations}  "
              f"top-knobs: {list(imp)[:3]}", flush=True)

        if wname in ("gapbs-pr", "xsbench"):
            claims.append(claim(
                f"table5/{wname}: best config eliminates unnecessary migrations",
                r_best.total_migrations <= max(0.25 * r_def.total_migrations, 50),
                f"{r_def.total_migrations} -> {r_best.total_migrations}"))
        if wname == "btree":
            claims.append(claim(
                "table5/btree: best config reduces init write migrations",
                r_best.total_migrations <= 0.7 * r_def.total_migrations,
                f"{r_def.total_migrations} -> {r_best.total_migrations}"))
        if wname == "silo":
            claims.append(claim(
                "table5/silo: hidden knob cooling_pages among important knobs",
                list(imp).index("cooling_pages") < 5
                if "cooling_pages" in imp else False,
                f"importance ranking: {list(imp)[:5]}"))
        if wname == "gups":
            claims.append(claim(
                "table5/gups: best config stabilizes hot classification "
                "(better hit rate, fewer wasteful migrations)",
                r_best.fast_hit_rate.mean() > r_def.fast_hit_rate.mean(),
                f"hit {r_def.fast_hit_rate.mean():.3f} -> "
                f"{r_best.fast_hit_rate.mean():.3f}"))

    out["claims"] = claims
    print_claims(claims)
    save("table5_analysis", out)
    return out


if __name__ == "__main__":
    run()
