"""Fig. 9 — tuning for different system configurations:
(a) thread counts, (b) fast:slow memory size ratios (on pmem-small).

Paper claims: (a) consistent gains across thread counts, best knob values
differ per thread count; (b) tuning matters most for small fast tiers
(1:16, 1:8) and the optimizer adapts thresholds to the ratio.

Ported to the typed Study API (PR 2): every point of the sweep is an
``ExperimentSpec`` (embedded in the result payload for replay) and each
tuning session evaluates whole candidate batches per SMAC round
(``batch_size=4``, process-pool sharded) instead of sequentially.
"""

from __future__ import annotations

import dataclasses

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec

from .common import budget, claim, print_claims, save

THREADS = [2, 4, 8]
RATIOS = [16.0, 8.0, 2.0, 1.0, 0.5]   # fast:slow = 1:r (r=0.5 -> 2:1)
# q=4 keeps enough adaptive SMAC rounds at quick budgets (q=8 loses the
# marginal bc-twitter gains) while still cutting wall-clock ~2-3x here
BATCH_SIZE = 4
OPTS = SimOptions(sampler="sparse", workers="auto")


def run(quick: bool = False) -> dict:
    b = budget(quick)
    out = {"threads": {}, "ratios": {}}
    claims = []

    # (a) thread counts, GUPS + BC-twitter on pmem-small
    per_thread_cfgs = {}
    for wname, inp in [("gups", "8GiB-hot"), ("gapbs-bc", "twitter")]:
        for t in (THREADS[:2] if quick else THREADS):
            study = Study(ExperimentSpec(
                engine="hemem",
                workload=WorkloadSpec(wname, inp, threads=t),
                machine="pmem-small", options=OPTS))
            res = study.tune(budget=b, batch_size=BATCH_SIZE, seed=13 + t)
            key = f"{wname}:{inp}@t{t}"
            out["threads"][key] = {"spec": study.spec.to_dict(),
                                   "improvement": res.improvement,
                                   "best_config": res.best.config}
            per_thread_cfgs.setdefault(wname, {})[t] = res
            print(f"  threads={t:2d} {wname:12s} {res.improvement:.2f}x",
                  flush=True)
    # "consistent performance improvement for all thread counts" — gains at
    # every point; BC-twitter magnitudes are small in our model (small-RSS
    # fast-cooling, see EXPERIMENTS.md deviations)
    ok_threads = all(r.improvement >= 1.02
                     for d in per_thread_cfgs.values() for r in d.values())
    claims.append(claim(
        "fig9a: consistent improvement across thread counts",
        ok_threads,
        ", ".join(f"{w}@t{t}={r.improvement:.2f}x"
                  for w, d in per_thread_cfgs.items() for t, r in d.items())))
    diff_cfgs = []
    for w, d in per_thread_cfgs.items():
        cfgs = [tuple(sorted(r.best.config.items())) for r in d.values()]
        diff_cfgs.append(len(set(cfgs)) > 1)
    claims.append(claim(
        "fig9a: best knob values differ across thread counts",
        all(diff_cfgs), f"distinct-per-thread: {diff_cfgs}"))

    # (b) memory ratios, GUPS on pmem-small — one base spec, replaced per r
    base = ExperimentSpec(engine="hemem",
                          workload=WorkloadSpec("gups", "8GiB-hot", threads=4),
                          machine="pmem-small", options=OPTS)
    ratio_imps = {}
    for r_ in (RATIOS[:3] if quick else RATIOS):
        study = Study(dataclasses.replace(base, fast_slow_ratio=r_))
        res = study.tune(budget=b, batch_size=BATCH_SIZE, seed=17)
        label = f"1:{int(r_)}" if r_ >= 1 else f"{int(1 / r_)}:1"
        ratio_imps[label] = res.improvement
        out["ratios"][label] = {"spec": study.spec.to_dict(),
                                "improvement": res.improvement,
                                "best_config": res.best.config}
        print(f"  ratio={label:5s} {res.improvement:.2f}x", flush=True)
    small = [v for k, v in ratio_imps.items() if k in ("1:16", "1:8")]
    large = [v for k, v in ratio_imps.items() if k in ("1:1", "2:1")]
    claims.append(claim(
        "fig9b: tuning matters most for small fast tiers",
        (min(small) >= 1.03) and (not large or max(small) >= max(large) - 0.05),
        f"{ratio_imps}"))
    out["claims"] = claims
    print_claims(claims)
    save("fig9_threads_ratios", out)
    return out


if __name__ == "__main__":
    run()
