"""Fig. 12 — DAMON region-monitoring heatmaps for GUPS.

Paper claims: irrespective of monitoring parameters, DAMON cannot separate
GUPS's hot pages from cold ones, because the hot set is scattered uniformly
across the address space while DAMON assumes per-region homogeneity.

We quantify this as the correlation between DAMON's per-page hotness estimate
(region access rate) and the true page heat, under default and aggressive
scanning configs — and contrast it against HeMem's PEBS-style estimate, which
separates the sets easily.
"""

from __future__ import annotations

import numpy as np

from repro.core.engine import HMSDKEngine, HeMemEngine
from repro.core.knobs import HEMEM_SPACE, HMSDK_SPACE
from repro.core.pages import TierState
from repro.core.simulator import scale_config
from repro.core.workloads import make_workload

from .common import claim, print_claims, save


def _auc(score: np.ndarray, truth: np.ndarray) -> float:
    """Probability a random hot page outscores a random cold page."""
    hot, cold = score[truth], score[~truth]
    if len(hot) == 0 or len(cold) == 0:
        return 0.5
    # rank-based AUC with tie correction (average ranks)
    allv = np.concatenate([hot, cold])
    order = np.argsort(allv, kind="stable")
    ranks = np.empty(len(order))
    ranks[order] = np.arange(1, len(order) + 1)
    sorted_v = allv[order]
    # average ranks over tie groups
    i = 0
    while i < len(sorted_v):
        j = i
        while j + 1 < len(sorted_v) and sorted_v[j + 1] == sorted_v[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = 0.5 * (i + 1 + j + 1)
        i = j + 1
    r_hot = ranks[:len(hot)].sum()
    return float((r_hot - len(hot) * (len(hot) + 1) / 2)
                 / (len(hot) * len(cold)))


def _run_monitor(engine_cls, space, cfg, wl, epochs=30):
    tier = TierState(wl.n_pages, wl.n_pages)  # capacity irrelevant here
    eng = engine_cls(scale_config(
        "hmsdk" if engine_cls is HMSDKEngine else "hemem", cfg, wl.scale),
        tier, seed=0)
    for e in range(epochs):
        reads, writes = wl.epoch_access(e)
        tier.allocate_first_touch((reads + writes) > 0)
        eng.observe(reads, writes, wl.epoch_ms)
    if engine_cls is HMSDKEngine:
        return eng.nr_accesses[eng.region_of_page]
    return eng.read_counts + eng.write_counts


def run(quick: bool = False) -> dict:
    wl = make_workload("gups", "8GiB-hot", threads=12, scale=0.25, seed=0)
    reads0, writes0 = wl.epoch_access(0)
    truth = (reads0 + writes0) > np.median(reads0 + writes0) * 3

    damon_cfgs = {
        "default": HMSDK_SPACE.default_config(),
        "high-freq": HMSDK_SPACE.validate(
            dict(sample_us=100, aggr_us=10000, nr_regions=1000)),
    }
    out = {"auc": {}}
    for name, cfg in damon_cfgs.items():
        score = _run_monitor(HMSDKEngine, HMSDK_SPACE, cfg, wl)
        out["auc"][f"damon/{name}"] = _auc(score, truth)
    hemem_score = _run_monitor(HeMemEngine, HEMEM_SPACE,
                               HEMEM_SPACE.default_config(), wl)
    out["auc"]["hemem/default"] = _auc(hemem_score, truth)

    for k, v in out["auc"].items():
        print(f"  {k:18s} hot/cold separation AUC = {v:.3f}", flush=True)

    claims = [
        claim("fig12: DAMON cannot separate GUPS hot pages (any config)",
              all(v < 0.75 for k, v in out["auc"].items()
                  if k.startswith("damon/")),
              f"{ {k: round(v, 3) for k, v in out['auc'].items()} }"),
        claim("fig12: PEBS-style monitoring separates them easily",
              out["auc"]["hemem/default"] > 0.9,
              f"hemem AUC={out['auc']['hemem/default']:.3f}"),
    ]
    out["claims"] = claims
    print_claims(claims)
    save("fig12_damon_gups", out)
    return out


if __name__ == "__main__":
    run()
