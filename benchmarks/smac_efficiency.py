"""§3.1 — SMAC sample efficiency.

Paper claims: SMAC finds the best-performing (Fig.-1-grid-level) GUPS
configuration within 10-16 iterations, making it 2.5-4x more sample-efficient
than the grid search.

Runs through the typed :class:`~repro.core.study.Study` API: the reference
grid evaluates as one batched ``Study.run(configs=...)`` pass and each SMAC
session is a ``Study.tune`` call.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import ExperimentSpec, Study, WorkloadSpec
from repro.core.knobs import HEMEM_SPACE

from .common import claim, print_claims, save
from .fig1_grid import CT_GRID, RH_GRID


def run(quick: bool = False) -> dict:
    study = Study(ExperimentSpec(engine="hemem",
                                 workload=WorkloadSpec("gups", "8GiB-hot")))
    rh = RH_GRID[::2] if quick else RH_GRID
    ct = CT_GRID[::2] if quick else CT_GRID
    base = HEMEM_SPACE.default_config()
    grid_cfgs = [HEMEM_SPACE.validate(dict(base, read_hot_threshold=r,
                                           cooling_threshold=c))
                 for r, c in itertools.product(rh, ct)]
    grid_vals = [r.total_s for r in study.run(configs=grid_cfgs)]
    grid_best = float(min(grid_vals))
    grid_evals = len(grid_cfgs)

    iters_needed, improvements = [], []
    seeds = [1, 2] if quick else [1, 2, 3]
    for seed in seeds:
        res = study.tune(budget=40 if quick else 60, seed=seed, n_init=10)
        it = res.iterations_to(grid_best, rtol=0.02)
        iters_needed.append(it if it is not None else res.budget + 1)
        improvements.append(res.improvement)

    med = float(np.median(iters_needed))
    speedup = grid_evals / med
    out = {"grid_best_s": grid_best, "grid_evals": grid_evals,
           "iters_to_grid_optimum": iters_needed,
           "median_iters": med, "sample_efficiency_x": speedup,
           "improvements": improvements}
    claims = [
        claim("smac: reaches grid-level optimum within ~10-16 iterations",
              med <= 24,
              f"median {med:.0f} iterations (seeds: {iters_needed})"),
        claim("smac: >= 2.5x more sample-efficient than grid search",
              speedup >= (1.5 if quick else 2.5),
              f"{grid_evals} grid evals vs {med:.0f} SMAC iters "
              f"= {speedup:.1f}x" + (" [quick grid]" if quick else "")),
    ]
    out["claims"] = claims
    print_claims(claims)
    save("smac_efficiency", out)
    return out


if __name__ == "__main__":
    run()
