"""§3.1 — SMAC sample efficiency.

Paper claims: SMAC finds the best-performing (Fig.-1-grid-level) GUPS
configuration within 10-16 iterations, making it 2.5-4x more sample-efficient
than the grid search.
"""

from __future__ import annotations

import numpy as np

from repro.core.knobs import HEMEM_SPACE
from repro.core.simulator import Scenario
from repro.core.bo.smac import grid_search
from repro.core.bo.tuner import TuningSession

from .common import claim, print_claims, save
from .fig1_grid import CT_GRID, RH_GRID


def run(quick: bool = False) -> dict:
    sc = Scenario("gups", "8GiB-hot")
    f = sc.objective("hemem")
    rh = RH_GRID[::2] if quick else RH_GRID
    ct = CT_GRID[::2] if quick else CT_GRID
    _, grid_best, cells = grid_search(
        HEMEM_SPACE, f, {"read_hot_threshold": rh, "cooling_threshold": ct})
    grid_evals = len(cells)

    iters_needed, improvements = [], []
    seeds = [1, 2] if quick else [1, 2, 3]
    for seed in seeds:
        session = TuningSession("hemem", f, scenario_key=sc.key,
                                budget=40 if quick else 60, seed=seed,
                                n_init=10)
        res = session.run()
        it = res.iterations_to(grid_best, rtol=0.02)
        iters_needed.append(it if it is not None else res.budget + 1)
        improvements.append(res.improvement)

    med = float(np.median(iters_needed))
    speedup = grid_evals / med
    out = {"grid_best_s": grid_best, "grid_evals": grid_evals,
           "iters_to_grid_optimum": iters_needed,
           "median_iters": med, "sample_efficiency_x": speedup,
           "improvements": improvements}
    claims = [
        claim("smac: reaches grid-level optimum within ~10-16 iterations",
              med <= 24,
              f"median {med:.0f} iterations (seeds: {iters_needed})"),
        claim("smac: >= 2.5x more sample-efficient than grid search",
              speedup >= (1.5 if quick else 2.5),
              f"{grid_evals} grid evals vs {med:.0f} SMAC iters "
              f"= {speedup:.1f}x" + (" [quick grid]" if quick else "")),
    ]
    out["claims"] = claims
    print_claims(claims)
    save("smac_efficiency", out)
    return out


if __name__ == "__main__":
    run()
