"""Fig. 7 — tuning with different application inputs + cross-input transfer.

Paper claims: the best configuration for one input usually does NOT perform
well on the other input (often worse than default).

Ported to the typed Study API (continuing the PR 3 migration): one Study
per (workload, input), tuned with batched SMAC rounds (``batch_size=4``,
process-pool sharded); the transfer evaluations reuse the destination
input's Study so its cached workload trace serves both directions.
"""

from __future__ import annotations

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec

from .common import budget, claim, print_claims, save

PAIRS = [
    ("gapbs-bc", "kron", "twitter"),
    ("gapbs-pr", "kron", "twitter"),
    ("silo", "ycsb-c", "tpc-c"),
]

BATCH_SIZE = 4


def _study(wname: str, inp: str) -> Study:
    return Study(ExperimentSpec(
        engine="hemem", workload=WorkloadSpec(wname, inp),
        options=SimOptions(sampler="sparse", workers="auto")))


def run(quick: bool = False) -> dict:
    out = {"pairs": {}}
    claims = []
    bad_transfers = 0
    total_transfers = 0
    for wname, in_a, in_b in PAIRS:
        entry = {}
        results = {}
        studies = {}
        for inp in (in_a, in_b):
            studies[inp] = _study(wname, inp)
            res = studies[inp].tune(budget=budget(quick),
                                    batch_size=BATCH_SIZE, seed=11)
            results[inp] = res
            entry[inp] = {"spec": studies[inp].spec.to_dict(),
                          "default_s": res.default_value,
                          "best_s": res.best_value,
                          "improvement": res.improvement}
        # transfer: run each best config on the OTHER input
        for src, dst in ((in_a, in_b), (in_b, in_a)):
            transfer_s = studies[dst].run(
                configs=[results[src].best.config])[0].total_s
            rel_to_best = transfer_s / results[dst].best_value
            rel_to_default = transfer_s / results[dst].default_value
            entry[f"{src}->{dst}"] = {
                "transfer_s": transfer_s,
                "vs_native_best": rel_to_best,
                "vs_default": rel_to_default,
            }
            total_transfers += 1
            if rel_to_best > 1.05:   # clearly worse than native tuning
                bad_transfers += 1
            print(f"  {wname}: {src}->{dst}  {rel_to_best:.2f}x of native best, "
                  f"{rel_to_default:.2f}x of default", flush=True)
        out["pairs"][wname] = entry

    claims.append(claim(
        "fig7: best configs usually do not transfer across inputs",
        bad_transfers * 2 >= total_transfers,   # "in most cases" (paper §4.3)
        f"{bad_transfers}/{total_transfers} transfers worse than native tuning"))
    out["claims"] = claims
    print_claims(claims)
    save("fig7_input_transfer", out)
    return out


if __name__ == "__main__":
    run()
