"""Shared harness for the paper-figure benchmarks.

Every ``figN_*`` module exposes ``run(quick: bool) -> dict`` returning a JSON-
serializable result payload including a ``claims`` list of
``(name, ok, detail)`` tuples validating that figure's paper claims.
``benchmarks.run`` executes all of them and writes ``bench_output.txt`` +
``benchmarks/results/*.json``.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Tuple

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

#: the paper's benchmark suite with default inputs (Table 4)
SUITE = [
    ("gapbs-bc", "kron"), ("gapbs-pr", "kron"), ("gapbs-cc", "kron"),
    ("silo", "ycsb-c"), ("btree", ""), ("xsbench", ""),
    ("gups", "8GiB-hot"), ("graph500", "kron"),
]


def budget(quick: bool) -> int:
    """Optimizer budget: the paper uses 100; quick mode trims to 40."""
    return 40 if quick else 100


def save(name: str, payload: Dict[str, Any]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=2, default=_coerce)


def _coerce(o):
    import numpy as np
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(type(o))


def claim(name: str, ok: bool, detail: str) -> Tuple[str, bool, str]:
    return (name, bool(ok), detail)


def print_claims(claims: List[Tuple[str, bool, str]]) -> None:
    for name, ok, detail in claims:
        print(f"  [{'PASS' if ok else 'FAIL'}] {name}: {detail}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
