"""Fig. 13 — tuned HeMem vs Memtis (dynamic-threshold SOTA), normalized to
HeMem-default.

Paper claims: Memtis beats HeMem-default on some workloads but the tuned
HeMem configuration outperforms Memtis on ALL workloads (~1.56x on average).

Ported to the typed Study API (completing the PR 2 migration): HeMem is
tuned with batched SMAC rounds and the Memtis baseline is one
``Study.run()`` on the same workload spec — no ``Scenario``/
``tune_scenario``/``evaluate`` shims.  Result payloads embed the
replayable specs.
"""

from __future__ import annotations

import numpy as np

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec

from .common import SUITE, budget, claim, print_claims, save

BATCH_SIZE = 4


def run(quick: bool = False) -> dict:
    b = budget(quick)
    out = {"workloads": {}}
    claims = []
    ratios = {}          # memtis_s / tuned_hemem_s (>1 -> tuned wins)
    memtis_beats_default = 0
    suite = SUITE if not quick else SUITE[:4]
    for wname, inp in suite:
        opts = SimOptions(sampler="sparse", workers="auto")
        wspec = WorkloadSpec(wname, inp)
        study = Study(ExperimentSpec(engine="hemem", workload=wspec,
                                     options=opts))
        res = study.tune(budget=b, batch_size=BATCH_SIZE, seed=29)
        memtis = Study(ExperimentSpec(engine="memtis", workload=wspec,
                                      options=opts))
        memtis_s = memtis.run().total_s
        ratios[study.key] = memtis_s / res.best_value
        if memtis_s < res.default_value:
            memtis_beats_default += 1
        out["workloads"][study.key] = {
            "spec": study.spec.to_dict(),
            "hemem_default_s": res.default_value,
            "hemem_best_s": res.best_value,
            "memtis_s": memtis_s,
            "tuned_vs_memtis": memtis_s / res.best_value,
        }
        print(f"  {study.key:22s} default={res.default_value:7.1f} "
              f"tuned={res.best_value:7.1f} memtis={memtis_s:7.1f} "
              f"tuned-vs-memtis={memtis_s / res.best_value:.2f}x", flush=True)

    geo = float(np.exp(np.mean(np.log(list(ratios.values())))))
    claims.append(claim(
        "fig13: tuned HeMem outperforms Memtis on (almost) all workloads",
        sum(v >= 0.98 for v in ratios.values()) >= len(ratios) - 1,
        ", ".join(f"{k.split(':')[0]}={v:.2f}x" for k, v in ratios.items())))
    claims.append(claim(
        "fig13: average tuned-HeMem advantage ~1.56x over Memtis",
        1.15 <= geo <= 2.2,
        f"geomean {geo:.2f}x (paper: 1.56x)"))
    claims.append(claim(
        "fig13: Memtis beats HeMem-default on some workloads",
        memtis_beats_default >= 1,
        f"{memtis_beats_default}/{len(suite)} workloads"))
    out["claims"] = claims
    out["geomean_tuned_vs_memtis"] = geo
    print_claims(claims)
    save("fig13_memtis", out)
    return out


if __name__ == "__main__":
    run()
