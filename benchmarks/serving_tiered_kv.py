"""Beyond-paper: the tuned knobs on the REAL JAX serving path.

Runs the TieredKVCache decode loop (paged-attention kernel + engine-driven
migrations) under (a) HeMem defaults, (b) a BO-tuned config, (c) no
migrations, and checks that tuning the SAME Table-2 knobs improves the
production metric (attention-mass recall at bounded migration cost).
"""

from __future__ import annotations

import numpy as np

from repro.core.bo.tuner import TuningSession
from repro.core.knobs import HEMEM_SPACE
from repro.core.tiered_kv import KVSpec, TieredKVCache

from .common import claim, print_claims, save


def _run(config, steps=96, migrate=True, seed=7):
    rng = np.random.default_rng(seed)
    spec = KVSpec(n_layers=2, kv_heads=2, head_dim=16, page_tokens=8)
    cache = TieredKVCache(spec, batch=2, max_pages_per_seq=48, hbm_pages=12,
                          config=config)
    for step in range(steps):
        k = rng.normal(size=(2, spec.n_layers, spec.kv_heads, spec.head_dim))
        cache.append(k, k)
        cache._record_reads()
        if migrate and step % 8 == 7:
            cache.step_engine(50.0)
    return cache


def _objective(config) -> float:
    cache = _run(config)
    return 100.0 * (1.0 - cache.recall()) + 0.05 * cache.migrations


def run(quick: bool = False) -> dict:
    budget = 12 if quick else 30
    session = TuningSession("hemem", _objective,
                            scenario_key="tiered-kv-serving",
                            budget=budget, seed=0, n_init=max(6, budget // 3))
    res = session.run()

    default_cache = _run(HEMEM_SPACE.default_config())
    tuned_cache = _run(res.best.config)
    frozen_cache = _run(HEMEM_SPACE.default_config(), migrate=False)

    out = {
        "default": {"recall": default_cache.recall(),
                    "migrations": default_cache.migrations,
                    "objective": res.default_value},
        "tuned": {"recall": tuned_cache.recall(),
                  "migrations": tuned_cache.migrations,
                  "objective": res.best_value,
                  "config": res.best.config},
        "no_migration": {"recall": frozen_cache.recall()},
    }
    for k in ("default", "tuned", "no_migration"):
        print(f"  {k:14s} recall={out[k]['recall']:.3f} "
              f"migs={out[k].get('migrations', 0)}", flush=True)

    claims = [
        claim("serving: engine-driven migration beats frozen placement",
              out["tuned"]["recall"] > out["no_migration"]["recall"] + 0.02,
              f"tuned recall {out['tuned']['recall']:.3f} vs frozen "
              f"{out['no_migration']['recall']:.3f}"),
        claim("serving: BO-tuning the Table-2 knobs improves the real "
              "serving objective over defaults",
              res.best_value <= res.default_value * 0.98,
              f"objective {res.default_value:.1f} -> {res.best_value:.1f}"),
    ]
    out["claims"] = claims
    print_claims(claims)
    save("serving_tiered_kv", out)
    return out


if __name__ == "__main__":
    run()
