"""Compiled tiered-KV serving under replayed request traffic.

Three measurements, all on the REAL serving path (paged-attention kernel +
engine-driven migrations), receipts in ``BENCH_serving.json`` (repo root
and ``benchmarks/results/``):

1. **Fused-step speedup** — the compiled ``decode_step`` (one jitted
   append+attend+record call, batched ``page_migrate`` epochs) vs the
   per-page Python reference loop at batch >= 256, interleaved min-of-N
   after a warmup step (acceptance: >= 3x).
2. **Traffic replay** — Poisson and bursty-diurnal request arrivals
   (:class:`~repro.core.traffic.TrafficSpec`) over hundreds of concurrent
   sequences with arrivals/completions, reporting p50/p99 modeled decode
   latency, measured throughput, and attention-mass recall per pattern.
3. **Knob tuning** — ``Study.tune`` with a custom serving objective
   (p99 latency / recall over a replay) driving the Table-2 ``HEMEM_SPACE``
   knobs; acceptance: tuned objective <= 0.98x defaults.

The lifted ``kv-hemem`` engine is also exercised through the simulator's
``backend="jax"`` path on the registered ``kv-poisson`` workload, asserting
the compiled dispatch takes it (no numpy-fallback warning).
"""

from __future__ import annotations

import json
import logging
import os
import time
from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from repro.core import ExperimentSpec, SimOptions, Study
from repro.core.knobs import HEMEM_SPACE
from repro.core.tiered_kv import KVSpec, TieredKVCache
from repro.core.traffic import TrafficSpec, replay_schedule

from .common import claim, print_claims, save

# float32 pools: XLA CPU software-emulates bfloat16, which would inflate
# the attention cost both arms share and compress the measured ratio
SPEC = KVSpec(n_layers=2, kv_heads=2, head_dim=16, page_tokens=4,
              dtype=jnp.float32)

#: modeled serving machine: HBM vs PCIe-host bandwidth + per-step compute.
#: Latency is modeled at the paper's production page granule (2 MiB), not
#: the miniature test spec's page size, so residency actually moves the
#: tail: a non-resident page costs ~65us of PCIe reads vs ~2.6us from HBM.
NEAR_GBS, FAR_GBS, COMPUTE_MS = 800.0, 32.0, 0.2
MODEL_PAGE_BYTES = 2 << 20


def _page_ms(pages, gbs: float, page_bytes: int = MODEL_PAGE_BYTES):
    return pages * page_bytes * 1e3 / (gbs * 1e9)


def replay(config, traffic: TrafficSpec, *, batch: int, max_pages: int,
           hbm_frac: float = 0.25, seed: int = 0, compiled: bool = True,
           engine_every: int = 8, dt_ms: float = 50.0) -> Dict:
    """Replay one arrival trace through a TieredKVCache; returns latency/
    recall/throughput stats.  Deterministic in (config, traffic, seed)."""
    hbm_pages = max(2, int(batch * max_pages * hbm_frac))
    sched = replay_schedule(traffic, batch,
                            max_pages * SPEC.page_tokens, seed)
    cache = TieredKVCache(SPEC, batch, max_pages, hbm_pages, config=config,
                          compiled=compiled)
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(batch, SPEC.n_layers, SPEC.kv_heads,
                         SPEC.head_dim)).astype(np.float32)
    q = rng.normal(size=(batch, SPEC.kv_heads,
                         SPEC.head_dim)).astype(np.float32)
    if compiled:       # compile outside the timed loop (shared jit cache)
        warm = TieredKVCache(SPEC, batch, max_pages, hbm_pages,
                             config=config, compiled=True)
        warm.decode_step(k, k, q).block_until_ready()
        warm.step_engine(dt_ms)
        warm.reset_seqs(np.ones(batch, bool))
    lats: List[np.ndarray] = []
    tokens = 0
    out = None
    t0 = time.perf_counter()
    for t in range(traffic.steps):
        active = sched["active"][t]
        if not active.any():
            continue
        out = cache.decode_step(k, k, q, active=active)
        moved = 0
        if t % engine_every == engine_every - 1:
            m0 = cache.migrations
            cache.step_engine(dt_ms)
            moved = cache.migrations - m0
        res, tot = cache.last_step_pages
        res = np.asarray(res, np.float64)
        tot = np.asarray(tot, np.float64)
        # modeled per-sequence decode latency: compute floor + resident
        # pages over HBM + non-resident over PCIe + migration stall
        lat = (COMPUTE_MS + _page_ms(res, NEAR_GBS)
               + _page_ms(tot - res, FAR_GBS)
               + _page_ms(float(moved), FAR_GBS))
        lats.append(lat[active])
        tokens += int(active.sum())
        cache.reset_seqs(sched["done"][t])
    if out is not None:
        out.block_until_ready()
    wall = time.perf_counter() - t0
    lat = np.concatenate(lats) if lats else np.zeros(1)
    return {
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "recall": cache.recall(),
        "migrations": cache.migrations,
        "completed": int(sched["completed"]),
        "tokens": tokens,
        "tokens_per_s": tokens / max(wall, 1e-9),
        "wall_s": wall,
    }


def serving_objective(stats: Dict) -> float:
    """Lower-is-better serving score: tail latency penalized by recall."""
    return stats["p99_ms"] / max(stats["recall"], 1e-3)


def _speedup(batch: int, steps: int, rounds: int) -> Dict:
    """Compiled vs Python-loop decode_step wall clock, interleaved
    min-of-N.  Both arms are warmed to steady state first (3 full page
    cycles + one engine epoch) so neither measurement includes jit or
    eager-op compilation."""
    mp = 8
    caches = {m: TieredKVCache(SPEC, batch, mp, batch * mp // 4,
                               compiled=(m == "compiled"))
              for m in ("compiled", "python")}
    rng = np.random.default_rng(3)
    k = rng.normal(size=(batch, SPEC.n_layers, SPEC.kv_heads,
                         SPEC.head_dim)).astype(np.float32)
    q = rng.normal(size=(batch, SPEC.kv_heads,
                         SPEC.head_dim)).astype(np.float32)
    for c in caches.values():                       # warmup / compile
        for i in range(3 * SPEC.page_tokens):
            c.decode_step(k, k, q).block_until_ready()
        c.step_engine(50.0)
    best = {m: float("inf") for m in caches}
    for _ in range(rounds):                         # interleaved min-of-N
        for m, c in caches.items():
            t0 = time.perf_counter()
            for _ in range(steps):
                out = c.decode_step(k, k, q)
            out.block_until_ready()
            best[m] = min(best[m], time.perf_counter() - t0)
    return {"batch": batch, "steps": steps,
            "compiled_ms_per_step": best["compiled"] / steps * 1e3,
            "python_ms_per_step": best["python"] / steps * 1e3,
            "speedup": best["python"] / best["compiled"]}


def _jax_dispatch_check() -> Dict:
    """Run kv-hemem through the simulator's backend="jax" path on the
    registered kv-poisson traffic workload; the lifted engine must compile
    (no numpy-fallback warning)."""
    records: List[logging.LogRecord] = []

    class _Catch(logging.Handler):
        def emit(self, r):
            records.append(r)

    h = _Catch()
    logging.getLogger("repro.core.simulator").addHandler(h)
    try:
        res = Study(ExperimentSpec(
            engine="kv-hemem", workload="kv-poisson",
            options=SimOptions(backend="jax"))).run()
    finally:
        logging.getLogger("repro.core.simulator").removeHandler(h)
    fell_back = any("falling back" in r.getMessage() for r in records)
    return {"total_s": res.total_s, "fallback_warned": fell_back}


def run(quick: bool = False) -> dict:
    if quick:
        traffic_steps, batch, mp = 192, 64, 8
        tune_budget, tune_steps, tune_batch = 10, 96, 32
        sp_steps, sp_rounds = 3, 2
    else:
        traffic_steps, batch, mp = 512, 288, 8
        tune_budget, tune_steps, tune_batch = 24, 160, 48
        sp_steps, sp_rounds = 6, 3

    default = HEMEM_SPACE.default_config()
    patterns = {
        "poisson": TrafficSpec(pattern="poisson", arrival_rate=batch / 24,
                               steps=traffic_steps),
        "bursty-diurnal": TrafficSpec(pattern="bursty-diurnal",
                                      arrival_rate=batch / 24,
                                      steps=traffic_steps),
    }

    print("  fused-step speedup (batch=256)...", flush=True)
    speed = _speedup(batch=256, steps=sp_steps, rounds=sp_rounds)
    print(f"    compiled {speed['compiled_ms_per_step']:.2f} ms/step vs "
          f"python {speed['python_ms_per_step']:.2f} -> "
          f"{speed['speedup']:.1f}x", flush=True)

    out: Dict = {"speedup": speed, "traffic": {}, "spec": {
        "kv": {"n_layers": SPEC.n_layers, "kv_heads": SPEC.kv_heads,
               "head_dim": SPEC.head_dim, "page_tokens": SPEC.page_tokens},
        "batch": batch, "max_pages": mp,
        "patterns": {k: v.to_json() for k, v in patterns.items()}}}
    for name, tr in patterns.items():
        stats = replay(default, tr, batch=batch, max_pages=mp, seed=11)
        out["traffic"][name] = stats
        print(f"    {name:15s} p50={stats['p50_ms']:.2f}ms "
              f"p99={stats['p99_ms']:.2f}ms recall={stats['recall']:.3f} "
              f"{stats['tokens_per_s']:.0f} tok/s", flush=True)

    # -- Study.tune with the embedded replayable serving objective ---------
    tune_traffic = TrafficSpec(pattern="bursty-diurnal",
                               arrival_rate=tune_batch / 24,
                               steps=tune_steps)

    def objective(config) -> float:
        return serving_objective(replay(config, tune_traffic,
                                        batch=tune_batch, max_pages=mp,
                                        seed=5))

    study = Study(ExperimentSpec(engine="kv-hemem", workload="kv-poisson"))
    res = study.tune(budget=tune_budget, seed=0,
                     n_init=max(4, tune_budget // 3), objective=objective)
    out["tuning"] = {
        "budget": tune_budget, "default_objective": res.default_value,
        "tuned_objective": res.best_value, "best_config": res.best.config,
        "traffic": tune_traffic.to_json(),
    }
    print(f"    tuned objective {res.default_value:.2f} -> "
          f"{res.best_value:.2f}", flush=True)

    out["jax_dispatch"] = _jax_dispatch_check()

    claims = [
        claim("serving: fused compiled step >= 3x over the Python loop "
              "at batch 256",
              speed["speedup"] >= 3.0,
              f"{speed['speedup']:.1f}x "
              f"({speed['python_ms_per_step']:.2f} -> "
              f"{speed['compiled_ms_per_step']:.2f} ms/step)"),
        claim("serving: traffic replay reports tail latency + recall "
              "under both arrival patterns",
              all(out["traffic"][p]["completed"] > 0
                  and out["traffic"][p]["p99_ms"]
                  >= out["traffic"][p]["p50_ms"]
                  for p in patterns),
              ", ".join(f"{p}: p99={out['traffic'][p]['p99_ms']:.2f}ms "
                        f"recall={out['traffic'][p]['recall']:.3f}"
                        for p in patterns)),
        claim("serving: BO-tuning the Table-2 knobs improves the "
              "p99/recall serving objective (<= 0.98x default)",
              res.best_value <= res.default_value * 0.98,
              f"objective {res.default_value:.2f} -> {res.best_value:.2f}"),
        claim("serving: lifted kv-hemem engine compiles under "
              "backend='jax' (no numpy-fallback warning)",
              not out["jax_dispatch"]["fallback_warned"],
              f"sim total_s={out['jax_dispatch']['total_s']:.1f}"),
    ]
    out["claims"] = claims
    print_claims(claims)
    save("serving_tiered_kv", out)
    # the acceptance artifact also lives at the repo root
    root = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serving.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=2, default=float)
        f.write("\n")
    return out


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
