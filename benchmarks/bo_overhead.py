"""BO hot-path overhead: ask/tell wall clock vs evaluation wall clock.

PR 5 acceptance receipts: a budget-64, q=8 ``Study.tune`` run under the
pre-PR-5 optimizer (``surrogate="reference"`` recursive forest fit +
``acquisition="legacy"`` per-tree descent / ``np.vectorize``'d erf / dict
candidate pools) vs the compiled default (level-synchronous array-native
fit + fused jitted EI acquisition + encoded pools).  The per-round
fit / acquisition / evaluation breakdown and the >= 3x ask/tell reduction
are recorded in ``BENCH_bo.json`` (repo root and benchmarks/results/).

Both runs use the same seeds; histories differ between acquisition modes
(different candidate-pool RNG protocols — see repro.core.bo.smac), so the
comparison is about optimizer cost, with best-values reported for context.
"""

from __future__ import annotations

import json
import os

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec

from .common import claim, print_claims, save


def _tune(budget: int, q: int, **kwargs) -> dict:
    study = Study(ExperimentSpec(
        engine="hemem", workload=WorkloadSpec("gups", "8GiB-hot"),
        options=SimOptions(sampler="sparse")))
    res = study.tune(budget=budget, batch_size=q, seed=11, **kwargs)
    return {
        "spec": study.spec.to_dict(),
        "best_s": res.best_value,
        "improvement": res.improvement,
        "wall_s": res.wall_s,
        "ask_tell_s": res.optimizer_overhead_s,
        "evaluation_s": res.evaluation_s,
        "overhead_fraction_of_eval": res.overhead_fraction,
        "fit_s": float(sum(r["fit_s"] for r in res.round_times)),
        "acquisition_s": float(sum(r["ask_s"] - r["fit_s"]
                                   for r in res.round_times)),
        "rounds": res.round_times,
    }


def run(quick: bool = False) -> dict:
    budget = 24 if quick else 64
    q = 4 if quick else 8
    repeats = 1 if quick else 2
    print(f"  budget={budget} q={q} (gups:8GiB-hot, hemem)", flush=True)
    out = {"budget": budget, "q": q, "repeats": repeats}
    arms = {"before": dict(surrogate="reference", acquisition="legacy"),
            "after": {}}
    # interleaved min-of-N (same methodology as BENCH_backend): this box is
    # 2-core and throttles, so each arm keeps its least-noisy run
    runs = {label: [] for label in arms}
    for _ in range(repeats):
        for label, kwargs in arms.items():
            runs[label].append(_tune(budget, q, **kwargs))
    for label in arms:
        out[label] = min(runs[label], key=lambda r: r["ask_tell_s"])
    speedup = out["before"]["ask_tell_s"] / max(out["after"]["ask_tell_s"],
                                                1e-12)
    out["ask_tell_speedup_x"] = speedup
    for label in ("before", "after"):
        r = out[label]
        print(f"  {label:6s} ask+tell={r['ask_tell_s']:7.3f}s "
              f"(fit {r['fit_s']:.3f}s, acq {r['acquisition_s']:.3f}s)  "
              f"eval={r['evaluation_s']:7.3f}s  "
              f"overhead={100 * r['overhead_fraction_of_eval']:.1f}% of eval",
              flush=True)

    claims = [
        claim("bo: ask/tell overhead reduced >= 3x vs pre-PR-5 optimizer",
              speedup >= 3.0, f"{speedup:.1f}x "
              f"({out['before']['ask_tell_s']:.3f}s -> "
              f"{out['after']['ask_tell_s']:.3f}s)"),
        claim("bo: ask/tell is a small fraction of evaluation wall clock",
              out["after"]["overhead_fraction_of_eval"] <= 0.25,
              f"{100 * out['after']['overhead_fraction_of_eval']:.1f}% "
              "of evaluation"),
    ]
    out["claims"] = claims
    print_claims(claims)
    save("BENCH_bo", out)
    root = os.path.join(os.path.dirname(__file__), "..", "BENCH_bo.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
