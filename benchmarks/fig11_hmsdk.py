"""Fig. 11 — tuning HMSDK (DAMON-based) on the NUMA machine.

Paper claims: significant gains for some workloads (PR, Btree, XSBench via
better monitoring / eliminated migrations), modest for others, and NO gain
for GUPS (DAMON's region assumption fails — see fig12).

Ported to the typed Study API (completing the PR 2 migration): batched
SMAC rounds (``batch_size=4``, process-pool sharded) replace the
deprecated ``Scenario``/``tune_scenario`` shims; result payloads embed the
replayable spec.
"""

from __future__ import annotations

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec

from .common import SUITE, budget, claim, print_claims, save

BATCH_SIZE = 4


def run(quick: bool = False) -> dict:
    b = budget(quick)
    out = {"workloads": {}}
    claims = []
    imps = {}
    suite = SUITE if not quick else [("gapbs-pr", "kron"), ("xsbench", ""),
                                     ("gups", "8GiB-hot")]
    for wname, inp in suite:
        study = Study(ExperimentSpec(
            engine="hmsdk", workload=WorkloadSpec(wname, inp),
            machine="numa",
            options=SimOptions(sampler="sparse", workers="auto")))
        res = study.tune(budget=b, batch_size=BATCH_SIZE, seed=23)
        imps[wname] = res.improvement
        out["workloads"][study.key] = {
            "spec": study.spec.to_dict(),
            "default_s": res.default_value, "best_s": res.best_value,
            "improvement": res.improvement, "best_config": res.best.config,
        }
        print(f"  {study.key:26s} {res.improvement:.2f}x", flush=True)

    others = {k: v for k, v in imps.items() if k != "gups"}
    import numpy as _np
    claims.append(claim(
        "fig11: HMSDK is tunable too (significant gains for some workloads, "
        "modest with others — paper §4.5)",
        sum(v >= 1.08 for v in others.values()) >= 2
        and _np.median(list(others.values())) >= 1.005,
        ", ".join(f"{k}={v:.2f}x" for k, v in imps.items())))
    if "gups" in imps:
        # The residual gain is churn-suppression only (see fig12: DAMON's
        # hot/cold separation AUC stays ~0.5 for GUPS under every config) —
        # placement itself cannot be improved.
        claims.append(claim(
            "fig11: no meaningful HMSDK gain for GUPS (DAMON limitation)",
            imps["gups"] <= 1.15,
            f"gups={imps['gups']:.2f}x (churn suppression only; "
            "placement unimprovable per fig12 AUC)"))
    out["claims"] = claims
    print_claims(claims)
    save("fig11_hmsdk", out)
    return out


if __name__ == "__main__":
    run()
