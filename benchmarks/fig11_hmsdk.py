"""Fig. 11 — tuning HMSDK (DAMON-based) on the NUMA machine.

Paper claims: significant gains for some workloads (PR, Btree, XSBench via
better monitoring / eliminated migrations), modest for others, and NO gain
for GUPS (DAMON's region assumption fails — see fig12).
"""

from __future__ import annotations

from repro.core.simulator import Scenario
from repro.core.bo.tuner import tune_scenario

from .common import SUITE, budget, claim, print_claims, save


def run(quick: bool = False) -> dict:
    b = budget(quick)
    out = {"workloads": {}}
    claims = []
    imps = {}
    suite = SUITE if not quick else [("gapbs-pr", "kron"), ("xsbench", ""),
                                     ("gups", "8GiB-hot")]
    for wname, inp in suite:
        sc = Scenario(wname, inp, machine="numa")
        res = tune_scenario("hmsdk", sc, budget=b, seed=23)
        imps[wname] = res.improvement
        out["workloads"][sc.key] = {
            "default_s": res.default_value, "best_s": res.best_value,
            "improvement": res.improvement, "best_config": res.best.config,
        }
        print(f"  {sc.key:26s} {res.improvement:.2f}x", flush=True)

    others = {k: v for k, v in imps.items() if k != "gups"}
    import numpy as _np
    claims.append(claim(
        "fig11: HMSDK is tunable too (significant gains for some workloads, "
        "modest with others — paper §4.5)",
        sum(v >= 1.08 for v in others.values()) >= 2
        and _np.median(list(others.values())) >= 1.005,
        ", ".join(f"{k}={v:.2f}x" for k, v in imps.items())))
    if "gups" in imps:
        # The residual gain is churn-suppression only (see fig12: DAMON's
        # hot/cold separation AUC stays ~0.5 for GUPS under every config) —
        # placement itself cannot be improved.
        claims.append(claim(
            "fig11: no meaningful HMSDK gain for GUPS (DAMON limitation)",
            imps["gups"] <= 1.15,
            f"gups={imps['gups']:.2f}x (churn suppression only; "
            "placement unimprovable per fig12 AUC)"))
    out["claims"] = claims
    print_claims(claims)
    save("fig11_hmsdk", out)
    return out


if __name__ == "__main__":
    run()
