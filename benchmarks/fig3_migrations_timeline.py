"""Figs. 3/4/5/8 — the time-series evidence behind Table 5.

* Fig 3 (BC): with the best config, migrations arrive in bursts at iteration
  boundaries (the frontier is promoted quickly), while the default migrates
  continuously and ends up doing more total work.
* Fig 4 (PR): streaming pattern — the default keeps migrating pages with no
  reuse; the best config's migration count flatlines.
* Fig 5 (XSBench): hot set stays fast-tier resident under the best config
  (placement stability), bulk churn eliminated.
* Fig 8 (BC kron vs twitter): twitter's popular-node pages concentrate
  traffic; the per-input heatmaps differ, which is why configs don't
  transfer (fig7).

Runs through the typed :class:`~repro.core.study.Study` API (tuning via
``Study.tune``, heatmap series via a ``SimOptions(record_heatmap=True)``
study).  Saves the raw time series + access heatmaps to
results/fig3_timelines.json.
"""

from __future__ import annotations

import numpy as np

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.workloads import make_workload

from .common import budget, claim, print_claims, save


def _tune(wname, inp, b):
    study = Study(ExperimentSpec(engine="hemem",
                                 workload=WorkloadSpec(wname, inp)))
    return study.tune(budget=b, seed=31)


def _series(wname, inp, cfg):
    spec = ExperimentSpec(
        engine="hemem" if cfg is None else {"name": "hemem", "config": cfg},
        workload=WorkloadSpec(wname, inp, threads=12, scale=0.25),
        machine="pmem-large",
        options=SimOptions(record_heatmap=True, heat_bins=64))
    return Study(spec).run()


def run(quick: bool = False) -> dict:
    b = budget(quick)
    out = {}
    claims = []

    # BC: default-vs-best migration timelines
    res = _tune("gapbs-bc", "kron", b)
    r_def = _series("gapbs-bc", "kron", None)
    r_best = _series("gapbs-bc", "kron", res.best.config)
    out["bc"] = {
        "cum_migrations_default": r_def.cum_migrations,
        "cum_migrations_best": r_best.cum_migrations,
        "wall_default_s": r_def.total_s, "wall_best_s": r_best.total_s,
    }
    # burstiness: fraction of best-config migrations inside iteration-start
    # windows (iterations are 15 epochs; window = first 5)
    mig_best = np.diff(r_best.cum_migrations, prepend=0)
    epochs = np.arange(len(mig_best))
    in_window = (epochs % 15) < 5
    burst_frac = float(mig_best[in_window].sum() /
                       max(mig_best.sum(), 1))
    out["bc"]["burst_frac_best"] = burst_frac
    claims.append(claim(
        "fig3/bc: best-config migrations concentrate at iteration starts",
        burst_frac > 0.5,
        f"{burst_frac:.0%} of migrations in the first third of iterations"))

    # PR: default churns, best flatlines
    res_pr = _tune("gapbs-pr", "kron", b)
    r_def = _series("gapbs-pr", "kron", None)
    r_best = _series("gapbs-pr", "kron", res_pr.best.config)
    out["pr"] = {
        "total_migrations_default": r_def.total_migrations,
        "total_migrations_best": r_best.total_migrations,
    }
    claims.append(claim(
        "fig4/pr: streaming pages keep default migrating; best flatlines",
        r_best.total_migrations < 0.2 * max(r_def.total_migrations, 1),
        f"{r_def.total_migrations} -> {r_best.total_migrations}"))

    # XSBench: hot rows of the heatmap stay fast-resident under best
    res_xs = _tune("xsbench", "", b)
    r_best = _series("xsbench", "", res_xs.best.config)
    hot_bins = 1   # first bin is entirely hot-set pages (first-touch layout)
    hot_resid = float(r_best.placement[10:, :hot_bins].mean())
    out["xsbench"] = {"hot_bin_residency_best": hot_resid}
    claims.append(claim(
        "fig5/xsbench: hot set stays fast-tier resident under best config",
        hot_resid > 0.9, f"hot-bin residency {hot_resid:.2f}"))

    # Fig 8: kron vs twitter page-level skew differs (popular-node pages)
    def top_page_share(inp, frac=0.005):
        wl = make_workload("gapbs-bc", inp, threads=12, scale=0.25, seed=0)
        reads, writes = wl.epoch_access(5)
        acc = np.sort(reads + writes)[::-1]
        k = max(1, int(len(acc) * frac))
        return float(acc[:k].sum() / max(acc.sum(), 1e-9))
    skew_kron = top_page_share("kron")
    skew_tw = top_page_share("twitter")
    out["fig8"] = {"top_half_pct_share_kron": skew_kron,
                   "top_half_pct_share_twitter": skew_tw}
    claims.append(claim(
        "fig8: twitter concentrates traffic on popular-node pages far more "
        "than kron",
        skew_tw > skew_kron * 1.3,
        f"top-0.5%-page share: twitter {skew_tw:.2f} vs kron {skew_kron:.2f}"))

    out["claims"] = claims
    print_claims(claims)
    save("fig3_timelines", out)
    return out


if __name__ == "__main__":
    run()
