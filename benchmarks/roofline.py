"""Roofline analysis from the dry-run artifacts (§Roofline of EXPERIMENTS.md).

Per (arch x shape) cell on the single-pod mesh (256 chips), derive:

  compute term    = HLO_FLOPs_dev / peak_FLOPs          (197 TFLOP/s bf16)
  memory term     = HLO_bytes_dev / HBM_bw              (819 GB/s)
  collective term = collective_bytes_dev / link_bw      (~50 GB/s ICI)

Sources: ``compiled.cost_analysis()`` flops / bytes-accessed and the
collective operand bytes parsed from ``compiled.as_text()`` — all recorded by
``repro.launch.dryrun``.  Two methodology notes (validated in
``test_roofline.py`` and EXPERIMENTS.md §Dry-run):

  1. The SPMD module is the per-device program, so cost_analysis numbers are
     per-chip already.
  2. XLA's HloCostAnalysis counts while-loop bodies ONCE.  The layer stack
     and the gradient-accumulation loop are lax.scans, so we correct by the
     known static trip counts: K = n_micro x n_layer_groups (train),
     n_layer_groups (prefill/decode).  The correction is exact for the
     scan-resident work, which dominates every cell; out-of-loop work
     (embedding, final loss reduction) is over-counted by K but is orders of
     magnitude smaller.

MODEL_FLOPS uses the 6·N_active·D convention (train) / 2·N_active·D
(inference) — the "useful"-compute yardstick; its ratio against HLO FLOPs
exposes remat/dispatch overheads.
"""

from __future__ import annotations

import glob
import json
import os

from .common import claim, print_claims, save

PEAK_FLOPS = 197e12          # bf16 per chip (v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _n_groups(arch: str) -> int:
    from repro.configs import get_config
    from repro.models.transformer import pattern_period
    cfg = get_config(arch)
    return cfg.n_layers // pattern_period(cfg)


def _tokens(shape: str, res: dict) -> float:
    from repro.models.config import SHAPES
    sh = SHAPES[shape]
    if sh.kind == "decode":
        return sh.global_batch           # one token per sequence
    return sh.global_batch * sh.seq_len


def _analytic_hbm_bytes(res: dict) -> float:
    """Per-chip HBM traffic model (the fused-TPU counterpart of the CPU
    backend's unfused bytes-accessed): weight streaming + activation traffic
    + KV-cache reads, all bf16.

      train:   3 reads of the (sharded) params per microbatch (fwd, remat
               re-fwd, bwd) + grad/optimizer write traffic + activations
      prefill: 1 read of params + activations
      decode:  1 read of params + full KV-cache read
    """
    from repro.models.config import SHAPES
    from repro.configs import get_config
    cfg = get_config(res["arch"])
    sh = SHAPES[res["shape"]]
    chips = res["n_chips"]
    P_dev = 2.0 * res["param_count"] / chips        # bf16 shard (FSDP+TP)
    act_frac = res["active_param_count"] / res["param_count"]
    tokens_dev = _tokens(res["shape"], res) / chips
    act_bytes = 2.0 * tokens_dev * cfg.d_model * cfg.n_layers * 6

    if res.get("step_kind") == "train_step":
        n_micro = res.get("n_micro", 1)
        # dense weights stream 3x per microbatch; MoE experts only the
        # active fraction after the first touch
        w_traffic = P_dev * (1 + 2 * act_frac) * n_micro
        opt = 3.0 * P_dev * 2                       # grads + moments (fp32)
        return w_traffic + opt + 3 * act_bytes
    if res.get("step_kind") == "prefill_step":
        return P_dev * act_frac + act_bytes
    # decode: params (active) + KV cache for this step
    kv_bytes = 2.0 * 2.0 * sh.global_batch * min(sh.seq_len,
                                                 cfg.window or sh.seq_len) \
        * cfg.n_kv_heads * cfg.hd * cfg.n_layers / chips
    return P_dev * act_frac + kv_bytes + act_bytes


def analyze_cell(res: dict) -> dict:
    arch, shape = res["arch"], res["shape"]
    chips = res["n_chips"]
    k_groups = _n_groups(arch)
    n_micro = res.get("n_micro", 1)
    K = (n_micro * k_groups) if res.get("step_kind") == "train_step" \
        else k_groups

    flops_dev = res["cost_analysis"].get("flops", 0.0) * K
    bytes_dev_raw = res["cost_analysis"].get("bytes accessed", 0.0) * K
    # the CPU backend's bytes-accessed is an UNFUSED upper bound; the fused
    # HBM traffic model below is the roofline memory term (both reported)
    bytes_dev = _analytic_hbm_bytes(res)
    coll_dev = res["collective_bytes_total"] * K

    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_n = coll_dev / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dominant = max(terms, key=terms.get)

    mult = 6.0 if res.get("step_kind") == "train_step" else 2.0
    model_flops = mult * res["active_param_count"] * _tokens(shape, res)
    model_flops_dev = model_flops / chips
    ratio = model_flops_dev / max(flops_dev, 1.0)
    bound = max(t_c, t_m, t_n)
    frac = (model_flops_dev / PEAK_FLOPS) / max(bound, 1e-12)

    return {
        "arch": arch, "shape": shape, "mesh": res["mesh"],
        "step_kind": res.get("step_kind"), "K": K,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "unfused_bytes_s": bytes_dev_raw / HBM_BW,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": ratio,
        "roofline_fraction": frac,
    }


def load_cells(mesh: str = "single"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            res = json.load(f)
        if "error" in res or "skipped" in res:
            cells.append(res)
            continue
        cells.append(analyze_cell(res))
    return cells


def format_table(cells) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'step':12s} "
           f"{'T_comp(s)':>10s} {'T_mem(s)':>10s} {'T_coll(s)':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if "skipped" in c:
            lines.append(f"{c['arch']:24s} {c['shape']:12s} SKIP "
                         f"({c['skipped'][:60]}...)")
            continue
        if "error" in c:
            lines.append(f"{c['arch']:24s} {c['shape']:12s} ERROR")
            continue
        lines.append(
            f"{c['arch']:24s} {c['shape']:12s} {c['step_kind'] or '':12s} "
            f"{c['compute_s']:10.4f} {c['memory_s']:10.4f} "
            f"{c['collective_s']:10.4f} {c['dominant']:>10s} "
            f"{c['useful_flops_ratio']:7.2f} {c['roofline_fraction']:9.3f}")
    return "\n".join(lines)


def run(quick: bool = False) -> dict:
    cells = load_cells("single")
    ok = [c for c in cells if "dominant" in c]
    skipped = [c for c in cells if "skipped" in c]
    failed = [c for c in cells if "error" in c]

    table = format_table(cells)
    print(table, flush=True)

    multi = load_cells("multi")
    multi_ok = [c for c in multi if "dominant" in c]

    n_expected_skips = 7 * 1   # 7 full-attention archs skip long_500k
    claims = [
        claim("dryrun: every applicable (arch x shape) cell lowered+compiled "
              "on the single-pod mesh",
              len(failed) == 0 and len(ok) + len(skipped) == 40,
              f"{len(ok)} ok, {len(skipped)} skipped, {len(failed)} failed"),
        claim("dryrun: multi-pod (2x16x16) mesh compiles every cell too",
              len([c for c in multi if 'error' in c]) == 0,
              f"{len(multi_ok)} ok / {len(multi)} total"),
        claim("roofline: every compiled cell has a dominant term identified",
              all(c.get("dominant") for c in ok), "see table"),
    ]
    out = {"cells": cells, "multi_cells": multi, "table": table,
           "claims": claims}
    print_claims(claims)
    save("roofline", out)
    return out


if __name__ == "__main__":
    run()
