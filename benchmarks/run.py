"""Run every paper-figure benchmark + the roofline harness.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,fig2,...]

Prints a summary line per benchmark plus PASS/FAIL per paper claim, and
exits non-zero if any claim fails.
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time

MODULES = [
    "fig1_grid",
    "fig2_best_vs_default",
    "fig3_migrations_timeline",
    "smac_efficiency",
    "table5_analysis",
    "fig6_pmem_small",
    "fig7_input_transfer",
    "fig9_threads_ratios",
    "fig10_numa",
    "fig11_hmsdk",
    "fig12_damon_gups",
    "fig13_memtis",
    "bo_overhead",
    "serving_tiered_kv",
    "roofline",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced budgets (~4x faster)")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    names = [m for m in MODULES
             if not args.only or any(o in m for o in args.only.split(","))]
    all_claims = []
    t_start = time.time()
    for name in names:
        print(f"\n=== benchmarks.{name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            payload = mod.run(quick=args.quick)
            claims = payload.get("claims", [])
        except Exception as e:  # keep the harness running
            import traceback
            traceback.print_exc()
            claims = [(f"{name}: completed without error", False, repr(e))]
        all_claims.extend(claims)
        print(f"--- {name}: {time.time() - t0:.1f}s", flush=True)

    n_pass = sum(ok for _, ok, _ in all_claims)
    print("\n================ SUMMARY ================")
    for cname, ok, detail in all_claims:
        print(f"[{'PASS' if ok else 'FAIL'}] {cname}")
    print(f"{n_pass}/{len(all_claims)} claims validated "
          f"in {time.time() - t_start:.0f}s")
    return 0 if n_pass == len(all_claims) else 1


if __name__ == "__main__":
    sys.exit(main())
