"""Fig. 2 — BO-tuned best HeMem configuration vs default, all 8 workloads
on pmem-large.

Paper claims: improvements of 1.07-2.09x for all workloads barring Graph500
(which shows ~no gain).

Ported to the typed Study API (PR 2): each tuning session evaluates whole
candidate batches per SMAC round (``batch_size=4``, process-pool sharded),
and the final default-vs-best bars come from one ``Study.sweep`` batched
pass per workload instead of sequential re-evaluations.  Result payloads
embed the replayable ``ExperimentSpec``.
"""

from __future__ import annotations

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.knobs import HEMEM_SPACE

from .common import SUITE, budget, claim, print_claims, save

# q=4 keeps enough adaptive SMAC rounds at quick budgets while still
# cutting wall-clock ~2-3x on this box (see fig9 note)
BATCH_SIZE = 4


def run(quick: bool = False) -> dict:
    out = {"workloads": {}}
    claims = []
    imps = {}
    for wname, inp in SUITE:
        study = Study(ExperimentSpec(
            engine="hemem", workload=WorkloadSpec(wname, inp),
            options=SimOptions(sampler="sparse", workers="auto")))
        res = study.tune(budget=budget(quick), batch_size=BATCH_SIZE, seed=3)
        # one batched pass re-scores {default, best} through a shared trace
        sweep = study.sweep(configs=[HEMEM_SPACE.default_config(),
                                     res.best.config])
        default_s, best_s = sweep.total_s()[("hemem", study.spec.workload.key)]
        imp = default_s / best_s
        imps[study.key] = imp
        out["workloads"][study.key] = {
            "spec": study.spec.to_dict(),
            "default_s": default_s,
            "best_s": best_s,
            "improvement": imp,
            "best_config": res.best.config,
            "incumbent": res.incumbent_trajectory(),
        }
        print(f"  {study.key:34s} default={default_s:8.1f}s "
              f"best={best_s:8.1f}s  {imp:.2f}x", flush=True)

    non_g500 = {k: v for k, v in imps.items() if "graph500" not in k}
    claims.append(claim(
        "fig2: non-graph500 improvements within ~[1.07, 2.09]x band",
        all(1.02 <= v <= 2.30 for v in non_g500.values()),
        ", ".join(f"{k}={v:.2f}x" for k, v in non_g500.items())))
    claims.append(claim(
        "fig2: most workloads show >= 1.07x gains",
        sum(v >= 1.07 for v in non_g500.values()) >= len(non_g500) - 1,
        f"{sum(v >= 1.07 for v in non_g500.values())}/{len(non_g500)}"))
    g500 = [v for k, v in imps.items() if "graph500" in k][0]
    claims.append(claim(
        "fig2: graph500 shows the least gain (~none)",
        g500 <= 1.10 and g500 <= min(non_g500.values()) + 0.05,
        f"graph500={g500:.2f}x vs min(others)={min(non_g500.values()):.2f}x"))
    out["claims"] = claims
    print_claims(claims)
    save("fig2_best_vs_default", out)
    return out


if __name__ == "__main__":
    run()
