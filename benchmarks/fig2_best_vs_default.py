"""Fig. 2 — BO-tuned best HeMem configuration vs default, all 8 workloads
on pmem-large.

Paper claims: improvements of 1.07-2.09x for all workloads barring Graph500
(which shows ~no gain).
"""

from __future__ import annotations

from repro.core.simulator import Scenario
from repro.core.bo.tuner import tune_scenario

from .common import SUITE, budget, claim, print_claims, save


def run(quick: bool = False) -> dict:
    out = {"workloads": {}}
    claims = []
    imps = {}
    for wname, inp in SUITE:
        sc = Scenario(wname, inp)
        res = tune_scenario("hemem", sc, budget=budget(quick), seed=3)
        imps[sc.key] = res.improvement
        out["workloads"][sc.key] = {
            "default_s": res.default_value,
            "best_s": res.best_value,
            "improvement": res.improvement,
            "best_config": res.best.config,
            "incumbent": res.incumbent_trajectory(),
        }
        print(f"  {sc.key:22s} default={res.default_value:8.1f}s "
              f"best={res.best_value:8.1f}s  {res.improvement:.2f}x", flush=True)

    non_g500 = {k: v for k, v in imps.items() if not k.startswith("graph500")}
    claims.append(claim(
        "fig2: non-graph500 improvements within ~[1.07, 2.09]x band",
        all(1.02 <= v <= 2.30 for v in non_g500.values()),
        ", ".join(f"{k}={v:.2f}x" for k, v in non_g500.items())))
    claims.append(claim(
        "fig2: most workloads show >= 1.07x gains",
        sum(v >= 1.07 for v in non_g500.values()) >= len(non_g500) - 1,
        f"{sum(v >= 1.07 for v in non_g500.values())}/{len(non_g500)}"))
    g500 = [v for k, v in imps.items() if k.startswith("graph500")][0]
    claims.append(claim(
        "fig2: graph500 shows the least gain (~none)",
        g500 <= 1.10 and g500 <= min(non_g500.values()) + 0.05,
        f"graph500={g500:.2f}x vs min(others)={min(non_g500.values()):.2f}x"))
    out["claims"] = claims
    print_claims(claims)
    save("fig2_best_vs_default", out)
    return out


if __name__ == "__main__":
    run()
