"""Asynchronous tuning-service benchmark -> BENCH_study.json.

Measures the wall-clock win of ``Study.tune(executor="async")`` — the
slot-saturating trial executor with ASHA successive halving — against the
synchronous ``batch_size=q`` round-barrier path at equal suggestion budget,
and records the receipts the tune-service PR gates on:

* **wall-clock speedup** of async slots=8 + ASHA over synchronous q=8 at
  budget 512 (target > 2x, acceptance gate >= 1.5x);
* **slot utilization** of the async executor (busy slot-time over
  slots x makespan — the round barrier is what the async path removes);
* **ASHA savings**, reported separately: the fraction of full-budget epoch
  work the scheduler skipped, and the async-without-scheduler arm that
  isolates executor overhead from early stopping.

On a single-core host the evaluation slots cannot overlap, so the async
win comes from ASHA epoch savings plus ask-ahead chunking (``window``
amortizes surrogate fits exactly like the sync path's ``ask_batch``); on
multi-core hosts slot overlap compounds with both.  The jax backend is
used for every arm (the compiled epoch loop checkpoints mid-run, so
promoted trials resume from their rung boundary instead of re-simulating);
all compiles are warmed outside the timed regions, matching the repo's
other benchmarks.

Determinism receipts ride along: the async arm journals every decision and
the resulting journal must validate against ``tools/journal_schema.py``.

Usage::

    PYTHONPATH=src python -m benchmarks.study_async [--quick]
        [--budget N] [--slots N] [--window N] [--scale S] [--seed S]
"""

from __future__ import annotations

import argparse
import json
import os
import time


def _default_xla_flags():
    ncpu = os.cpu_count() or 1
    if "XLA_FLAGS" not in os.environ and ncpu > 1:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={min(ncpu, 8)}"


_default_xla_flags()  # before any (transitive) jax import

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec  # noqa: E402
from repro.core.knobs import get_space  # noqa: E402
from repro.core.simulator import run_simulation_segment  # noqa: E402
from repro.core.tune_service.asha import ASHAScheduler  # noqa: E402

from .common import claim, print_claims, save  # noqa: E402


def _study(scale: float, seed: int) -> Study:
    return Study(ExperimentSpec(
        engine="hemem", workload=WorkloadSpec("gups", scale=scale),
        machine="pmem-large",
        options=SimOptions(seed=seed, sampler="sparse", backend="jax")))


def _warm_compiles(study: Study, batch_size: int) -> float:
    """Compile every epoch-loop shape the arms will hit (B=q full run for
    the sync arm; B=1 full run + each ASHA rung segment length for the
    async arms) outside the timed regions."""
    t0 = time.time()
    wl = study.workload()
    cfg = get_space("hemem").default_config()
    study.run(configs=[cfg] * batch_size)          # sync arm: B=q, E=full
    rungs = ASHAScheduler(wl.n_epochs).rung_epochs
    lengths = sorted({hi - lo for lo, hi in
                      zip((0,) + rungs[:-1], rungs)} | {wl.n_epochs})
    for n in lengths:                              # async arms: B=1 segments
        run_simulation_segment(wl, "hemem", [cfg], study.machine,
                               seeds=study.spec.options.seed,
                               sampler="sparse", backend="jax",
                               epoch_start=0, epoch_stop=n)
    return time.time() - t0


def run(quick: bool = False, budget: int = None, slots: int = 8,
        window: int = None, scale: float = None, seed: int = 0) -> dict:
    budget = budget if budget is not None else (64 if quick else 512)
    scale = scale if scale is not None else (0.04 if quick else 0.1)
    window = window if window is not None else 4 * slots
    n_init = min(20, max(4, budget // 8))
    results_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(results_dir, exist_ok=True)
    journal = os.path.join(results_dir, "study_async_journal.jsonl")
    if os.path.exists(journal):
        os.remove(journal)

    study = _study(scale, seed)
    wl = study.workload()
    print(f"GUPS@{scale}/hemem (E={wl.n_epochs}, n_pages={wl.n_pages}), "
          f"budget={budget}, sync q=8 vs async slots={slots} "
          f"window={window}", flush=True)
    t_compile = _warm_compiles(study, batch_size=8)
    print(f"  compile warm-up: {t_compile:.1f}s (excluded from timings)",
          flush=True)

    kw = dict(budget=budget, seed=seed, n_init=n_init)

    t0 = time.time()
    r_sync = _study(scale, seed).tune(batch_size=8, **kw)
    t_sync = time.time() - t0
    print(f"  sync q=8:          {t_sync:7.2f}s  "
          f"best={r_sync.best_value:8.3f}s", flush=True)

    t0 = time.time()
    r_plain = _study(scale, seed).tune(executor="async", slots=slots,
                                       window=window, **kw)
    t_plain = time.time() - t0
    print(f"  async slots={slots}:     {t_plain:7.2f}s  "
          f"best={r_plain.best_value:8.3f}s  "
          f"util={r_plain.utilization:.2f}", flush=True)

    t0 = time.time()
    r_asha = _study(scale, seed).tune(executor="async", slots=slots,
                                      window=window, scheduler="asha",
                                      journal=journal, **kw)
    t_asha = time.time() - t0
    print(f"  async+asha:        {t_asha:7.2f}s  "
          f"best={r_asha.best_value:8.3f}s  "
          f"util={r_asha.utilization:.2f}  "
          f"epochs saved={r_asha.asha_epochs_saved_frac * 100:.0f}%",
          flush=True)

    speedup = t_sync / t_asha
    speedup_plain = t_sync / t_plain
    quality = abs(r_asha.best_value - r_sync.best_value) / r_sync.best_value

    # determinism receipt: the journal the timed run wrote must validate
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "tools"))
    import journal_schema
    journal_problems = journal_schema.validate_file(journal)

    def _arm(r, wall):
        return {
            "wall_s": float(wall), "best_value_s": float(r.best_value),
            "default_value_s": float(r.default_value),
            "improvement_x": float(r.improvement),
        }

    out = {
        "engine": "hemem", "workload": f"gups:8GiB-hot@{scale}",
        "n_epochs": wl.n_epochs, "n_pages": wl.n_pages,
        "budget": budget, "n_init": n_init, "seed": seed,
        "slots": slots, "window": window,
        "cpu_count": os.cpu_count(),
        "compile_warmup_s": float(t_compile),
        "arms": {
            "sync_q8": _arm(r_sync, t_sync),
            "async_slots": dict(_arm(r_plain, t_plain),
                                utilization=float(r_plain.utilization),
                                makespan_s=float(r_plain.makespan_s),
                                busy_s=float(r_plain.busy_s)),
            "async_slots_asha": dict(
                _arm(r_asha, t_asha),
                utilization=float(r_asha.utilization),
                makespan_s=float(r_asha.makespan_s),
                busy_s=float(r_asha.busy_s),
                epochs_committed=int(r_asha.epochs_committed),
                epochs_full_budget=int(budget * wl.n_epochs),
                asha_epochs_saved_frac=float(r_asha.asha_epochs_saved_frac),
                n_stopped_early=int(r_asha.n_stopped_early),
                n_failed=int(r_asha.n_failed)),
        },
        "speedup_async_asha_x": float(speedup),
        "speedup_async_plain_x": float(speedup_plain),
        "best_value_delta_pct": float(quality * 100),
        "journal": os.path.relpath(journal,
                                   os.path.join(os.path.dirname(__file__),
                                                os.pardir)),
        "journal_valid": not journal_problems,
    }
    gate = 1.5 if not quick else 1.0  # quick mode checks wiring, not perf
    out["claims"] = [
        claim("async slots + ASHA beats synchronous q=8 wall-clock "
              f"(gate >= {gate}x, target > 2x)", speedup >= gate,
              f"{speedup:.2f}x at budget {budget} "
              f"({t_sync:.1f}s -> {t_asha:.1f}s, 1-core host: ASHA + "
              f"ask-chunking only, no slot overlap)"),
        claim("evaluation slots stay saturated (no round barrier)",
              r_asha.utilization >= 0.5,
              f"utilization {r_asha.utilization:.2f} over "
              f"{r_asha.makespan_s:.1f}s makespan"),
        claim("ASHA epoch savings reported separately",
              0.0 < r_asha.asha_epochs_saved_frac < 1.0,
              f"{r_asha.asha_epochs_saved_frac * 100:.0f}% of "
              f"{budget * wl.n_epochs} full-budget epochs skipped; "
              f"plain async (no scheduler) {speedup_plain:.2f}x"),
        claim("async incumbent tracks the synchronous one",
              quality <= 0.10,
              f"best_value delta {quality * 100:.2f}% at equal budget"),
        claim("study journal validates against the schema",
              not journal_problems,
              "tools/journal_schema.py: " +
              ("ok" if not journal_problems else
               "; ".join(journal_problems[:3]))),
    ]
    print_claims(out["claims"])
    save("BENCH_study", out)
    root = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_study.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=2)
    return out


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="tiny budget/scale: wiring check, not a perf gate")
    p.add_argument("--budget", type=int, default=None)
    p.add_argument("--slots", type=int, default=8)
    p.add_argument("--window", type=int, default=None,
                   help="ask-ahead depth (default 4*slots)")
    p.add_argument("--scale", type=float, default=None)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    run(quick=args.quick, budget=args.budget, slots=args.slots,
        window=args.window, scale=args.scale, seed=args.seed)


if __name__ == "__main__":
    main()
