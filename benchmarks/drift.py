"""Online re-tuning under workload drift -> BENCH_drift.json.

The drift PR's receipts: on phase-shifting workloads
(:mod:`repro.core.drift`), does ``Study.tune(online=True)`` actually
re-adapt — beating the static default config, approaching the per-phase
static-best oracle, and NEVER thrashing?  Three arms per scenario, all on
the compiled backend with common random numbers so comparisons are paired:

* **default** — the engine's default config runs the whole drifting trace
  unchanged (what you get with no tuning at all);
* **online** — the sliding-window online tuner
  (:class:`~repro.core.tune_online.OnlineTuner`): windowed CRN candidate
  batches, histogram/residual phase-change detection, warm-restarted SMAC,
  hysteresis/dwell switch guard;
* **oracle** — per-phase static-best: at each TRUE phase boundary (the
  oracle knows the spec), a fresh SMAC searches that phase from the
  oracle's own system state and the single best config runs the phase.
  This is the information-unfair lower bound the online tuner is graded
  against.

Scenarios: ``hotspot`` (gups hot-set rotation, 3 phases x 20 epochs) and
``splice`` (gups -> silo/ycsb-c wholesale change at epoch 30) — the two
drift families the acceptance gates name.

Reported per scenario (written to ``BENCH_drift.json``, repo root and
``benchmarks/results/``):

* cumulative wall of each arm + the online/default and online/oracle
  ratios.  The oracle comparison is gated on the STEADY-STATE ratio
  (windows past the cold-start window 0): the oracle deploys a tuned
  config from epoch 0, which no online method can match before its first
  measurement, so the cold-start window is reported in the raw ratio but
  excluded from the gate (gates: online < default;
  steady-state online <= ``ORACLE_SLACK`` x oracle);
* **time-to-readapt**: per true switch, how many windows until the online
  arm's deployed window wall is back within 10% of the oracle's for the
  same window (gate: re-adapts within ``READAPT_WINDOWS`` windows);
* switch/detection/guard receipts with the zero-thrash assertion
  (``thrash_events == 0`` — the hysteresis/dwell guard makes config
  oscillation structurally impossible; this gate pins it).

Usage::

    PYTHONPATH=src python -m benchmarks.drift [--smoke|--quick]
        [--scale S] [--seed N] [--window W] [--batch Q]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import ExperimentSpec, SimOptions, Study  # noqa: E402
from repro.core import engine_jax  # noqa: E402
from repro.core.bo.smac import SMACOptimizer  # noqa: E402
from repro.core.drift import BUILTIN_DRIFTS  # noqa: E402
from repro.core.knobs import get_space  # noqa: E402
from repro.core.simulator import run_simulation_segment  # noqa: E402

from .common import claim, print_claims, save  # noqa: E402

#: acceptance slack: online cumulative wall vs the per-phase oracle's
ORACLE_SLACK = 1.6
#: acceptance bound on windows-to-readapt after a true phase switch
READAPT_WINDOWS = 3
#: "re-adapted" = deployed window wall within 10% of the oracle's window
READAPT_TOL = 1.10

SCENARIOS = {"hotspot": "drift-hotspot", "splice": "drift-splice"}


def _study(drift_name: str, scale: float, seed: int) -> Study:
    return Study(ExperimentSpec(
        engine="hemem",
        workload=dict(name=drift_name, scale=scale),
        options=SimOptions(seed=seed, backend="jax", crn=True,
                           sampler="sparse")))


def _segment(study: Study, configs, lo, hi, carry, return_carry=True):
    spec, opts = study.spec, study.spec.options
    seg_carry = None if carry is None else \
        engine_jax.broadcast_carry_row(carry, 0, len(configs))
    return run_simulation_segment(
        study.workload(), spec.engine.name, configs, study.machine,
        fast_slow_ratio=spec.fast_slow_ratio, seeds=opts.seed,
        sampler=opts.sampler, fast_capacity_pages=spec.fast_capacity_pages,
        backend="jax", crn=True, exact_select=opts.exact_select,
        epoch_start=lo, epoch_stop=hi, carry=seg_carry,
        return_carry=return_carry)


def default_arm(study: Study) -> np.ndarray:
    """Per-epoch walls of the default config over the whole trace."""
    out = _segment(study, [study.spec.engine.config], 0, None, None,
                   return_carry=False)
    return np.asarray(out["wall_ms"])[:, 0]


def oracle_arm(study: Study, dspec, q: int, rounds: int, seed: int):
    """Per-phase static-best with TRUE switch knowledge (lower bound).

    At each phase boundary the oracle runs ``rounds`` SMAC candidate
    batches of ``q`` over the phase — every batch a paired CRN
    counterfactual from the oracle's own system state — then deploys the
    single best config for the phase.  Returns the composed per-epoch
    walls and the per-phase configs.
    """
    space = get_space(study.spec.engine.name)
    bounds = list(dspec.phase_starts) + [dspec.n_epochs]
    carry, walls, configs = None, [], []
    for i in range(len(dspec.phases)):
        lo, hi = bounds[i], bounds[i + 1]
        opt = SMACOptimizer(space, seed=seed + 7 * i, n_init=q)
        best_cfg, best_val = study.spec.engine.config, float("inf")
        for _ in range(rounds):
            cands = opt.ask_batch(q)
            vals = np.asarray(
                _segment(study, cands, lo, hi, carry,
                         return_carry=False)["wall_ms"]).sum(axis=0)
            opt.tell_batch(cands, vals)
            j = int(np.argmin(vals))
            if float(vals[j]) < best_val:
                best_cfg, best_val = dict(cands[j]), float(vals[j])
        out = _segment(study, [best_cfg], lo, hi, carry)
        carry = out["carry"]
        walls.append(np.asarray(out["wall_ms"])[:, 0])
        configs.append(best_cfg)
    return np.concatenate(walls), configs


def _window_sums(per_epoch: np.ndarray, W: int) -> np.ndarray:
    return np.array([per_epoch[lo:lo + W].sum()
                     for lo in range(0, len(per_epoch), W)])


def readapt_times(online_w, oracle_w, switch_epochs, W):
    """Windows-to-readapt per true switch (None = never within the run)."""
    out = []
    for s in switch_epochs:
        k0 = -(-s // W)  # first window fully past the switch
        t = None
        for k in range(k0, len(online_w)):
            if online_w[k] <= READAPT_TOL * oracle_w[k]:
                t = k - k0
                break
        out.append(t)
    return out


def run_scenario(name: str, scale: float, seed: int, W: int, q: int,
                 budget: int, oracle_rounds: int, verbose: bool):
    dspec = BUILTIN_DRIFTS[SCENARIOS[name]]
    study = _study(dspec.name, scale, seed)
    print(f"== {name}: {dspec.name} n_epochs={dspec.n_epochs} "
          f"switches={list(dspec.switch_epochs)} scale={scale} "
          f"W={W} q={q} budget={budget}", flush=True)

    t0 = time.time()
    default_pe = default_arm(study)
    res = study.tune(online=True, window_epochs=W, batch_size=q,
                     budget=budget, seed=seed, verbose=verbose)
    oracle_pe, oracle_cfgs = oracle_arm(study, dspec, q, oracle_rounds,
                                        seed)
    wall_s = time.time() - t0

    online_w = res.deployed_walls
    oracle_w = _window_sums(oracle_pe, W)
    default_w = _window_sums(default_pe, W)
    readapt = readapt_times(online_w, oracle_w, dspec.switch_epochs, W)
    totals = {"default": float(default_pe.sum()),
              "online": float(res.total_wall_ms),
              "oracle": float(oracle_pe.sum())}
    out = {
        "scenario": name, "drift": dspec.name,
        "n_epochs": dspec.n_epochs,
        "switch_epochs": list(dspec.switch_epochs),
        "scale": scale, "seed": seed, "window_epochs": W, "q": q,
        "budget": budget, "oracle_rounds": oracle_rounds,
        "totals_ms": totals,
        "online_vs_default": totals["online"] / totals["default"],
        "online_vs_oracle": totals["online"] / totals["oracle"],
        # steady state: drop window 0 from both arms (cold start — the
        # oracle is pre-tuned at epoch 0, the online arm cannot be)
        "online_vs_oracle_steady":
            float(online_w[1:].sum() / oracle_w[1:].sum()),
        "readapt_windows": readapt,
        "switches": res.switches, "detections": res.detections,
        "guard_blocks": res.guard_blocks,
        "thrash_events": res.thrash_events,
        "evals_used": res.evals_used,
        "window_walls_ms": {"online": online_w.tolist(),
                            "oracle": oracle_w.tolist(),
                            "default": default_w.tolist()},
        "oracle_configs": oracle_cfgs,
        "final_config": res.final_config,
        "wall_s": wall_s,
    }
    print(f"   totals (ms): default={totals['default']:.0f} "
          f"online={totals['online']:.0f} oracle={totals['oracle']:.0f}  "
          f"readapt={readapt}  switches={res.switches} "
          f"thrash={res.thrash_events}  [{wall_s:.1f}s]", flush=True)
    return out


def run(smoke: bool = False, quick: bool = False, scale=None, seed: int = 0,
        window=None, batch=None, verbose: bool = False):
    if smoke:
        scale = scale or 0.03
        W, q, budget, rounds = window or 10, batch or 3, 18, 1
    elif quick:
        scale = scale or 0.04
        W, q, budget, rounds = window or 10, batch or 4, 24, 2
    else:
        scale = scale or 0.06
        W, q, budget, rounds = window or 10, batch or 6, 36, 4

    scenarios = [run_scenario(n, scale, seed, W, q, budget, rounds,
                              verbose) for n in SCENARIOS]

    claims = []
    for s in scenarios:
        nm = s["scenario"]
        claims.append(claim(
            f"{nm}: zero config thrashing",
            s["thrash_events"] == 0,
            f"thrash_events = {s['thrash_events']}, "
            f"guard_blocks = {s['guard_blocks']}"))
        claims.append(claim(
            f"{nm}: receipts complete",
            bool(s["window_walls_ms"]["online"])
            and s["detections"] >= len(s["switch_epochs"]),
            f"{len(s['window_walls_ms']['online'])} windows, "
            f"{s['detections']} detections for "
            f"{len(s['switch_epochs'])} true switches"))
        if not smoke:  # perf gates need the non-smoke budgets
            claims.append(claim(
                f"{nm}: online beats default",
                s["online_vs_default"] < 1.0,
                f"online/default = {s['online_vs_default']:.3f}"))
            claims.append(claim(
                f"{nm}: online approaches per-phase oracle (steady state)",
                s["online_vs_oracle_steady"] <= ORACLE_SLACK,
                f"steady online/oracle = "
                f"{s['online_vs_oracle_steady']:.3f} (slack {ORACLE_SLACK};"
                f" raw incl. cold start = {s['online_vs_oracle']:.3f})"))
            claims.append(claim(
                f"{nm}: re-adapts within {READAPT_WINDOWS} windows",
                all(t is not None and t <= READAPT_WINDOWS
                    for t in s["readapt_windows"]),
                f"readapt = {s['readapt_windows']}"))
    print_claims(claims)

    out = {"mode": "smoke" if smoke else ("quick" if quick else "full"),
           "scenarios": scenarios,
           "claims": claims,
           "ok": all(ok for _, ok, _ in claims)}
    save("BENCH_drift", out)
    root = os.path.join(os.path.dirname(__file__), "..", "BENCH_drift.json")
    with open(root, "w") as f:
        json.dump(out, f, indent=2, default=float)
    return out


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny wiring check (CI): no perf gates")
    p.add_argument("--quick", action="store_true",
                   help="reduced budgets, perf gates active")
    p.add_argument("--scale", type=float, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--window", type=int, default=None)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--verbose", action="store_true")
    args = p.parse_args()
    out = run(smoke=args.smoke, quick=args.quick, scale=args.scale,
              seed=args.seed, window=args.window, batch=args.batch,
              verbose=args.verbose)
    raise SystemExit(0 if out["ok"] else 1)


if __name__ == "__main__":
    main()
