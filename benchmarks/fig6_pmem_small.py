"""Fig. 6 — the same tuning experiments on pmem-small (fewer threads,
smaller DRAM bandwidth).

Paper claims: results are very similar to pmem-large — gains persist when
switching to different hardware.
"""

from __future__ import annotations

from repro.core.simulator import Scenario
from repro.core.bo.tuner import tune_scenario

from .common import SUITE, budget, claim, print_claims, save


def run(quick: bool = False) -> dict:
    out = {"workloads": {}}
    claims = []
    imps = {}
    suite = SUITE if not quick else SUITE[3:]
    for wname, inp in suite:
        sc = Scenario(wname, inp, machine="pmem-small", threads=4)
        res = tune_scenario("hemem", sc, budget=budget(quick), seed=7)
        imps[sc.key] = res.improvement
        out["workloads"][sc.key] = {
            "default_s": res.default_value, "best_s": res.best_value,
            "improvement": res.improvement,
        }
        print(f"  {sc.key:34s} {res.improvement:.2f}x", flush=True)
    non_g500 = {k: v for k, v in imps.items() if not k.startswith("graph500")}
    claims.append(claim(
        "fig6: gains persist on pmem-small for most workloads",
        sum(v >= 1.05 for v in non_g500.values()) >= len(non_g500) - 1,
        ", ".join(f"{k.split('@')[0]}={v:.2f}x" for k, v in imps.items())))
    out["claims"] = claims
    print_claims(claims)
    save("fig6_pmem_small", out)
    return out


if __name__ == "__main__":
    run()
