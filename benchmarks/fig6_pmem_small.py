"""Fig. 6 — the same tuning experiments on pmem-small (fewer threads,
smaller DRAM bandwidth).

Paper claims: results are very similar to pmem-large — gains persist when
switching to different hardware.

Ported to the typed Study API (continuing the PR 3 migration): one
``ExperimentSpec`` per workload on the pmem-small machine profile, tuned
with batched SMAC rounds (``batch_size=4``, process-pool sharded) instead
of the deprecated ``Scenario``/``tune_scenario`` shims.  Result payloads
embed the replayable spec.
"""

from __future__ import annotations

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec

from .common import SUITE, budget, claim, print_claims, save

BATCH_SIZE = 4


def run(quick: bool = False) -> dict:
    out = {"workloads": {}}
    claims = []
    imps = {}
    suite = SUITE if not quick else SUITE[3:]
    for wname, inp in suite:
        study = Study(ExperimentSpec(
            engine="hemem",
            workload=WorkloadSpec(wname, inp, threads=4),
            machine="pmem-small",
            options=SimOptions(sampler="sparse", workers="auto")))
        res = study.tune(budget=budget(quick), batch_size=BATCH_SIZE, seed=7)
        imps[study.key] = res.improvement
        out["workloads"][study.key] = {
            "spec": study.spec.to_dict(),
            "default_s": res.default_value, "best_s": res.best_value,
            "improvement": res.improvement,
        }
        print(f"  {study.key:34s} {res.improvement:.2f}x", flush=True)
    non_g500 = {k: v for k, v in imps.items() if "graph500" not in k}
    claims.append(claim(
        "fig6: gains persist on pmem-small for most workloads",
        sum(v >= 1.05 for v in non_g500.values()) >= len(non_g500) - 1,
        ", ".join(f"{k.split('@')[0]}={v:.2f}x" for k, v in imps.items())))
    out["claims"] = claims
    print_claims(claims)
    save("fig6_pmem_small", out)
    return out


if __name__ == "__main__":
    run()
