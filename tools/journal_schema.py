"""Validate a tune-service study journal (JSON-lines) standalone.

Checks the structural invariants the deterministic control loop guarantees
(see ``repro.core.tune_service.journal`` for the event vocabulary):

* line 1 is a ``study`` header with a known schema ``version``;
* every event carries its required fields with the right types;
* ``ask`` precedes any ``eval``/``fail``/``rung``/``tell`` for a trial,
  and trial indices are asked densely in order (0, 1, 2, ...);
* per trial, committed ``eval`` epochs are strictly increasing and every
  later segment follows a ``promote`` decision;
* a trial journals at most one terminal path (``fail`` excludes ``tell``);
* at most one ``default`` and one ``done`` event, in their legal spots;
* (version 2) fleet lease lifecycles are well-formed per work unit:
  ``lease`` opens at attempt 0, each ``expire`` names the unit's current
  attempt, each ``reissue`` increments it, and deadlines are heartbeat
  counts (wall-clock-free);
* (version 2) ``retry`` attempts per trial count 1, 2, ... and only a
  non-terminal trial retries;
* (version 3) ``reject`` and ``reconnect`` reference an open lease at its
  current attempt (a reject precedes that attempt's ``expire``; a
  reconnect re-attaches the still-live lease);
* **unknown event types FAIL validation** — a journal written by newer
  code must not silently pass an older validator.

Usage::

    python tools/journal_schema.py STUDY.jsonl [...]

Exit status 0 when every journal validates; 1 otherwise (problems are
listed per file).  A truncated final line (the study was SIGKILLed
mid-append) is tolerated, matching resume semantics.
"""

import json
import sys

#: required fields (name -> type) per event type
EVENT_FIELDS = {
    "study": {"version": int, "spec": dict, "budget": int, "slots": int,
              "rung_epochs": list, "optimizer": str, "opt_seed": int},
    "default": {"value": float},
    "ask": {"trial": int, "group": int, "config": dict},
    "eval": {"trial": int, "epochs": int, "value": float},
    "rung": {"trial": int, "rung": int, "decision": str},
    "fail": {"trial": int, "epochs": int, "error": str},
    "tell": {"trial": int, "group": int, "value": float},
    "done": {"best_trial": int, "best_value": float},
    # version 2: bounded trial retries + fleet lease lifecycles
    "retry": {"trial": int, "attempt": int, "epochs": int, "error": str},
    "lease": {"unit": int, "attempt": int, "deadline": int},
    "expire": {"unit": int, "attempt": int, "reason": str},
    "reissue": {"unit": int, "attempt": int},
    # version 3: socket-transport lease events (an invalid frame killing
    # a live lease; a reconnected worker re-attaching one)
    "reject": {"unit": int, "attempt": int, "reason": str},
    "reconnect": {"unit": int, "attempt": int},
}
KNOWN_VERSIONS = (1, 2, 3)


def validate_events(events):
    """Validate parsed journal events; returns a list of problem strings
    (empty == valid)."""
    problems = []

    def bad(i, msg):
        problems.append(f"event {i}: {msg}")

    if not events:
        return ["journal is empty"]
    asked = set()
    epochs_seen = {}        # trial -> last committed eval epochs
    promoted = {}           # trial -> pending promote decisions
    terminal = {}           # trial -> "fail" | "tell"
    retries = {}            # trial -> retry attempts journaled
    lease_attempt = {}      # unit -> current lease attempt
    n_default = n_done = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "event" not in ev:
            bad(i, "not an object with an 'event' field")
            continue
        kind = ev["event"]
        fields = EVENT_FIELDS.get(kind)
        if fields is None:
            # FAIL, never skip: a journal from newer code must not pass
            bad(i, f"unknown event type {kind!r}")
            continue
        for name, typ in fields.items():
            if name not in ev:
                bad(i, f"{kind!r} missing required field {name!r}")
            elif typ is float:
                if not isinstance(ev[name], (int, float)) \
                        or isinstance(ev[name], bool):
                    bad(i, f"{kind}.{name} is not a number")
            elif not isinstance(ev[name], typ) or isinstance(ev[name], bool):
                bad(i, f"{kind}.{name} is not {typ.__name__}")
        if any(p.startswith(f"event {i}:") for p in problems):
            continue
        if i == 0 and kind != "study":
            bad(i, f"journal must start with a 'study' header, got {kind!r}")
        if kind == "study":
            if i != 0:
                bad(i, "'study' header after the first line")
            elif ev["version"] not in KNOWN_VERSIONS:
                bad(i, f"unknown schema version {ev['version']}")
        elif kind == "default":
            n_default += 1
            if n_default > 1:
                bad(i, "more than one 'default' event")
        elif kind == "done":
            n_done += 1
            if n_done > 1:
                bad(i, "more than one 'done' event")
            elif i != len(events) - 1:
                bad(i, "'done' is not the final event")
        elif kind == "ask":
            if ev["trial"] != len(asked):
                bad(i, f"trial {ev['trial']} asked out of order "
                       f"(expected {len(asked)})")
            asked.add(ev["trial"])
        elif kind == "lease":
            u = ev["unit"]
            if u in lease_attempt:
                bad(i, f"unit {u} leased twice")
            elif ev["attempt"] != 0:
                bad(i, f"unit {u} lease opens at attempt {ev['attempt']}, "
                       f"expected 0")
            lease_attempt[u] = 0
        elif kind == "expire":
            u = ev["unit"]
            if u not in lease_attempt:
                bad(i, f"'expire' for unit {u} with no 'lease'")
            elif ev["attempt"] != lease_attempt[u]:
                bad(i, f"unit {u} expired at attempt {ev['attempt']}, "
                       f"current is {lease_attempt[u]}")
        elif kind == "reissue":
            u = ev["unit"]
            if u not in lease_attempt:
                bad(i, f"'reissue' for unit {u} with no 'lease'")
            elif ev["attempt"] != lease_attempt[u] + 1:
                bad(i, f"unit {u} reissued as attempt {ev['attempt']}, "
                       f"expected {lease_attempt[u] + 1}")
            else:
                lease_attempt[u] = ev["attempt"]
        elif kind in ("reject", "reconnect"):
            # version 3: both reference the unit's CURRENT lease attempt
            # (a reject is followed by that attempt's expire; a reconnect
            # re-attaches the still-live lease)
            u = ev["unit"]
            if u not in lease_attempt:
                bad(i, f"{kind!r} for unit {u} with no 'lease'")
            elif ev["attempt"] != lease_attempt[u]:
                bad(i, f"unit {u} {kind} at attempt {ev['attempt']}, "
                       f"current is {lease_attempt[u]}")
        elif kind == "retry":
            t = ev["trial"]
            if t not in asked:
                bad(i, f"'retry' for trial {t} before its 'ask'")
            elif t in terminal:
                bad(i, f"'retry' for trial {t} after terminal "
                       f"{terminal[t]!r}")
            elif ev["attempt"] != retries.get(t, 0) + 1:
                bad(i, f"trial {t} retry attempt {ev['attempt']}, "
                       f"expected {retries.get(t, 0) + 1}")
            else:
                retries[t] = ev["attempt"]
        else:  # eval / rung / fail / tell
            t = ev["trial"]
            if t not in asked:
                bad(i, f"{kind!r} for trial {t} before its 'ask'")
                continue
            if t in terminal:
                bad(i, f"{kind!r} for trial {t} after terminal "
                       f"{terminal[t]!r}")
                continue
            if kind == "eval":
                last = epochs_seen.get(t)
                if last is not None:
                    if ev["epochs"] <= last:
                        bad(i, f"trial {t} eval epochs {ev['epochs']} not "
                               f"> previous {last}")
                    if not promoted.get(t):
                        bad(i, f"trial {t} re-evaluated without a "
                               f"'promote' decision")
                    else:
                        promoted[t] -= 1
                epochs_seen[t] = ev["epochs"]
            elif kind == "rung":
                if ev["decision"] not in ("promote", "stop"):
                    bad(i, f"unknown rung decision {ev['decision']!r}")
                elif ev["decision"] == "promote":
                    promoted[t] = promoted.get(t, 0) + 1
            elif kind == "fail":
                terminal[t] = "fail"
            elif kind == "tell":
                if t not in epochs_seen:
                    bad(i, f"'tell' for trial {t} with no committed eval")
                terminal[t] = "tell"
    return problems


def validate_file(path):
    """Parse + validate one journal file; returns problem strings."""
    events = []
    with open(path, "r", encoding="utf-8") as fh:
        lines = fh.read().split("\n")
    tail_ok = lines and lines[-1] == ""
    body = lines[:-1] if lines else []
    for i, line in enumerate(body):
        try:
            events.append(json.loads(line))
        except ValueError:
            if i == len(body) - 1 and not tail_ok:
                break  # torn final write (SIGKILL): tolerated
            return [f"line {i + 1}: invalid JSON"]
    return validate_events(events)


def main(argv):
    if not argv:
        print(__doc__)
        return 2
    status = 0
    for path in argv:
        problems = validate_file(path)
        if problems:
            status = 1
            print(f"{path}: INVALID")
            for p in problems:
                print(f"  {p}")
        else:
            print(f"{path}: ok")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
