#!/usr/bin/env python
"""Bring up a tune-service worker fleet from ONE frozen FleetSpec.

The spec file (see :class:`repro.core.tune_service.FleetSpec`) is the
whole hand-off between the coordinator host and the worker hosts: bind
address, shared auth key, worker count / host list, heartbeat + lease
parameters and the transport caps.  This tool turns it into running
workers:

initialize a spec (mints a fresh 32-byte auth key, picks a free port)::

    python tools/fleet_launch.py --init fleet.json --workers 4

start the coordinator against it (any host that can reach the workers)::

    Study(spec).tune(executor="fleet", scheduler="asha",
                     fleet_spec=FleetSpec.load("fleet.json"),
                     journal="study.jsonl")

bring up the workers:

* **local mode** (``hosts`` empty in the spec): spawns ``workers`` local
  subprocesses of ``python -m repro.core.tune_service.worker``, passes
  the auth key via the ``REPRO_FLEET_KEY`` environment variable (argv is
  visible in ``ps``; the key must not be), health-checks every greet by
  watching worker stdout for the ``worker N greeted`` announce line, and
  tears the fleet down cleanly (SIGTERM, then SIGKILL) on exit or
  Ctrl-C::

      python tools/fleet_launch.py fleet.json

* **remote mode** (``hosts`` listed, or ``--print``): prints one ready-
  to-run command per host — run each on its host; the workers re-dial
  with backoff until the coordinator is up, and reconnect if the link
  drops::

      python tools/fleet_launch.py fleet.json --print

The spec file contains the fleet's shared secret: keep it out of version
control and world-readable paths (``--init`` writes it ``0600``).
"""

import argparse
import os
import queue
import shlex
import signal
import socket
import subprocess
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.tune_service.transport import FleetSpec  # noqa: E402
from repro.core.tune_service.worker import KEY_ENV  # noqa: E402

GREETED = "greeted"


def _free_port(host: str) -> int:
    with socket.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def worker_command(spec_path: str, worker_id: int,
                   python: str = "python") -> str:
    """The per-host worker invocation (the auth key travels via the spec
    file / ``REPRO_FLEET_KEY``, never argv)."""
    return (f"{python} -m repro.core.tune_service.worker "
            f"--fleet-spec {shlex.quote(spec_path)} --id {worker_id}")


class LocalFleet:
    """``spec.workers`` locally-spawned socket workers, health-checked by
    their greet announces and torn down cleanly.  Context-manageable."""

    def __init__(self, spec: FleetSpec, spec_path: str):
        self.spec = spec
        self.spec_path = spec_path
        self._lines: "queue.Queue[str]" = queue.Queue()
        self.greeted: set = set()
        env = dict(os.environ, **{KEY_ENV: spec.auth_key})
        src = os.path.abspath(os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "..", "src"))
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        self.procs = []
        for i in range(spec.workers):
            p = subprocess.Popen(
                [sys.executable, "-m", "repro.core.tune_service.worker",
                 "--fleet-spec", spec_path, "--id", str(i)],
                stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                text=True, env=env)
            self.procs.append(p)
            threading.Thread(target=self._pump, args=(p,),
                             daemon=True).start()

    def _pump(self, p) -> None:
        for line in p.stdout:
            self._lines.put(line.rstrip())

    def wait_greeted(self, timeout_s: float = 60.0,
                     echo: bool = False) -> bool:
        """Health-check: every worker presented its signed greet and was
        welcomed (requires the coordinator to be up — workers re-dial
        with backoff until it is)."""
        deadline = time.monotonic() + timeout_s
        while len(self.greeted) < self.spec.workers:
            try:
                line = self._lines.get(
                    timeout=max(0.01, deadline - time.monotonic()))
            except queue.Empty:
                return False
            if echo:
                print(f"  {line}", flush=True)
            if GREETED in line:
                try:
                    self.greeted.add(int(line.split()[1]))
                except (IndexError, ValueError):
                    pass
            if time.monotonic() > deadline:
                return False
        return True

    @property
    def alive(self) -> int:
        return sum(1 for p in self.procs if p.poll() is None)

    def join(self, timeout_s: float) -> None:
        deadline = time.monotonic() + timeout_s
        for p in self.procs:
            try:
                p.wait(timeout=max(0.0, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                pass

    def terminate(self) -> None:
        for p in self.procs:
            if p.poll() is None:
                p.send_signal(signal.SIGTERM)
        self.join(2.0)
        for p in self.procs:
            if p.poll() is None:
                p.kill()
        for p in self.procs:
            try:
                p.wait(timeout=1.0)
            except subprocess.TimeoutExpired:
                pass
            if p.stdout is not None:
                p.stdout.close()

    def __enter__(self) -> "LocalFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.terminate()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("spec", metavar="SPEC.json", help="fleet spec file")
    ap.add_argument("--init", action="store_true",
                    help="write a fresh spec (new auth key, free port) "
                         "instead of launching")
    ap.add_argument("--workers", type=int, default=2,
                    help="worker count for --init")
    ap.add_argument("--host", default="127.0.0.1",
                    help="coordinator bind host for --init")
    ap.add_argument("--port", type=int, default=None,
                    help="coordinator port for --init (default: pick a "
                         "free one)")
    ap.add_argument("--hosts", default=None,
                    help="comma-separated worker hosts for --init "
                         "(remote mode; one per worker)")
    ap.add_argument("--heartbeat", type=float, default=None,
                    help="heartbeat cadence for --init")
    ap.add_argument("--print", dest="print_only", action="store_true",
                    help="print per-host worker commands, launch nothing")
    ap.add_argument("--greet-timeout", type=float, default=60.0,
                    help="seconds to wait for every worker's greet")
    args = ap.parse_args(argv)

    if args.init:
        kw = {"workers": args.workers, "host": args.host,
              "port": args.port if args.port is not None
              else _free_port(args.host)}
        if args.hosts:
            kw["hosts"] = tuple(h.strip() for h in args.hosts.split(","))
        if args.heartbeat is not None:
            kw["heartbeat_s"] = args.heartbeat
        spec = FleetSpec.generate(**kw)
        spec.save(args.spec)
        os.chmod(args.spec, 0o600)  # the spec holds the shared secret
        print(f"wrote {args.spec}: {spec.workers} workers, coordinator "
              f"{spec.host}:{spec.port} (auth key minted; file mode 0600)")
        return 0

    spec = FleetSpec.load(args.spec)
    if spec.port == 0:
        print("spec has port 0 (ephemeral): launched workers could not "
              "find the coordinator; re---init with a fixed port",
              file=sys.stderr)
        return 2

    if args.print_only or spec.external:
        hosts = spec.hosts or ("<worker-host>",) * spec.workers
        print(f"# coordinator: bind {spec.host}:{spec.port} "
              f"(Study.tune(executor='fleet', fleet_spec=...))")
        print(f"# copy {args.spec} to each worker host (mode 0600), then:")
        for i, h in enumerate(hosts):
            print(f"{h}$ {worker_command(args.spec, i)}")
        return 0

    with LocalFleet(spec, args.spec) as fleet:
        print(f"launched {spec.workers} workers -> "
              f"{spec.host}:{spec.port}; waiting for greets "
              f"(the workers re-dial until the coordinator is up)",
              flush=True)
        ok = fleet.wait_greeted(args.greet_timeout, echo=True)
        if not ok and fleet.alive < spec.workers:
            print("some workers exited before greeting (wrong key? "
                  "coordinator unreachable?)", file=sys.stderr)
            return 1
        if ok:
            print(f"all {spec.workers} workers greeted; serving until "
                  f"the coordinator shuts the fleet down (Ctrl-C to "
                  f"stop)", flush=True)
        try:
            while fleet.alive:
                time.sleep(0.25)
        except KeyboardInterrupt:
            pass
    print("fleet torn down")
    return 0


if __name__ == "__main__":
    sys.exit(main())
