"""Calibration harness: default vs random-search best per workload (dev tool)."""
import sys, time
import numpy as np
sys.path.insert(0, "src")
from repro.core.simulator import run_simulation, PMEM_LARGE
from repro.core.workloads import make_workload, PAPER_SUITE
from repro.core.knobs import HEMEM_SPACE

N_RAND = int(sys.argv[1]) if len(sys.argv) > 1 else 80
rng = np.random.default_rng(7)
configs = [HEMEM_SPACE.default_config()] + HEMEM_SPACE.sample_batch(rng, N_RAND)
t0 = time.time()
print(f"{'workload':22s} {'default':>8s} {'best':>8s} {'gain':>6s} {'static':>8s} {'oracle':>8s} best-config-delta")
for name, inp in PAPER_SUITE:
    wl = make_workload(name, inp, threads=12, scale=0.25, seed=0)
    times = []
    for cfg in configs:
        r = run_simulation(wl, "hemem", cfg, PMEM_LARGE, seed=0)
        times.append(r.total_s)
    times = np.array(times)
    best_i = int(times.argmin())
    st = run_simulation(wl, "static", {}, PMEM_LARGE, seed=0).total_s
    orc = run_simulation(wl, "oracle", {}, PMEM_LARGE, seed=0).total_s
    d = {k: v for k, v in configs[best_i].items() if v != configs[0][k]}
    print(f"{wl.key:22s} {times[0]:8.1f} {times.min():8.1f} {times[0]/times.min():5.2f}x {st:8.1f} {orc:8.1f} {d}")
print(f"[{time.time()-t0:.0f}s, {N_RAND} random configs]")
