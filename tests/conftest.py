import os
import sys

# tests must see ONE device (the dry-run sets 512 itself, in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# CI conformance matrix: REPRO_KERNELS_FORCE=pallas|ref pins the kernels
# dispatch (repro.kernels.ops.FORCE) for the whole session, so both paths
# run the full suite on CPU (pallas in interpret mode)
_force = os.environ.get("REPRO_KERNELS_FORCE")
if _force:
    if _force not in ("pallas", "ref"):
        raise ValueError(
            f"REPRO_KERNELS_FORCE must be 'pallas' or 'ref', got {_force!r}")
    from repro.kernels import ops as _kernel_ops
    _kernel_ops.FORCE = _force
