import os
import sys

# tests must see ONE device (the dry-run sets 512 itself, in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# CI conformance matrix: REPRO_KERNELS_FORCE=pallas|ref pins the kernels
# dispatch (repro.kernels.ops.FORCE) for the whole session, so both paths
# run the full suite on CPU (pallas in interpret mode)
_force = os.environ.get("REPRO_KERNELS_FORCE")
if _force:
    if _force not in ("pallas", "ref"):
        raise ValueError(
            f"REPRO_KERNELS_FORCE must be 'pallas' or 'ref', got {_force!r}")
    from repro.kernels import ops as _kernel_ops
    _kernel_ops.FORCE = _force

# CI surrogate matrix: REPRO_SURROGATE_FORCE=reference|fast pins the BO
# forest builder (repro.core.bo.rf.FORCE) for the whole session, so both
# paths run the suite (they must be bit-identical — tests/test_bo.py)
_sforce = os.environ.get("REPRO_SURROGATE_FORCE")
if _sforce:
    if _sforce not in ("reference", "fast"):
        raise ValueError("REPRO_SURROGATE_FORCE must be 'reference' or "
                         f"'fast', got {_sforce!r}")
    from repro.core.bo import rf as _bo_rf
    _bo_rf.FORCE = _sforce
