"""Deprecation shims: every legacy entry point warns AND matches the typed
API numerically.

CI runs this file with ``-W "error:repro.:DeprecationWarning"`` so a shim
that stops warning (or a new-API path that starts warning) fails loudly;
every intentional legacy call below is wrapped in ``pytest.warns``.
"""

import numpy as np
import pytest

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.pages import TierState

SCALE = 0.02


def _study(engine="hemem", **opts):
    return Study(ExperimentSpec(engine=engine,
                                workload=WorkloadSpec("gups", scale=SCALE),
                                options=SimOptions(**opts)))


def test_evaluate_warns_and_matches():
    from repro.core.simulator import evaluate
    with pytest.warns(DeprecationWarning, match="repro.core.simulator"):
        legacy = evaluate("hemem", None, "gups", scale=SCALE, seed=4)
    assert legacy == _study(seed=4).run().total_s


def test_evaluate_batch_warns_and_matches():
    from repro.core.knobs import HEMEM_SPACE
    from repro.core.simulator import evaluate_batch
    cfgs = [HEMEM_SPACE.default_config(),
            HEMEM_SPACE.validate({"migration_period": 100})]
    with pytest.warns(DeprecationWarning, match="evaluate_batch"):
        legacy = evaluate_batch("hemem", cfgs, "gups", scale=SCALE, seed=4)
    new = [r.total_s for r in
           _study(seed=4, sampler="sparse").run(configs=cfgs)]
    assert legacy == new


def test_run_simulation_warns_and_matches():
    from repro.core.simulator import run_simulation
    from repro.core.workloads import make_workload
    wl = make_workload("gups", "", threads=12, scale=SCALE, seed=0)
    with pytest.warns(DeprecationWarning, match="run_simulation"):
        legacy = run_simulation(wl, "static", {}, "pmem-large", seed=0)
    new = Study(ExperimentSpec(
        engine="static", workload=WorkloadSpec("gups", threads=12,
                                               scale=SCALE))).run()
    assert legacy.total_s == new.total_s
    np.testing.assert_array_equal(legacy.epoch_wall_ms, new.epoch_wall_ms)


def test_make_engine_warns_and_builds_wrapper():
    from repro.core.engine import HeMemEngine, make_engine
    from repro.core.knobs import HEMEM_SPACE
    tier = TierState(64, 8)
    with pytest.warns(DeprecationWarning, match="make_engine"):
        eng = make_engine("hemem", HEMEM_SPACE.default_config(), tier)
    assert isinstance(eng, HeMemEngine)
    with pytest.warns(DeprecationWarning), pytest.raises(KeyError):
        make_engine("hemen", {}, TierState(64, 8))


def test_scenario_warns_and_objective_matches():
    from repro.core.simulator import Scenario
    with pytest.warns(DeprecationWarning, match="Scenario"):
        sc = Scenario("gups", "", scale=SCALE, seed=6)
    cfg = _study().spec.engine.config
    assert sc.objective("hemem")(cfg) == _study(seed=6).run().total_s


def test_tune_scenario_warns_and_matches():
    from repro.core.bo.tuner import tune_scenario
    from repro.core.simulator import Scenario
    with pytest.warns(DeprecationWarning):
        sc = Scenario("gups", "", scale=SCALE)
        legacy = tune_scenario("hemem", sc, budget=4, seed=2)
    res = _study().tune(budget=4, seed=2)
    assert [o.value for o in legacy.history] == \
        [o.value for o in res.history]


def test_new_api_does_not_warn():
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        st = _study(seed=1)
        st.run()
        st.tune(budget=2, seed=1)
        st.sweep(engines=["static"])
