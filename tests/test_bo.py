"""Random-forest surrogate + SMAC optimizer unit/property tests."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 environments may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.bo.rf import RandomForest
from repro.core.bo.smac import SMACOptimizer, expected_improvement
from repro.core.knobs import HEMEM_SPACE, Knob, KnobSpace


def test_rf_fits_simple_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(200, 4))
    y = 3 * X[:, 0] + np.sin(6 * X[:, 1]) + 0.05 * rng.normal(size=200)
    rf = RandomForest(seed=1).fit(X, y)
    Xt = rng.uniform(size=(100, 4))
    yt = 3 * Xt[:, 0] + np.sin(6 * Xt[:, 1])
    pred, std = rf.predict(Xt)
    rmse = float(np.sqrt(np.mean((pred - yt) ** 2)))
    assert rmse < 0.5, rmse
    assert (std >= 0).all()


def test_rf_bootstrap_disagreement_gives_positive_std():
    """Across-tree spread (the EI uncertainty source) is non-degenerate on
    noisy data and shrinks as the target gets cleaner."""
    rng = np.random.default_rng(1)
    X = rng.uniform(size=(150, 3))
    y_noisy = X[:, 0] + rng.normal(0, 0.5, size=150)
    y_clean = X[:, 0] + rng.normal(0, 0.01, size=150)
    Xt = rng.uniform(size=(64, 3))
    _, std_noisy = RandomForest(seed=2).fit(X, y_noisy).predict(Xt)
    _, std_clean = RandomForest(seed=2).fit(X, y_clean).predict(Xt)
    assert std_noisy.mean() > 0
    assert std_noisy.mean() > std_clean.mean()


def test_expected_improvement_properties():
    mean = np.array([1.0, 1.0, 0.5])
    std = np.array([0.1, 1.0, 0.1])
    ei = expected_improvement(mean, std, best=1.0)
    assert ei[1] > ei[0]           # more uncertainty -> more EI at same mean
    assert ei[2] > ei[0]           # better mean -> more EI
    assert (ei >= 0).all()


def test_smac_minimizes_synthetic_knob_function():
    space = KnobSpace([
        Knob("a", 10, 1, 100, is_int=True),
        Knob("b", 500, 10, 5000, is_int=True, log=True),
        Knob("c", 5, 1, 10, is_int=True),
    ])

    def f(cfg):
        # optimum near a=70, b=100, c irrelevant
        return ((cfg["a"] - 70) / 100) ** 2 + \
            (np.log(cfg["b"] / 100)) ** 2 * 0.1
    opt = SMACOptimizer(space, seed=3, n_init=8)
    best = opt.minimize(f, budget=40)
    assert f(space.default_config()) > best.value
    assert abs(best.config["a"] - 70) < 25


def test_smac_starts_with_default():
    opt = SMACOptimizer(HEMEM_SPACE, seed=0)
    first = opt.ask()
    assert first == HEMEM_SPACE.default_config()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_ask_always_in_domain(seed):
    opt = SMACOptimizer(HEMEM_SPACE, seed=seed, n_init=3, n_candidates=32)
    rng = np.random.default_rng(seed)
    for i in range(8):
        cfg = opt.ask()
        for k in HEMEM_SPACE:
            assert k.lo <= cfg[k.name] <= k.hi
        opt.tell(cfg, float(rng.uniform(10, 100)))
