"""Random-forest surrogate + SMAC optimizer unit/property tests.

PR 5 additions: reference-vs-fast forest parity (bit-identical trees and
predictions under the shared randomness protocol), suggestion-history
regression under both surrogate paths, vectorized-erf agreement, and
``select_topk``-vs-argsort top-q-EI selection equivalence.
"""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 environments may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.bo import forest_fast
from repro.core.bo.rf import RandomForest
from repro.core.bo.smac import (SMACOptimizer, _norm_cdf, _norm_cdf_ref,
                                expected_improvement,
                                expected_improvement_ref)
from repro.core.knobs import HEMEM_SPACE, Knob, KnobSpace

try:
    import jax  # noqa: F401

    HAS_JAX = True
except ImportError:
    HAS_JAX = False


def test_rf_fits_simple_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(200, 4))
    y = 3 * X[:, 0] + np.sin(6 * X[:, 1]) + 0.05 * rng.normal(size=200)
    rf = RandomForest(seed=1).fit(X, y)
    Xt = rng.uniform(size=(100, 4))
    yt = 3 * Xt[:, 0] + np.sin(6 * Xt[:, 1])
    pred, std = rf.predict(Xt)
    rmse = float(np.sqrt(np.mean((pred - yt) ** 2)))
    assert rmse < 0.5, rmse
    assert (std >= 0).all()


def test_rf_bootstrap_disagreement_gives_positive_std():
    """Across-tree spread (the EI uncertainty source) is non-degenerate on
    noisy data and shrinks as the target gets cleaner."""
    rng = np.random.default_rng(1)
    X = rng.uniform(size=(150, 3))
    y_noisy = X[:, 0] + rng.normal(0, 0.5, size=150)
    y_clean = X[:, 0] + rng.normal(0, 0.01, size=150)
    Xt = rng.uniform(size=(64, 3))
    _, std_noisy = RandomForest(seed=2).fit(X, y_noisy).predict(Xt)
    _, std_clean = RandomForest(seed=2).fit(X, y_clean).predict(Xt)
    assert std_noisy.mean() > 0
    assert std_noisy.mean() > std_clean.mean()


def test_expected_improvement_properties():
    mean = np.array([1.0, 1.0, 0.5])
    std = np.array([0.1, 1.0, 0.1])
    ei = expected_improvement(mean, std, best=1.0)
    assert ei[1] > ei[0]           # more uncertainty -> more EI at same mean
    assert ei[2] > ei[0]           # better mean -> more EI
    assert (ei >= 0).all()


def test_smac_minimizes_synthetic_knob_function():
    space = KnobSpace([
        Knob("a", 10, 1, 100, is_int=True),
        Knob("b", 500, 10, 5000, is_int=True, log=True),
        Knob("c", 5, 1, 10, is_int=True),
    ])

    def f(cfg):
        # optimum near a=70, b=100, c irrelevant
        return ((cfg["a"] - 70) / 100) ** 2 + \
            (np.log(cfg["b"] / 100)) ** 2 * 0.1
    opt = SMACOptimizer(space, seed=3, n_init=8)
    best = opt.minimize(f, budget=40)
    assert f(space.default_config()) > best.value
    assert abs(best.config["a"] - 70) < 25


def test_smac_starts_with_default():
    opt = SMACOptimizer(HEMEM_SPACE, seed=0)
    first = opt.ask()
    assert first == HEMEM_SPACE.default_config()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_ask_always_in_domain(seed):
    opt = SMACOptimizer(HEMEM_SPACE, seed=seed, n_init=3, n_candidates=32)
    rng = np.random.default_rng(seed)
    for i in range(8):
        cfg = opt.ask()
        for k in HEMEM_SPACE:
            assert k.lo <= cfg[k.name] <= k.hi
        opt.tell(cfg, float(rng.uniform(10, 100)))


# ---------------------------------------------------------------------------
# PR 5: reference-vs-fast forest parity
# ---------------------------------------------------------------------------

_FLAT_FIELDS = ("feature", "threshold", "left", "right", "value", "n_nodes")


def _forest_cases():
    rng = np.random.default_rng(0)
    yield rng.uniform(size=(120, 6)), None
    yield np.tile(rng.uniform(size=(5, 3)), (8, 1)), None       # heavy ties
    yield rng.uniform(size=(40, 2)), np.ones(40)                # constant y
    yield (rng.integers(0, 3, size=(60, 5)) / 2.0,
           rng.normal(size=60))                                 # grid X
    yield rng.uniform(size=(4, 8)), None                        # tiny n


def test_reference_fast_forest_parity_bit_identical():
    """Both builders produce IDENTICAL flat trees and predictions given
    identical RNG streams (the PR 5 acceptance contract)."""
    for i, (X, y) in enumerate(_forest_cases()):
        if y is None:
            rng = np.random.default_rng(100 + i)
            y = X @ rng.normal(size=X.shape[1]) + 0.1 * rng.normal(
                size=len(X))
        ref = RandomForest(seed=i, mode="reference").fit(X, y)
        fast = RandomForest(seed=i, mode="fast").fit(X, y)
        for f in _FLAT_FIELDS:
            assert np.array_equal(getattr(ref.forest, f),
                                  getattr(fast.forest, f)), (i, f)
        Xt = np.random.default_rng(7).uniform(size=(33, X.shape[1]))
        for a, b in zip(ref.predict(Xt), fast.predict(Xt)):
            assert np.array_equal(a, b)
        for a, b in zip(ref.predict_batch(Xt), fast.predict_batch(Xt)):
            assert np.array_equal(a, b)


def test_flat_descent_matches_per_row_reference_walk():
    rng = np.random.default_rng(3)
    X = rng.uniform(size=(90, 4))
    y = np.sin(5 * X[:, 0]) + X[:, 1] + 0.1 * rng.normal(size=90)
    ref = RandomForest(seed=3, mode="reference").fit(X, y)
    Xt = rng.uniform(size=(40, 4))
    walk = np.stack([t.predict(Xt) for t in ref.trees])
    assert np.array_equal(walk, forest_fast.predict_forest(ref.forest, Xt))


def test_fast_is_the_default_mode():
    from repro.core.bo import rf
    assert rf.DEFAULT_MODE == "fast"
    assert rf.resolve_mode(None) in ("reference", "fast")
    with pytest.raises(ValueError):
        rf.resolve_mode("banana")


# ---------------------------------------------------------------------------
# PR 5: suggestion-history regression under both surrogate paths
# ---------------------------------------------------------------------------


def _history(surrogate, q, budget=32, seed=5, **kwargs):
    def f(cfg):
        return ((cfg["read_hot_threshold"] - 12) ** 2 * 0.1
                + np.log(cfg["migration_period"])
                + cfg["max_migration_rate"] * 0.05)

    opt = SMACOptimizer(HEMEM_SPACE, seed=seed, n_init=6,
                        surrogate=surrogate, **kwargs)
    done = 0
    while done < budget:
        cfgs = opt.ask_batch(min(q, budget - done))
        opt.tell_batch(cfgs, [f(c) for c in cfgs])
        done += len(cfgs)
    return [(tuple(sorted(o.config.items())), o.value)
            for o in opt.observations]


@pytest.mark.parametrize("q", [1, 8])
def test_suggestion_history_identical_reference_vs_fast(q):
    assert _history("reference", q) == _history("fast", q)


@pytest.mark.parametrize("q", [1, 8])
def test_suggestion_history_identical_across_acq_backends(q):
    """The fused acquisition suggests the same configs whether it runs the
    jitted jax path or the numpy fallback on these seeded runs (EI keys
    are f32 with index tie-break on both; the jax path computes in f32 so
    the agreement is within f32 tolerance, not a bitwise guarantee —
    near-ties could in principle resolve differently)."""
    if not HAS_JAX:
        pytest.skip("jax not installed")
    old = forest_fast.BACKEND
    try:
        forest_fast.BACKEND = "numpy"
        h_np = _history("fast", q)
        forest_fast.BACKEND = "jax"
        h_jax = _history("fast", q)
    finally:
        forest_fast.BACKEND = old
    assert h_np == h_jax


def test_legacy_acquisition_still_works_and_stays_in_domain():
    hist = _history(None, 4, budget=16, acquisition="legacy")
    assert len(hist) == 16
    for cfg, _ in hist:
        for k in HEMEM_SPACE:
            assert k.lo <= dict(cfg)[k.name] <= k.hi


# ---------------------------------------------------------------------------
# PR 5: vectorized erf / EI numeric agreement (satellite)
# ---------------------------------------------------------------------------


def test_vectorized_norm_cdf_matches_math_erf():
    z = np.linspace(-8.0, 8.0, 4001)
    exact = 0.5 * (1.0 + np.array([math.erf(v / math.sqrt(2)) for v in z]))
    assert np.abs(_norm_cdf(z) - exact).max() <= 1e-6
    assert np.abs(_norm_cdf_ref(z) - exact).max() <= 1e-12
    # erf itself agrees to 1e-6 too (Abramowitz-Stegun 7.1.26 bound 1.5e-7)
    ez = np.array([math.erf(v) for v in z])
    assert np.abs(forest_fast.erf(z) - ez).max() <= 1e-6


def test_expected_improvement_within_documented_tolerance_of_reference():
    """Documented bound: the A-S erf error (<= 1.5e-7) enters EI scaled by
    |best - mean|, so absolute EI agreement is <= ~5e-6 at O(10) objective
    scales and relative agreement is tight wherever EI is non-negligible."""
    rng = np.random.default_rng(0)
    mean = rng.normal(50, 10, size=512)
    std = np.abs(rng.normal(0, 5, size=512)) + 1e-6
    new = expected_improvement(mean, std, best=45.0)
    ref = expected_improvement_ref(mean, std, best=45.0)
    assert np.abs(new - ref).max() <= 5e-6
    big = ref > 0.1
    assert big.any()
    assert (np.abs(new - ref)[big] / ref[big]).max() <= 1e-4


# ---------------------------------------------------------------------------
# PR 5: top-q-EI selection via select_topk == stable argsort (satellite)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
@pytest.mark.parametrize("q", [1, 5, 40])
def test_topq_ei_select_topk_matches_stable_argsort(q):
    from repro.kernels import ops

    rng = np.random.default_rng(17)
    n = 40
    ei = rng.uniform(0, 1, size=n).astype(np.float32)
    ei[::4] = ei[1]          # force heavy ties
    valid = rng.uniform(size=n) < 0.8
    valid[:2] = True
    mask = np.asarray(ops.topk_mask(ei, q, valid=valid))
    order = np.argsort(-ei, kind="stable")
    expect = [int(i) for i in order if valid[i]][:q]
    assert set(np.flatnonzero(mask)) == set(expect)
    # and the full fused path agrees with the numpy fallback's selection
    X = rng.uniform(size=(64, 4))
    y = X[:, 0] + 0.1 * rng.normal(size=64)
    model = RandomForest(seed=1).fit(X, y)
    pool = rng.uniform(size=(96, 4))
    _, sel_np = forest_fast.suggest_topq(model.forest, pool, float(y.min()),
                                         model._y_mean, model._y_std,
                                         q=6, backend="numpy")
    _, sel_jax = forest_fast.suggest_topq(model.forest, pool, float(y.min()),
                                          model._y_mean, model._y_std,
                                          q=6, backend="jax")
    assert list(sel_np) == list(sel_jax)


# ---------------------------------------------------------------------------
# PR 5: encoded candidate generation (knobs.py satellites)
# ---------------------------------------------------------------------------


def test_quantize_unit_is_encode_decode_fixpoint():
    rng = np.random.default_rng(2)
    U = rng.uniform(size=(64, len(HEMEM_SPACE)))
    Q = HEMEM_SPACE.quantize_unit(U)
    # canonical rows are fixpoints and decode/encode round-trips agree
    assert np.array_equal(HEMEM_SPACE.quantize_unit(Q), Q)
    cfgs = HEMEM_SPACE.decode_batch(Q)
    assert np.allclose(HEMEM_SPACE.encode_batch(cfgs), Q, atol=1e-12)
    for c in cfgs:
        assert c == HEMEM_SPACE.validate(c)


def test_encoded_pool_generators_stay_in_domain():
    rng = np.random.default_rng(4)
    S = HEMEM_SPACE.sample_batch_encoded(rng, 32)
    x = HEMEM_SPACE.encode(HEMEM_SPACE.default_config())
    N = HEMEM_SPACE.neighbors_batch(x, rng, n=16, scale=0.2)
    for rows in (S, N):
        assert rows.shape[1] == len(HEMEM_SPACE)
        assert (rows >= 0).all() and (rows <= 1).all()
        for c in HEMEM_SPACE.decode_batch(rows):
            assert c == HEMEM_SPACE.validate(c)


def test_knob_importance_identical_across_surrogate_modes():
    from repro.core.bo.importance import knob_importance
    from repro.core.bo.smac import Observation

    rng = np.random.default_rng(9)
    obs = []
    for _ in range(40):
        cfg = HEMEM_SPACE.sample(rng)
        obs.append(Observation(cfg, float(np.log(cfg["migration_period"])
                                          + 0.1 * cfg["read_hot_threshold"])))
    a = knob_importance(HEMEM_SPACE, obs, surrogate="reference")
    b = knob_importance(HEMEM_SPACE, obs, surrogate="fast")
    assert a == b
    assert abs(sum(a.values()) - 1.0) < 1e-9
    assert list(a)[0] in ("migration_period", "read_hot_threshold")
