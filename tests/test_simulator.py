"""Simulator property tests: scale invariance, machine ordering, ratios."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 environments may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.knobs import HEMEM_SPACE
from repro.core.simulator import (MACHINES, NUMA, PMEM_LARGE, PMEM_SMALL,
                                  run_simulation, scale_config)
from repro.core.workloads import make_workload


def test_scale_invariance_of_speedup_ratios():
    """The default/tuned ratio should be roughly preserved across sim
    scales (the whole point of the scaled evaluation)."""
    tuned = HEMEM_SPACE.validate(dict(read_hot_threshold=30,
                                      write_hot_threshold=30))
    ratios = []
    for scale in (0.2, 0.35):
        wl = make_workload("gapbs-pr", "kron", threads=12, scale=scale)
        d = run_simulation(wl, "hemem", None, PMEM_LARGE, seed=0).total_s
        t = run_simulation(wl, "hemem", tuned, PMEM_LARGE, seed=0).total_s
        ratios.append(d / t)
    assert abs(ratios[0] - ratios[1]) / ratios[0] < 0.25, ratios


def test_numa_faster_than_pmem_for_slow_tier_bound_workloads():
    wl = make_workload("gups", "8GiB-hot", threads=12, scale=0.25)
    t_pmem = run_simulation(wl, "static", {}, PMEM_LARGE, seed=0).total_s
    t_numa = run_simulation(wl, "static", {}, NUMA, seed=0).total_s
    assert t_numa < t_pmem   # NUMA's far tier is ~5x faster


def test_bigger_fast_tier_never_hurts_oracle():
    wl = make_workload("silo", "ycsb-c", threads=12, scale=0.25)
    t_small = run_simulation(wl, "oracle", {}, PMEM_LARGE,
                             fast_slow_ratio=16.0, seed=0).total_s
    t_big = run_simulation(wl, "oracle", {}, PMEM_LARGE,
                           fast_slow_ratio=1.0, seed=0).total_s
    assert t_big <= t_small * 1.01


def test_scale_config_scales_page_semantics_only():
    cfg = HEMEM_SPACE.default_config()
    scaled = scale_config("hemem", cfg, 0.25)
    assert scaled["cooling_pages"] == int(cfg["cooling_pages"] * 0.25)
    assert scaled["read_hot_threshold"] == cfg["read_hot_threshold"]
    assert scaled["migration_period"] == cfg["migration_period"]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 20),
       wname=st.sampled_from(["gups", "silo", "xsbench", "graph500"]))
def test_property_simulation_outputs_sane(seed, wname):
    inp = {"gups": "8GiB-hot", "silo": "ycsb-c"}.get(wname, "")
    wl = make_workload(wname, inp, threads=12, scale=0.2, seed=seed)
    r = run_simulation(wl, "hemem", None, PMEM_LARGE, seed=seed)
    assert np.isfinite(r.total_s) and r.total_s > 0
    assert (r.epoch_wall_ms > 0).all()
    assert (np.diff(r.cum_migrations) >= 0).all()
    assert ((r.fast_hit_rate >= 0) & (r.fast_hit_rate <= 1)).all()
