"""Batched evaluation pipeline tests.

The core contract: ``run_simulation_batch`` with B configs produces
per-config results numerically equal to B sequential ``run_simulation``
calls with matched seeds (for both sampling backends), batching/sharding
never changes results, and the batch-SMAC path preserves sequential
semantics at q=1.
"""

import os

import numpy as np
import pytest

from repro.core.bo.rf import RandomForest
from repro.core.bo.smac import RandomSearch, SMACOptimizer
from repro.core.bo.tuner import TuningSession
from repro.core.engine import OracleEngine, make_batch_engine
from repro.core.knobs import HEMEM_SPACE, HMSDK_SPACE, MEMTIS_SPACE, get_space
from repro.core.pages import (BatchTierState, MigrationPlan, TierState,
                              migration_rate_pages)
from repro.core.simulator import (Scenario, run_simulation,
                                  run_simulation_batch)
from repro.core.workloads import make_workload

ALL_ENGINES = ("hemem", "hmsdk", "memtis", "static", "oracle")


def _configs_for(engine, n, seed=5):
    if engine in ("hemem", "hmsdk", "memtis"):
        space = get_space(engine)
        rng = np.random.default_rng(seed)
        return [space.default_config()] + [space.sample(rng)
                                           for _ in range(n - 1)]
    return [{} for _ in range(n)]


def _assert_results_equal(a, b):
    assert a.total_s == b.total_s
    assert np.array_equal(a.epoch_wall_ms, b.epoch_wall_ms)
    assert np.array_equal(a.cum_migrations, b.cum_migrations)
    assert np.array_equal(a.fast_hit_rate, b.fast_hit_rate)
    assert np.array_equal(a.sampling_ms, b.sampling_ms)
    assert np.array_equal(a.stall_ms, b.stall_ms)


@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("sampler", ["sparse", "elementwise"])
def test_batch_equals_sequential(engine, sampler):
    """B batched configs == B sequential runs with matched seeds."""
    wl = make_workload("gups", "8GiB-hot", threads=8, scale=0.04, seed=3)
    cfgs = _configs_for(engine, 3)
    batch = run_simulation_batch(wl, engine, cfgs, "pmem-large", seeds=7,
                                 sampler=sampler)
    for cfg, b in zip(cfgs, batch):
        s = run_simulation(wl, engine, cfg, "pmem-large", seed=7,
                           sampler=sampler)
        _assert_results_equal(b, s)


def test_batch_per_config_seeds():
    """A per-config seed vector matches per-seed sequential runs."""
    wl = make_workload("silo", "ycsb-c", threads=8, scale=0.04, seed=1)
    cfgs = _configs_for("hemem", 3)
    seeds = [11, 12, 13]
    batch = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=seeds)
    for cfg, seed, b in zip(cfgs, seeds, batch):
        s = run_simulation(wl, "hemem", cfg, "pmem-large", seed=seed,
                           sampler="sparse")
        _assert_results_equal(b, s)


def test_batch_sharding_invariance():
    """workers only changes wall time, never results."""
    if (os.cpu_count() or 1) < 2:
        pytest.skip("needs >= 2 CPUs")
    wl = make_workload("gups", "8GiB-hot", threads=8, scale=0.04, seed=2)
    cfgs = _configs_for("hemem", 4)
    one = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=9)
    two = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=9,
                               workers=2)
    for a, b in zip(one, two):
        _assert_results_equal(a, b)


def test_batch_jax_backend_matches_numpy():
    """backend="jax" (the compiled epoch loop) tracks the numpy reference.

    Since PR 3 the jax backend compiles engines + samplers end-to-end with
    counter-based draws, so parity on sampled engines is statistical —
    the strict contract lives in tests/test_jax_backend.py.
    """
    pytest.importorskip("jax")
    wl = make_workload("xsbench", "", threads=8, scale=0.04, seed=4)
    cfgs = _configs_for("hemem", 2)
    a = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=5)
    b = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=5,
                             backend="jax")
    for ra, rb in zip(a, b):
        assert abs(ra.total_s - rb.total_s) / ra.total_s < 0.2


def test_sparse_sampler_distribution():
    """sparse and elementwise sampling agree in distribution (mean/var)."""
    wl = make_workload("gups", "8GiB-hot", threads=8, scale=0.04, seed=0)
    reads, _ = wl.epoch_access(0)
    lam = reads / 5000.0
    from repro.core.engine import sparse_poisson
    rng = np.random.default_rng(0)
    S = np.stack([sparse_poisson(rng, reads, 1.0 / 5000.0)
                  for _ in range(300)])
    # Poisson: mean == var == lam
    hot = lam > 1.0
    assert abs(S[:, hot].mean() - lam[hot].mean()) / lam[hot].mean() < 0.05
    assert abs(S[:, hot].var() - lam[hot].mean()) / lam[hot].mean() < 0.10
    cold = ~hot
    assert abs(S[:, cold].mean() - lam[cold].mean()) / lam[cold].mean() < 0.05


# ---------------------------------------------------------------------------
# Batched tier state
# ---------------------------------------------------------------------------
def test_batch_tier_state_matches_sequential_loop():
    rng = np.random.default_rng(0)
    n, cap, B = 128, 16, 3
    btier = BatchTierState(B, n, cap)
    tiers = [TierState(n, cap) for _ in range(B)]
    for step in range(5):
        touched = rng.uniform(size=n) < 0.4
        counts = btier.allocate_first_touch(touched)
        for b, t in enumerate(tiers):
            assert t.allocate_first_touch(touched) == counts[b]
        plans = []
        for b, t in enumerate(tiers):
            cand = np.flatnonzero(t.allocated & ~t.in_fast)
            k = min(len(cand), t.fast_free, 1 + b)
            promote = cand[:k]
            plans.append(MigrationPlan(promote=promote,
                                       demote=np.zeros(0, np.int64)))
            t.apply(plans[-1])
        btier.apply(plans)
        for b, t in enumerate(tiers):
            assert np.array_equal(btier.in_fast[b], t.in_fast)
            assert btier.total_promoted[b] == t.total_promoted


def test_batch_allocation_mixed_mask_forms():
    """Regression: after a per-row (B, n) allocation diverges the rows, a
    later shared (n,) mask must still allocate on every row (the row-0
    no-new-pages shortcut only applies while rows are provably uniform)."""
    bt = BatchTierState(2, 8, 4)
    per_row = np.zeros((2, 8), bool)
    per_row[0, :4] = True          # row 1 touches nothing
    bt.allocate_first_touch(per_row)
    shared = np.zeros(8, bool)
    shared[:4] = True              # row 0 already has these, row 1 does not
    counts = bt.allocate_first_touch(shared)
    assert counts.tolist() == [0, 4]
    assert bt.allocated[1, :4].all()


def test_tierstate_is_thin_batch_wrapper():
    t = TierState(16, 4)
    assert t.batch_state.batch == 1
    t.allocate_first_touch(np.ones(16, bool))
    assert t.fast_used == 4
    assert t.in_fast is not None and t.in_fast.shape == (16,)
    with pytest.raises(AssertionError):
        t.apply(MigrationPlan(promote=np.array([0]),
                              demote=np.zeros(0, np.int64)))


def test_migration_rate_pages_shared_helper():
    # scalar and vector forms agree and keep int-truncation semantics
    assert migration_rate_pages(10, 500.0, 2 ** 21) == \
        int(10 * 2 ** 30 * 0.5 / 2 ** 21)
    vec = migration_rate_pages(np.array([10.0, 2.0]),
                               np.array([500.0, 500.0]), 2 ** 21)
    assert vec.tolist() == [migration_rate_pages(10.0, 500.0, 2 ** 21),
                            migration_rate_pages(2.0, 500.0, 2 ** 21)]


def test_oracle_promotions_never_exceed_post_demotion_capacity():
    """Regression: with few demotion candidates the oracle must cap its
    promotions at the post-demotion free capacity."""
    tier = TierState(32, 4)
    tier.allocate_first_touch(np.ones(32, bool))
    eng = OracleEngine({}, tier)
    heat = np.arange(32, dtype=float)
    for _ in range(3):
        eng.observe(heat, np.zeros(32), 500.0)
        plan = eng.plan(500.0, 10 ** 6)
        assert len(plan.promote) <= tier.fast_free + len(plan.demote)
        tier.apply(plan)  # would assert on capacity violation
    assert set(np.flatnonzero(tier.in_fast)) == set(range(28, 32))


# ---------------------------------------------------------------------------
# Batched knob encoding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("space", [HEMEM_SPACE, HMSDK_SPACE, MEMTIS_SPACE])
def test_encode_decode_batch_match_scalar(space):
    rng = np.random.default_rng(3)
    cfgs = [space.sample(rng) for _ in range(16)]
    X = space.encode_batch(cfgs)
    assert X.shape == (16, len(space))
    for i, c in enumerate(cfgs):
        assert np.allclose(X[i], space.encode(c), atol=1e-12)
    decoded = space.decode_batch(X)
    for i, row in enumerate(X):
        assert decoded[i] == space.decode(row)


def test_validate_batch_matches_scalar():
    cfgs = [{"sampling_period": 1}, {"sampling_period": 1e9},
            {"read_hot_threshold": 7.6}]
    assert HEMEM_SPACE.validate_batch(cfgs) == \
        [HEMEM_SPACE.validate(c) for c in cfgs]
    with pytest.raises(KeyError):
        HEMEM_SPACE.validate_batch([{"bogus": 1}])


# ---------------------------------------------------------------------------
# Batch-SMAC
# ---------------------------------------------------------------------------
def test_rf_predict_batch_matches_predict():
    rng = np.random.default_rng(0)
    X = rng.uniform(size=(80, 5))
    y = X[:, 0] + np.sin(4 * X[:, 1])
    rf = RandomForest(seed=1).fit(X, y)
    Xt = rng.uniform(size=(64, 5))
    m1, s1 = rf.predict(Xt)
    m2, s2 = rf.predict_batch(Xt)
    assert np.array_equal(m1, m2)
    assert np.array_equal(s1, s2)


def test_ask_batch_q1_is_bit_identical_to_ask():
    a = SMACOptimizer(HEMEM_SPACE, seed=42, n_init=3)
    b = SMACOptimizer(HEMEM_SPACE, seed=42, n_init=3)
    rng = np.random.default_rng(0)
    for _ in range(6):
        ca = a.ask()
        cb = b.ask_batch(1)[0]
        assert ca == cb
        v = float(rng.uniform(1, 10))
        a.tell(ca, v)
        b.tell_batch([cb], [v])


def test_ask_batch_fills_exploration_then_model_slots():
    opt = SMACOptimizer(HEMEM_SPACE, seed=1, n_init=4)
    first = opt.ask_batch(6)
    assert len(first) == 6
    assert first[0] == HEMEM_SPACE.default_config()
    rng = np.random.default_rng(0)
    opt.tell_batch(first, [float(rng.uniform(10, 100)) for _ in first])
    nxt = opt.ask_batch(6)
    assert len(nxt) == 6
    for cfg in nxt:
        for k in HEMEM_SPACE:
            assert k.lo <= cfg[k.name] <= k.hi
    # model-based slots must be distinct suggestions
    keys = [tuple(sorted(c.items())) for c in nxt]
    assert len(set(keys)) > 1


def test_random_search_ask_batch():
    opt = RandomSearch(HEMEM_SPACE, seed=0)
    batch = opt.ask_batch(4)
    assert batch[0] == HEMEM_SPACE.default_config()
    opt.tell_batch(batch, [1.0, 2.0, 3.0, 4.0])
    assert opt.best.value == 1.0
    assert opt.ask_batch(2)[0] != HEMEM_SPACE.default_config() or True
    assert len(opt.observations) == 4


def test_tuning_session_batch_budget_and_history():
    sc = Scenario(workload="gups", input_name="8GiB-hot", scale=0.04)
    session = TuningSession(
        "hemem", sc.objective("hemem"), scenario_key=sc.key, budget=10,
        seed=0, n_init=4, batch_size=4,
        objective_batch=sc.objective_batch("hemem"))
    res = session.run()
    assert len(res.history) == 10
    assert res.best_value <= res.history[0].value
    assert res.default_value > 0
