"""Compiled tiered-KV serving: conformance, traffic, deprecations, cache.

The contract under test (see ``repro.core.serving_jax``):

* the fused compiled decode/engine step is conformant **by construction**
  with the per-page Python reference loop — identical HBM residency sets
  and migration counts, because both modes share ONE jitted engine-decision
  executable and feed it bit-identical integer access counts;
* traffic replay (``repro.core.traffic``) is deterministic in
  ``(spec, seed)`` and JSON-round-trippable;
* the lifted ``kv-hemem`` engine dispatches through the compiled jax
  backend (no numpy-fallback warning);
* ``record_reads`` is the public name (``_record_reads`` is a deprecated
  shim) and is fused — hence unavailable — on the compiled path.
"""

import logging
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import ExperimentSpec, SimOptions, Study
from repro.core.workloads import make_workload
from repro.core.knobs import HEMEM_SPACE
from repro.core.tiered_kv import KVSpec, TieredKVCache
from repro.core.traffic import (TrafficSpec, arrival_trace, replay_schedule,
                                step_read_counts)

SPEC = KVSpec(n_layers=1, kv_heads=2, head_dim=8, page_tokens=8)

#: corner configs for the conformance sweep: default; hair-trigger
#: promotion with fast epochs; sluggish cooling with slow epochs; tiny
#: thresholds with aggressive cooling
CORNERS = [
    None,
    dict(read_hot_threshold=1, sampling_period=100, migration_period=10),
    dict(read_hot_threshold=24, cooling_threshold=40,
         migration_period=2000, sampling_period=8000),
    dict(read_hot_threshold=2, write_hot_threshold=1, cooling_threshold=4,
         cooling_pages=1024, migration_period=10),
]


def _drive(cache: TieredKVCache, steps: int, *, engine_every=5, seed=0):
    """Deterministic decode loop with completion-style resets (a sequence
    finishes after 16 + 3*b tokens — staggered per slot); returns per-epoch
    (slot_of, migrations) snapshots."""
    B = cache.batch
    rng = np.random.default_rng(seed)
    k = rng.normal(size=(B, cache.spec.n_layers, cache.spec.kv_heads,
                         cache.spec.head_dim)).astype(np.float32)
    q = rng.normal(size=(B, cache.spec.kv_heads,
                         cache.spec.head_dim)).astype(np.float32)
    limit = 16 + 3 * np.arange(B)
    snaps = []
    for t in range(steps):
        cache.decode_step(k, k, q)
        if t % engine_every == engine_every - 1:
            cache.step_engine(50.0)
            snaps.append((cache.slot_of.copy(), cache.migrations))
        done = cache.lengths >= limit
        if done.any():
            cache.reset_seqs(done)
    return snaps


@pytest.mark.parametrize("config", CORNERS)
def test_compiled_matches_reference_residency(config):
    """Compiled and reference modes agree on HBM residency (which logical
    pages sit in fast memory) and on total migration counts at every
    engine epoch — bitwise, not approximately."""
    kw = dict(batch=3, max_pages_per_seq=4, hbm_pages=5, config=config)
    ref = TieredKVCache(SPEC, compiled=False, **kw)
    com = TieredKVCache(SPEC, compiled=True, **kw)
    sr = _drive(ref, 60)
    sc = _drive(com, 60)
    assert len(sr) == len(sc) == 12
    for e, ((slot_r, mig_r), (slot_c, mig_c)) in enumerate(zip(sr, sc)):
        assert mig_r == mig_c, f"epoch {e}: migration counts diverge"
        np.testing.assert_array_equal(
            slot_r >= 0, slot_c >= 0,
            err_msg=f"epoch {e}: HBM residency sets diverge")
    assert ref.recall() == pytest.approx(com.recall(), abs=1e-9)
    if config is None or config.get("migration_period", 10) <= 10:
        assert sr[-1][1] > 0, "sweep produced no migrations (test too weak)"


def test_compiled_decode_equals_append_attend():
    """decode_step is the fusion of append + attend (same state, output)."""
    kw = dict(batch=2, max_pages_per_seq=3, hbm_pages=4)
    a = TieredKVCache(SPEC, compiled=True, **kw)
    b = TieredKVCache(SPEC, compiled=True, **kw)
    rng = np.random.default_rng(1)
    k = rng.normal(size=(2, 1, 2, 8)).astype(np.float32)
    q = rng.normal(size=(2, 2, 8)).astype(np.float32)
    for _ in range(6):
        out_a = a.decode_step(k, k, q)
        b.append(k, k)
        out_b = b.attend(q)
        np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))
    np.testing.assert_array_equal(a.lengths, b.lengths)
    np.testing.assert_array_equal(a.slot_of, b.slot_of)
    res, tot = a.last_step_pages
    assert res.shape == tot.shape == (2,)
    assert (res <= tot).all() and (tot >= 1).all()


def test_step_read_counts_numpy_jax_bitwise():
    """The integer access profile both paths feed the engine is identical
    under numpy and jitted jax (pure int32 arithmetic, the conformance
    anchor)."""
    import jax.numpy as jnp
    lengths = np.array([0, 1, 7, 8, 9, 64], np.int32)
    c_np, a_np = step_read_counts(lengths, 8, 8, 4096, xp=np)
    f = jax.jit(lambda ln: step_read_counts(ln, 8, 8, 4096, xp=jnp))
    c_j, a_j = f(jnp.asarray(lengths))
    np.testing.assert_array_equal(c_np, np.asarray(c_j))
    np.testing.assert_array_equal(a_np, np.asarray(a_j))


# -- traffic ----------------------------------------------------------------

def test_arrival_trace_deterministic():
    spec = TrafficSpec(pattern="bursty-diurnal", arrival_rate=3.0, steps=64)
    a1, l1 = arrival_trace(spec, seed=7)
    a2, l2 = arrival_trace(spec, seed=7)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(l1, l2)
    a3, _ = arrival_trace(spec, seed=8)
    assert not np.array_equal(a1, a3)


def test_traffic_spec_json_roundtrip():
    spec = TrafficSpec(pattern="bursty-diurnal", arrival_rate=2.5,
                       steps=96, burst_factor=4.0)
    assert TrafficSpec.from_json(spec.to_json()) == spec
    with pytest.raises(ValueError, match="unknown traffic pattern"):
        TrafficSpec(pattern="sawtooth")


def test_replay_schedule_accounting():
    spec = TrafficSpec(pattern="poisson", arrival_rate=2.0, steps=80)
    sched = replay_schedule(spec, batch=8, max_tokens=24, seed=3)
    active, done = sched["active"], sched["done"]
    assert done[active].size and not done[~active].any(), \
        "done must imply active"
    assert sched["completed"] == done.sum()
    # a completed request decoded exactly its (clamped) length
    runs = active.sum(0)
    assert runs.sum() > 0


def test_kv_workloads_registered():
    for name in ("kv-poisson", "kv-diurnal"):
        wl = make_workload(name, scale=1.0)
        assert wl.n_pages == 8 * 32
        r, w = wl.epoch_access(0)
        assert r.shape == (wl.n_pages,) and r.sum() > 0


# -- lifted engine dispatch -------------------------------------------------

def test_kv_hemem_jax_dispatch_no_fallback(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.core.simulator"):
        res = Study(ExperimentSpec(
            engine="kv-hemem", workload="kv-poisson",
            options=SimOptions(backend="jax"))).run()
    assert res.total_s > 0
    assert not any("falling back" in r.getMessage() for r in caplog.records)


# -- deprecations / API edges ----------------------------------------------

def test_record_reads_public_and_shim():
    cache = TieredKVCache(SPEC, batch=2, max_pages_per_seq=3, hbm_pages=4)
    rng = np.random.default_rng(0)
    k = rng.normal(size=(2, 1, 2, 8))
    cache.append(k, k)
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # public name: no warning
        cache.record_reads()
    with pytest.deprecated_call():
        cache._record_reads()


def test_compiled_record_reads_is_fused():
    cache = TieredKVCache(SPEC, batch=2, max_pages_per_seq=3, hbm_pages=4,
                          compiled=True)
    with pytest.raises(RuntimeError, match="fuses read recording"):
        cache.record_reads()


# -- XLA compile cache plumbing --------------------------------------------

def test_compile_cache_dir_respects_env(tmp_path, monkeypatch):
    from repro.core import simulator
    target = tmp_path / "xla-cache"
    monkeypatch.setenv("JAX_COMPILATION_CACHE_DIR", str(target))
    assert simulator.compile_cache_dir() == str(target)
    assert target.is_dir()                      # created eagerly


def test_worker_init_points_jax_at_cache(tmp_path, monkeypatch):
    from repro.core import simulator
    monkeypatch.delenv("JAX_COMPILATION_CACHE_DIR", raising=False)
    simulator._worker_init(str(tmp_path))
    import os
    assert os.environ["JAX_COMPILATION_CACHE_DIR"] == str(tmp_path)
    assert os.environ["JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS"] == "0"


def _count_cache_files(d):
    import os
    return sum(len(fs) for _, _, fs in os.walk(d))


def _fresh_pool():
    from repro.core import simulator
    if simulator._POOL is not None:
        simulator._POOL.shutdown(wait=True, cancel_futures=True)
    simulator._POOL, simulator._POOL_SIZE = None, 0


def test_sharded_workers_warm_start_from_compile_cache(tmp_path,
                                                       monkeypatch):
    """A second worker pool must hit the shared XLA disk cache instead of
    re-jitting the epoch loop (the carried ROADMAP thread): the first
    sharded jax run populates ``compile_cache_dir()``, a pool spun up
    afterwards adds no new cache entries."""
    import os
    if (os.cpu_count() or 1) < 2:
        pytest.skip("needs >= 2 CPUs")
    from repro.core.knobs import get_space
    from repro.core.simulator import run_simulation_batch
    cache = tmp_path / "xla-cache"
    monkeypatch.setenv("JAX_COMPILATION_CACHE_DIR", str(cache))
    wl = make_workload("gups", "8GiB-hot", threads=8, scale=0.02, seed=3)
    space = get_space("hemem")
    cfgs = [space.default_config(),
            space.sample(np.random.default_rng(5))]
    _fresh_pool()
    try:
        run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=9,
                             backend="jax", workers=2)
        n_cold = _count_cache_files(cache)
        assert n_cold > 0, "first sharded run wrote no cache entries"
        _fresh_pool()                       # new workers, cold jit caches
        run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=9,
                             backend="jax", workers=2)
        assert _count_cache_files(cache) == n_cold, \
            "second pool re-jitted instead of warm-starting from disk"
    finally:
        _fresh_pool()
