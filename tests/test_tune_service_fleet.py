"""Fleet coordinator: lease-and-commit determinism under injected faults.

The acceptance bars of the fault-tolerant fleet PR:

* ``Study.tune(executor="fleet", workers=N)`` reproduces the local async
  executor's suggestions and incumbent **bit-identically** (process and
  socket transports) — remote placement cannot change a decision;
* every injector in :mod:`repro.core.tune_service.faults` (kill / stall /
  drop / dup / delay / hang) leaves the incumbent bit-identical to the
  fault-free run, and two runs under the same fault plan write
  **byte-identical** journals (lease/expire/reissue histories included);
* a unit whose lease expires ``max_attempts`` times is surrendered as a
  FAILED trial — the study finishes, never wedges;
* at zero live workers the coordinator degrades to its local slot;
* a coordinator SIGKILLed mid-run (mid-re-issue included) resumes from
  its journal byte-identically to an uninterrupted twin.

The hardened-fleet PR adds:

* the network-shaped injectors (corrupt / truncate / replay / partition /
  latency) leave the incumbent bit-identical, journal deterministic
  ``reject``/``reconnect`` events, and twin runs stay byte-identical;
* ``scheduler="asha"`` composes with the fleet (both pools): rung
  decisions match the local async ASHA run bitwise, survive the fault
  matrix, and a SIGKILL mid-rung resumes byte-identically;
* a :class:`FleetSpec` + ``tools/fleet_launch.py`` round-trip — CLI
  workers launched from one spec file, auth key via environment — is
  bit-identical to the self-spawned fleet, and the key never reaches the
  journal or argv.
"""

import os
import signal
import subprocess
import sys
import time

import pytest

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.tune_service import (FaultPlan, FleetExecutor, read_events,
                                     tear_journal)
from repro.core.tune_service.trial import FAILED, TERMINATED

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

SCALE = 0.02
#: common study shape: budget 6 = units 1..6, unit 0 is the default config
KW = dict(budget=6, seed=9, n_init=3)
#: tight heartbeats so silence expiries land in ~1s, not test-timeout land
FLEET_KW = dict(heartbeat_s=0.05, lease_deadline=20)


def _spec(**opts):
    return ExperimentSpec(engine="hemem",
                          workload=WorkloadSpec("gups", scale=SCALE),
                          options=SimOptions(backend="numpy", **opts))


def _histories_equal(a, b):
    return [(o.config, o.value) for o in a.history] == \
        [(o.config, o.value) for o in b.history]


@pytest.fixture(scope="module")
def baseline():
    """The local async twin every fleet run must reproduce bitwise."""
    return Study(_spec()).tune(executor="async", slots=2, **KW)


# ---------------------------------------------------------------------------
# placement invariance: fleet == local async, both transports
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pool", ["process", "socket"])
def test_fleet_matches_async_local(pool, baseline):
    r = Study(_spec()).tune(executor="fleet", workers=2, pool=pool,
                            **KW, **FLEET_KW)
    assert r.best_value == baseline.best_value
    assert r.best.config == baseline.best.config
    assert _histories_equal(r, baseline)
    assert r.trials == baseline.trials
    fs = r.fleet
    assert fs["pool"] == pool and fs["workers"] == 2
    assert fs["n_expired_leases"] == 0 and fs["n_worker_deaths"] == 0
    assert not fs["degraded"]


# ---------------------------------------------------------------------------
# the fault matrix: every injector, journal twins byte-identical
# ---------------------------------------------------------------------------
FAULT_CASES = {
    # injector -> (plan, expected expire reason or None)
    "kill": (FaultPlan(kill=[(2, 0)]), "worker-dead"),
    "stall": (FaultPlan(stall=[(2, 0)]), "expired"),
    "drop": (FaultPlan(drop=[(2, 0)]), "lost"),
    "dup": (FaultPlan(dup=[(2, 0)]), None),
    "delay": (FaultPlan(delay=[(2, 0, 1.5)]), "expired"),
}


@pytest.mark.parametrize("injector", sorted(FAULT_CASES))
def test_fleet_journal_twins_under_fault(injector, baseline, tmp_path):
    plan, reason = FAULT_CASES[injector]
    runs, raws = [], []
    for twin in range(2):
        j = str(tmp_path / f"{injector}{twin}.jsonl")
        r = Study(_spec()).tune(executor="fleet", workers=2, faults=plan,
                                journal=j, **KW, **FLEET_KW)
        runs.append(r)
        raws.append(open(j, "rb").read())
    assert raws[0] == raws[1]
    for r in runs:
        # the fault cost re-execution, never a decision
        assert r.best_value == baseline.best_value
        assert _histories_equal(r, baseline)
        assert r.trials == baseline.trials
    events = read_events(str(tmp_path / f"{injector}0.jsonl"))
    expires = [e for e in events if e["event"] == "expire"]
    reissues = [e for e in events if e["event"] == "reissue"]
    if reason is None:  # dup: the twin is absorbed, no lease ever expires
        assert not expires and not reissues
        assert runs[0].fleet["n_duplicate_results"] >= 1
    else:
        assert [e["reason"] for e in expires] == [reason]
        assert [(e["unit"], e["attempt"]) for e in expires] == [(2, 0)]
        assert [(e["unit"], e["attempt"]) for e in reissues] == [(2, 1)]
    if injector == "kill":
        assert runs[0].fleet["n_worker_deaths"] == 1
        assert runs[0].fleet["n_respawns"] == 1
    # the faulty journal still validates standalone
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "tools"))
    import journal_schema
    assert journal_schema.validate_file(
        str(tmp_path / f"{injector}0.jsonl")) == []


def test_fleet_worker_death_promotes_hot_spare(baseline):
    """A process-fleet death refills the slot from the booted hot spare
    (the respawn boot lands on the replacement spare, off the critical
    path) — and the promotion changes nothing the study sees."""
    r = Study(_spec()).tune(executor="fleet", workers=2,
                            faults=FaultPlan(kill=[(2, 0)]),
                            **KW, **FLEET_KW)
    fs = r.fleet
    assert fs["n_worker_deaths"] == 1 and fs["n_respawns"] == 1
    assert fs["n_spare_promotions"] == 1
    assert r.best_value == baseline.best_value
    assert _histories_equal(r, baseline)


def test_fleet_hang_unwedged_by_timeout(baseline, tmp_path):
    # heartbeats keep flowing, the result never comes: only the per-unit
    # timeout can unwedge it, and the bounded trial retry absorbs the loss
    j = str(tmp_path / "hang.jsonl")
    r = Study(_spec()).tune(executor="fleet", workers=2,
                            faults=FaultPlan(hang=[(2, 0)]), timeout_s=0.6,
                            journal=j, **KW, **FLEET_KW)
    assert r.best_value == baseline.best_value
    assert r.n_failed == 0
    retries = [e for e in read_events(j) if e["event"] == "retry"]
    assert len(retries) == 1 and "timeout" in retries[0]["error"]


def test_fleet_surrenders_after_max_attempts(tmp_path):
    # unit 2 loses every result message on every attempt: the lease
    # expires max_attempts (4) times, the unit is surrendered, and with
    # retries=0 the trial fails — the study finishes, never wedges
    plan = FaultPlan(drop=[(2, 0), (2, 1), (2, 2), (2, 3)])
    j = str(tmp_path / "surrender.jsonl")
    r = Study(_spec()).tune(executor="fleet", workers=2, faults=plan,
                            retries=0, journal=j, **KW, **FLEET_KW)
    states = [t["state"] for t in r.trials]
    assert states.count(FAILED) == 1 and states.count(TERMINATED) == 5
    failed = next(t for t in r.trials if t["state"] == FAILED)
    assert "lease expired 4 times" in failed["error"]
    events = read_events(j)
    assert len([e for e in events if e["event"] == "expire"]) == 4
    assert len([e for e in events if e["event"] == "reissue"]) == 3


def test_fleet_degrades_to_local_at_zero_workers(baseline):
    # one worker, killed mid-unit, no respawn budget: every remaining unit
    # runs on the coordinator's local slot — slower, never wedged, and
    # still bit-identical (the unit is a pure function of its coordinates)
    r = Study(_spec()).tune(executor="fleet", workers=1,
                            faults=FaultPlan(kill=[(1, 0)]), max_respawns=0,
                            **KW, **FLEET_KW)
    fs = r.fleet
    assert fs["degraded"] and fs["n_worker_deaths"] == 1
    assert fs["n_respawns"] == 0
    # different study shape than the slots=2 baseline: compare to its own
    # local twin instead
    twin = Study(_spec()).tune(executor="async", slots=1, **KW)
    assert r.best_value == twin.best_value
    assert _histories_equal(r, twin)


# ---------------------------------------------------------------------------
# resume: torn journal, and a SIGKILLed coordinator mid-faulty-run
# ---------------------------------------------------------------------------
def test_fleet_resume_from_torn_journal(tmp_path):
    plan = FaultPlan(kill=[(2, 0)], drop=[(4, 0)])
    kw = dict(executor="fleet", workers=2, faults=plan, **KW, **FLEET_KW)
    j1, j2 = str(tmp_path / "full.jsonl"), str(tmp_path / "torn.jsonl")
    r1 = Study(_spec()).tune(journal=j1, **kw)
    raw = open(j1, "rb").read()
    import shutil
    shutil.copy(j1, j2)
    tear_journal(j2, 9)
    r2 = Study(_spec()).tune(journal=j2, resume=True, **kw)
    assert open(j2, "rb").read() == raw
    assert r2.trials == r1.trials
    assert r2.best_value == r1.best_value
    assert r2.resumed


_KILL_SCRIPT = """
import sys
sys.path.insert(0, {src!r})
from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.tune_service import FaultPlan
spec = ExperimentSpec(engine="hemem",
                      workload=WorkloadSpec("gups", scale={scale!r}),
                      options=SimOptions(backend="numpy"))
Study(spec).tune(budget=24, seed=9, n_init=4, executor="fleet", workers=2,
                 faults=FaultPlan(kill_every=4), max_respawns=24,
                 heartbeat_s=0.05, lease_deadline=20, journal={journal!r})
"""


def test_fleet_coordinator_sigkill_resume_is_byte_identical(tmp_path):
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    kw = dict(budget=24, seed=9, n_init=4, executor="fleet", workers=2,
              faults=FaultPlan(kill_every=4), max_respawns=24, **FLEET_KW)
    j_twin = str(tmp_path / "twin.jsonl")
    r_twin = Study(_spec()).tune(journal=j_twin, **kw)

    j_kill = str(tmp_path / "killed.jsonl")
    proc = subprocess.Popen(
        [sys.executable, "-c",
         _KILL_SCRIPT.format(src=os.path.abspath(src), scale=SCALE,
                             journal=j_kill)])
    try:
        # SIGKILL once the study is past its first injected worker death
        # (unit 4's lease history is journaled at its commit), so the
        # resume replays a re-issue and continues into live ones
        deadline = time.time() + 120
        while time.time() < deadline:
            if os.path.exists(j_kill):
                raw = open(j_kill, "rb").read()
                if raw.count(b'"event": "reissue"') >= 1 and \
                        len(raw.splitlines()) >= 15:
                    break
            time.sleep(0.01)
        else:
            pytest.fail("killed study never journaled a re-issue")
        os.kill(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    assert 0 < len(read_events(j_kill)) < len(read_events(j_twin))

    r_res = Study(_spec()).tune(journal=j_kill, resume=True, **kw)
    assert open(j_kill, "rb").read() == open(j_twin, "rb").read()
    assert r_res.trials == r_twin.trials
    assert r_res.best_value == r_twin.best_value
    assert _histories_equal(r_res, r_twin)


# ---------------------------------------------------------------------------
# argument validation
# ---------------------------------------------------------------------------
def test_fleet_rejects_bad_arguments():
    with pytest.raises(ValueError, match="workers"):
        FleetExecutor(workers=0)
    with pytest.raises(ValueError, match="pool"):
        FleetExecutor(workers=1, pool="carrier-pigeon")
    with pytest.raises(ValueError, match="lease_deadline"):
        FleetExecutor(workers=1, lease_deadline=0)
    with pytest.raises(ValueError, match="executor"):
        Study(_spec()).tune(budget=2, workers=2)  # sync path: no fleet knobs


# ---------------------------------------------------------------------------
# network-shaped faults (socket transport): journal twins byte-identical
# ---------------------------------------------------------------------------
NET_FAULT_CASES = {
    # injector -> (plan, journaled reject reason or None)
    "corrupt": (FaultPlan(corrupt=[(2, 0)]), "bad-signature"),
    "truncate": (FaultPlan(truncate=[(2, 0)]), "truncated"),
    # a replayed VALID result: the first copy commits and releases the
    # lease before the replayed copy is even read, so the reject is
    # wall-clock-free stats only — never journaled
    "replay": (FaultPlan(replay=[(2, 0)]), None),
}


@pytest.mark.parametrize("injector", sorted(NET_FAULT_CASES))
def test_socket_fleet_net_fault_journal_twins(injector, baseline, tmp_path):
    plan, reason = NET_FAULT_CASES[injector]
    runs, raws = [], []
    for twin in range(2):
        j = str(tmp_path / f"{injector}{twin}.jsonl")
        r = Study(_spec()).tune(executor="fleet", workers=2, pool="socket",
                                faults=plan, journal=j, **KW, **FLEET_KW)
        runs.append(r)
        raws.append(open(j, "rb").read())
    assert raws[0] == raws[1]
    for r in runs:
        assert r.best_value == baseline.best_value
        assert _histories_equal(r, baseline)
        assert r.trials == baseline.trials
        assert r.fleet["n_rejected_frames"] >= 1
    events = read_events(str(tmp_path / f"{injector}0.jsonl"))
    rejects = [e for e in events if e["event"] == "reject"]
    if reason is None:
        assert not rejects  # stats-only: first commit already won
        assert runs[0].fleet["n_duplicate_results"] == 0
    else:
        assert [(e["unit"], e["attempt"], e["reason"])
                for e in rejects] == [(2, 0, reason)]
        expires = [e for e in events if e["event"] == "expire"]
        assert [(e["unit"], e["reason"]) for e in expires] == [(2, "reject")]
        assert [(e["unit"], e["attempt"]) for e in events
                if e["event"] == "reissue"] == [(2, 1)]
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "tools"))
    import journal_schema
    assert journal_schema.validate_file(
        str(tmp_path / f"{injector}0.jsonl")) == []


def test_socket_fleet_reconnect_mid_lease(baseline, tmp_path):
    """A partition mid-lease: the link drops on unit 2's first busy
    heartbeat and the worker re-dials while its evaluation keeps
    computing.  The coordinator re-attaches the live lease (``reconnect``
    journaled at commit), nothing is re-executed, and two partitioned
    runs write byte-identical journals."""
    plan = FaultPlan(partition=[(2, 0, 0.2)])
    raws, runs = [], []
    for twin in range(2):
        j = str(tmp_path / f"part{twin}.jsonl")
        r = Study(_spec()).tune(executor="fleet", workers=2, pool="socket",
                                faults=plan, journal=j, **KW, **FLEET_KW)
        runs.append(r)
        raws.append(open(j, "rb").read())
    assert raws[0] == raws[1]
    for r in runs:
        assert r.best_value == baseline.best_value
        assert _histories_equal(r, baseline)
        assert r.trials == baseline.trials
        assert r.fleet["n_reconnects"] == 1
    events = read_events(str(tmp_path / "part0.jsonl"))
    recon = [e for e in events if e["event"] == "reconnect"]
    assert [(e["unit"], e["attempt"]) for e in recon] == [(2, 0)]
    # the lease survived the gap: no expiry, no re-issue, no duplicate
    assert not [e for e in events if e["event"] in ("expire", "reissue")]
    assert runs[0].fleet["n_duplicate_results"] == 0


def test_socket_fleet_under_injected_latency(baseline):
    """Link latency on every frame (the CI fleet-socket-smoke shape):
    slower, bit-identical."""
    r = Study(_spec()).tune(executor="fleet", workers=2, pool="socket",
                            faults=FaultPlan(net_delay_s=0.005),
                            **KW, **FLEET_KW)
    assert r.best_value == baseline.best_value
    assert _histories_equal(r, baseline)
    assert r.trials == baseline.trials


# ---------------------------------------------------------------------------
# ASHA over fleets: early stopping composes with leases (ROADMAP 3a)
# ---------------------------------------------------------------------------
ASHA_KW = dict(budget=6, seed=9, n_init=3, scheduler="asha")


@pytest.fixture(scope="module")
def asha_baseline():
    return Study(_spec()).tune(executor="async", slots=2, **ASHA_KW)


@pytest.mark.parametrize("pool", ["process", "socket"])
def test_fleet_asha_matches_async_asha(pool, asha_baseline):
    r = Study(_spec()).tune(executor="fleet", workers=2, pool=pool,
                            **ASHA_KW, **FLEET_KW)
    assert r.best_value == asha_baseline.best_value
    assert _histories_equal(r, asha_baseline)
    assert r.trials == asha_baseline.trials
    assert r.epochs_committed == asha_baseline.epochs_committed
    assert r.asha_epochs_saved_frac > 0  # rungs actually stopped trials


def test_fleet_asha_journal_twins_under_faults(asha_baseline, tmp_path):
    # promote/early-stop composes with heartbeat expiry + straggler
    # re-issue: a killed worker and a dropped result mid-rung change
    # re-execution, never a rung decision
    plan = FaultPlan(kill=[(2, 0)], drop=[(4, 0)])
    raws = []
    for twin in range(2):
        j = str(tmp_path / f"asha{twin}.jsonl")
        r = Study(_spec()).tune(executor="fleet", workers=2, faults=plan,
                                journal=j, **ASHA_KW, **FLEET_KW)
        raws.append(open(j, "rb").read())
        assert r.best_value == asha_baseline.best_value
        assert _histories_equal(r, asha_baseline)
        assert r.trials == asha_baseline.trials
    assert raws[0] == raws[1]


_ASHA_KILL_SCRIPT = """
import sys
sys.path.insert(0, {src!r})
from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.tune_service import FaultPlan
spec = ExperimentSpec(engine="hemem",
                      workload=WorkloadSpec("gups", scale={scale!r}),
                      options=SimOptions(backend="numpy"))
Study(spec).tune(budget=16, seed=9, n_init=4, executor="fleet", workers=2,
                 scheduler="asha", faults=FaultPlan(kill_every=6),
                 max_respawns=24, heartbeat_s=0.05, lease_deadline=20,
                 journal={journal!r})
"""


def test_fleet_asha_sigkill_resume_is_byte_identical(tmp_path):
    """SIGKILL the coordinator mid-rung (rung decisions already
    journaled, more to come) and resume: byte-identical to the
    uninterrupted fleet x ASHA twin."""
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    kw = dict(budget=16, seed=9, n_init=4, executor="fleet", workers=2,
              scheduler="asha", faults=FaultPlan(kill_every=6),
              max_respawns=24, **FLEET_KW)
    j_twin = str(tmp_path / "twin.jsonl")
    r_twin = Study(_spec()).tune(journal=j_twin, **kw)

    j_kill = str(tmp_path / "killed.jsonl")
    proc = subprocess.Popen(
        [sys.executable, "-c",
         _ASHA_KILL_SCRIPT.format(src=os.path.abspath(src), scale=SCALE,
                                  journal=j_kill)])
    try:
        # kill once at least one rung decision is journaled (mid-rung:
        # more trials are still climbing)
        deadline = time.time() + 120
        while time.time() < deadline:
            if os.path.exists(j_kill):
                raw = open(j_kill, "rb").read()
                if raw.count(b'"event": "rung"') >= 2:
                    break
            time.sleep(0.01)
        else:
            pytest.fail("killed study never journaled a rung decision")
        os.kill(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    assert 0 < len(read_events(j_kill)) < len(read_events(j_twin))

    r_res = Study(_spec()).tune(journal=j_kill, resume=True, **kw)
    assert open(j_kill, "rb").read() == open(j_twin, "rb").read()
    assert r_res.trials == r_twin.trials
    assert r_res.best_value == r_twin.best_value
    assert _histories_equal(r_res, r_twin)


# ---------------------------------------------------------------------------
# the deployable fleet: spec-driven launcher + externally-launched workers
# ---------------------------------------------------------------------------
def test_fleet_spec_launcher_roundtrip(baseline, tmp_path):
    """The whole multi-host shape on one box: ``FleetSpec`` written to
    disk, ``tools/fleet_launch.py`` bringing up CLI workers that dial in
    and greet (auth key via environment, never argv), the coordinator
    binding the spec's port — and the study still bit-identical."""
    import socket as socket_mod
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "tools"))
    import fleet_launch
    from repro.core.tune_service import FleetSpec

    with socket_mod.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    spec = FleetSpec.generate(workers=2, hosts=("127.0.0.1", "127.0.0.1"),
                              port=port, heartbeat_s=FLEET_KW["heartbeat_s"],
                              lease_deadline=FLEET_KW["lease_deadline"])
    spec_path = str(tmp_path / "fleet.json")
    spec.save(spec_path)

    j = str(tmp_path / "fleet.jsonl")
    with fleet_launch.LocalFleet(spec, spec_path) as fleet:
        # workers re-dial with backoff until the coordinator binds
        r = Study(_spec()).tune(executor="fleet", fleet_spec=spec,
                                journal=j, **KW)
        assert fleet.wait_greeted(timeout_s=30.0)
        fleet.join(10.0)  # the coordinator's shutdown frame ends them
        assert fleet.alive == 0
    assert r.best_value == baseline.best_value
    assert _histories_equal(r, baseline)
    assert r.trials == baseline.trials
    assert not r.fleet["degraded"]
    # the journal never saw the fleet's secret
    assert spec.auth_key.encode() not in open(j, "rb").read()


def test_fleet_launch_init_and_print(tmp_path, capsys):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                    "tools"))
    import fleet_launch
    from repro.core.tune_service import FleetSpec

    spec_path = str(tmp_path / "fleet.json")
    assert fleet_launch.main([spec_path, "--init", "--workers", "3",
                              "--hosts", "h1,h2,h3"]) == 0
    assert os.stat(spec_path).st_mode & 0o777 == 0o600
    spec = FleetSpec.load(spec_path)
    assert spec.workers == 3 and spec.external and spec.port != 0
    capsys.readouterr()
    assert fleet_launch.main([spec_path, "--print"]) == 0
    out = capsys.readouterr().out
    # one command per host, keyless argv
    for h in ("h1", "h2", "h3"):
        assert f"{h}$" in out
    assert spec.auth_key not in out


def test_fleet_spec_requires_fleet_executor():
    from repro.core.tune_service import FleetSpec
    with pytest.raises(ValueError, match="fleet_spec"):
        Study(_spec()).tune(budget=2, fleet_spec=FleetSpec.generate())
