"""Transport chaos: the authenticated frame codec under hostile bytes.

The hardened-fleet PR's security bars, pinned endpoint-by-endpoint:

* every malformed frame class — truncated, oversize, bit-flipped,
  replayed, unsigned / wrong-key, wrong magic, wrong version, stalled
  mid-frame — raises its specific :class:`FrameError` instead of
  unpickling attacker bytes or wedging the reader;
* the oversize gate fires BEFORE any payload allocation (a corrupt
  4-byte length header used to balloon a 4 GiB buffer);
* a live coordinator fed stranger garbage rejects + drops and the study
  still completes (nothing wedges, nothing is leased to the stranger);
* a worker dialing a hostile/garbled coordinator fails fast instead of
  redialing forever;
* :class:`FleetSpec` round-trips through JSON, validates its fields and
  refuses unknown ones.
"""

import os
import socket
import threading
import time

import pytest

from repro.core.tune_service.transport import (
    _HEADER, DEFAULT_MAX_FRAME_BYTES, MAGIC, SIG_BYTES, VERSION,
    FleetSpec, FrameChannel, FrameError, FrameMagicError,
    FrameProtocolError, FrameReplayError, FrameSignatureError,
    FrameTimeoutError, FrameTooLargeError, FrameTruncatedError,
    FrameVersionError, accept_greet, greet, reject_reason)

KEY = bytes(range(32))
OTHER_KEY = bytes(range(32, 64))


def _pair(**kw):
    a, b = socket.socketpair()
    return FrameChannel(a, KEY, **kw), FrameChannel(b, KEY, **kw)


# ---------------------------------------------------------------------------
# the happy path: signed frames round-trip, sequences advance
# ---------------------------------------------------------------------------

def test_roundtrip_and_sequences():
    tx, rx = _pair()
    for i in range(5):
        tx.send({"type": "heartbeat", "n": i})
        assert rx.recv(wait_timeout=1.0) == {"type": "heartbeat", "n": i}
    tx.close(), rx.close()


def test_idle_poll_returns_none():
    tx, rx = _pair()
    t0 = time.monotonic()
    assert rx.recv(wait_timeout=0.05) is None
    assert time.monotonic() - t0 < 1.0
    # a zero timeout is an instant poll, not a transport error
    assert rx.recv(wait_timeout=0.0) is None
    tx.close(), rx.close()


def test_short_key_refused():
    a, b = socket.socketpair()
    with pytest.raises(ValueError, match="16 bytes"):
        FrameChannel(a, b"short")
    a.close(), b.close()


# ---------------------------------------------------------------------------
# the fuzz corpus: every malformed-frame class -> its specific rejection
# ---------------------------------------------------------------------------

def _valid_frame(chan, obj={"type": "heartbeat"}):
    return chan.encode(obj)


def test_truncated_frame_rejected():
    tx, rx = _pair()
    raw = _valid_frame(tx)
    tx.sock.sendall(raw[: len(raw) // 2])
    tx.close()
    with pytest.raises(FrameTruncatedError):
        rx.recv(wait_timeout=1.0)
    rx.close()


def test_clean_close_is_eof_not_frame_error():
    tx, rx = _pair()
    tx.close()
    with pytest.raises(EOFError):
        rx.recv(wait_timeout=1.0)
    rx.close()


def test_oversize_header_rejected_before_allocation():
    tx, rx = _pair(max_frame=4096)
    # a header claiming a ~4 GiB payload: the cap must fire on the header
    # alone — no payload bytes exist to read, so any attempt to allocate/
    # read the claimed body would wedge this single-threaded test
    evil = _HEADER.pack(MAGIC, VERSION, 0, 0xFFFF0000)
    tx.sock.sendall(evil)
    with pytest.raises(FrameTooLargeError):
        rx.recv(wait_timeout=1.0)
    tx.close(), rx.close()


def test_oversize_outgoing_rejected():
    tx, rx = _pair(max_frame=4096)
    with pytest.raises(FrameTooLargeError):
        tx.send({"blob": b"x" * 8192})
    tx.close(), rx.close()


def test_bitflip_anywhere_in_payload_rejected():
    for flip in (0, 7):  # first and last payload byte
        tx, rx = _pair()
        raw = bytearray(_valid_frame(tx, {"v": 1.0}))
        idx = -1 if flip else _HEADER.size + SIG_BYTES
        raw[idx] ^= 0x01
        tx.sock.sendall(bytes(raw))
        with pytest.raises(FrameSignatureError):
            rx.recv(wait_timeout=1.0)
        tx.close(), rx.close()


def test_unsigned_and_wrong_key_rejected():
    # wrong key: a peer without the fleet spec cannot forge a signature
    a, b = socket.socketpair()
    tx = FrameChannel(a, OTHER_KEY)
    rx = FrameChannel(b, KEY)
    tx.send({"type": "hello", "worker": 0})
    with pytest.raises(FrameSignatureError):
        rx.recv(wait_timeout=1.0)
    tx.close(), rx.close()
    # zeroed signature: same rejection
    tx, rx = _pair()
    raw = bytearray(_valid_frame(tx))
    raw[_HEADER.size:_HEADER.size + SIG_BYTES] = b"\x00" * SIG_BYTES
    tx.sock.sendall(bytes(raw))
    with pytest.raises(FrameSignatureError):
        rx.recv(wait_timeout=1.0)
    tx.close(), rx.close()


def test_replayed_frame_rejected():
    tx, rx = _pair()
    raw = _valid_frame(tx)
    tx.send_bytes(raw)
    assert rx.recv(wait_timeout=1.0) == {"type": "heartbeat"}
    tx.send_bytes(raw)  # identical bytes, valid signature, stale seq
    with pytest.raises(FrameReplayError):
        rx.recv(wait_timeout=1.0)
    tx.close(), rx.close()


def test_bad_magic_and_version_rejected():
    tx, rx = _pair()
    tx.sock.sendall(b"GET / HTTP/1.1\r\n" + b"\x00" * 32)
    with pytest.raises(FrameMagicError):
        rx.recv(wait_timeout=1.0)
    tx.close(), rx.close()
    tx, rx = _pair()
    raw = bytearray(_valid_frame(tx))
    raw[3] = VERSION + 1  # version byte
    tx.sock.sendall(bytes(raw))
    with pytest.raises(FrameVersionError):
        rx.recv(wait_timeout=1.0)
    tx.close(), rx.close()


def test_stalled_peer_bounded_by_frame_timeout():
    tx, rx = _pair(frame_timeout_s=0.2)
    raw = _valid_frame(tx)
    tx.sock.sendall(raw[:4])  # header started, then silence (no close)
    t0 = time.monotonic()
    with pytest.raises(FrameTimeoutError):
        rx.recv(wait_timeout=1.0)
    assert time.monotonic() - t0 < 2.0  # bounded, not wedged
    tx.close(), rx.close()


def test_reject_reasons_are_journal_stable():
    assert reject_reason(FrameSignatureError()) == "bad-signature"
    assert reject_reason(FrameTooLargeError()) == "oversize"
    assert reject_reason(FrameReplayError()) == "replay"
    assert reject_reason(FrameTruncatedError()) == "truncated"
    assert reject_reason(FrameTimeoutError()) == "timeout"
    assert reject_reason(FrameMagicError()) == "bad-magic"
    assert reject_reason(FrameVersionError()) == "bad-version"
    assert reject_reason(FrameProtocolError()) == "protocol"
    assert reject_reason(OSError("boom")) == "transport"


# ---------------------------------------------------------------------------
# the greet handshake: identity before leases
# ---------------------------------------------------------------------------

def test_greet_roundtrip():
    tx, rx = _pair()
    t = threading.Thread(target=greet, args=(tx, 3), daemon=True)
    t.start()
    assert accept_greet(rx, timeout_s=2.0) == 3
    t.join(timeout=2.0)
    assert not t.is_alive()
    tx.close(), rx.close()


def test_greet_requires_hello_first():
    tx, rx = _pair()
    tx.send({"type": "result", "unit": 0})  # signed, but not a hello
    with pytest.raises(FrameProtocolError):
        accept_greet(rx, timeout_s=1.0)
    tx.close(), rx.close()
    # a bool worker id is not an identity
    tx, rx = _pair()
    tx.send({"type": "hello", "worker": True})
    with pytest.raises(FrameProtocolError):
        accept_greet(rx, timeout_s=1.0)
    tx.close(), rx.close()


def test_greet_wrong_key_never_welcomed():
    a, b = socket.socketpair()
    tx = FrameChannel(a, OTHER_KEY)
    rx = FrameChannel(b, KEY)
    worker_exc = []

    def worker_greet():
        try:
            greet(tx, 0, timeout_s=2.0)
        except Exception as e:  # noqa: BLE001 - captured for assertion
            worker_exc.append(e)

    t = threading.Thread(target=worker_greet, daemon=True)
    t.start()
    with pytest.raises(FrameSignatureError):
        accept_greet(rx, timeout_s=2.0)
    rx.close()  # coordinator drops: the worker's greet fails fast
    t.join(timeout=5.0)
    assert isinstance(worker_exc[0], FrameProtocolError)
    tx.close()


def test_silent_peer_greet_times_out():
    tx, rx = _pair()
    with pytest.raises(FrameTimeoutError):
        accept_greet(rx, timeout_s=0.1)
    tx.close(), rx.close()


# ---------------------------------------------------------------------------
# endpoint fuzz: a live coordinator under stranger garbage
# ---------------------------------------------------------------------------

def _unit(x):
    return {"value": float(x) * 2.0, "slot_s": 0.0}


def test_stranger_garbage_does_not_wedge_the_fleet():
    from repro.core.tune_service.coordinator import FleetExecutor
    ex = FleetExecutor(workers=1, pool="socket", heartbeat_s=0.05,
                       lease_deadline=40)
    try:
        addr = ex.address
        assert addr is not None
        # a stranger who can reach the port: raw garbage, an unsigned
        # pickle-shaped blob, and a half-greet then hangup
        for blob in (b"\x00" * 64, b"GET / HTTP/1.1\r\n\r\n",
                     _HEADER.pack(MAGIC, VERSION, 0, 16) + b"j" * 48):
            s = socket.create_connection(addr, timeout=2.0)
            s.sendall(blob)
            s.close()
        for i in range(3):
            ex.submit(_unit, i)
        got = [ex.pop_next() for _ in range(3)]
        assert [r["value"] for _, r in got] == [0.0, 2.0, 4.0]
        stats = ex.stats()
        assert stats["n_rejected_frames"] >= 3
        # the stranger never held a lease: nothing was expired for it
        assert stats["degraded"] is False
    finally:
        ex.close()


def test_hostile_coordinator_does_not_wedge_the_worker():
    """A worker dialing a garbage-speaking endpoint fails fast (greet
    gets no valid welcome) instead of redialing forever."""
    from repro.core.tune_service.worker import socket_main

    srv = socket.create_server(("127.0.0.1", 0))
    addr = srv.getsockname()[:2]

    def hostile():
        conn, _ = srv.accept()
        conn.recv(4096)          # swallow the hello
        conn.sendall(b"\xde\xad\xbe\xef" * 16)  # garbage "welcome"
        conn.close()

    t = threading.Thread(target=hostile, daemon=True)
    t.start()
    t0 = time.monotonic()
    socket_main(addr, 0, heartbeat_s=0.05, key=KEY, max_redials=2,
                redial_backoff_s=0.05)
    assert time.monotonic() - t0 < 10.0  # returned, not wedged
    srv.close()


# ---------------------------------------------------------------------------
# FleetSpec: one frozen JSON artifact describes the whole fleet
# ---------------------------------------------------------------------------

def test_fleet_spec_roundtrip(tmp_path):
    spec = FleetSpec.generate(workers=3, port=5555,
                              hosts=("a", "b", "c"), heartbeat_s=0.2)
    path = os.path.join(tmp_path, "fleet.json")
    spec.save(path)
    assert FleetSpec.load(path) == spec
    assert spec.external
    assert len(spec.key_bytes) == 32
    assert FleetSpec.from_dict(spec.to_dict()) == spec


def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="workers"):
        FleetSpec(workers=0)
    with pytest.raises(ValueError, match="one host per worker"):
        FleetSpec(workers=2, hosts=("a",))
    with pytest.raises(ValueError, match="hex"):
        FleetSpec(auth_key="not-hex!")
    with pytest.raises(ValueError, match="16 bytes"):
        FleetSpec(auth_key="aabb")
    with pytest.raises(ValueError, match="max_frame_bytes"):
        FleetSpec(max_frame_bytes=16)
    with pytest.raises(ValueError, match="unknown FleetSpec fields"):
        FleetSpec.from_dict({"workers": 2, "warp_drive": True})
    with pytest.raises(ValueError, match="no auth_key"):
        FleetSpec().key_bytes
    assert FleetSpec.generate(workers=2).max_frame_bytes == \
        DEFAULT_MAX_FRAME_BYTES
