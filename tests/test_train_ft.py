"""Fault-tolerance tests: checkpoint/restart, preemption, straggler
detection, elastic re-mesh, deterministic data resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataState, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models.registry import get_model
from repro.train.trainer import Trainer


@pytest.fixture
def small_trainer(tmp_path):
    def make(workdir="run", **kw):
        cfg, _ = get_model("chatglm3-6b", smoke=True)
        mesh = make_local_mesh()
        defaults = dict(global_batch=4, seq_len=32, total_steps=60,
                        ckpt_every=10, lr=1e-3)
        defaults.update(kw)
        return Trainer(cfg, mesh, str(tmp_path / workdir), **defaults)
    return make


def test_loss_decreases(small_trainer):
    tr = small_trainer()
    out = tr.run(n_steps=30)
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]


def test_checkpoint_restart_resumes_identically(small_trainer, tmp_path):
    # uninterrupted reference: 30 steps in one go
    ref = small_trainer("ref")
    ref.run(n_steps=30)
    ref_losses = {m["step"]: m["loss"] for m in ref.metrics_log}

    # interrupted run: 20 steps (checkpoints at 10, 20), then a FRESH
    # trainer on the same workdir must resume from step 20 and produce the
    # same losses as the uninterrupted run
    tr1 = small_trainer("a")
    tr1.run(n_steps=20)
    tr1.ckpt.wait()
    tr2 = small_trainer("a")
    assert tr2.data_state.step == 20
    out = tr2.run(n_steps=10)
    compared = 0
    for m in out["metrics"]:
        if m["step"] in ref_losses and m["step"] >= 20:
            assert abs(m["loss"] - ref_losses[m["step"]]) < 1e-3, m
            compared += 1
    assert compared >= 1


def test_preemption_checkpoints_on_stop(small_trainer):
    tr = small_trainer("b", ckpt_every=1000)   # no periodic checkpoints
    tr.run(n_steps=5)
    tr.request_stop()
    out = tr.run(n_steps=10)      # stops immediately, final sync ckpt
    from repro.ckpt import latest_step
    assert latest_step(tr.workdir) == out["final_step"]


def test_straggler_detection():
    import time as _time
    from repro.train import trainer as trmod
    cfg, _ = get_model("chatglm3-6b", smoke=True)
    mesh = make_local_mesh()
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, mesh, d, global_batch=4, seq_len=32,
                     total_steps=40, ckpt_every=1000, straggler_z=2.5)
        orig = tr.train_step
        calls = {"n": 0}

        def slow_step(state, batch):
            calls["n"] += 1
            if calls["n"] == 25:
                _time.sleep(1.0)   # injected straggler
            return orig(state, batch)

        tr.train_step = slow_step
        out = tr.run(n_steps=40)
        assert any(s[0] == 24 + out["final_step"] - 40 or True
                   for s in out["stragglers"])
        assert len(out["stragglers"]) >= 1


def test_elastic_remesh_resumes(tmp_path):
    cfg, _ = get_model("chatglm3-6b", smoke=True)
    mesh1 = make_local_mesh()
    tr = Trainer(cfg, mesh1, str(tmp_path / "e"), global_batch=4,
                 seq_len=32, total_steps=40, ckpt_every=10)
    tr.run(n_steps=10)
    tr.ckpt.wait()
    # "new cluster": rebuild mesh (same CPU here; the re-shard path is the
    # same code that handles a different device count)
    mesh2 = make_local_mesh()
    tr.restore_elastic(mesh2)
    assert tr.data_state.step == 10
    out = tr.run(n_steps=5)
    assert out["final_step"] == 15


def test_data_pipeline_deterministic_and_sliced():
    d = SyntheticLM(vocab=128, seq_len=16, global_batch=8, seed=3)
    a = d.batch_at(5)
    b = d.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    half = d.batch_at(5, lo=2, hi=6)
    np.testing.assert_array_equal(a["tokens"][2:6], half["tokens"])
    c = d.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted with masked tail
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert (a["labels"][:, -1] == -1).all()
