"""Asynchronous tuning service: sync equivalence, determinism, resume.

The acceptance bars of the tune-service PR:

* ``Study.tune(executor="async", slots=1, scheduler=None)`` reproduces the
  synchronous path's suggestions and incumbent **bit-identically** for all
  five engines;
* the study is placement-invariant — wall-clock completion order (slot
  delays) cannot change any decision or journal byte;
* a study killed mid-rung and resumed from its journal produces a journal,
  trial table and incumbent byte/bit-identical to an uninterrupted twin;
* a failure in the objective yields a FAILED trial (traceback journaled),
  skips its tell, and does not derail the study.
"""

import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import (EngineSpec, ExperimentSpec, SimOptions, Study,
                        WorkloadSpec)
from repro.core.knobs import Knob, KnobSpace, get_space
from repro.core.tune_service import (ASHAScheduler, AsyncTuningResult,
                                     PROMOTE, STOP, StudyJournal, Trial,
                                     TrialExecutor, read_events)
from repro.core.tune_service.trial import (FAILED, PAUSED, PENDING, RUNNING,
                                           TERMINATED)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

SCALE = 0.02
ALL_ENGINES = ["hemem", "hmsdk", "memtis", "static", "oracle"]

#: static/oracle have no registered knob space; engines read config keys
#: with defaults, so a real-but-inert knob gives the optimizer a domain
TINY_SPACE = KnobSpace([
    Knob("max_migration_rate", 10, 2, 20, is_int=True),
])


def _spec(engine="hemem", workload="gups", **opts):
    return ExperimentSpec(engine=engine,
                          workload=WorkloadSpec(workload, scale=SCALE),
                          options=SimOptions(**opts))


def _space_for(engine):
    try:
        return get_space(engine)
    except KeyError:
        return TINY_SPACE


def _histories_equal(a, b):
    return [(o.config, o.value) for o in a.history] == \
        [(o.config, o.value) for o in b.history]


# ---------------------------------------------------------------------------
# slots=1 / scheduler=None  ==  the synchronous path, bit-identically
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_async_slots1_matches_sync(engine):
    space = _space_for(engine)
    kw = dict(budget=4, seed=9, n_init=3, space=space)
    r_sync = Study(_spec(engine, backend="numpy")).tune(**kw)
    r_async = Study(_spec(engine, backend="numpy")).tune(
        executor="async", slots=1, scheduler=None, **kw)
    assert isinstance(r_async, AsyncTuningResult)
    assert r_async.default_value == r_sync.default_value
    assert _histories_equal(r_sync, r_async)
    assert r_async.best_value == r_sync.best_value
    assert r_async.best.config == r_sync.best.config


def test_async_slots1_matches_sync_jax_crn():
    # the out-of-order tell_batch(crn=True) regression pin: the async path
    # must feed the optimizer the same (config, value) stream as sync even
    # with CRN evaluation
    kw = dict(budget=4, seed=9, n_init=3)
    r_sync = Study(_spec(backend="jax", crn=True)).tune(**kw)
    r_async = Study(_spec(backend="jax", crn=True)).tune(
        executor="async", **kw)
    assert _histories_equal(r_sync, r_async)
    assert r_async.best.config == r_sync.best.config
    assert r_async.best_value == r_sync.best_value


def test_sync_path_rejects_async_knobs():
    with pytest.raises(ValueError, match="executor='async'"):
        Study(_spec(backend="numpy")).tune(budget=2, slots=4)
    with pytest.raises(ValueError, match="scheduler='asha'"):
        Study(_spec(backend="numpy")).tune(
            budget=2, executor="async", scheduler="asha",
            objective=lambda c: 0.0)
    with pytest.raises(ValueError, match="unknown executor"):
        Study(_spec(backend="numpy")).tune(budget=2, executor="ray")


# ---------------------------------------------------------------------------
# placement invariance: slot delays cannot change decisions
# ---------------------------------------------------------------------------
def test_async_placement_invariant_under_slot_delays(tmp_path):
    # deterministic values, adversarially jittered completion times: the
    # journals (every ask/eval/tell decision) must still be byte-identical
    def make_objective(jitter_seed):
        rng = np.random.default_rng(jitter_seed)

        def obj(cfg):
            time.sleep(float(rng.random()) * 0.01)
            return float(cfg["sampling_period"])

        return obj

    journals = []
    for run, jitter in enumerate([0, 1234]):
        j = str(tmp_path / f"jit{run}.jsonl")
        r = Study(_spec(backend="numpy")).tune(
            budget=6, seed=9, n_init=3, executor="async", slots=3,
            objective=make_objective(jitter), journal=j)
        journals.append(open(j, "rb").read())
        assert len(r.history) == 6
    assert journals[0] == journals[1]


# ---------------------------------------------------------------------------
# trial state machine
# ---------------------------------------------------------------------------
def test_trial_state_machine():
    t = Trial(index=0, config={}, encoded=np.zeros(1), spec={}, seed=0)
    assert t.state == PENDING
    with pytest.raises(ValueError, match="illegal trial transition"):
        t.advance(PAUSED)
    t.advance(RUNNING)
    t.advance(PAUSED)
    t.advance(RUNNING)
    t.advance(TERMINATED)
    assert t.terminal
    with pytest.raises(ValueError):
        t.advance(RUNNING)
    with pytest.raises(ValueError, match="unknown trial state"):
        Trial(index=1, config={}, encoded=np.zeros(1), spec={},
              seed=0).advance("ZOMBIE")


def test_trial_value_at_is_segment_invariant():
    t = Trial(index=0, config={}, encoded=np.zeros(1), spec={}, seed=0)
    wall = np.linspace(1.0, 60.0, 60)
    t.epoch_wall_ms = [wall[:15], wall[15:30], wall[30:]]
    u = Trial(index=1, config={}, encoded=np.zeros(1), spec={}, seed=0)
    u.epoch_wall_ms = [wall]
    for e in (15, 30, 60):
        assert t.value_at(e) == u.value_at(e)
    with pytest.raises(ValueError, match="evaluated epochs"):
        t.value_at(61)


# ---------------------------------------------------------------------------
# ASHA scheduler
# ---------------------------------------------------------------------------
def test_asha_rung_budgets():
    s = ASHAScheduler(60)
    assert s.rung_epochs == (15, 30, 60)
    assert ASHAScheduler(1).rung_epochs == (1,)   # degenerate rungs dedupe
    assert ASHAScheduler(5).rung_epochs == (2, 3, 5)
    with pytest.raises(ValueError):
        ASHAScheduler(60, eta=1)


def test_asha_promotion_rule():
    s = ASHAScheduler(60, eta=4)
    # first result at a rung is always the current best -> promotes
    assert s.report(0, 0, 10.0) == PROMOTE
    # worse results stop while the pool is small
    assert s.report(0, 1, 20.0) == STOP
    assert s.report(0, 2, 30.0) == STOP
    # a new best promotes...
    assert s.report(0, 3, 5.0) == PROMOTE
    # ...and with 8 results there are two promotion slots
    for i, v in enumerate([40.0, 50.0, 60.0], start=4):
        assert s.report(0, i, v) == STOP
    assert s.report(0, 7, 7.0) == PROMOTE
    # final rung never decides
    with pytest.raises(ValueError, match="final budget"):
        s.report(s.n_rungs - 1, 0, 1.0)


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------
def test_executor_commits_in_creation_order():
    ex = TrialExecutor(slots=4)
    try:
        delays = [0.03, 0.0, 0.02, 0.0]

        def unit(i):
            time.sleep(delays[i])
            return {"value": i}

        for i in range(4):
            ex.submit(unit, i)
        got = [ex.pop_next() for _ in range(4)]
        assert [seq for seq, _ in got] == [0, 1, 2, 3]
        assert [r["value"] for _, r in got] == [0, 1, 2, 3]
        assert ex.outstanding == 0
        assert ex.busy_s > 0.0
    finally:
        ex.close()


def test_executor_wraps_failures():
    ex = TrialExecutor(slots=1)
    try:
        def boom():
            raise RuntimeError("kaput")

        ex.submit(boom)
        _, result = ex.pop_next()
        assert "kaput" in result["error"] and "slot_s" in result
    finally:
        ex.close()
    with pytest.raises(ValueError, match="slots"):
        TrialExecutor(slots=0)
    with pytest.raises(ValueError, match="pool"):
        TrialExecutor(slots=1, pool="fiber")


# ---------------------------------------------------------------------------
# journal
# ---------------------------------------------------------------------------
def test_journal_tolerates_torn_tail(tmp_path):
    path = str(tmp_path / "j.jsonl")
    with StudyJournal(path) as j:
        j.append({"event": "study", "version": 1})
        j.append({"event": "ask", "trial": 0, "config": {"a": 1}})
        j.append({"event": "eval", "trial": 0, "epochs": 4, "value": 2.5})
    raw = open(path, "rb").read()
    open(path, "wb").write(raw[:-9])  # SIGKILL lands mid-append
    assert [e["event"] for e in read_events(path)] == ["study", "ask"]
    # resume truncates the torn bytes so appends continue cleanly
    with StudyJournal(path, resume=True) as j:
        assert j.append({"event": "study", "version": 1})["version"] == 1
        assert j.append({"event": "ask", "trial": 0,
                         "config": {"a": 1}})["config"] == {"a": 1}
        assert not j.replaying
        j.append({"event": "eval", "trial": 0, "epochs": 4, "value": 2.5})
    assert open(path, "rb").read() == raw


def test_journal_replay_divergence_raises(tmp_path):
    path = str(tmp_path / "j.jsonl")
    with StudyJournal(path) as j:
        j.append({"event": "study", "budget": 8})
    with StudyJournal(path, resume=True) as j:
        with pytest.raises(ValueError, match="diverged"):
            j.append({"event": "study", "budget": 16})
    with StudyJournal(path, resume=True) as j:
        with pytest.raises(ValueError, match="diverged"):
            j.append({"event": "ask", "trial": 0})
    with pytest.raises(FileNotFoundError):
        StudyJournal(str(tmp_path / "nope.jsonl"), resume=True)


def test_resume_requires_journal():
    with pytest.raises(ValueError, match="journal"):
        Study(_spec(backend="numpy")).tune(
            budget=2, executor="async", resume=True)


# ---------------------------------------------------------------------------
# fault injection (satellite: robustness)
# ---------------------------------------------------------------------------
def test_failed_trial_is_journaled_and_skipped(tmp_path):
    calls = {"n": 0}

    def obj(cfg):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected fault")
        return float(cfg["sampling_period"])

    j = str(tmp_path / "fault.jsonl")
    # retries=0 pins the first-error-is-terminal path (the default
    # retries=1 would absorb this one-shot transient; see the retry tests)
    r = Study(_spec(backend="numpy")).tune(
        budget=6, seed=9, n_init=3, executor="async", slots=2,
        objective=obj, journal=j, retries=0)
    states = [t["state"] for t in r.trials]
    assert states.count(FAILED) == 1 and r.n_failed == 1
    assert states.count(TERMINATED) == 5
    # the failed trial's tell was skipped; everything else was told
    assert len(r.history) == 5
    failed = next(t for t in r.trials if t["state"] == FAILED)
    assert "injected fault" in failed["error"]
    fails = [e for e in read_events(j) if e["event"] == "fail"]
    assert len(fails) == 1 and fails[0]["trial"] == failed["index"]
    assert "injected fault" in fails[0]["error"]
    assert not any(e["event"] == "tell" and e["trial"] == failed["index"]
                   for e in read_events(j))


def test_default_config_failure_is_fatal():
    def obj(cfg):
        raise RuntimeError("doomed from the start")

    with pytest.raises(RuntimeError, match="default-config baseline"):
        Study(_spec(backend="numpy")).tune(
            budget=2, executor="async", objective=obj)


def test_executor_unit_timeout():
    # a hung unit comes back as a timeout error result; the slot pool
    # survives and later units still run
    ex = TrialExecutor(slots=2)
    try:
        def sleeper():
            time.sleep(1.0)
            return {"value": 1.0}

        def quick():
            return {"value": 2.0}

        ex.submit(sleeper, timeout_s=0.2)
        ex.submit(quick)
        seq, r = ex.pop_next()
        assert seq == 0 and r.get("timeout")
        assert "timeout" in r["error"] and r["slot_s"] == 0.2
        _, r2 = ex.pop_next()
        assert r2["value"] == 2.0
    finally:
        ex.close()


def test_executor_close_cancels_queued():
    # close() must cancel queued units so an aborted study doesn't leave
    # orphan segments burning slots
    ran = []
    ex = TrialExecutor(slots=1)

    def unit(i):
        ran.append(i)
        time.sleep(0.2)
        return {"value": i}

    for i in range(3):
        ex.submit(unit, i)
    deadline = time.time() + 5.0
    while not ran and time.time() < deadline:
        time.sleep(0.005)
    ex.close()  # unit 0 is running (close waits for it); 1 and 2 cancel
    time.sleep(0.25)
    assert ran == [0]


def test_fail_n_times_markers_are_exact(tmp_path):
    # the atomic-marker contract: exactly n callers fail, later calls
    # succeed — the cross-process fault budget cannot over- or undershoot
    from repro.core.tune_service import FailNTimes
    obj = FailNTimes(str(tmp_path), n=2)
    cfg = {"sampling_period": 7}
    for _ in range(2):
        with pytest.raises(RuntimeError, match="transient"):
            obj(cfg)
    assert obj(cfg) == 7.0


def test_process_pool_worker_death_heals(tmp_path):
    # a pool="process" slot SIGKILLed mid-unit poisons the shared pool;
    # the executor rebuilds it and resubmits — results are deterministic,
    # so the study matches a fault-free twin exactly
    from repro.core.tune_service import KillNTimes
    kw = dict(budget=5, seed=9, n_init=3, executor="async", slots=2,
              pool="process")
    clean = Study(_spec(backend="numpy")).tune(
        objective=KillNTimes(str(tmp_path), n=0), **kw)
    killed_dir = tmp_path / "kills"
    killed_dir.mkdir()
    healed = Study(_spec(backend="numpy")).tune(
        objective=KillNTimes(str(killed_dir), n=1), **kw)
    assert healed.n_failed == 0
    assert healed.best_value == clean.best_value
    assert _histories_equal(healed, clean)
    assert len(os.listdir(killed_dir)) == 1  # the kill really fired


# ---------------------------------------------------------------------------
# bounded trial retry (satellite: robustness)
# ---------------------------------------------------------------------------
def test_transient_failure_retried_journal_twins(tmp_path):
    # slots=1 makes the call order canonical (default, trial 0, ...), so
    # failing exactly call 2 = trial 0's first attempt is deterministic:
    # the default retries=1 absorbs it, and two runs journal identically
    def make_objective():
        calls = {"n": 0}

        def obj(cfg):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("injected transient fault")
            return float(cfg["sampling_period"])

        return obj

    raws, runs = [], []
    for twin in range(2):
        j = str(tmp_path / f"retry{twin}.jsonl")
        r = Study(_spec(backend="numpy")).tune(
            budget=4, seed=9, n_init=3, executor="async", slots=1,
            objective=make_objective(), journal=j)
        runs.append(r)
        raws.append(open(j, "rb").read())
    assert raws[0] == raws[1]
    for r in runs:
        assert r.n_failed == 0
        assert all(t["state"] == TERMINATED for t in r.trials)
    events = read_events(str(tmp_path / "retry0.jsonl"))
    retries = [e for e in events if e["event"] == "retry"]
    assert len(retries) == 1
    assert retries[0]["trial"] == 0 and retries[0]["attempt"] == 1
    assert "injected transient fault" in retries[0]["error"]
    assert not any(e["event"] == "fail" for e in events)


def test_persistent_failure_retries_then_fails(tmp_path):
    # both the first attempt AND the bounded retry fail: the trial is
    # journaled retry-then-fail and surrendered as FAILED
    calls = {"n": 0}

    def obj(cfg):
        calls["n"] += 1
        if calls["n"] in (2, 3):  # trial 0's attempt 0 and its retry
            raise RuntimeError("injected persistent fault")
        return float(cfg["sampling_period"])

    j = str(tmp_path / "persist.jsonl")
    r = Study(_spec(backend="numpy")).tune(
        budget=4, seed=9, n_init=3, executor="async", slots=1,
        objective=obj, journal=j)
    states = [t["state"] for t in r.trials]
    assert states.count(FAILED) == 1 and r.n_failed == 1
    failed = next(t for t in r.trials if t["state"] == FAILED)
    assert failed["index"] == 0
    events = read_events(j)
    kinds = [e["event"] for e in events]
    assert kinds.count("retry") == 1 and kinds.count("fail") == 1
    assert kinds.index("retry") < kinds.index("fail")


# ---------------------------------------------------------------------------
# ASHA end-to-end + journal twins (jax checkpoint path)
# ---------------------------------------------------------------------------
def test_asha_async_jax_journal_twins(tmp_path):
    kw = dict(budget=8, seed=9, n_init=3, executor="async", slots=3,
              scheduler="asha")
    j1, j2 = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    r1 = Study(_spec(backend="jax")).tune(journal=j1, **kw)
    r2 = Study(_spec(backend="jax")).tune(journal=j2, **kw)
    assert open(j1, "rb").read() == open(j2, "rb").read()
    assert r1.trials == r2.trials
    # rung budgets are respected and early stops actually saved epochs
    rungs = (15, 30, 60)
    for t in r1.trials:
        assert t["epochs_run"] in rungs
    assert r1.n_stopped_early > 0
    assert 0.0 < r1.asha_epochs_saved_frac < 1.0
    # the incumbent is always a fully-evaluated trial
    assert r1.best_row["epochs_run"] == 60
    # extrapolated tells: early-stopped trials enter the history scaled to
    # full budget
    stopped = [t for t in r1.trials if t["epochs_run"] < 60]
    for t in stopped:
        assert t["told_value"] == pytest.approx(
            t["value"] * 60 / t["epochs_run"])


def test_asha_resume_from_torn_journal_is_bit_identical(tmp_path):
    kw = dict(budget=8, seed=9, n_init=3, executor="async", slots=3,
              scheduler="asha")
    j1, j2 = str(tmp_path / "full.jsonl"), str(tmp_path / "torn.jsonl")
    r1 = Study(_spec(backend="jax")).tune(journal=j1, **kw)
    raw = open(j1, "rb").read()
    lines = raw.split(b"\n")
    torn = b"\n".join(lines[:-7]) + b"\n" + lines[-7][:10]
    open(j2, "wb").write(torn)
    r2 = Study(_spec(backend="jax")).tune(journal=j2, resume=True, **kw)
    assert open(j2, "rb").read() == raw
    assert r2.trials == r1.trials
    assert r2.best_value == r1.best_value
    assert r2.best.config == r1.best.config
    assert r2.resumed


def test_resume_complete_journal_runs_no_evaluations(tmp_path, monkeypatch):
    import repro.core.tune_service.service as svc
    kw = dict(budget=5, seed=9, n_init=3, executor="async", slots=2)
    j = str(tmp_path / "done.jsonl")
    r1 = Study(_spec(backend="numpy")).tune(journal=j, **kw)
    raw = open(j, "rb").read()

    def no_eval(payload):
        raise AssertionError("complete journal must not re-evaluate")

    monkeypatch.setattr(svc, "_eval_segment", no_eval)
    r2 = Study(_spec(backend="numpy")).tune(journal=j, resume=True, **kw)
    assert open(j, "rb").read() == raw
    assert r2.trials == r1.trials and r2.best_value == r1.best_value


def test_resume_rejects_changed_parameters(tmp_path):
    j = str(tmp_path / "j.jsonl")
    Study(_spec(backend="numpy")).tune(
        budget=3, seed=9, n_init=2, executor="async", journal=j)
    with pytest.raises(ValueError, match="diverged"):
        Study(_spec(backend="numpy")).tune(
            budget=5, seed=9, n_init=2, executor="async", journal=j,
            resume=True)


# ---------------------------------------------------------------------------
# kill/resume (satellite: SIGKILL a live study mid-rung)
# ---------------------------------------------------------------------------
_KILL_SCRIPT = """
import sys
sys.path.insert(0, {src!r})
from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
spec = ExperimentSpec(engine="hemem",
                      workload=WorkloadSpec("gups", scale={scale!r}),
                      options=SimOptions(backend="numpy"))
print("ready", flush=True)
Study(spec).tune(budget=64, seed=9, n_init=5, executor="async", slots=4,
                 scheduler="asha", journal={journal!r})
"""


def test_sigkill_then_resume_matches_uninterrupted_twin(tmp_path):
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    kw = dict(budget=64, seed=9, n_init=5, executor="async", slots=4,
              scheduler="asha")
    j_twin = str(tmp_path / "twin.jsonl")
    r_twin = Study(_spec(backend="numpy")).tune(journal=j_twin, **kw)

    j_kill = str(tmp_path / "killed.jsonl")
    proc = subprocess.Popen(
        [sys.executable, "-c",
         _KILL_SCRIPT.format(src=os.path.abspath(src), scale=SCALE,
                             journal=j_kill)],
        stdout=subprocess.PIPE)
    try:
        # SIGKILL once the study is demonstrably mid-rung (journal growing)
        deadline = time.time() + 120
        while time.time() < deadline:
            if os.path.exists(j_kill) and \
                    len(open(j_kill, "rb").read().splitlines()) >= 20:
                break
            time.sleep(0.01)
        else:
            pytest.fail("killed study never reached mid-rung")
        os.kill(proc.pid, signal.SIGKILL)
    finally:
        proc.wait()
    killed_events = read_events(j_kill)
    assert 0 < len(killed_events) < len(read_events(j_twin))

    r_res = Study(_spec(backend="numpy")).tune(journal=j_kill, resume=True,
                                               **kw)
    assert open(j_kill, "rb").read() == open(j_twin, "rb").read()
    assert r_res.trials == r_twin.trials
    assert r_res.best_value == r_twin.best_value
    assert r_res.best.config == r_twin.best.config
    assert _histories_equal(r_twin, r_res)
