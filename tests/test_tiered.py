"""TieredKVCache / TieredParamStore behaviour + optimizer/compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiered_kv import KVSpec, TieredKVCache
from repro.core.tiered_params import TieredParamStore
from repro.optim import (Adafactor, AdamW, compressed_psum, ef_compress,
                         ef_decompress)


def _fill(cache: TieredKVCache, steps: int, rng):
    s = cache.spec
    for _ in range(steps):
        k = rng.normal(size=(cache.batch, s.n_layers, s.kv_heads,
                             s.head_dim))
        cache.append(k, k)


def test_tiered_kv_append_attend_roundtrip():
    rng = np.random.default_rng(0)
    spec = KVSpec(n_layers=2, kv_heads=2, head_dim=16, page_tokens=4)
    cache = TieredKVCache(spec, batch=2, max_pages_per_seq=8, hbm_pages=16)
    _fill(cache, 12, rng)
    q = rng.normal(size=(2, 4, 16))
    out = cache.attend(q)
    assert out.shape == (2, 4, 16)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    assert (cache.lengths == 12).all()


def test_tiered_kv_engine_keeps_hot_pages_resident():
    """With a tiny fast tier, the engine should keep the high-attention-mass
    pages (sink + recent) resident, beating a no-migration baseline."""
    rng = np.random.default_rng(1)
    spec = KVSpec(n_layers=1, kv_heads=1, head_dim=8, page_tokens=4)

    def run(config, migrate: bool):
        cache = TieredKVCache(spec, batch=1, max_pages_per_seq=64,
                              hbm_pages=8, config=config)
        for step in range(180):
            k = rng.normal(size=(1, 1, 1, 8))
            cache.append(k, k)
            cache.record_reads()
            if migrate and step % 10 == 9:
                cache.step_engine(100.0)
        return cache

    tuned = run(dict(read_hot_threshold=1, sampling_period=100,
                     migration_period=10), migrate=True)
    frozen = run(dict(), migrate=False)
    assert tuned.recall() > frozen.recall()
    assert tuned.migrations > 0


def test_tiered_kv_attend_only_uses_resident_pages():
    rng = np.random.default_rng(2)
    spec = KVSpec(n_layers=1, kv_heads=1, head_dim=8, page_tokens=4)
    cache = TieredKVCache(spec, batch=1, max_pages_per_seq=8, hbm_pages=2)
    _fill(cache, 16, rng)   # 4 pages; only 2 fit
    assert (cache.slot_of >= 0).sum() <= 2
    q = rng.normal(size=(1, 1, 8))
    out = cache.attend(q)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())


def test_tiered_params_hot_experts_promoted():
    rng = np.random.default_rng(3)
    weights = {"w": rng.normal(size=(16, 8, 8)).astype(np.float32)}
    store = TieredParamStore(weights, hbm_experts=4,
                             config=dict(read_hot_threshold=1,
                                         sampling_period=100))
    hot = np.array([12, 13, 14, 15])
    for _ in range(30):
        store.route(np.repeat(hot, 50))
        store.step_engine(100.0)
    assert set(np.flatnonzero(store.slot_of >= 0)) >= set(hot.tolist())
    # gather returns correct values regardless of tier
    g = store.gather("w", np.array([12, 0]))
    np.testing.assert_allclose(np.asarray(g[0], np.float32),
                               weights["w"][12], atol=2e-2)


def test_adamw_and_adafactor_reduce_quadratic():
    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)

    for opt in (AdamW(lr=0.1), Adafactor(lr=0.5)):
        params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
        state = opt.init(params)
        l0 = float(loss(params))
        for _ in range(60):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert float(loss(params)) < 0.05 * l0, type(opt).__name__


def test_ef_int8_compression_error_feedback():
    rng = np.random.default_rng(4)
    g_stream = [jnp.asarray(rng.normal(size=(64,)), jnp.float32)
                for _ in range(50)]
    residual = jnp.zeros((64,))
    err_accum = jnp.zeros((64,))
    for g in g_stream:
        q, scale, residual = ef_compress(g, residual)
        out = ef_decompress(q, scale)
        err_accum = err_accum + (g - out)
    # with error feedback, the *accumulated* error stays bounded (the
    # residual carries it forward instead of losing it)
    assert float(jnp.abs(residual).max()) < 0.05
    per_step_err = float(jnp.abs(err_accum).mean()) / len(g_stream)
    assert per_step_err < 0.01
