"""Online re-tuning: window loop, warm restart, hysteresis guard,
journal kill/resume byte-identity, and fleet x ASHA composition.

Contracts pinned here (see ``repro.core.tune_online``):

* ``Study.tune(online=True)`` re-adapts on a drifting trace: it detects
  phase changes, applies config switches behind the hysteresis/dwell
  guard, and by construction can never thrash (``thrash_events == 0``);
* the run is a deterministic function of ``(spec, seed, parameters)`` —
  two runs journal byte-identical files, and a killed run resumed from a
  truncated journal (torn final line included) reproduces the
  uninterrupted journal byte for byte;
* warm restart (``SMACOptimizer(seed_configs=...)``) suggests the seeded
  elites first, before default/random init;
* ``executor="fleet"`` with ``scheduler="asha"`` actually early-stops
  (it used to silently run every trial at full budget, then fail fast —
  ROADMAP 3a, closed by the hardened-fleet PR: rung segments re-derive
  ``[0, hi)`` from scratch, so promote/stop composes with leases).
"""

import os

import numpy as np
import pytest

from repro.core import DriftSpec, ExperimentSpec, SimOptions, Study
from repro.core.bo.smac import SMACOptimizer
from repro.core.knobs import get_space

pytestmark = []

jax = pytest.importorskip("jax")

# a tiny 2-phase hot-set rotation: enough epochs for two windows per
# phase at W=4, small enough that the whole suite compiles one shape
TINY = DriftSpec.hotspot(base="gups", n_phases=2, phase_epochs=8)


def _study(seed=0, scale=0.03):
    return Study(ExperimentSpec(
        engine="hemem", workload=dict(name=TINY.register(), scale=scale),
        options=SimOptions(seed=seed, backend="jax", crn=True,
                           sampler="sparse")))


def _tune(study, **kw):
    args = dict(online=True, window_epochs=4, batch_size=3, budget=12,
                seed=1)
    args.update(kw)
    return study.tune(**args)


# ---------------------------------------------------------------------------
# the loop end to end
# ---------------------------------------------------------------------------

def test_online_smoke_readapts_without_thrash():
    res = _tune(_study())
    assert len(res.windows) == 4              # 16 epochs / W=4
    assert res.total_wall_ms > 0
    assert res.evals_used <= 12
    assert res.thrash_events == 0             # guard makes it structural
    assert res.detections >= 1                # the rotation is detected
    # every window journals the full decision record
    w = res.windows[1]
    assert w.epoch_lo == 4 and w.epoch_hi == 8
    assert w.deployed and len(w.candidate_walls_ms) == len(w.candidates)
    assert res.windows[1].divergence is not None


def test_online_deployed_wall_is_cumulative():
    res = _tune(_study())
    assert res.total_wall_ms == pytest.approx(
        float(res.deployed_walls.sum()))


def test_online_switch_requires_hysteresis_margin():
    """hysteresis=1-eps means no candidate can ever clear the margin:
    zero switches, and the would-be wins are counted as guard blocks."""
    res = _tune(_study(), hysteresis=0.999)
    assert res.switches == 0
    assert res.thrash_events == 0


def test_online_budget_caps_candidate_evals():
    res = _tune(_study(), budget=5)
    assert res.evals_used <= 5


# ---------------------------------------------------------------------------
# determinism + journal kill/resume
# ---------------------------------------------------------------------------

def test_online_journal_deterministic_and_resumable(tmp_path):
    j1, j2, jt = (str(tmp_path / n) for n in ("a.jsonl", "b.jsonl",
                                              "torn.jsonl"))
    _tune(_study(), journal=j1)
    _tune(_study(), journal=j2)
    ref = open(j1, "rb").read()
    assert open(j2, "rb").read() == ref       # deterministic twin

    # kill mid-study: keep 3 complete events plus a TORN 4th line, resume
    lines = ref.splitlines(keepends=True)
    assert len(lines) >= 5
    with open(jt, "wb") as f:
        f.write(b"".join(lines[:3]) + lines[3][: len(lines[3]) // 2])
    res = _tune(_study(), journal=jt, resume=True)
    assert open(jt, "rb").read() == ref       # byte-identical resume
    assert res.thrash_events == 0


def test_online_resume_rejects_mismatched_params(tmp_path):
    j = str(tmp_path / "j.jsonl")
    _tune(_study(), journal=j)
    with pytest.raises(ValueError, match="diverged"):
        _tune(_study(), journal=j, resume=True, seed=2)


# ---------------------------------------------------------------------------
# argument validation
# ---------------------------------------------------------------------------

def test_online_requires_window_epochs():
    with pytest.raises(ValueError, match="window_epochs"):
        _study().tune(online=True)


def test_window_epochs_requires_online():
    with pytest.raises(ValueError, match="online=True"):
        _study().tune(window_epochs=4)


def test_online_rejects_async_executor():
    with pytest.raises(ValueError, match="incompatible"):
        _tune(_study(), executor="async")


def test_online_requires_jax_backend():
    st = Study(ExperimentSpec(
        engine="hemem", workload=dict(name=TINY.register(), scale=0.03),
        options=SimOptions(backend="numpy")))
    with pytest.raises(ValueError, match="jax"):
        _tune(st)


# ---------------------------------------------------------------------------
# warm restart: seeded elites go out first
# ---------------------------------------------------------------------------

def test_seed_configs_suggested_first_in_order():
    space = get_space("hemem")
    rng = np.random.default_rng(0)
    elites = [space.sample(rng) for _ in range(3)]
    opt = SMACOptimizer(space, seed=0, seed_configs=elites)
    assert [opt.ask() for _ in range(3)] == elites


def test_seed_configs_fill_batch_head_then_backfill():
    space = get_space("hemem")
    rng = np.random.default_rng(0)
    elites = [space.sample(rng) for _ in range(2)]
    opt = SMACOptimizer(space, seed=0, seed_configs=elites)
    batch = opt.ask_batch(5)
    assert len(batch) == 5
    assert batch[:2] == elites
    # more seeds than the batch: the remainder stays queued
    opt2 = SMACOptimizer(space, seed=0, seed_configs=elites * 3)
    assert len(opt2.ask_batch(4)) == 4
    assert opt2.ask() == elites[0]  # 5th seed still queued


# ---------------------------------------------------------------------------
# fleet x ASHA: early stopping now composes with leases (ROADMAP 3a)
# ---------------------------------------------------------------------------

def test_fleet_asha_early_stops():
    """The fleet executor honours ASHA rungs: stopped trials run fewer
    epochs than promoted ones, and the incumbent matches the local async
    ASHA run bitwise (the old code silently ran full budget, then failed
    fast; rung segments now re-derive ``[0, hi)`` from scratch)."""
    def spec():
        return ExperimentSpec(
            engine="hemem", workload=dict(name="gups", scale=0.03),
            options=SimOptions(backend="jax", sampler="sparse"))
    kw = dict(budget=4, seed=3, n_init=2, scheduler="asha")
    base = Study(spec()).tune(executor="async", slots=2, **kw)
    r = Study(spec()).tune(executor="fleet", workers=2, **kw)
    assert [(o.config, o.value) for o in r.history] == \
        [(o.config, o.value) for o in base.history]
    assert r.epochs_committed == base.epochs_committed
    # early stopping really fired: not every trial reached full epochs
    assert r.epochs_committed < r.budget * r.max_epochs
    assert r.asha_epochs_saved_frac > 0
