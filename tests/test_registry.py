"""Registry layer: register/lookup/did-you-mean, spec JSON round-trips."""

import json

import pytest

from repro.core.knobs import SPACES, Knob, KnobSpace, get_space
from repro.core.registry import (BACKENDS, ENGINES, MACHINES, SAMPLERS,
                                 WORKLOADS, Registry, register_engine)
from repro.core.specs import (EngineSpec, ExperimentSpec, SimOptions,
                              WorkloadSpec)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# ---------------------------------------------------------------------------
# Registry mechanics
# ---------------------------------------------------------------------------
def test_register_direct_and_decorator():
    reg = Registry("widget")
    reg.register("a", 1)

    @reg.register("b")
    def thing():
        return 2

    assert reg.get("a") == 1 and reg.get("b") is thing
    assert reg.names() == ["a", "b"] and "a" in reg and len(reg) == 2


def test_duplicate_registration_rejected_unless_overwrite():
    reg = Registry("widget")
    reg.register("a", 1)
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a", 2)
    reg.register("a", 2, overwrite=True)
    assert reg.get("a") == 2


def test_unknown_name_suggests_close_match():
    reg = Registry("widget")
    reg.register("elementwise", 1)
    with pytest.raises(KeyError) as ei:
        reg.get("elementwize")
    assert "did you mean 'elementwise'" in str(ei.value)
    assert "unknown widget" in str(ei.value)


def test_builtin_registries_are_populated():
    assert {"hemem", "hmsdk", "memtis", "static", "oracle"} <= set(ENGINES)
    assert {"gups", "silo", "btree", "xsbench", "graph500", "gapbs-bc",
            "gapbs-pr", "gapbs-cc"} <= set(WORKLOADS)
    assert {"elementwise", "sparse"} <= set(SAMPLERS)
    assert {"numpy", "jax"} <= set(BACKENDS)
    assert {"pmem-large", "pmem-small", "numa"} <= set(MACHINES)


def test_entry_points_raise_with_suggestions():
    from repro.core.engine import make_batch_engine
    from repro.core.pages import BatchTierState
    from repro.core.simulator import get_machine
    from repro.core.workloads import make_workload
    with pytest.raises(KeyError, match="did you mean 'gups'"):
        make_workload("gupz")
    with pytest.raises(KeyError, match="did you mean 'hemem'"):
        make_batch_engine("hemen", [{}], BatchTierState(1, 16, 4))
    with pytest.raises(KeyError, match="did you mean 'pmem-small'"):
        get_machine("pmem-smal")
    with pytest.raises(KeyError, match="did you mean 'sparse'"):
        SimOptions(sampler="sparze")


def test_builtin_components_are_picklable_for_pool_shards():
    # run_simulation_batch ships the resolved engine/workload/sampler/backend
    # to process-pool workers so spawn-start children can re-register them;
    # builtins must therefore stay picklable module-level objects
    import pickle
    for reg, names in ((ENGINES, ["hemem", "hmsdk", "memtis", "static",
                                  "oracle"]),
                       (WORKLOADS, ["gups", "silo", "gapbs-bc"]),
                       (SAMPLERS, ["elementwise", "sparse"]),
                       (BACKENDS, ["numpy", "jax"])):
        for name in names:
            pickle.dumps(reg.get(name))


def test_registry_get_supports_dict_style_default():
    assert ENGINES.get("not-an-engine", None) is None
    assert ENGINES.get("not-an-engine", 42) == 42
    with pytest.raises(KeyError):
        ENGINES.get("not-an-engine")


def test_register_engine_with_space_feeds_get_space():
    space = KnobSpace([Knob("k", 1, 1, 10)])

    @register_engine("spaced-reg-test", space=space)
    class _Dummy:  # noqa: D401 — only registration is under test
        pass

    assert ENGINES.get("spaced-reg-test") is _Dummy
    assert get_space("spaced-reg-test") is space
    # don't leak into other tests (the dummy isn't a usable engine)
    ENGINES.unregister("spaced-reg-test")
    del SPACES["spaced-reg-test"]


# ---------------------------------------------------------------------------
# Specs: validation + JSON round-trip
# ---------------------------------------------------------------------------
def test_engine_spec_validates_and_completes_config():
    s = EngineSpec("hemem", {"sampling_period": 200})
    assert s.config["sampling_period"] == 200
    assert s.config.keys() == get_space("hemem").default_config().keys()
    with pytest.raises(KeyError, match="unknown knobs"):
        EngineSpec("hemem", {"bogus_knob": 1})
    assert EngineSpec("static").config == {}  # no knob space: passthrough


def test_workload_spec_validates():
    with pytest.raises(KeyError, match="unknown workload"):
        WorkloadSpec("nope")
    with pytest.raises(ValueError, match="scale"):
        WorkloadSpec("gups", scale=0.0)
    assert WorkloadSpec("silo", "ycsb-c").key == "silo:ycsb-c"


def test_spec_json_round_trip():
    spec = ExperimentSpec(
        engine=EngineSpec("hemem", {"read_hot_threshold": 4}),
        workload=WorkloadSpec("silo", "ycsb-c", threads=8, scale=0.1),
        machine="pmem-small", fast_slow_ratio=4.0,
        options=SimOptions(seed=11, sampler="sparse", workers=2,
                           backend="numpy"))
    wire = json.loads(json.dumps(spec.to_dict()))
    assert ExperimentSpec.from_dict(wire) == spec
    # shorthand coercion yields the same spec as the explicit form
    assert ExperimentSpec(engine="static", workload="gups") == \
        ExperimentSpec(engine=EngineSpec("static"),
                       workload=WorkloadSpec("gups"))


def test_sim_options_round_trip_and_validation():
    o = SimOptions(seed=3, sampler="sparse", workers="auto", backend="jax")
    assert SimOptions.from_dict(json.loads(json.dumps(o.to_dict()))) == o
    with pytest.raises(KeyError, match="unknown backend"):
        SimOptions(backend="torch")
