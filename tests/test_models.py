"""Per-architecture smoke tests (reduced configs) + decode/prefill
consistency for the attention families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import SHAPES
from repro.models.registry import (extra_shape, get_model, list_archs,
                                   make_batch, shape_applicable)

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg, _ = get_model(arch, smoke=True)
    params, specs = T.init(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 32)
    logits, aux = T.forward(params, cfg, batch["tokens"],
                            batch.get("extra"))
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss = T.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    # specs tree mirrors params tree
    jax.tree.map(lambda p, s: None, params, specs,
                 is_leaf=lambda x: hasattr(x, "shape") or x is None)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.optim import make_optimizer
    from repro.train.step import build_train_step, make_state
    cfg, _ = get_model(arch, smoke=True)
    opt = make_optimizer("adamw", 1e-3)
    state, _ = make_state(jax.random.PRNGKey(0), cfg, opt)
    step = build_train_step(cfg, opt, n_micro=2, use_flash=False)
    batch = make_batch(cfg, 4, 16)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree.map(lambda a, b: a.astype(jnp.float32) -
                     b.astype(jnp.float32), state.params, state2.params),
        0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits
    position by position (exact cache correctness)."""
    cfg, _ = get_model(arch, smoke=True)
    if any(k in ("mlstm", "slstm", "rglru") for k in cfg.pattern):
        tol = 0.15   # recurrent chunked vs stepwise: fp32 assoc differences
    else:
        tol = 3e-2   # bf16 matmul order differences
    params, _ = T.init(jax.random.PRNGKey(1), cfg)
    B, S = 2, 8
    batch = make_batch(cfg, B, S, key=jax.random.PRNGKey(2))
    tokens = batch["tokens"]
    full_logits, _ = T.forward(params, cfg, tokens, batch.get("extra"),
                               use_flash=False)

    cache, _ = T.decode_init(cfg, B, max_len=S + 4)
    es = extra_shape(cfg, B)
    if es is not None:
        cache = T.prime_cross_kv(params, cfg, cache, batch["extra"])
    outs = []
    for t in range(S):
        logits, cache = T.decode_step(params, cfg, tokens[:, t:t + 1],
                                      jnp.int32(t), cache)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1).astype(jnp.float32)
    ref = full_logits.astype(jnp.float32)
    err = float(jnp.max(jnp.abs(dec - ref)))
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert err / scale < tol, f"{arch}: rel err {err / scale}"


def test_long_500k_applicability_matches_design():
    expected_runs = {"h2o-danube-3-4b", "recurrentgemma-2b", "xlstm-1.3b"}
    for arch in ARCHS:
        cfg, _ = get_model(arch)
        runs = shape_applicable(cfg, SHAPES["long_500k"])
        assert runs == (arch in expected_runs), arch


def test_param_counts_match_published_sizes():
    """Analytic parameter counts should land near the advertised sizes."""
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "command-r-plus-104b": (90e9, 115e9),
        "gemma2-9b": (8e9, 11e9),
        "chatglm3-6b": (5e9, 7.5e9),
        "h2o-danube-3-4b": (3e9, 4.6e9),
        "recurrentgemma-2b": (2e9, 3.4e9),
        "xlstm-1.3b": (1.0e9, 1.8e9),
        "granite-moe-1b-a400m": (0.8e9, 1.5e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg, _ = get_model(arch)
        n = cfg.param_count()
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in [{lo},{hi}]"


def test_kimi_active_params_about_32b():
    cfg, _ = get_model("kimi-k2-1t-a32b")
    a = cfg.active_param_count()
    assert 20e9 <= a <= 45e9, a / 1e9


def test_pattern_period_detection():
    from repro.models.transformer import pattern_period
    cfg, _ = get_model("gemma2-9b")
    assert pattern_period(cfg) == 2
    cfg, _ = get_model("recurrentgemma-2b")
    # 26 layers with a 3-periodic pattern do not divide evenly: the stack
    # falls back to a fully-unrolled single group (documented compile cost)
    assert pattern_period(cfg) == 26
    cfg, _ = get_model("command-r-plus-104b")
    assert pattern_period(cfg) == 1
    cfg, _ = get_model("xlstm-1.3b")
    assert pattern_period(cfg) == 8
