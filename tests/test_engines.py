"""Unit + property tests for tiering engines and page-state invariants."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 environments may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core.engine import (HeMemEngine, HMSDKEngine, MemtisEngine,
                               OracleEngine, make_engine)
from repro.core.knobs import HEMEM_SPACE, HMSDK_SPACE, MEMTIS_SPACE
from repro.core.pages import MigrationPlan, TierState


def _mk(n=256, cap=32, engine="hemem", **kv):
    tier = TierState(n, cap)
    space = {"hemem": HEMEM_SPACE, "hmsdk": HMSDK_SPACE,
             "memtis": MEMTIS_SPACE}[engine]
    cfg = space.validate(kv)
    return tier, make_engine(engine, cfg, tier, seed=0)


def test_tierstate_invariants_enforced():
    tier = TierState(16, 4)
    tier.allocate_first_touch(np.ones(16, bool))
    assert tier.fast_used == 4
    with pytest.raises(AssertionError):
        tier.apply(MigrationPlan(promote=np.array([0]),
                                 demote=np.zeros(0, np.int64)))  # already fast


def test_hemem_promotes_hot_pages():
    tier, eng = _mk()
    tier.allocate_first_touch(np.ones(256, bool))
    reads = np.zeros(256)
    reads[200:210] = 1e6          # very hot, slow-tier pages
    for _ in range(5):
        eng.observe(reads, np.zeros(256), 500.0)
        plan = eng.plan(500.0, 10000)
        tier.apply(plan)
    assert tier.in_fast[200:210].sum() >= 8


def test_hemem_cooling_halves_counts():
    tier, eng = _mk(cooling_pages=65536)   # sync full sweeps
    tier.allocate_first_touch(np.ones(256, bool))
    reads = np.zeros(256)
    reads[0] = 1e9                # drives the sample counter over trigger
    eng.observe(reads, np.zeros(256), 500.0)
    assert eng.cooling_events > 0


def test_hemem_rate_limit_respected():
    tier, eng = _mk(n=4096, cap=2048)
    tier.allocate_first_touch(np.ones(4096, bool))
    reads = np.zeros(4096)
    reads[2048:] = 1e6
    eng.observe(reads, np.zeros(4096), 500.0)
    plan = eng.plan(500.0, max_pages_this_epoch=7)
    assert plan.n_pages <= 14     # promote<=7 bounded + matching demotes


def test_oracle_fills_capacity_with_hottest():
    tier = TierState(64, 8)
    tier.allocate_first_touch(np.ones(64, bool))
    eng = OracleEngine({}, tier)
    heat = np.arange(64, dtype=float)
    eng.observe(heat, np.zeros(64), 500.0)
    tier.apply(eng.plan(500.0, 10 ** 6))
    assert set(np.flatnonzero(tier.in_fast)) == set(range(56, 64))


def test_memtis_adapts_threshold():
    tier, eng = _mk(engine="memtis", n=256, cap=32)
    tier.allocate_first_touch(np.ones(256, bool))
    reads = np.zeros(256)
    reads[:64] = 5e5
    for _ in range(10):
        eng.observe(reads, np.zeros(256), 500.0)
        tier.apply(eng.plan(500.0, 10 ** 6))
    assert eng.hot_threshold > 1.0


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(32, 512),
    cap_frac=st.floats(0.05, 0.9),
    seed=st.integers(0, 10),
)
def test_property_apply_never_violates_capacity(n, cap_frac, seed):
    """For random access patterns and any engine, the fast tier never
    exceeds capacity and no page is in two tiers (single in_fast bool by
    construction; capacity asserted by TierState)."""
    rng = np.random.default_rng(seed)
    cap = max(1, int(n * cap_frac))
    tier = TierState(n, cap)
    eng = HeMemEngine(HEMEM_SPACE.default_config(), tier, seed=seed)
    for _ in range(8):
        touched = rng.uniform(size=n) < 0.7
        tier.allocate_first_touch(touched)
        reads = rng.gamma(0.3, 2e5, size=n) * touched
        writes = rng.gamma(0.2, 5e4, size=n) * touched
        eng.observe(reads, writes, 500.0)
        plan = eng.plan(500.0, 10 ** 6)
        tier.apply(plan)           # asserts invariants internally
        assert tier.fast_used <= cap


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50))
def test_property_knob_space_roundtrip(seed):
    rng = np.random.default_rng(seed)
    for space in (HEMEM_SPACE, HMSDK_SPACE, MEMTIS_SPACE):
        cfg = space.sample(rng)
        enc = space.encode(cfg)
        assert ((enc >= 0) & (enc <= 1)).all()
        dec = space.decode(enc)
        for k in cfg:
            knob = space[k]
            assert knob.lo <= dec[k] <= knob.hi
            if not knob.log:
                assert abs(knob.to_unit(cfg[k]) - knob.to_unit(dec[k])) < 0.02
