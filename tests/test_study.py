"""Study front-end: equivalence with the legacy paths + registry extension.

The acceptance bar of PR 2: ``Study(spec).run()`` / ``.tune()`` must be
numerically identical to the legacy ``evaluate`` / ``tune_scenario`` paths
with matched seeds, and an engine registered via ``@register_engine`` in
THIS file must run through ``Study`` without touching engine.py dispatch.
"""

import numpy as np
import pytest

from repro.core import (EngineSpec, ExperimentSpec, SimOptions, Study,
                        WorkloadSpec, register_engine)
from repro.core.engine import BatchTieringEngine
from repro.core.knobs import Knob, KnobSpace, get_space
from repro.core.pages import MigrationPlan

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")

SCALE = 0.02
ALL_ENGINES = ["hemem", "hmsdk", "memtis", "static", "oracle"]


def _spec(engine="hemem", workload="gups", **opts):
    return ExperimentSpec(engine=engine,
                          workload=WorkloadSpec(workload, scale=SCALE),
                          options=SimOptions(**opts))


# ---------------------------------------------------------------------------
# run()
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_run_matches_legacy_evaluate(engine):
    from repro.core.simulator import evaluate
    res = Study(_spec(engine, seed=5)).run()
    legacy = evaluate(engine, None, "gups", "", threads=None, scale=SCALE,
                      seed=5)
    assert res.total_s == legacy
    assert res.engine == engine and res.workload == "gups:8GiB-hot"


def test_run_batch_matches_single_runs():
    space = get_space("hemem")
    rng = np.random.default_rng(0)
    cfgs = [space.default_config()] + space.sample_batch(rng, 2)
    study = Study(_spec(seed=2, sampler="sparse"))
    batch = study.run(configs=cfgs)
    for cfg, res in zip(cfgs, batch):
        single = Study(ExperimentSpec(
            engine=EngineSpec("hemem", cfg),
            workload=WorkloadSpec("gups", scale=SCALE),
            options=SimOptions(seed=2, sampler="sparse"))).run()
        assert res.total_s == single.total_s
        np.testing.assert_array_equal(res.epoch_wall_ms, single.epoch_wall_ms)


# ---------------------------------------------------------------------------
# tune()
# ---------------------------------------------------------------------------
def test_tune_matches_legacy_tune_scenario():
    from repro.core.bo.tuner import tune_scenario
    from repro.core.simulator import Scenario
    res = Study(_spec()).tune(budget=5, seed=9)
    legacy = tune_scenario("hemem", Scenario("gups", "", scale=SCALE),
                           budget=5, seed=9)
    assert [o.value for o in res.history] == \
        [o.value for o in legacy.history]
    assert [o.config for o in res.history] == \
        [o.config for o in legacy.history]
    assert res.default_value == legacy.default_value


def test_tune_batched_matches_legacy_batched():
    from repro.core.bo.tuner import tune_scenario
    from repro.core.simulator import Scenario
    res = Study(_spec(sampler="sparse")).tune(budget=6, batch_size=3, seed=9)
    legacy = tune_scenario("hemem", Scenario("gups", "", scale=SCALE),
                           budget=6, seed=9, batch_size=3)
    assert [o.value for o in res.history] == \
        [o.value for o in legacy.history]
    assert len(res.history) == 6


# ---------------------------------------------------------------------------
# sweep()
# ---------------------------------------------------------------------------
def test_sweep_grid_matches_individual_runs():
    study = Study(_spec(seed=1, sampler="sparse"))
    sweep = study.sweep(engines=["static", "oracle"],
                        workloads=["gups", "xsbench"])
    assert len(sweep) == 4
    for (ename, wkey), results in sweep.items():
        assert len(results) == 1
        single = Study(ExperimentSpec(
            engine=ename, workload=WorkloadSpec(wkey.split(":")[0],
                                                scale=SCALE),
            options=SimOptions(seed=1, sampler="sparse"))).run()
        assert results[0].total_s == single.total_s
    totals = sweep.total_s()
    assert totals[("oracle", "gups")][0] <= totals[("static", "gups")][0]


def test_sweep_cross_cell_scheduler_matches_sequential():
    """With workers > 1 the sweep flattens all cells into one shard queue;
    scheduling must never change results, even when every cell is smaller
    than the worker count."""
    import os
    if (os.cpu_count() or 1) < 2:
        pytest.skip("needs >= 2 CPUs")
    study_seq = Study(_spec(seed=4, sampler="sparse"))
    study_par = Study(_spec(seed=4, sampler="sparse", workers=2))
    kw = dict(engines=["static", "hemem"], workloads=["gups", "xsbench"])
    seq = study_seq.sweep(**kw)
    par = study_par.sweep(**kw)
    assert set(seq.cells) == set(par.cells)
    for key in seq.cells:
        for a, b in zip(seq[key], par[key]):
            assert a.total_s == b.total_s
            np.testing.assert_array_equal(a.epoch_wall_ms, b.epoch_wall_ms)


def test_run_simulation_cells_orders_and_seeds():
    from repro.core.simulator import run_simulation_cells
    from repro.core.workloads import make_workload
    wl = make_workload("gups", "", threads=8, scale=SCALE, seed=3)
    cfgs = [get_space("hemem").default_config()]
    out = run_simulation_cells([(wl, "static", [{}, {}]),
                                (wl, "hemem", cfgs)], seeds=3)
    assert [len(c) for c in out] == [2, 1]
    assert out[1][0].engine == "hemem"
    ref = Study(ExperimentSpec(
        engine="hemem", workload=WorkloadSpec("gups", threads=8, scale=SCALE),
        options=SimOptions(seed=3, sampler="sparse"))).run()
    assert out[1][0].total_s == ref.total_s
    with pytest.raises(ValueError, match="seeds"):
        run_simulation_cells([(wl, "static", [{}])], seeds=[[1, 2]])


def test_sweep_shared_configs_across_engines():
    study = Study(_spec())
    cfgs = [get_space("hemem").default_config(),
            get_space("hemem").validate({"read_hot_threshold": 2})]
    sweep = study.sweep({"configs": cfgs})
    assert [r.config["read_hot_threshold"] for r in
            sweep[("hemem", "gups")]] == [8, 2]


# ---------------------------------------------------------------------------
# extension seam: a new engine registered HERE runs through Study
# ---------------------------------------------------------------------------
TRACE_SPACE = KnobSpace([
    Knob("promote_top_k", 16, 1, 256, is_int=True, log=True),
])


@register_engine("topk-test", space=TRACE_SPACE)
class BatchTopKEngine(BatchTieringEngine):
    """Toy policy: keep the top-k hottest observed pages in the fast tier."""

    def __init__(self, configs, btier, seeds=0, sampler="elementwise"):
        super().__init__(configs, btier, seeds, sampler)
        self._heat = np.zeros((self.batch, btier.n_pages))
        self.top_k = self._knob("promote_top_k", dtype=np.int64)

    def observe(self, reads, writes, epoch_ms):
        self._heat = 0.5 * self._heat + (reads + writes)[None, :]
        self.samples_last_epoch = np.zeros(self.batch)

    def plan(self, epoch_ms, max_pages_this_epoch):
        plans = []
        for b in range(self.batch):
            k = int(self.top_k[b])
            hot = np.argsort(-self._heat[b], kind="stable")[:k]
            want = np.zeros(self.btier.n_pages, dtype=bool)
            want[hot] = True
            promote = np.flatnonzero(want & ~self.btier.in_fast[b]
                                     & self.btier.allocated[b])
            room = int(self.btier.fast_free[b])
            plans.append(MigrationPlan(promote=promote[:room],
                                       demote=np.zeros(0, dtype=np.int64)))
        return plans


def test_registered_engine_runs_through_study():
    res = Study(_spec("topk-test")).run()
    assert res.engine == "topk-test" and np.isfinite(res.total_s)
    # its knob space is visible to the tuner without touching engine.py
    tr = Study(_spec("topk-test")).tune(budget=3, seed=0, n_init=2)
    assert len(tr.history) == 3 and np.isfinite(tr.best_value)


def test_registered_engine_specs_round_trip():
    spec = _spec("topk-test")
    assert spec.engine.config == {"promote_top_k": 16}
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec


def test_tune_surrogate_modes_produce_identical_histories():
    """PR 5: the fast level-synchronous forest and the recursive reference
    are bit-identical, so full tuning runs agree config-for-config."""
    results = {}
    for mode in ("reference", "fast"):
        res = Study(_spec(sampler="sparse")).tune(
            budget=8, batch_size=4, seed=3, n_init=4, surrogate=mode)
        results[mode] = ([o.config for o in res.history],
                         [o.value for o in res.history])
    assert results["reference"] == results["fast"]


def test_tune_records_round_time_breakdown():
    res = Study(_spec(sampler="sparse")).tune(budget=6, batch_size=3, seed=1,
                                              n_init=2)
    assert len(res.round_times) == 2
    for r in res.round_times:
        assert set(r) == {"ask_s", "fit_s", "eval_s", "tell_s", "q"}
        assert r["eval_s"] > 0 and r["ask_s"] >= r["fit_s"] >= 0
    assert res.optimizer_overhead_s >= 0
    assert res.evaluation_s > 0
    assert res.overhead_fraction < 1.0  # ask/tell is cheaper than evaluation
    seq = Study(_spec()).tune(budget=2, seed=1, n_init=1)
    assert len(seq.round_times) == 2
    assert all(r["q"] == 1.0 for r in seq.round_times)
