"""Phase-shifting workloads: spec validation, determinism, backend parity
across phase boundaries, segmentation at switch epochs, jit-cache hygiene,
and the TrafficSpec construction-time validation that rides along.

The contracts pinned here (see ``repro.core.drift``):

* a ``DriftSpec`` validates at CONSTRUCTION (bad switch epochs, phase
  count mismatches, unknown JSON keys with did-you-mean hints) — the
  KnobSpace convention, not a silent mid-study trace anomaly;
* the composed trace is deterministic in ``(spec, seed)`` and registers
  as an ordinary picklable workload;
* the backend-parity contract holds across phase boundaries unchanged:
  deterministic engines plan bitwise-identical migrations on both
  backends, and jax segments stopping/resuming exactly at a phase switch
  are bitwise identical to an unsegmented drifting run;
* recompile warnings fire once per CAUSE, not once per phase switch, and
  compiled segments are reused when shapes repeat across phases.
"""

import dataclasses
import json
import logging
import pickle

import numpy as np
import pytest

from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
from repro.core.drift import (BUILTIN_DRIFTS, DriftPhase, DriftSpec,
                              build_drift_workload, histogram_divergence,
                              window_histogram)
from repro.core.registry import WORKLOADS
from repro.core.simulator import run_simulation_batch, run_simulation_segment
from repro.core.traffic import TrafficSpec
from repro.core.workloads import make_workload


# ---------------------------------------------------------------------------
# spec validation (construction-time, KnobSpace convention)
# ---------------------------------------------------------------------------

def test_drift_spec_needs_two_phases():
    with pytest.raises(ValueError, match="at least 2 phases"):
        DriftSpec(phases=(DriftPhase("gups"),), switch_epochs=(),
                  n_epochs=40)


def test_drift_spec_switch_count_mismatch():
    with pytest.raises(ValueError, match="one switch epoch per phase"):
        DriftSpec(phases=(DriftPhase("gups"), DriftPhase("btree")),
                  switch_epochs=(10, 20), n_epochs=40)


@pytest.mark.parametrize("switches", [(0,), (40,), (45,), (-3,)])
def test_drift_spec_switch_out_of_range(switches):
    with pytest.raises(ValueError, match="strictly increasing inside"):
        DriftSpec(phases=(DriftPhase("gups"), DriftPhase("btree")),
                  switch_epochs=switches, n_epochs=40)


def test_drift_spec_switches_must_increase():
    with pytest.raises(ValueError, match="strictly increasing"):
        DriftSpec(phases=tuple(DriftPhase("gups") for _ in range(3)),
                  switch_epochs=(20, 10), n_epochs=40)


def test_drift_spec_unknown_key_did_you_mean():
    d = DriftSpec.hotspot().to_dict()
    d["switch_epoch"] = d.pop("switch_epochs")
    with pytest.raises(KeyError, match="did you mean 'switch_epochs'"):
        DriftSpec.from_dict(d)


def test_drift_phase_unknown_key_did_you_mean():
    with pytest.raises(KeyError, match="did you mean 'seed_offset'"):
        DriftPhase.from_dict({"workload": {"name": "gups"},
                              "seed_offst": 1})


def test_drift_phase_negative_seed_offset():
    with pytest.raises(ValueError, match="seed_offset"):
        DriftPhase("gups", seed_offset=-1)


def test_drift_phase_name_input_shorthand():
    p = DriftPhase.coerce("silo:ycsb-c")
    assert p.workload.name == "silo" and p.workload.input_name == "ycsb-c"


def test_hotspot_needs_two_phases():
    with pytest.raises(ValueError, match="n_phases >= 2"):
        DriftSpec.hotspot(n_phases=1)


# ---------------------------------------------------------------------------
# JSON round trip + registration
# ---------------------------------------------------------------------------

def test_drift_spec_json_round_trip():
    spec = DriftSpec.splice("gups", "silo:ycsb-c", switch_epoch=30,
                            n_epochs=60)
    twin = DriftSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert twin == spec
    assert twin.name == spec.name  # digest-stable


def test_drift_spec_digest_name_content_addressed():
    a = DriftSpec.hotspot(n_phases=2, phase_epochs=10)
    b = DriftSpec.hotspot(n_phases=2, phase_epochs=10)
    c = DriftSpec.hotspot(n_phases=2, phase_epochs=12)
    assert a.name == b.name and a.name != c.name


def test_register_makes_plain_workload_name():
    spec = DriftSpec.hotspot(n_phases=2, phase_epochs=5)
    name = spec.register()
    wl = make_workload(name, "", threads=4, scale=0.03, seed=1)
    assert wl.n_epochs == spec.n_epochs
    # the builder is picklable (shard workers rebuild from the spec)
    builder = WORKLOADS.get(name)
    assert pickle.loads(pickle.dumps(builder)) is not None


def test_drift_spec_coerces_through_experiment_spec():
    spec = DriftSpec.hotspot(n_phases=2, phase_epochs=5)
    exp = ExperimentSpec(engine="static", workload=spec)
    assert exp.workload.name == spec.name
    assert exp.workload.name in WORKLOADS.names()


def test_phase_of():
    spec = BUILTIN_DRIFTS["drift-hotspot"]
    assert spec.phase_starts == (0, 20, 40)
    assert spec.phase_of(0) == 0
    assert spec.phase_of(19) == 0
    assert spec.phase_of(20) == 1
    assert spec.phase_of(59) == 2
    with pytest.raises(ValueError):
        spec.phase_of(60)


# ---------------------------------------------------------------------------
# composed trace semantics
# ---------------------------------------------------------------------------

def test_drift_trace_deterministic_in_spec_and_seed():
    spec = BUILTIN_DRIFTS["drift-splice"]
    a = build_drift_workload(spec, threads=4, scale=0.03, seed=7)
    b = build_drift_workload(spec, threads=4, scale=0.03, seed=7)
    c = build_drift_workload(spec, threads=4, scale=0.03, seed=8)
    for e in (0, 29, 30, 59):
        ra, wa = a.epoch_access(e)
        rb, wb = b.epoch_access(e)
        assert np.array_equal(ra, rb) and np.array_equal(wa, wb)
    rc, _ = c.epoch_access(0)
    assert not np.array_equal(a.epoch_access(0)[0], rc)


def test_drift_trace_changes_exactly_at_switch():
    spec = BUILTIN_DRIFTS["drift-hotspot"]
    wl = build_drift_workload(spec, threads=4, scale=0.03, seed=3)
    # within a phase the base trace replays: epochs 0 and 20 are the
    # local epoch-0 of DIFFERENT seeds, so they differ; 20 vs 40 too
    r0 = wl.epoch_access(0)[0]
    r20 = wl.epoch_access(20)[0]
    r40 = wl.epoch_access(40)[0]
    assert not np.array_equal(r0, r20)
    assert not np.array_equal(r20, r40)


def test_drift_pads_shorter_phase_to_max_pages():
    spec = DriftSpec.splice("gups", "silo:ycsb-c", switch_epoch=5,
                            n_epochs=10)
    wl = build_drift_workload(spec, threads=4, scale=0.03, seed=0)
    parts = [make_workload(p.workload.name, p.workload.input_name,
                           threads=4, scale=0.03, seed=0)
             for p in spec.phases]
    assert wl.n_pages == max(p.n_pages for p in parts)
    for e in (0, 9):
        r, w = wl.epoch_access(e)
        assert r.shape == (wl.n_pages,) and w.shape == (wl.n_pages,)


def test_window_histogram_divergence_detects_phases():
    spec = BUILTIN_DRIFTS["drift-hotspot"]
    wl = build_drift_workload(spec, threads=4, scale=0.03, seed=3)
    h0 = window_histogram(wl, 0, 10)
    h1 = window_histogram(wl, 10, 20)   # same phase
    h2 = window_histogram(wl, 20, 30)   # next phase
    assert histogram_divergence(h0, h1) == 0.0  # procedural replay
    assert histogram_divergence(h1, h2) > 0.25  # detector threshold margin


# ---------------------------------------------------------------------------
# wset workload (working-set growth primitive)
# ---------------------------------------------------------------------------

def test_wset_workload_fraction_inputs():
    small = make_workload("wset", "f25", threads=4, scale=0.05, seed=0)
    big = make_workload("wset", "f100", threads=4, scale=0.05, seed=0)
    assert small.n_pages == big.n_pages
    r_s = small.epoch_access(0)[0]
    r_b = big.epoch_access(0)[0]
    # the touched set is a prefix: growth makes it a strict superset
    n_s = (r_s > r_s.min()).sum()
    n_b = (r_b > r_b.min()).sum()
    assert n_s < n_b


@pytest.mark.parametrize("inp", ["25", "f0", "f101", "fxx"])
def test_wset_rejects_bad_inputs(inp):
    with pytest.raises(ValueError):
        make_workload("wset", inp, threads=4, scale=0.05, seed=0)


# ---------------------------------------------------------------------------
# TrafficSpec: construction-time validation (was a silent clamp)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(arrival_rate=-1.0), "arrival_rate"),
    (dict(steps=0), "steps"),
    (dict(decode_lo=0), "decode_lo"),
    (dict(decode_lo=96, decode_hi=32), "decode_lo must be <= decode_hi"),
    (dict(period=0), "period"),
    (dict(amplitude=1.5), "amplitude"),
    (dict(burst_prob=1.5), "burst_prob"),
    (dict(burst_factor=-1.0), "burst_factor"),
])
def test_traffic_spec_validates_at_construction(kw, match):
    with pytest.raises(ValueError, match=match):
        TrafficSpec(**kw)


def test_traffic_spec_from_json_did_you_mean():
    with pytest.raises(KeyError, match="did you mean 'arrival_rate'"):
        TrafficSpec.from_json({"arrival_rte": 2.0})


def test_traffic_spec_round_trip_still_works():
    spec = TrafficSpec(pattern="bursty-diurnal", arrival_rate=2.0)
    assert TrafficSpec.from_json(spec.to_json()) == spec


# ---------------------------------------------------------------------------
# backend parity + segmentation across phase boundaries (compiled path)
# ---------------------------------------------------------------------------

jax_mod = pytest.importorskip("jax")

from repro.core import engine_jax  # noqa: E402
from repro.core.knobs import get_space  # noqa: E402


def _drift_wl(name="drift-splice", scale=0.03, seed=3):
    return make_workload(name, "", threads=8, scale=scale, seed=seed)


@pytest.mark.parametrize("engine", ["static", "oracle"])
def test_numpy_jax_parity_bitwise_on_drift(engine):
    """Deterministic engines: bitwise-identical migrations across ALL
    phase boundaries of a drifting trace, numpy vs compiled."""
    wl = _drift_wl()
    cfgs = [{}]
    a = run_simulation_batch(wl, engine, cfgs, seeds=0, sampler="sparse",
                             backend="numpy")[0]
    b = run_simulation_batch(wl, engine, cfgs, seeds=0, sampler="sparse",
                             backend="jax")[0]
    assert np.array_equal(a.cum_migrations, b.cum_migrations)
    np.testing.assert_allclose(a.total_s, b.total_s, rtol=1e-4)


def test_segment_split_at_phase_switch_bitwise_jax():
    """Stopping/resuming EXACTLY at the phase-switch epoch is invisible:
    per-epoch walls bitwise equal to the unsegmented drifting run."""
    wl = _drift_wl()          # switch at epoch 30
    space = get_space("hemem")
    cfgs = [space.default_config(),
            space.sample(np.random.default_rng(5))]
    full = run_simulation_segment(wl, "hemem", cfgs, seeds=0,
                                  sampler="sparse", backend="jax")
    first = run_simulation_segment(wl, "hemem", cfgs, seeds=0,
                                   sampler="sparse", backend="jax",
                                   epoch_stop=30, return_carry=True)
    second = run_simulation_segment(wl, "hemem", cfgs, seeds=0,
                                    sampler="sparse", backend="jax",
                                    epoch_start=30, carry=first["carry"])
    stitched = np.concatenate([first["wall_ms"], second["wall_ms"]], axis=0)
    assert np.array_equal(stitched, full["wall_ms"])


def test_segment_prefix_at_phase_switch_numpy():
    """numpy supports prefix segments only: the prefix ending at the
    switch epoch is bitwise the full run's prefix."""
    wl = _drift_wl()
    cfgs = [{}]
    full = run_simulation_batch(wl, "static", cfgs, seeds=0,
                                sampler="sparse", backend="numpy")[0]
    prefix = run_simulation_segment(wl, "static", cfgs, seeds=0,
                                    sampler="sparse", backend="numpy",
                                    epoch_stop=30)
    assert np.array_equal(prefix["wall_ms"][:, 0],
                          np.asarray(full.epoch_wall_ms)[:30])
    with pytest.raises(ValueError, match="prefix"):
        run_simulation_segment(wl, "static", cfgs, seeds=0,
                               sampler="sparse", backend="numpy",
                               epoch_start=30)


def test_drift_run_compiles_once_per_shape():
    """One drifting run = ONE compiled shape: phase switches never
    retrace (fixed n_pages via padding; epoch ids travel as data)."""
    wl = _drift_wl("drift-hotspot")
    cfg = get_space("hemem").default_config()
    before = len(engine_jax.compiled_cache_info())
    run_simulation_batch(wl, "hemem", [cfg, dict(cfg)], seeds=0,
                         sampler="sparse", backend="jax")
    added = len(engine_jax.compiled_cache_info()) - before
    assert added <= 1


def test_recompile_warns_once_per_cause(caplog):
    """Repeated same-cause recompiles (e.g. alternating batch widths at
    phase switches) warn ONCE; segment-length-only changes never warn."""
    wl = _drift_wl("drift-hotspot", scale=0.025, seed=11)
    cfg = get_space("hemem").default_config()
    engine_jax.reset_recompile_warnings()

    def seg(B, lo, hi):
        run_simulation_segment(wl, "hemem", [dict(cfg)] * B, seeds=0,
                               sampler="sparse", backend="jax",
                               epoch_start=0, epoch_stop=hi - lo)

    with caplog.at_level(logging.WARNING, logger="repro.core.engine_jax"):
        seg(1, 0, 10)    # first compile of this (engine, n, sampler): silent
        seg(2, 0, 10)    # B changed: warn
        seg(1, 0, 20)    # n_epochs-only change: debug, not a warning
        seg(2, 0, 20)    # same cause as the B=2 compile: suppressed
    warnings = [r for r in caplog.records if r.levelno >= logging.WARNING]
    assert len(warnings) == 1, \
        [r.getMessage() for r in warnings]
    assert "B" in warnings[0].getMessage()
