"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as fa
from repro.kernels.paged_attention import paged_attention as pa
from repro.kernels.page_migrate import page_migrate as pm


def _dense_attention(q, k, v, causal, window, cap):
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D).astype(np.float64) / np.sqrt(D)
    s = np.einsum("bskgd,btkd->bskgt", qg, k.astype(np.float64))
    if cap > 0:
        s = cap * np.tanh(s / cap)
    qp = np.arange(S)[:, None]
    kp = np.arange(k.shape[1])[None, :]
    mask = np.ones((S, k.shape[1]), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = np.where(mask[None, :, None, None], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = np.where(mask[None, :, None, None], p, 0.0)
    out = np.einsum("bskgt,btkd->bskgd", p / p.sum(-1, keepdims=True),
                    v.astype(np.float64))
    return out.reshape(B, S, H, D)


SWEEP = [
    # B, S, H, KV, D, causal, window, cap, dtype
    (1, 128, 4, 4, 64, True, 0, 0.0, jnp.float32),
    (2, 256, 8, 2, 64, True, 0, 0.0, jnp.float32),
    (1, 256, 4, 1, 128, True, 128, 0.0, jnp.float32),
    (2, 128, 4, 4, 64, False, 0, 0.0, jnp.float32),
    (1, 256, 2, 2, 256, True, 0, 50.0, jnp.float32),
    (1, 128, 4, 4, 64, True, 0, 0.0, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,KV,D,causal,window,cap,dtype", SWEEP)
def test_flash_attention_vs_oracle(B, S, H, KV, D, causal, window, cap,
                                   dtype):
    rng = np.random.default_rng(42)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), dtype)
    out_pallas = fa(q, k, v, causal=causal, window=window, logit_softcap=cap,
                    interpret=True)
    out_ref = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                      logit_softcap=cap)
    out_dense = _dense_attention(np.asarray(q, np.float64),
                                 np.asarray(k, np.float64),
                                 np.asarray(v, np.float64),
                                 causal, window, cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_pallas, np.float64), out_dense,
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(out_ref, np.float64), out_dense,
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,KV,D,page,ppseq,P", [
    (2, 8, 4, 64, 16, 4, 16),
    (3, 4, 1, 128, 8, 8, 64),
    (1, 16, 8, 64, 32, 2, 8),
])
def test_paged_attention_vs_oracle(B, H, KV, D, page, ppseq, P):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    table = jnp.asarray(
        np.stack([rng.choice(P, ppseq, replace=False) for _ in range(B)]),
        jnp.int32)
    lengths = jnp.asarray(rng.integers(1, page * ppseq + 1, B), jnp.int32)
    out_p = pa(q, kp, vp, table, lengths, interpret=True)
    out_r = ref.paged_attention_ref(q, kp, vp, table, lengths)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_ignores_unused_pages():
    """Pages past `lengths` must not affect the result."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 4, 32)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(8, 8, 2, 32)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(8, 8, 2, 32)), jnp.float32)
    table = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    out_a = pa(q, kp, vp, table, jnp.asarray([9], jnp.int32), interpret=True)
    kp2 = kp.at[2:].set(999.0)
    vp2 = vp.at[2:].set(-999.0)
    out_b = pa(q, kp2, vp2, table, jnp.asarray([9], jnp.int32),
               interpret=True)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               atol=1e-6)


@pytest.mark.parametrize("P,elems,n", [(8, 64, 4), (16, 256, 16), (4, 32, 1)])
def test_page_migrate_vs_oracle(P, elems, n):
    rng = np.random.default_rng(11)
    dst = jnp.asarray(rng.normal(size=(P, elems)), jnp.float32)
    src = jnp.asarray(rng.normal(size=(P, elems)), jnp.float32)
    d_ids = jnp.asarray(rng.choice(P, n, replace=False), jnp.int32)
    s_ids = jnp.asarray(rng.choice(P, n, replace=False), jnp.int32)
    # sprinkle no-ops
    if n > 2:
        d_ids = d_ids.at[0].set(-1)
        s_ids = s_ids.at[1].set(-1)
    out_p = pm(dst.copy(), src, d_ids, s_ids, interpret=True)
    out_r = ref.page_migrate_ref(dst, src, d_ids, s_ids)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r))


def test_hotness_update_ref():
    counts = jnp.zeros(16)
    ids = jnp.asarray([3, 3, 5, -1, 3], jnp.int32)
    new, hot = ref.hotness_update_ref(counts, ids, cool=False,
                                      hot_threshold=2.0)
    assert new[3] == 3 and new[5] == 1
    assert bool(hot[3]) and not bool(hot[5])
    cooled, _ = ref.hotness_update_ref(new, jnp.asarray([-1], jnp.int32),
                                       cool=True, hot_threshold=2.0)
    assert cooled[3] == 1.5
