"""Minimal stand-in for the ``hypothesis`` API used by this test suite.

The tier-1 suite must collect and pass in environments without hypothesis
installed.  Test modules import via::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, st

The stub runs each ``@given`` test over a deterministic sample of the
strategy space (seeded per test name), honouring ``max_examples`` from
``@settings``.  It implements only what the suite uses: ``st.integers``,
``st.floats``, ``st.sampled_from``, ``@given(**kwargs)`` and
``@settings(max_examples=..., deadline=...)``.
"""

from __future__ import annotations

import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def sample(self, rng: np.random.Generator):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = int(lo), int(hi)

    def sample(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = float(lo), float(hi)

    def sample(self, rng):
        return float(rng.uniform(self.lo, self.hi))


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def sample(self, rng):
        return self.options[int(rng.integers(len(self.options)))]


class _St:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value, max_value):
        return _Floats(min_value, max_value)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)


st = _St()


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Records ``max_examples`` on the (already ``@given``-wrapped) test."""

    def deco(fn):
        fn._stub_max_examples = int(max_examples)
        return fn

    return deco


def given(**strategies):
    """Runs the test over deterministic samples of the keyword strategies."""

    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                drawn = {name: strat.sample(rng)
                         for name, strat in strategies.items()}
                fn(**drawn)

        # keep identity for test discovery/reporting, but NOT the wrapped
        # signature — pytest would mistake strategy params for fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
