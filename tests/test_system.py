"""End-to-end behaviour tests for the paper's system (sim + BO)."""

import numpy as np
import pytest

from repro.core.knobs import HEMEM_SPACE, get_space
from repro.core.simulator import (PMEM_LARGE, NUMA, Scenario, evaluate,
                                  run_simulation)
from repro.core.workloads import PAPER_SUITE, make_workload
from repro.core.bo.tuner import TuningSession, tune_scenario


def test_default_beats_pathological_config():
    """A config that disables useful migration should not beat a sane one
    on GUPS (whose hot set must be migrated)."""
    wl = make_workload("gups", "8GiB-hot", threads=12, scale=0.25)
    good = run_simulation(wl, "hemem", None, PMEM_LARGE, seed=0)
    off = HEMEM_SPACE.validate(dict(migration_period=5000,
                                    max_migration_rate=2))
    crippled = run_simulation(wl, "hemem", off, PMEM_LARGE, seed=0)
    assert good.total_s < crippled.total_s


def test_oracle_bounds_everything():
    for name, inp in PAPER_SUITE[:4]:
        wl = make_workload(name, inp, threads=12, scale=0.25)
        orc = run_simulation(wl, "oracle", {}, PMEM_LARGE, seed=0)
        dflt = run_simulation(wl, "hemem", None, PMEM_LARGE, seed=0)
        assert orc.total_s <= dflt.total_s * 1.02, (name, inp)


def test_bo_improves_over_default():
    res = tune_scenario("hemem", Scenario("silo", "ycsb-c"), budget=25,
                        seed=0)
    assert res.improvement > 1.1


def test_bo_beats_random_search_sample_efficiency():
    sc = Scenario("gups", "8GiB-hot")
    smac = tune_scenario("hemem", sc, budget=25, seed=1, optimizer="smac")
    rand = tune_scenario("hemem", sc, budget=25, seed=1, optimizer="random")
    # SMAC should be at least as good with the same budget (generous margin)
    assert smac.best_value <= rand.best_value * 1.10


def test_numa_gains_smaller_than_pmem():
    pm = tune_scenario("hemem", Scenario("gapbs-pr", "kron"), budget=20,
                       seed=2)
    nm = tune_scenario("hemem",
                       Scenario("gapbs-pr", "kron", machine="numa"),
                       budget=20, seed=2)
    assert nm.improvement <= pm.improvement + 0.05


def test_evaluate_deterministic():
    cfg = HEMEM_SPACE.default_config()
    a = evaluate("hemem", cfg, "xsbench", "", "pmem-large")
    b = evaluate("hemem", cfg, "xsbench", "", "pmem-large")
    assert a == b
