"""Cross-backend conformance suite for exact top-k page selection.

Pins down the contract of ``repro.kernels.select_topk`` (Pallas, interpret
mode off-TPU) and ``repro.kernels.ref.select_topk_ref`` (pure jnp): on any
candidate mask / priority / k combination, the selected **index sets** must
be bit-identical to the numpy stable-sort reference the tiering engines
define (promotions: hottest first; demotions: coldest first; priority ties
break by page index, ascending).

The property corpus (hypothesis when installed, the deterministic stub
otherwise) covers random masks, heavy priority ties, k in {0, 1, n} and
empty/full candidate sets — NaN-free, as the engines' nonnegative
count/rate priorities guarantee.  A second block checks the
``repro.kernels.ops`` dispatch (the ``FORCE`` switch, honoured by the
compiled epoch loop's jit-cache key) and that all five batch engines
produce bit-identical simulations whichever implementation serves
selection.

Run under ``REPRO_KERNELS_FORCE=pallas`` / ``=ref`` (the CI conformance
matrix) to pin the global dispatch; the parametrized tests below exercise
both paths regardless.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.kernels import ops  # noqa: E402
from repro.kernels.ref import select_topk_ref  # noqa: E402
from repro.kernels.select_topk import select_topk as select_topk_pallas  # noqa: E402

# one fixed shape for the whole property corpus: jit traces once per
# dispatch path instead of once per example
B, N = 3, 256


def _pallas(*args):
    return select_topk_pallas(*args, interpret=True)


IMPLS = {"pallas": _pallas, "ref": select_topk_ref}


def np_select(mask, heat, k, largest):
    """The numpy stable-sort reference the engines implement: indices of
    the top-k candidates (ties by index, ascending), sorted."""
    idx = np.flatnonzero(mask)
    k = min(int(k), idx.size)
    key = -heat[idx] if largest else heat[idx]
    order = np.argsort(key, kind="stable")
    return np.sort(idx[order[:k]])


def assert_conforms(p_mask, p_heat, d_mask, d_heat, kp, kd,
                    impls=tuple(IMPLS)):
    args = (jnp.asarray(p_mask), jnp.asarray(p_heat), jnp.asarray(d_mask),
            jnp.asarray(d_heat), jnp.asarray(kp), jnp.asarray(kd))
    for name in impls:
        pm, dm = IMPLS[name](*args)
        pm, dm = np.asarray(pm), np.asarray(dm)
        for b in range(p_mask.shape[0]):
            np.testing.assert_array_equal(
                np.flatnonzero(pm[b]), np_select(p_mask[b], p_heat[b],
                                                 kp[b], True),
                err_msg=f"{name}: promote row {b} (k={kp[b]})")
            np.testing.assert_array_equal(
                np.flatnonzero(dm[b]), np_select(d_mask[b], d_heat[b],
                                                 kd[b], False),
                err_msg=f"{name}: demote row {b} (k={kd[b]})")


def _corpus_case(seed: int, levels: int, density: float):
    """One property example: (B, N) masks/heats and per-row k values that
    sweep the edges {0, 1, N} plus a random interior point."""
    rng = np.random.default_rng(seed)
    if levels:  # small integer grid => heavy priority ties
        p_heat = rng.integers(0, levels, size=(B, N)).astype(np.float32)
        d_heat = rng.integers(0, levels, size=(B, N)).astype(np.float32)
    else:
        p_heat = rng.uniform(0.0, 1e6, size=(B, N)).astype(np.float32)
        d_heat = rng.uniform(0.0, 1e6, size=(B, N)).astype(np.float32)
    p_mask = rng.uniform(size=(B, N)) < density
    d_mask = rng.uniform(size=(B, N)) < density
    edges = [0, 1, N, int(rng.integers(0, N + 1))]
    kp = np.array([edges[b % len(edges)] for b in range(B)], np.float32)
    kd = np.array([edges[(b + 1) % len(edges)] for b in range(B)],
                  np.float32)
    return p_mask, p_heat, d_mask, d_heat, kp, kd


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       levels=st.sampled_from([0, 2, 3, 17, 255]),
       density=st.floats(0.05, 0.95))
def test_property_conformance(seed, levels, density):
    assert_conforms(*_corpus_case(seed, levels, density))


@pytest.mark.parametrize("impl", list(IMPLS))
def test_all_priorities_tied_select_lowest_indices(impl):
    """A fully tied tier must fill in page-index order (numpy stability)."""
    p_mask = np.ones((B, N), bool)
    heat = np.full((B, N), 7.0, np.float32)
    k = np.array([0, 1, 13], np.float32)
    assert_conforms(p_mask, heat, p_mask, heat, k, k, impls=(impl,))
    pm, _ = IMPLS[impl](jnp.asarray(p_mask), jnp.asarray(heat),
                        jnp.asarray(p_mask), jnp.asarray(heat),
                        jnp.asarray(k), jnp.asarray(k))
    assert np.flatnonzero(np.asarray(pm)[2]).tolist() == list(range(13))


@pytest.mark.parametrize("impl", list(IMPLS))
def test_k_exceeding_candidates_takes_all(impl):
    rng = np.random.default_rng(5)
    p_mask = rng.uniform(size=(B, N)) < 0.1
    heat = rng.integers(0, 3, size=(B, N)).astype(np.float32)
    k = np.full(B, N, np.float32)  # far above the candidate count
    assert_conforms(p_mask, heat, p_mask, heat, k, k, impls=(impl,))


@pytest.mark.parametrize("impl", list(IMPLS))
def test_empty_candidate_sets(impl):
    z = np.zeros((B, N), bool)
    heat = np.ones((B, N), np.float32)
    k = np.full(B, 10.0, np.float32)
    pm, dm = IMPLS[impl](jnp.asarray(z), jnp.asarray(heat), jnp.asarray(z),
                         jnp.asarray(heat), jnp.asarray(k), jnp.asarray(k))
    assert not np.asarray(pm).any() and not np.asarray(dm).any()


def test_adversarial_near_tie_floats():
    """Adjacent float32 values (one ulp apart) must NOT be treated as ties
    — exactness means full 32-bit priority resolution."""
    base = np.float32(1000.0)
    up = np.nextafter(base, np.float32(np.inf), dtype=np.float32)
    heat = np.tile(np.array([base, up] * (N // 2), np.float32), (B, 1))
    mask = np.ones((B, N), bool)
    k = np.full(B, N // 2, np.float32)
    assert_conforms(mask, heat, mask, heat, k, k)
    pm, dm = select_topk_ref(jnp.asarray(mask), jnp.asarray(heat),
                             jnp.asarray(mask), jnp.asarray(heat),
                             jnp.asarray(k), jnp.asarray(k))
    # promote takes every `up`, demote every `base` — no index fallback
    assert np.flatnonzero(np.asarray(pm)[0]).tolist() == \
        list(range(1, N, 2))
    assert np.flatnonzero(np.asarray(dm)[0]).tolist() == \
        list(range(0, N, 2))


# ---------------------------------------------------------------------------
# ops dispatch (the FORCE switch the compiled epoch loop keys on)
# ---------------------------------------------------------------------------
@pytest.fixture
def restore_force():
    old = ops.FORCE
    yield
    ops.FORCE = old


def test_ops_dispatch_honours_force(restore_force):
    case = _corpus_case(123, 4, 0.4)
    args = tuple(jnp.asarray(a) for a in case)
    outs = {}
    for force in ("pallas", "ref"):
        ops.FORCE = force
        assert ops.select_path() == force
        outs[force] = tuple(np.asarray(x) for x in ops.select_topk(*args))
    for a, b in zip(outs["pallas"], outs["ref"]):
        np.testing.assert_array_equal(a, b)
    assert_conforms(*case)  # and both agree with the numpy reference


@pytest.mark.parametrize("engine", ["hemem", "hmsdk", "memtis", "static",
                                    "oracle"])
@pytest.mark.parametrize("sampler", ["sparse", "elementwise"])
def test_engine_simulation_identical_across_dispatch(restore_force, engine,
                                                     sampler):
    """The acceptance bar: for every engine and sampler, the compiled epoch
    loop must produce bit-identical simulations whether selection runs
    through the Pallas kernel (interpret mode) or the pure-jnp ref."""
    from repro.core.knobs import get_space
    from repro.core.simulator import run_simulation_batch
    from repro.core.workloads import make_workload
    wl = make_workload("gups", "8GiB-hot", threads=8, scale=0.02, seed=3)
    if engine in ("hemem", "hmsdk", "memtis"):
        cfgs = [get_space(engine).default_config(),
                get_space(engine).sample(np.random.default_rng(1))]
    else:
        cfgs = [{}, {}]
    results = {}
    for force in ("ref", "pallas"):
        ops.FORCE = force
        results[force] = run_simulation_batch(
            wl, engine, cfgs, "pmem-large", seeds=7, sampler=sampler,
            backend="jax")
    for a, b in zip(results["ref"], results["pallas"]):
        assert np.array_equal(a.cum_migrations, b.cum_migrations)
        assert np.array_equal(a.epoch_wall_ms, b.epoch_wall_ms)
        assert np.array_equal(a.fast_hit_rate, b.fast_hit_rate)
