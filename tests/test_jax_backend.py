"""Compiled jax epoch loop: numpy-vs-jax parity, CRN, scan fidelity, cache.

The backend contract (see ``repro.core.engine_jax``):

* numpy is the bit-exact reference; the jax path draws different but
  equal-in-distribution monitoring noise, so parity on sampled engines is
  statistical (tight for the deterministic engines);
* ``crn=True`` makes the per-epoch monitoring draws bitwise-identical
  across the B configs of a batch;
* the ``lax.scan`` epoch loop matches the same step function run as a
  Python epoch loop, epoch by epoch;
* jitted epoch functions are cached per (engine, n_pages, sampler) and a
  recompilation logs a one-line warning.
"""

import logging

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import engine_jax
from repro.core.bo.smac import SMACOptimizer
from repro.core.knobs import HEMEM_SPACE, get_space
from repro.core.simulator import (PAGE_BYTES, _epoch_consts, _fast_capacity,
                                  get_machine, run_simulation_batch,
                                  scale_config)
from repro.core.specs import SimOptions
from repro.core.workloads import make_workload

ALL_ENGINES = ("hemem", "hmsdk", "memtis", "static", "oracle")
#: statistical tolerance for engines whose monitoring is sampled (the jax
#: draws are equal in distribution, not in stream — and at the tiny test
#: scale the simulation amplifies stream differences chaotically: numpy
#: itself moves ~30-45% across seeds there, while at scale 0.25 numpy and
#: jax agree to ~1e-3, see test_parity_tightens_at_realistic_scale).
#: The deterministic engines (no monitoring noise) plan bit-identical
#: migrations under exact selection, so they must agree to float32
#: cost-model rounding at EVERY scale — measured < 1e-5, pinned at 1e-4.
REL_TOL = {"hemem": 0.35, "hmsdk": 0.35, "memtis": 0.35,
           "static": 1e-4, "oracle": 1e-4}


def _wl(scale=0.04, seed=3, name="gups", inp="8GiB-hot"):
    return make_workload(name, inp, threads=8, scale=scale, seed=seed)


def _configs(engine, n, seed=5):
    if engine in ("hemem", "hmsdk", "memtis"):
        space = get_space(engine)
        rng = np.random.default_rng(seed)
        return [space.default_config()] + [space.sample(rng)
                                           for _ in range(n - 1)]
    return [{} for _ in range(n)]


# ---------------------------------------------------------------------------
# numpy-vs-jax parity: all five engines, both sampler spellings
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ALL_ENGINES)
@pytest.mark.parametrize("sampler", ["sparse", "elementwise"])
def test_backend_parity(engine, sampler):
    wl = _wl()
    cfgs = _configs(engine, 2)
    ref = run_simulation_batch(wl, engine, cfgs, "pmem-large", seeds=7,
                               sampler=sampler)
    jx = run_simulation_batch(wl, engine, cfgs, "pmem-large", seeds=7,
                              sampler=sampler, backend="jax")
    for a, b in zip(ref, jx):
        assert np.isfinite(b.total_s) and b.total_s > 0
        rel = abs(a.total_s - b.total_s) / a.total_s
        assert rel < REL_TOL[engine], \
            f"{engine}/{sampler}: rel diff {rel:.3f}"
        if engine in ("static", "oracle"):
            # no sampling + exact selection: migration plans are
            # bit-identical and per-epoch walls agree to f32 rounding
            assert np.array_equal(a.cum_migrations, b.cum_migrations)
            rel_e = np.max(np.abs(a.epoch_wall_ms - b.epoch_wall_ms)
                           / np.maximum(a.epoch_wall_ms, 1e-9))
            assert rel_e < 1e-4


def test_parity_holds_on_a_second_workload():
    wl = _wl(name="silo", inp="ycsb-c")
    cfgs = _configs("hemem", 2)
    ref = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=1)
    jx = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=1,
                              backend="jax")
    for a, b in zip(ref, jx):
        assert abs(a.total_s - b.total_s) / a.total_s < 0.2


@pytest.mark.parametrize("engine", ["static", "oracle"])
def test_deterministic_engines_exact_at_toy_scale(engine):
    """With exact selection the noise-free engines match numpy at EVERY
    scale — bit-identical migration plans, f32-rounding-level walls — not
    just at the paper's ≥ 0.25 evaluation scale."""
    wl = _wl(scale=0.02)
    ref = run_simulation_batch(wl, engine, [{}], "pmem-large", seeds=7)[0]
    jx = run_simulation_batch(wl, engine, [{}], "pmem-large", seeds=7,
                              backend="jax")[0]
    assert np.array_equal(ref.cum_migrations, jx.cum_migrations)
    assert abs(ref.total_s - jx.total_s) / ref.total_s < 1e-4


def test_parity_tightens_at_realistic_scale():
    """At scale 0.25 (the paper-default evaluation scale) the simulation is
    no longer chaos-dominated and the backends agree closely."""
    wl = make_workload("btree", "", threads=8, scale=0.25, seed=3)
    cfg = get_space("hemem").default_config()
    a = run_simulation_batch(wl, "hemem", [cfg], "pmem-large", seeds=1)[0]
    b = run_simulation_batch(wl, "hemem", [cfg], "pmem-large", seeds=1,
                             backend="jax")[0]
    assert abs(a.total_s - b.total_s) / a.total_s < 0.05


# ---------------------------------------------------------------------------
# CRN: common random numbers across the batch
# ---------------------------------------------------------------------------
def test_crn_draws_bitwise_identical_across_batch():
    wl = _wl()
    cfg = HEMEM_SPACE.default_config()
    res = run_simulation_batch(wl, "hemem", [cfg] * 3, "pmem-large", seeds=0,
                               backend="jax", crn=True)
    for r in res[1:]:
        # identical configs + shared noise => identical trajectories, bitwise
        assert np.array_equal(res[0].epoch_wall_ms, r.epoch_wall_ms)
        assert np.array_equal(res[0].sampling_ms, r.sampling_ms)
        assert np.array_equal(res[0].cum_migrations, r.cum_migrations)


def test_without_crn_equal_seed_rows_draw_independently():
    wl = _wl()
    cfg = HEMEM_SPACE.default_config()
    res = run_simulation_batch(wl, "hemem", [cfg] * 2, "pmem-large", seeds=0,
                               backend="jax", crn=False)
    assert not np.array_equal(res[0].epoch_wall_ms, res[1].epoch_wall_ms)


def test_crn_row0_matches_non_crn_row0():
    """CRN shares row 0's stream: the first config's result is unchanged."""
    wl = _wl()
    cfgs = _configs("hemem", 2)
    a = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0,
                             backend="jax", crn=False)
    b = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0,
                             backend="jax", crn=True)
    assert np.array_equal(a[0].epoch_wall_ms, b[0].epoch_wall_ms)


def test_crn_with_per_config_seeds_survives_sharding():
    """Regression: with crn=True and per-config seeds, every row must share
    the GLOBAL first seed — a shard must not re-anchor on its local first
    seed (that broke both the CRN bitwise and sharding invariants)."""
    import os
    wl = _wl()
    cfg = HEMEM_SPACE.default_config()
    one = run_simulation_batch(wl, "hemem", [cfg] * 4, "pmem-large",
                               seeds=[1, 2, 3, 4], backend="jax", crn=True)
    for r in one[1:]:
        assert np.array_equal(one[0].epoch_wall_ms, r.epoch_wall_ms)
    if (os.cpu_count() or 1) >= 2:
        two = run_simulation_batch(wl, "hemem", [cfg] * 4, "pmem-large",
                                   seeds=[1, 2, 3, 4], backend="jax",
                                   crn=True, workers=2)
        for a, b in zip(one, two):
            assert np.array_equal(a.epoch_wall_ms, b.epoch_wall_ms)


def test_crn_requires_jax_backend():
    wl = _wl()
    with pytest.raises(ValueError, match="crn"):
        run_simulation_batch(wl, "hemem", [HEMEM_SPACE.default_config()],
                             "pmem-large", seeds=0, backend="numpy",
                             crn=True)
    with pytest.raises(ValueError, match="crn"):
        SimOptions(crn=True, backend="numpy")
    SimOptions(crn=True, backend="jax")  # valid


def test_jax_sharding_and_batch_offset_invariance():
    """Process-pool sharding must not change jax results: counter keys use
    the global batch index, shipped to shards as batch_offset."""
    import os
    if (os.cpu_count() or 1) < 2:
        pytest.skip("needs >= 2 CPUs")
    wl = _wl()
    cfgs = _configs("hemem", 4)
    one = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=9,
                               backend="jax")
    two = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=9,
                               backend="jax", workers=2)
    for a, b in zip(one, two):
        assert np.array_equal(a.epoch_wall_ms, b.epoch_wall_ms)


# ---------------------------------------------------------------------------
# lax.scan vs Python epoch loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["hemem", "memtis", "oracle"])
def test_scan_matches_python_epoch_loop(engine):
    wl = _wl(scale=0.02)
    machine = get_machine("pmem-large")
    const = _epoch_consts(wl, engine, machine, PAGE_BYTES)
    fast_cap = _fast_capacity(wl, 8.0, None)
    cfgs = [scale_config(engine, c, wl.scale) for c in _configs(engine, 2)]
    scanned = engine_jax.run_epochs(wl, engine, cfgs, const, fast_cap,
                                    PAGE_BYTES, [0, 1], "sparse")
    looped = engine_jax.run_epochs(wl, engine, cfgs, const, fast_cap,
                                   PAGE_BYTES, [0, 1], "sparse",
                                   python_loop=True)
    for key in scanned:
        assert np.allclose(scanned[key], looped[key], rtol=1e-5,
                           atol=1e-5), key


# ---------------------------------------------------------------------------
# counter-based RNG + fused Poisson kernel
# ---------------------------------------------------------------------------
def test_base_keys_crn_semantics():
    ks = engine_jax.base_keys([0, 0, 0], 0, crn=False)
    assert len(set(ks.tolist())) == 3          # equal seeds, distinct rows
    kc = engine_jax.base_keys([0, 5, 9], 0, crn=True)
    assert len(set(kc.tolist())) == 1          # all rows share row 0's key
    assert kc[0] == ks[0]                      # ... which is the non-CRN row 0
    shifted = engine_jax.base_keys([0, 0], 1, crn=False)
    assert shifted[0] == ks[1]                 # offset = global batch index


def test_counter_uniform_deterministic_and_in_unit_interval():
    idx = np.arange(10000, dtype=np.uint32)
    key = np.full(1, 123, dtype=np.uint32)
    u1 = np.asarray(engine_jax.counter_uniform(key, idx))
    u2 = np.asarray(engine_jax.counter_uniform(key, idx))
    assert np.array_equal(u1, u2)
    assert (u1 > 0).all() and (u1 < 1).all()
    assert abs(u1.mean() - 0.5) < 0.02


@pytest.mark.parametrize("lam", [0.05, 0.8, 3.0, 20.0, 300.0])
def test_fused_poisson_mean_and_variance(lam):
    """The hybrid kernel (exact CDF inversion below POISSON_SWITCH,
    popcount-normal above) matches Poisson mean and variance."""
    n = 200_000
    idx = np.arange(n, dtype=np.uint32)
    keys = engine_jax.base_keys([42], 0, False)
    import jax.numpy as jnp
    h1 = engine_jax.counter_hash(keys[:1], np.uint32(1), idx)
    h2 = engine_jax.counter_hash(keys[:1], np.uint32(2), idx)
    s = np.asarray(engine_jax._poisson_from_hash(
        jnp.full(n, lam, jnp.float32), jnp.asarray(h1), jnp.asarray(h2)))
    assert (s >= 0).all()
    assert abs(s.mean() - lam) / lam < 0.05
    assert abs(s.var() - lam) / lam < 0.10


@pytest.mark.parametrize("mode", ["ref", "pallas", "quantized"])
def test_select_top_counts_and_order(mode):
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    B, n = 3, 500
    heat = jnp.asarray(rng.uniform(size=(B, n)).astype(np.float32))
    p_mask = jnp.asarray(rng.uniform(size=(B, n)) < 0.3)
    d_mask = jnp.asarray(~np.asarray(p_mask) & (rng.uniform(size=(B, n)) < 0.5))
    kp = jnp.asarray(np.array([7, 0, 100], np.float32))
    kd = jnp.asarray(np.array([5, 3, 10_000], np.float32))
    pm, dm = engine_jax.select_top(p_mask, heat, d_mask, heat, kp, kd,
                                   mode=mode)
    pm, dm = np.asarray(pm), np.asarray(dm)
    # exact counts: min(k, candidate count) — in EVERY mode
    for b in range(B):
        assert pm[b].sum() == min(int(kp[b]), int(np.asarray(p_mask)[b].sum()))
        assert dm[b].sum() == min(int(kd[b]), int(np.asarray(d_mask)[b].sum()))
        assert not (pm[b] & ~np.asarray(p_mask)[b]).any()
        assert not (dm[b] & ~np.asarray(d_mask)[b]).any()
    h = np.asarray(heat)
    if mode == "quantized":
        # quantized order: hot/cold only on average within collision tiers
        sel = h[0][pm[0]]
        unsel = h[0][np.asarray(p_mask)[0] & ~pm[0]]
        assert sel.mean() > unsel.mean()
        dsel = h[0][dm[0]]
        dunsel = h[0][np.asarray(d_mask)[0] & ~dm[0]]
        assert dsel.mean() < dunsel.mean()
    else:
        # exact order: bit-identical to the numpy stable-sort reference
        for b in range(B):
            for mask, got, sign in ((np.asarray(p_mask), pm, -1),
                                    (np.asarray(d_mask), dm, +1)):
                idx = np.flatnonzero(mask[b])
                k = min(int((kp if sign < 0 else kd)[b]), idx.size)
                order = np.argsort(sign * h[b][idx], kind="stable")
                assert np.array_equal(np.flatnonzero(got[b]),
                                      np.sort(idx[order[:k]]))


def test_quantized_select_reachable_and_distinct():
    """exact_select=False keeps the historical log-quantized selection
    compiled and reachable (the ablation path) — and the jit cache keys
    the two implementations separately."""
    wl = _wl()
    cfgs = _configs("hemem", 2)
    exact = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=7,
                                 backend="jax")
    quant = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=7,
                                 backend="jax", exact_select=False)
    modes = {k[-1] for k in engine_jax.compiled_cache_info()}
    assert "quantized" in modes and modes & {"ref", "pallas"}
    for a, b in zip(exact, quant):
        assert np.isfinite(b.total_s) and b.total_s > 0
        # same workload, same noise — only selection order differs, so the
        # trajectories stay close but need not match
        assert abs(a.total_s - b.total_s) / a.total_s < 0.35
    # and the typed options spell it the same way
    res = run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=7,
                               backend="jax", exact_select=False)
    for a, b in zip(quant, res):
        assert np.array_equal(a.epoch_wall_ms, b.epoch_wall_ms)


def test_sim_options_exact_select_roundtrip():
    opts = SimOptions(backend="jax", exact_select=False)
    assert SimOptions.from_dict(opts.to_dict()) == opts
    assert SimOptions().exact_select  # exact is the default


def test_custom_engine_falls_back_to_numpy_loop_with_warning(caplog):
    """Engines outside the compiled builtins run the numpy epoch loop under
    backend='jax' (ROADMAP follow-up) — loudly, via one warning line."""
    from repro.core import simulator
    from repro.core.engine import BatchStaticEngine
    from repro.core.registry import register_engine

    @register_engine("fallback-probe", overwrite=True)
    class FallbackProbeEngine(BatchStaticEngine):
        pass

    simulator._JAX_FALLBACK_WARNED.clear()
    wl = _wl(scale=0.02)
    with caplog.at_level(logging.WARNING, logger="repro.core.simulator"):
        jx = run_simulation_batch(wl, "fallback-probe", [{}], "pmem-large",
                                  seeds=3, backend="jax")
    msgs = [r.message for r in caplog.records
            if "falling back to the numpy epoch loop" in r.message]
    assert len(msgs) == 1 and "fallback-probe" in msgs[0]
    # the warning fires once per distinct cause, not per call
    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.core.simulator"):
        run_simulation_batch(wl, "fallback-probe", [{}], "pmem-large",
                             seeds=3, backend="jax")
    assert not any("falling back" in r.message for r in caplog.records)
    # the fallback is the numpy loop (same RNG streams; only the vmapped
    # jax cost model differs, to f32 rounding)
    ref = run_simulation_batch(wl, "fallback-probe", [{}], "pmem-large",
                               seeds=3)
    assert np.allclose(jx[0].epoch_wall_ms, ref[0].epoch_wall_ms, rtol=1e-5)


# ---------------------------------------------------------------------------
# jit cache + recompilation warning
# ---------------------------------------------------------------------------
def test_jit_cache_reuses_and_warns_on_shape_change(caplog):
    wl = _wl(scale=0.02, seed=11)
    cfgs = _configs("hemem", 2, seed=8)
    run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0,
                         backend="jax")
    size0 = len(engine_jax.compiled_cache_info())
    # same shapes: no new cache entry, no warning
    with caplog.at_level(logging.WARNING, logger="repro.core.engine_jax"):
        run_simulation_batch(wl, "hemem", cfgs, "pmem-large", seeds=0,
                             backend="jax")
    assert len(engine_jax.compiled_cache_info()) == size0
    assert not any("recompiling" in r.message for r in caplog.records)
    # new batch size for the same (engine, n_pages, sampler): one-line warning
    with caplog.at_level(logging.WARNING, logger="repro.core.engine_jax"):
        run_simulation_batch(wl, "hemem", cfgs + _configs("hemem", 1, seed=9),
                             "pmem-large", seeds=0, backend="jax")
    assert any("recompiling" in r.message for r in caplog.records)
    assert len(engine_jax.compiled_cache_info()) == size0 + 1


# ---------------------------------------------------------------------------
# Study integration + CRN-aware SMAC tell
# ---------------------------------------------------------------------------
def test_study_runs_with_jax_backend_and_crn():
    from repro.core import ExperimentSpec, SimOptions, Study, WorkloadSpec
    spec = ExperimentSpec(
        engine="hemem",
        workload=WorkloadSpec("gups", "8GiB-hot", threads=8, scale=0.02),
        options=SimOptions(backend="jax", crn=True))
    study = Study(spec)
    res = study.run(configs=[HEMEM_SPACE.default_config()] * 2)
    assert np.array_equal(res[0].epoch_wall_ms, res[1].epoch_wall_ms)
    tuned = study.tune(budget=6, batch_size=3, n_init=2, seed=0)
    assert len(tuned.history) == 6
    assert tuned.best_value > 0


def test_tell_batch_crn_debias_with_control():
    opt = SMACOptimizer(HEMEM_SPACE, seed=0, n_init=2)
    base = HEMEM_SPACE.default_config()
    other = HEMEM_SPACE.sample(np.random.default_rng(0))
    opt.tell(base, 100.0)
    opt.tell(other, 120.0)
    # the round re-evaluates `base` (control) under shared noise +7: the
    # whole round is shifted back by the control's delta
    third = HEMEM_SPACE.sample(np.random.default_rng(1))
    opt.tell_batch([base, third], [107.0, 97.0], crn=True)
    assert opt.observations[-2].value == pytest.approx(100.0)
    assert opt.observations[-1].value == pytest.approx(90.0)
    # without crn, values are recorded untouched
    opt.tell_batch([base, third], [107.0, 97.0])
    assert opt.observations[-2].value == pytest.approx(107.0)
    assert opt.observations[-1].value == pytest.approx(97.0)


def test_ask_batch_include_incumbent_plants_control():
    opt = SMACOptimizer(HEMEM_SPACE, seed=3, n_init=2)
    rng = np.random.default_rng(0)
    # during the init phase the schedule stays exploratory
    cfgs = opt.ask_batch(2, include_incumbent=True)
    opt.tell_batch(cfgs, [float(rng.uniform(50, 100)) for _ in cfgs])
    batch = opt.ask_batch(3, include_incumbent=True)
    assert batch[0] == opt.best.config
    # and q=1/no-flag behaviour is unchanged
    assert opt.ask_batch(1) is not None
