"""Epoch-based tiered-memory simulator: the black-box f(θ) the optimizer tunes.

The simulator executes a :class:`~repro.core.workloads.Workload` against a
tiering engine on a :class:`Machine` and returns the workload's execution
time.  It models, per epoch of fixed application work:

* **access cost** — bandwidth-bound and latency-bound components per tier,
  using the Table-3 machine characteristics (asymmetric NVM read/write
  bandwidth, per-tier load latencies, thread-level memory parallelism);
* **migration cost** — migrated bytes consume bandwidth on *both* tiers
  (promotions read from the far tier, demotions write to it), competing with
  application traffic; writes to in-flight pages stall on the write-protect
  barrier (HeMem §3.2);
* **monitoring cost** — PEBS-style sampling interrupts charge CPU time per
  sample (the paper's deployment fix #1 reduced, but did not eliminate, this);
  DAMON's page-table scans are far cheaper per probe;
* **engine cost** — extra kernel time some engines burn (Memtis page
  allocation/splitting, §4.6).

**Batched evaluation** is the primary entry point:
:func:`run_simulation_batch` carries a whole batch of B candidate
configurations through ONE shared workload trace — the engines keep
``(B, n_pages)`` state, and the batch can additionally be sharded over a
process pool (``workers=N``) or, with multi-cell work, scheduled through
one shared shard queue (:func:`run_simulation_cells`, used by
``Study.sweep``).  :func:`run_simulation` itself is the thin ``B=1``
wrapper kept for existing callers.

**Two-backend contract** (``backend=``):

* ``"numpy"`` (default) — the bit-exact reference.  Per-config random
  streams are independent and seeded exactly like the single-config path,
  so ``run_simulation_batch([c1..cB])`` returns the same numbers as B
  sequential :func:`run_simulation` calls with matched seeds and the same
  ``sampler``.
* ``"jax"`` — the compiled fast path: the WHOLE epoch loop (engine
  observe/plan, fused Poisson sampling kernels, tier update and this
  module's access-cost model) jit-compiles into one ``lax.scan`` per
  (engine, workload shape); see :mod:`repro.core.engine_jax`.  Draws are
  counter-based — equal in distribution to the reference but not
  stream-compatible, so cross-backend parity is statistical for the
  sampled engines; migration-plan selection itself is **exact** (the
  top-k selection kernel of :mod:`repro.kernels.select_topk` returns
  bit-identical index sets to the reference's stable sorts;
  ``exact_select=False`` restores the historical log-quantized
  approximation for ablations).  ``crn=True`` additionally shares the
  monitoring noise bitwise across the batch (common random numbers) for
  paired candidate comparisons during tuning; leave it off when
  estimating absolute performance from independent replicas.
  Engines/samplers outside the builtin set (and traces beyond the
  compiled path's page ceiling) fall back to the numpy epoch loop with
  the vmapped jax cost model — a one-line warning records the downgrade.

Scaling: ``workload.scale`` shrinks the page count and access volume while
*time semantics stay real*: effective bandwidth and memory-level parallelism
shrink by the same factor, so per-page access rates, thresholds, periods and
wall-clock times all match the full-size system.  Knobs with page-count
semantics (``cooling_pages``, ring sizes, ``nr_regions``) are scaled when the
engine is instantiated; see :func:`scale_config`.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

from ._deprecation import warn_deprecated
from . import engine_jax
from .engine import make_batch_engine
from .knobs import get_space
from .pages import BatchTierState, PAGE_BYTES, migration_rate_pages
from .registry import (BACKENDS, MACHINES as MACHINE_REGISTRY,
                       register_backend, register_machine)
from .workloads import Workload, make_workload

CACHELINE = 64


# ---------------------------------------------------------------------------
# Machines — paper Table 3, plus a TPU-v5e host-offload profile for the
# beyond-paper serving substrate.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Machine:
    name: str
    cores: int
    near_bw_gbs: float          # fast-tier bandwidth (GB/s)
    far_bw_read_gbs: float      # slow-tier read bandwidth (GB/s)
    far_bw_write_gbs: float     # slow-tier write bandwidth (GB/s)
    near_lat_ns: float
    far_lat_ns: float
    sample_us: float            # CPU time per PEBS sample (post-fix #1)
    scan_us: float              # CPU time per DAMON page-table probe
    default_threads: int

    @property
    def far_symmetric(self) -> bool:
        return abs(self.far_bw_read_gbs - self.far_bw_write_gbs) < 1e-9


PMEM_LARGE = Machine("pmem-large", cores=24, near_bw_gbs=138.0,
                     far_bw_read_gbs=7.45, far_bw_write_gbs=2.25,
                     near_lat_ns=80.0, far_lat_ns=200.0,
                     sample_us=0.8, scan_us=0.05, default_threads=12)
PMEM_SMALL = Machine("pmem-small", cores=16, near_bw_gbs=46.0,
                     far_bw_read_gbs=6.8, far_bw_write_gbs=1.85,
                     near_lat_ns=80.0, far_lat_ns=200.0,
                     sample_us=0.8, scan_us=0.05, default_threads=4)
NUMA = Machine("numa", cores=20, near_bw_gbs=56.0,
               far_bw_read_gbs=36.0, far_bw_write_gbs=36.0,
               near_lat_ns=95.0, far_lat_ns=145.0,
               sample_us=0.8, scan_us=0.05, default_threads=12)
#: TPU v5e chip with host-DRAM offload over PCIe: the two-tier system the
#: production TieredKVCache manages.  "Threads" = the single decode stream;
#: MLP comes from DMA queue depth.
TPU_V5E_HOST = Machine("tpu-v5e-host", cores=1, near_bw_gbs=819.0,
                       far_bw_read_gbs=16.0, far_bw_write_gbs=16.0,
                       near_lat_ns=600.0, far_lat_ns=2500.0,
                       sample_us=0.05, scan_us=0.05, default_threads=1)

for _m in (PMEM_LARGE, PMEM_SMALL, NUMA, TPU_V5E_HOST):
    register_machine(_m)

#: machine profiles by name — now the shared registry (dict-like view)
MACHINES = MACHINE_REGISTRY


def get_machine(name: str) -> Machine:
    """Look up a registered machine profile (did-you-mean on unknown names)."""
    return MACHINE_REGISTRY.get(name)


def _as_machine(machine: "Machine | str") -> Machine:
    """Resolve a machine argument; ad-hoc Machine instances are registered on
    first use so specs referencing them by name stay replayable.  Reusing a
    registered name for a *different* profile keeps the instance for the
    current call but does NOT re-register it — replay-by-name resolves to
    the first profile; use ``register_machine(..., overwrite=True)`` (or a
    fresh name) to make a new profile the replay target."""
    if isinstance(machine, str):
        return get_machine(machine)
    if machine.name not in MACHINE_REGISTRY:
        register_machine(machine)
    return machine


# ---------------------------------------------------------------------------
# Config scaling (page-count-semantics knobs only; see module docstring).
# ---------------------------------------------------------------------------
_PAGE_SEMANTIC_KNOBS = {
    "hemem": ("cooling_pages", "hot_ring_reqs_threshold",
              "cold_ring_reqs_threshold"),
    "kv-hemem": ("cooling_pages", "hot_ring_reqs_threshold",
                 "cold_ring_reqs_threshold"),
    "hmsdk": ("nr_regions",),
    "memtis": (),
    "static": (),
    "oracle": (),
}


def scale_config(engine_name: str, config: Mapping[str, Any],
                 scale: float) -> Dict[str, Any]:
    out = dict(config)
    for k in _PAGE_SEMANTIC_KNOBS.get(engine_name, ()):
        if k in out:
            out[k] = max(1, int(round(out[k] * scale)))
    return out


# ---------------------------------------------------------------------------
# Simulation result
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SimResult:
    workload: str
    engine: str
    machine: str
    config: Dict[str, Any]
    total_s: float
    epoch_wall_ms: np.ndarray       # per-epoch wall time
    cum_migrations: np.ndarray      # cumulative migrated pages over epochs
    fast_hit_rate: np.ndarray       # fraction of accesses served by fast tier
    sampling_ms: np.ndarray
    stall_ms: np.ndarray
    heatmap: Optional[np.ndarray] = None   # (epochs, heat_bins) access heat
    placement: Optional[np.ndarray] = None  # (epochs, heat_bins) frac in fast

    @property
    def total_migrations(self) -> int:
        return int(self.cum_migrations[-1]) if len(self.cum_migrations) else 0


# ---------------------------------------------------------------------------
# Access-cost math — one scalar-config definition, reused by the vectorized
# numpy path and (vmapped) by the optional JAX backend.
# ---------------------------------------------------------------------------
def _access_cost(xp, acc_f, acc_s, reads_s, writes_s, promote_bytes,
                 demote_bytes, w_mig, est_wall_ms, samples, engine_ms,
                 const: Mapping[str, float]):
    """Per-config epoch wall-time model.  ``xp`` is numpy or jax.numpy; all
    per-config inputs are scalars (vmap/broadcast supplies the batch axis)."""
    bytes_f = acc_f * CACHELINE
    # bandwidth-bound terms (migration traffic shares the devices)
    t_near = (bytes_f + promote_bytes + demote_bytes) / const["near_bw"]
    t_far = ((reads_s * CACHELINE + promote_bytes) / const["far_bw_r"]
             + (writes_s * CACHELINE + demote_bytes) / const["far_bw_w"])
    # latency-bound term
    t_lat = (acc_f * const["near_lat_s"] + acc_s * const["far_lat_s"]) \
        / const["eff_par"]
    t_mem = xp.maximum(xp.maximum(t_near, t_far), t_lat)

    # write-protect stalls: HeMem write-protects in-flight pages, so only
    # the writes that land *during* a page's copy window stall, each for
    # half the copy time on average.  Expected stalled writes per page =
    # page_write_rate x copy_duration; a stalled thread cannot overlap, so
    # the app-level cost divides by thread count (scale-adjusted).
    page_copy_s = const["page_copy_s"]
    epoch_s_est = xp.maximum(est_wall_ms * 1e-3, page_copy_s)
    frac_in_flight = xp.minimum(page_copy_s / epoch_s_est, 1.0)
    stall_s = xp.where(
        (promote_bytes + demote_bytes) > 0,
        w_mig * frac_in_flight * (page_copy_s / 2.0) / const["stall_denom"],
        0.0)

    sampling_s = samples * const["probe_us"] * 1e-6 / const["threads_floor"]
    engine_s = engine_ms * 1e-3
    wall_ms = (xp.maximum(const["compute_ms"], t_mem * 1e3)
               + stall_s * 1e3 + sampling_s * 1e3 + engine_s * 1e3)
    hit_rate = acc_f / xp.maximum(acc_f + acc_s, 1e-12)
    return wall_ms, stall_s, sampling_s, hit_rate


_JAX_COST = None


def _jax_cost_fn():
    """Lazily build the jitted+vmapped JAX version of the access-cost math."""
    global _JAX_COST
    if _JAX_COST is None:
        try:
            import jax
            import jax.numpy as jnp
        except ImportError as e:  # pragma: no cover - env without jax
            raise RuntimeError(
                "backend='jax' requires jax; install it or use the default "
                "numpy backend") from e

        def scalar(acc_f, acc_s, reads_s, writes_s, pb, db, w_mig, est,
                   samples, engine_ms, const):
            return _access_cost(jnp, acc_f, acc_s, reads_s, writes_s, pb, db,
                                w_mig, est, samples, engine_ms, const)

        _JAX_COST = jax.jit(jax.vmap(scalar, in_axes=(0,) * 10 + (None,)))
    return _JAX_COST


def _numpy_cost_fn():
    return functools.partial(_access_cost, np)


# backends are zero-arg factories returning the vectorized cost callable;
# the numpy path broadcasts, the jax path jit+vmaps the same scalar math
register_backend("numpy", _numpy_cost_fn)
register_backend("jax", _jax_cost_fn)


# ---------------------------------------------------------------------------
# Core loop (batched)
# ---------------------------------------------------------------------------
def _epoch_consts(workload: Workload, engine_name: str, machine: Machine,
                  page_bytes: int) -> Dict[str, float]:
    """The scalar constants of the access-cost model (shared by both
    backends).  Effective parallel resources shrink with ``scale`` so time
    semantics stay real; see the module docstring."""
    threads = workload.threads
    scale = workload.scale
    eff_bw = scale
    eff_par = threads * workload.mlp * scale
    near_bw = machine.near_bw_gbs * 1e9 * eff_bw
    far_bw_r = machine.far_bw_read_gbs * 1e9 * eff_bw
    far_bw_w = machine.far_bw_write_gbs * 1e9 * eff_bw
    # probe-cost knob: engines that sample pay per-sample CPU; DAMON pays per
    # scan probe (engine reports its probes via samples_last_epoch).
    probe_us = machine.scan_us if engine_name == "hmsdk" else machine.sample_us
    return {
        "near_bw": near_bw, "far_bw_r": far_bw_r, "far_bw_w": far_bw_w,
        "near_lat_s": machine.near_lat_ns * 1e-9,
        "far_lat_s": machine.far_lat_ns * 1e-9,
        "eff_par": eff_par,
        "page_copy_s": page_bytes / max(min(far_bw_r, near_bw), 1.0),
        "stall_denom": max(threads * scale, 1e-9),
        "probe_us": probe_us, "threads_floor": max(threads, 1),
        "compute_ms": workload.compute_ms,
    }


def _fast_capacity(workload: Workload, fast_slow_ratio: float,
                   fast_capacity_pages: Optional[int]) -> int:
    if fast_capacity_pages is not None:
        return int(fast_capacity_pages)
    return max(1, int(round(workload.n_pages / (1.0 + fast_slow_ratio))))


def _run_batch_jax(workload: Workload, engine_name: str,
                   configs: Sequence[Mapping[str, Any]], machine: Machine,
                   fast_slow_ratio: float, seeds, sampler: str,
                   record_heatmap: bool, heat_bins: int,
                   fast_capacity_pages: Optional[int], crn: bool,
                   batch_offset: int,
                   exact_select: bool = True) -> List[SimResult]:
    """The compiled fast path: one ``lax.scan`` over epochs per batch (see
    :mod:`repro.core.engine_jax` for the backend contract)."""
    B = len(configs)
    n = workload.n_pages
    scale = workload.scale
    fast_cap = _fast_capacity(workload, fast_slow_ratio, fast_capacity_pages)
    sim_cfgs = [scale_config(engine_name, c, scale) for c in configs]
    const = _epoch_consts(workload, engine_name, machine, PAGE_BYTES)
    out = engine_jax.run_epochs(
        workload, engine_name, sim_cfgs, const, fast_cap, PAGE_BYTES,
        seeds, sampler, crn=crn, batch_offset=batch_offset,
        record_placement=record_heatmap, exact_select=exact_select)
    wall = np.asarray(out["wall_ms"], dtype=np.float64)
    cum_mig = np.asarray(out["cum_migrations"], dtype=np.float64)
    hit_rate = np.asarray(out["hit_rate"], dtype=np.float64)
    sampling_ms = np.asarray(out["sampling_ms"], dtype=np.float64)
    stall_ms = np.asarray(out["stall_ms"], dtype=np.float64)
    n_epochs = workload.n_epochs
    heat = place = None
    if record_heatmap:
        bin_of = np.arange(n) * heat_bins // n
        bin_sizes = np.maximum(np.bincount(bin_of, minlength=heat_bins), 1)
        heat = np.zeros((n_epochs, heat_bins))
        place = np.zeros((B, n_epochs, heat_bins))
        in_fast = np.asarray(out["in_fast"])
        acc_t = (out["trace_reads"] + out["trace_writes"]).astype(np.float64)
        for e in range(n_epochs):
            heat[e] = np.bincount(bin_of, weights=acc_t[e],
                                  minlength=heat_bins)
            for b in range(B):
                place[b, e] = np.bincount(
                    bin_of, weights=in_fast[e, b].astype(np.float64),
                    minlength=heat_bins) / bin_sizes
    return [SimResult(
        workload=workload.key, engine=engine_name, machine=machine.name,
        config=dict(configs[b]), total_s=float(wall[:, b].sum() / 1e3),
        epoch_wall_ms=wall[:, b].copy(), cum_migrations=cum_mig[:, b].copy(),
        fast_hit_rate=hit_rate[:, b].copy(),
        sampling_ms=sampling_ms[:, b].copy(),
        stall_ms=stall_ms[:, b].copy(),
        heatmap=heat if record_heatmap else None,
        placement=place[b] if record_heatmap else None) for b in range(B)]


#: jax-fallback reasons already warned about (one line per distinct cause)
_JAX_FALLBACK_WARNED: set = set()


def _warn_jax_fallback(engine_name: str, sampler: str, n_pages: int) -> None:
    """One-line warning when ``backend="jax"`` silently cannot compile the
    requested combination and the numpy epoch loop runs instead (the
    vmapped jax cost model still applies)."""
    lifted = engine_jax.jax_engines()
    if engine_name not in lifted:
        reason = (f"engine {engine_name!r} has no lifted jax definition "
                  f"(compiled: {lifted}); register one with "
                  f"engine_jax.register_jax_engine to compile it")
    elif sampler not in engine_jax.JAX_SAMPLERS:
        reason = (f"sampler {sampler!r} is not one of the fused builtins "
                  f"{engine_jax.JAX_SAMPLERS}")
    elif n_pages > engine_jax.MAX_PAGES:
        reason = (f"trace has {n_pages} pages, above the compiled path's "
                  f"{engine_jax.MAX_PAGES}-page ceiling")
    else:
        reason = "jax is not installed"
    key = (engine_name, sampler, reason)
    if key in _JAX_FALLBACK_WARNED:
        return
    _JAX_FALLBACK_WARNED.add(key)
    import logging
    logging.getLogger(__name__).warning(
        "backend='jax': %s; falling back to the numpy epoch loop "
        "(vmapped jax cost model only)", reason)


def _run_batch_local(workload: Workload, engine_name: str,
                     configs: Sequence[Mapping[str, Any]],
                     machine: Machine, fast_slow_ratio: float,
                     seeds, sampler: str, record_heatmap: bool,
                     heat_bins: int, fast_capacity_pages: Optional[int],
                     backend: str, crn: bool = False,
                     batch_offset: int = 0,
                     exact_select: bool = True,
                     epoch_stop: Optional[int] = None) -> List[SimResult]:
    if backend == "jax":
        if engine_jax.supports(engine_name, sampler, workload.n_pages):
            # the compiled fast path: engines + samplers + cost model fused
            # into one jitted lax.scan over epochs
            return _run_batch_jax(workload, engine_name, configs, machine,
                                  fast_slow_ratio, seeds, sampler,
                                  record_heatmap, heat_bins,
                                  fast_capacity_pages, crn, batch_offset,
                                  exact_select)
        _warn_jax_fallback(engine_name, sampler, workload.n_pages)
    if crn:
        raise ValueError(
            "crn=True (common random numbers) requires the compiled jax "
            "path (backend='jax', builtin engine/sampler, trace within its "
            "page limit): the numpy engines consume sequential RNG streams "
            "that cannot be shared across configs (got "
            f"backend={backend!r}, engine={engine_name!r}, "
            f"sampler={sampler!r}, n_pages={workload.n_pages})")
    B = len(configs)
    n = workload.n_pages
    scale = workload.scale
    fast_capacity_pages = _fast_capacity(workload, fast_slow_ratio,
                                         fast_capacity_pages)
    tier = BatchTierState(B, n, fast_capacity_pages)
    sim_cfgs = [scale_config(engine_name, c, scale) for c in configs]
    engine = make_batch_engine(engine_name, sim_cfgs, tier, seeds=seeds,
                               sampler=sampler)

    page_bytes = tier.page_bytes
    const = _epoch_consts(workload, engine_name, machine, page_bytes)

    n_epochs = workload.n_epochs if epoch_stop is None \
        else min(int(epoch_stop), workload.n_epochs)
    wall = np.zeros((n_epochs, B))
    cum_mig = np.zeros((n_epochs, B))
    hit_rate = np.zeros((n_epochs, B))
    sampling_ms_a = np.zeros((n_epochs, B))
    stall_ms_a = np.zeros((n_epochs, B))
    heat = np.zeros((n_epochs, heat_bins)) if record_heatmap else None
    place = np.zeros((B, n_epochs, heat_bins)) if record_heatmap else None
    bin_of = (np.arange(n) * heat_bins // n) if record_heatmap else None
    bin_sizes = np.maximum(np.bincount(bin_of, minlength=heat_bins), 1) \
        if record_heatmap else None

    mig_cost_free = engine.zero_cost_migrations
    rates = engine.max_rates_gibs()
    est_wall_ms = np.full(B, workload.epoch_ms)  # running estimate
    total_mig = np.zeros(B)
    # per-config reduction buffers
    acc_f = np.zeros(B)
    reads_s = np.zeros(B)
    writes_s = np.zeros(B)
    w_mig = np.zeros(B)
    n_promote = np.zeros(B)
    n_demote = np.zeros(B)
    cost_fn = BACKENDS.get(backend)()

    for e in range(n_epochs):
        reads, writes = workload.epoch_access(e)
        touched = (reads + writes) > (1.0 / max(n, 1))
        tier.allocate_first_touch(touched)

        engine.observe(reads, writes, est_wall_ms)
        max_pages = migration_rate_pages(rates, est_wall_ms, page_bytes,
                                         scale)
        plans = engine.plan(est_wall_ms, max_pages)
        tier.apply(plans)

        acc = reads + writes
        acc_sum = float(acc.sum())
        # boolean-mask extraction sums, NOT matvecs: the float summation
        # order must match the historical scalar path bit-for-bit so that
        # batch results stay exactly equal to sequential runs
        for b, plan in enumerate(plans):
            in_fast_b = tier.in_fast[b]
            acc_f[b] = float(acc[in_fast_b].sum())
            slow = ~in_fast_b
            reads_s[b] = float(reads[slow].sum())
            writes_s[b] = float(writes[slow].sum())
            n_promote[b] = len(plan.promote)
            n_demote[b] = len(plan.demote)
            total_mig[b] += plan.n_pages
            if plan.n_pages and not mig_cost_free:
                w_mig[b] = float(writes[plan.promote].sum()
                                 + writes[plan.demote].sum())
            else:
                w_mig[b] = 0.0
        cum_mig[e] = total_mig
        acc_s = acc_sum - acc_f
        if mig_cost_free:
            promote_bytes = np.zeros(B)
            demote_bytes = np.zeros(B)
        else:
            promote_bytes = n_promote * page_bytes
            demote_bytes = n_demote * page_bytes

        wall_ms, stall_s, sampling_s, hr = cost_fn(
            acc_f, acc_s, reads_s, writes_s, promote_bytes, demote_bytes,
            w_mig, est_wall_ms, engine.samples_last_epoch,
            engine.overhead_ms_last_epoch, const)
        wall[e] = wall_ms
        est_wall_ms = np.asarray(wall_ms, dtype=np.float64)
        hit_rate[e] = hr
        sampling_ms_a[e] = np.asarray(sampling_s) * 1e3
        stall_ms_a[e] = np.asarray(stall_s) * 1e3

        if record_heatmap:
            heat[e] = np.bincount(bin_of, weights=acc, minlength=heat_bins)
            for b in range(B):
                place[b, e] = (np.bincount(
                    bin_of, weights=tier.in_fast[b].astype(np.float64),
                    minlength=heat_bins) / bin_sizes)

    return [SimResult(
        workload=workload.key, engine=engine_name, machine=machine.name,
        config=dict(configs[b]), total_s=float(wall[:, b].sum() / 1e3),
        epoch_wall_ms=wall[:, b].copy(), cum_migrations=cum_mig[:, b].copy(),
        fast_hit_rate=hit_rate[:, b].copy(),
        sampling_ms=sampling_ms_a[:, b].copy(),
        stall_ms=stall_ms_a[:, b].copy(),
        # the access heatmap comes from the shared trace, so all B results
        # reference one array; placement is per config
        heatmap=heat if record_heatmap else None,
        placement=place[b] if record_heatmap else None) for b in range(B)]


# ---------------------------------------------------------------------------
# Process-pool sharding for batch evaluation
# ---------------------------------------------------------------------------
_POOL = None
_POOL_SIZE = 0


def compile_cache_dir() -> str:
    """The XLA persistent-compilation-cache directory shipped to worker
    shards (and honoured by the parent when it sets the env itself).

    ``JAX_COMPILATION_CACHE_DIR`` overrides; the default is a stable
    per-user path under the system temp dir so successive pools — and
    successive *processes* — warm-start instead of re-jitting the epoch
    loop per worker."""
    import tempfile
    d = os.environ.get("JAX_COMPILATION_CACHE_DIR") or os.path.join(
        tempfile.gettempdir(), f"repro-xla-cache-{os.getuid()}")
    os.makedirs(d, exist_ok=True)
    return d


def _worker_init(cache_dir: str) -> None:
    """Pool initializer: point the worker's (not-yet-imported) jax at the
    shared XLA compile cache.  Runs before any shard work, so the env is in
    place when the worker first imports jax and every compilation it would
    repeat lands as a disk hit instead."""
    os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", cache_dir)
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
    os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "-1")


def _get_pool(workers: int):
    global _POOL, _POOL_SIZE
    # a larger warm pool serves smaller requests (e.g. a tuning run's partial
    # final batch) — only grow, never tear down and respawn mid-run
    if _POOL is None or workers > _POOL_SIZE:
        import concurrent.futures
        import multiprocessing as mp
        if _POOL is not None:
            _POOL.shutdown(wait=False, cancel_futures=True)
        # forking a parent whose XLA runtime is already initialized is
        # unsupported (threads are not inherited) and can hang the workers;
        # fall back to spawn once jax has been imported
        import sys
        use_fork = "fork" in mp.get_all_start_methods() and \
            "jax" not in sys.modules
        ctx = mp.get_context("fork" if use_fork else "spawn")
        _POOL = concurrent.futures.ProcessPoolExecutor(
            max_workers=workers, mp_context=ctx,
            initializer=_worker_init, initargs=(compile_cache_dir(),))
        _POOL_SIZE = workers
    return _POOL


def _discard_pool(pool) -> None:
    """Forget (and shut down) a broken shared pool so the next
    :func:`_get_pool` call builds a fresh one — the tuning executor's
    BrokenProcessPool self-heal path."""
    global _POOL, _POOL_SIZE
    try:
        pool.shutdown(wait=False, cancel_futures=True)
    except Exception:
        pass
    if pool is _POOL:
        _POOL = None
        _POOL_SIZE = 0


def _shard_worker(args):
    (wl_spec, components, engine_name, configs, machine, fast_slow_ratio,
     seeds, sampler, record_heatmap, heat_bins, fast_capacity_pages,
     backend, crn, batch_offset, exact_select) = args
    # spawn-context workers start from a fresh interpreter that only imported
    # this module, so components registered (or overridden) by user code are
    # unknown there; the parent's resolved objects shipped in the payload are
    # authoritative — register them unconditionally so the worker dispatches
    # to exactly what the parent resolved
    from .registry import BACKENDS as _B, ENGINES as _E, SAMPLERS as _S, \
        WORKLOADS as _W
    for reg, name, obj in ((_E, engine_name, components[0]),
                           (_W, wl_spec[0], components[1]),
                           (_S, sampler, components[2]),
                           (_B, backend, components[3])):
        reg.register(name, obj, overwrite=True)
    wl = make_workload(*wl_spec)
    return _run_batch_local(wl, engine_name, configs, machine,
                            fast_slow_ratio, seeds, sampler, record_heatmap,
                            heat_bins, fast_capacity_pages, backend,
                            crn=crn, batch_offset=batch_offset,
                            exact_select=exact_select)


def _resolve_workers(workers, batch: int) -> int:
    if workers in ("auto", 0, None):
        workers = os.cpu_count() or 1
    return max(1, min(int(workers), batch))


def run_simulation_cells(cells,
                         machine: Machine | str = PMEM_LARGE,
                         fast_slow_ratio: float = 8.0,
                         seeds=0,
                         sampler: str = "sparse",
                         record_heatmap: bool = False,
                         heat_bins: int = 128,
                         fast_capacity_pages: Optional[int] = None,
                         backend: str = "numpy",
                         crn: bool = False,
                         workers: int = 1,
                         exact_select: bool = True) -> List[List[SimResult]]:
    """Evaluate many (workload, engine, config-batch) *cells* through one
    shared work queue.

    ``cells`` is a sequence of ``(workload, engine_name, configs)`` tuples;
    the return value is one ``List[SimResult]`` per cell, in input order.
    With ``workers > 1`` every cell is split into config shards and ALL
    shards across ALL cells are submitted to the process pool at once, so
    the pool stays saturated even when individual cells are smaller than
    the worker count (previously each cell was a sequential barrier).
    Scheduling never changes results — each shard computes exactly what the
    sequential path would (the jax backend keys its counter-based draws by
    the GLOBAL batch index, shipped to each shard as ``batch_offset``).

    ``seeds`` is an int (shared by every config of every cell) or one seed
    sequence per cell (one seed per config).
    """
    machine = _as_machine(machine)
    cells = [(wl, eng, [dict(c) for c in cfgs]) for wl, eng, cfgs in cells]
    n_cells = len(cells)
    if n_cells == 0:
        return []
    if np.ndim(seeds) == 0:
        cell_seeds = [[int(seeds)] * len(cfgs) for _, _, cfgs in cells]
    else:
        rows = list(seeds)
        if any(np.ndim(r) == 0 for r in rows):
            raise ValueError("seeds must be an int or one seed sequence "
                             "per cell (one seed per config); got a flat "
                             "sequence — wrap it per cell")
        cell_seeds = [[int(s) for s in row] for row in rows]
        if len(cell_seeds) != n_cells or any(
                len(row) != len(cells[i][2])
                for i, row in enumerate(cell_seeds)):
            raise ValueError("seeds must be an int or one seed sequence "
                             "per cell (one seed per config)")
    if crn:
        # the CRN contract is per cell: every row shares the CELL's first
        # seed.  Collapsing here (before sharding) keeps the shared stream
        # anchored to the global row 0 even when the batch is split over
        # workers — otherwise a shard would key off ITS first seed and both
        # the bitwise-CRN and sharding-invariance guarantees would break.
        cell_seeds = [[row[0]] * len(row) for row in cell_seeds]
    total = sum(len(cfgs) for _, _, cfgs in cells)
    if total == 0:
        return [[] for _ in range(n_cells)]
    workers = _resolve_workers(workers, total)
    if workers > 1 and backend == "jax":
        # results are identical either way; worker processes share the XLA
        # persistent compile cache (see _worker_init), so only the first
        # pool ever compiles a given shard shape — later workers and later
        # pools warm-start from disk
        import logging
        logging.getLogger(__name__).info(
            "sharding a jax-backend batch over %d worker processes; shards "
            "warm-start from the shared XLA compile cache at %s "
            "(first-ever run per shape still compiles once per worker)",
            workers, compile_cache_dir())
    if workers == 1:
        return [_run_batch_local(wl, eng, cfgs, machine, fast_slow_ratio,
                                 cell_seeds[i], sampler, record_heatmap,
                                 heat_bins, fast_capacity_pages, backend,
                                 crn=crn, exact_select=exact_select)
                for i, (wl, eng, cfgs) in enumerate(cells)]

    from .registry import ENGINES as _ENGINES, SAMPLERS as _SAMPLERS, \
        WORKLOADS as _WORKLOADS
    # one flat shard queue across all cells: shard size targets `workers`
    # equal slices of the TOTAL config count (never crossing a cell), so the
    # pool saturates even when every cell is smaller than the worker count
    shard_size = max(1, -(-total // workers))
    pool = _get_pool(workers)
    futures = []
    for ci, (wl, eng, cfgs) in enumerate(cells):
        wl_spec = (wl.name, wl.input_name, wl.threads, wl.scale, wl.seed)
        # resolved components travel with the shard so spawn-start workers
        # can serve names registered outside this module (see _shard_worker)
        components = (_ENGINES.get(eng), _WORKLOADS.get(wl.name),
                      _SAMPLERS.get(sampler), BACKENDS.get(backend))
        for lo in range(0, len(cfgs), shard_size):
            hi = min(lo + shard_size, len(cfgs))
            fut = pool.submit(_shard_worker, (
                wl_spec, components, eng, cfgs[lo:hi], machine,
                fast_slow_ratio, cell_seeds[ci][lo:hi], sampler,
                record_heatmap, heat_bins, fast_capacity_pages, backend,
                crn, lo, exact_select))
            futures.append((ci, fut))
    out: List[List[SimResult]] = [[] for _ in range(n_cells)]
    for ci, fut in futures:  # shards were submitted in config order per cell
        out[ci].extend(fut.result())
    return out


def run_simulation_batch(workload: Workload, engine_name: str,
                         configs: Sequence[Mapping[str, Any]],
                         machine: Machine | str = PMEM_LARGE,
                         fast_slow_ratio: float = 8.0,
                         seeds=0,
                         sampler: str = "sparse",
                         record_heatmap: bool = False,
                         heat_bins: int = 128,
                         fast_capacity_pages: Optional[int] = None,
                         backend: str = "numpy",
                         crn: bool = False,
                         workers: int = 1,
                         exact_select: bool = True) -> List[SimResult]:
    """Simulate ``workload`` under B candidate configs in one pass.

    The workload trace is generated once and shared; engine state carries a
    leading batch axis.  With the default ``backend="numpy"``, per-config
    RNG streams are seeded from ``seeds`` (an int, applied to every config —
    matching how sequential tuning reuses one scenario seed — or a
    per-config sequence), so results are numerically identical to B
    sequential :func:`run_simulation` calls with matched ``seed`` and
    ``sampler`` — the numpy path is the bit-exact reference.
    ``backend="jax"`` compiles the whole epoch loop (engines + samplers +
    cost model) into one jitted ``lax.scan`` with counter-based monitoring
    draws — equal in distribution, not stream-compatible; see
    :mod:`repro.core.engine_jax`.  Its migration-plan selection is exact
    by default (bit-identical index sets to the reference's stable sorts;
    ``exact_select=False`` restores the log-quantized ablation path).
    ``crn=True`` (jax only) shares the monitoring noise bitwise across
    all B configs (common random numbers) so within-batch comparisons see
    identical noise.

    ``sampler="sparse"`` (default) draws the exact Poisson sampling
    distribution at cost ∝ events; ``"elementwise"`` reproduces the
    historical per-page draws bit-for-bit.  ``workers > 1`` (or ``"auto"``)
    shards the batch over a persistent process pool; sharding never changes
    results, only wall time.
    """
    configs = list(configs)
    B = len(configs)
    if B == 0:
        return []
    if np.ndim(seeds) == 0:
        seeds = [int(seeds)] * B
    seeds = [int(s) for s in seeds]
    if len(seeds) != B:
        raise ValueError("seeds must be an int or one seed per config")
    return run_simulation_cells(
        [(workload, engine_name, configs)], machine, fast_slow_ratio,
        [seeds], sampler, record_heatmap, heat_bins, fast_capacity_pages,
        backend, crn, workers, exact_select)[0]


def run_simulation_segment(workload: Workload, engine_name: str,
                           configs: Sequence[Mapping[str, Any]],
                           machine: Machine | str = PMEM_LARGE,
                           fast_slow_ratio: float = 8.0,
                           seeds=0,
                           sampler: str = "sparse",
                           fast_capacity_pages: Optional[int] = None,
                           backend: str = "numpy",
                           crn: bool = False,
                           batch_offset: int = 0,
                           exact_select: bool = True,
                           epoch_start: int = 0,
                           epoch_stop: Optional[int] = None,
                           carry: Any = None,
                           return_carry: bool = False
                           ) -> Dict[str, Any]:
    """Partial-epoch evaluation — the tune service's checkpoint/restore hook.

    Evaluates epochs ``[epoch_start, epoch_stop)`` of the workload (defaults
    to the full range) and returns ``{"wall_ms": (seg, B) float64 array,
    "carry": <scan-carry pytree or None>}``.  Per-epoch walls are bitwise
    identical to the corresponding rows of a full :func:`run_simulation_batch`
    pass — segmentation is invisible to the numerics.

    ``backend="jax"`` (compiled-path combinations) supports true mid-run
    checkpointing: pass ``return_carry=True`` to get the scan carry back
    (numpy-ified, picklable) and feed it to the next segment via ``carry`` +
    ``epoch_start``.  The numpy reference path has sequential RNG state that
    cannot be checkpointed, so it only supports prefixes
    (``epoch_start=0``): a partial-budget re-evaluation re-runs from epoch 0
    to ``epoch_stop`` — exact (the prefix of a full run is bit-identical),
    just without the resume shortcut.
    """
    configs = [dict(c) for c in configs]
    B = len(configs)
    machine = _as_machine(machine)
    if np.ndim(seeds) == 0:
        seeds = [int(seeds)] * B
    seeds = [int(s) for s in seeds]
    if len(seeds) != B:
        raise ValueError("seeds must be an int or one seed per config")
    if crn:
        seeds = [seeds[0]] * len(seeds)
    use_jax = backend == "jax" and engine_jax.supports(
        engine_name, sampler, workload.n_pages)
    if backend == "jax" and not use_jax:
        _warn_jax_fallback(engine_name, sampler, workload.n_pages)
    if use_jax:
        fast_cap = _fast_capacity(workload, fast_slow_ratio,
                                  fast_capacity_pages)
        sim_cfgs = [scale_config(engine_name, c, workload.scale)
                    for c in configs]
        const = _epoch_consts(workload, engine_name, machine, PAGE_BYTES)
        out = engine_jax.run_epochs(
            workload, engine_name, sim_cfgs, const, fast_cap, PAGE_BYTES,
            seeds, sampler, crn=crn, batch_offset=batch_offset,
            exact_select=exact_select, epoch_start=epoch_start,
            epoch_stop=epoch_stop, carry=carry, return_carry=return_carry)
        # the materialized segment trace rides along (compiled path only):
        # the online tuner's sampled-histogram drift detector consumes it
        # without regenerating the procedural workload epochs
        return {"wall_ms": np.asarray(out["wall_ms"], dtype=np.float64),
                "carry": out.get("carry"),
                "trace_reads": out.get("trace_reads"),
                "trace_writes": out.get("trace_writes")}
    if crn:
        raise ValueError(
            "crn=True requires the compiled jax path; see run_simulation_batch")
    if epoch_start != 0 or carry is not None or return_carry:
        raise ValueError(
            "the numpy epoch loop has sequential RNG state and cannot be "
            "checkpointed mid-run: only prefix segments (epoch_start=0, no "
            "carry) are supported; use backend='jax' for resumable trials")
    results = _run_batch_local(
        workload, engine_name, configs, machine, fast_slow_ratio, seeds,
        sampler, False, 128, fast_capacity_pages, backend,
        batch_offset=batch_offset, exact_select=exact_select,
        epoch_stop=epoch_stop)
    wall = np.stack([np.asarray(r.epoch_wall_ms, dtype=np.float64)
                     for r in results], axis=1)
    return {"wall_ms": wall, "carry": None}


def run_simulation(workload: Workload, engine_name: str,
                   config: Optional[Mapping[str, Any]] = None,
                   machine: Machine | str = PMEM_LARGE,
                   fast_slow_ratio: float = 8.0,
                   seed: int = 0,
                   record_heatmap: bool = False,
                   heat_bins: int = 128,
                   fast_capacity_pages: Optional[int] = None,
                   sampler: str = "elementwise") -> SimResult:
    """Deprecated ``B=1`` wrapper over :func:`run_simulation_batch`.

    Use :class:`repro.core.study.Study` (``Study(spec).run()``) instead.
    ``fast_slow_ratio`` r sets fast-tier capacity = RSS/(1+r) (the
    paper's "1:r memory size ratio"; default 1:8, §4.1).
    """
    warn_deprecated("repro.core.simulator.run_simulation",
                    "Study(ExperimentSpec(...)).run()")
    machine = _as_machine(machine)
    if config is None:
        config = get_space(engine_name).default_config() \
            if engine_name in ("hemem", "hmsdk", "memtis") else {}
    return _run_batch_local(workload, engine_name, [config], machine,
                            fast_slow_ratio, [seed], sampler, record_heatmap,
                            heat_bins, fast_capacity_pages, "numpy")[0]


# ---------------------------------------------------------------------------
# f(θ) for the tuner — deprecated shims over the typed Study API.
# ---------------------------------------------------------------------------
def _legacy_study(engine_name: str, workload_name: str, input_name: str,
                  machine: "Machine | str", threads: Optional[int],
                  scale: float, fast_slow_ratio: float, seed: int,
                  sampler: str, workers="auto-off", backend: str = "numpy"):
    """Build the Study equivalent of the historical loose-kwargs call."""
    from .specs import EngineSpec, ExperimentSpec, SimOptions, WorkloadSpec
    from .study import Study
    machine = _as_machine(machine)
    spec = ExperimentSpec(
        engine=EngineSpec(engine_name),
        workload=WorkloadSpec(workload_name, input_name, threads=threads,
                              scale=scale),
        machine=machine.name, fast_slow_ratio=fast_slow_ratio,
        options=SimOptions(seed=seed, sampler=sampler,
                           workers=1 if workers == "auto-off" else workers,
                           backend=backend))
    # pass the resolved Machine through: an ad-hoc instance whose name
    # collides with a registered profile must win, as it did pre-shim
    return Study(spec, machine=machine)


def evaluate(engine_name: str, config: Mapping[str, Any], workload_name: str,
             input_name: str = "", machine: Machine | str = PMEM_LARGE,
             threads: Optional[int] = None, scale: float = 0.25,
             fast_slow_ratio: float = 8.0, seed: int = 0,
             sampler: str = "elementwise") -> float:
    """Execution time (seconds) of one workload run — the objective of §3.

    Deprecated: use ``Study(ExperimentSpec(...)).run().total_s``.
    """
    warn_deprecated("repro.core.simulator.evaluate",
                    "Study(ExperimentSpec(...)).run().total_s")
    study = _legacy_study(engine_name, workload_name, input_name, machine,
                          threads, scale, fast_slow_ratio, seed, sampler)
    if config is None:
        return study.run().total_s
    return study.run(configs=[config])[0].total_s


def evaluate_batch(engine_name: str, configs: Sequence[Mapping[str, Any]],
                   workload_name: str, input_name: str = "",
                   machine: Machine | str = PMEM_LARGE,
                   threads: Optional[int] = None, scale: float = 0.25,
                   fast_slow_ratio: float = 8.0, seed: int = 0,
                   sampler: str = "sparse", workers: int = 1,
                   backend: str = "numpy") -> List[float]:
    """Batched objective: execution times of all B candidate configs.

    Deprecated: use ``Study(ExperimentSpec(...)).run(configs=...)``.
    """
    warn_deprecated("repro.core.simulator.evaluate_batch",
                    "Study(ExperimentSpec(...)).run(configs=...)")
    study = _legacy_study(engine_name, workload_name, input_name, machine,
                          threads, scale, fast_slow_ratio, seed, sampler,
                          workers=workers, backend=backend)
    return [r.total_s for r in study.run(configs=configs)]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A fully-specified tuning target: workload × input × machine × setting.

    Deprecated: :class:`repro.core.specs.ExperimentSpec` composes the same
    information as typed sub-specs (plus a :class:`~repro.core.specs.
    SimOptions` for evaluation-mode options) and round-trips through JSON.
    """
    workload: str
    input_name: str = ""
    machine: str = "pmem-large"
    threads: Optional[int] = None
    scale: float = 0.25
    fast_slow_ratio: float = 8.0
    seed: int = 0

    def __post_init__(self):
        warn_deprecated("repro.core.simulator.Scenario",
                        "repro.core.specs.ExperimentSpec", stacklevel=4)

    def _study(self, engine_name: str, sampler: str = "elementwise",
               workers: int = 1, backend: str = "numpy"):
        return _legacy_study(engine_name, self.workload, self.input_name,
                             self.machine, self.threads, self.scale,
                             self.fast_slow_ratio, self.seed, sampler,
                             workers=workers, backend=backend)

    def objective(self, engine_name: str):
        study = self._study(engine_name)

        def f(config: Mapping[str, Any]) -> float:
            return study.run(configs=[config])[0].total_s
        return f

    def objective_batch(self, engine_name: str, sampler: str = "sparse",
                        workers: int = 1, backend: str = "numpy"):
        study = self._study(engine_name, sampler=sampler, workers=workers,
                            backend=backend)

        def f(configs: Sequence[Mapping[str, Any]]) -> List[float]:
            return [r.total_s for r in study.run(configs=configs)]
        return f

    @property
    def key(self) -> str:
        inp = f":{self.input_name}" if self.input_name else ""
        return f"{self.workload}{inp}@{self.machine}"
