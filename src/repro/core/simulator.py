"""Epoch-based tiered-memory simulator: the black-box f(θ) the optimizer tunes.

The simulator executes a :class:`~repro.core.workloads.Workload` against a
:class:`~repro.core.engine.TieringEngine` on a :class:`Machine` and returns the
workload's execution time.  It models, per epoch of fixed application work:

* **access cost** — bandwidth-bound and latency-bound components per tier,
  using the Table-3 machine characteristics (asymmetric NVM read/write
  bandwidth, per-tier load latencies, thread-level memory parallelism);
* **migration cost** — migrated bytes consume bandwidth on *both* tiers
  (promotions read from the far tier, demotions write to it), competing with
  application traffic; writes to in-flight pages stall on the write-protect
  barrier (HeMem §3.2);
* **monitoring cost** — PEBS-style sampling interrupts charge CPU time per
  sample (the paper's deployment fix #1 reduced, but did not eliminate, this);
  DAMON's page-table scans are far cheaper per probe;
* **engine cost** — extra kernel time some engines burn (Memtis page
  allocation/splitting, §4.6).

Scaling: ``workload.scale`` shrinks the page count and access volume while
*time semantics stay real*: effective bandwidth and memory-level parallelism
shrink by the same factor, so per-page access rates, thresholds, periods and
wall-clock times all match the full-size system.  Knobs with page-count
semantics (``cooling_pages``, ring sizes, ``nr_regions``) are scaled when the
engine is instantiated; see :func:`scale_config`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Mapping, Optional

import numpy as np

from .engine import TieringEngine, make_engine
from .knobs import get_space
from .pages import PAGE_BYTES, TierState
from .workloads import Workload, make_workload

CACHELINE = 64


# ---------------------------------------------------------------------------
# Machines — paper Table 3, plus a TPU-v5e host-offload profile for the
# beyond-paper serving substrate.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Machine:
    name: str
    cores: int
    near_bw_gbs: float          # fast-tier bandwidth (GB/s)
    far_bw_read_gbs: float      # slow-tier read bandwidth (GB/s)
    far_bw_write_gbs: float     # slow-tier write bandwidth (GB/s)
    near_lat_ns: float
    far_lat_ns: float
    sample_us: float            # CPU time per PEBS sample (post-fix #1)
    scan_us: float              # CPU time per DAMON page-table probe
    default_threads: int

    @property
    def far_symmetric(self) -> bool:
        return abs(self.far_bw_read_gbs - self.far_bw_write_gbs) < 1e-9


PMEM_LARGE = Machine("pmem-large", cores=24, near_bw_gbs=138.0,
                     far_bw_read_gbs=7.45, far_bw_write_gbs=2.25,
                     near_lat_ns=80.0, far_lat_ns=200.0,
                     sample_us=0.8, scan_us=0.05, default_threads=12)
PMEM_SMALL = Machine("pmem-small", cores=16, near_bw_gbs=46.0,
                     far_bw_read_gbs=6.8, far_bw_write_gbs=1.85,
                     near_lat_ns=80.0, far_lat_ns=200.0,
                     sample_us=0.8, scan_us=0.05, default_threads=4)
NUMA = Machine("numa", cores=20, near_bw_gbs=56.0,
               far_bw_read_gbs=36.0, far_bw_write_gbs=36.0,
               near_lat_ns=95.0, far_lat_ns=145.0,
               sample_us=0.8, scan_us=0.05, default_threads=12)
#: TPU v5e chip with host-DRAM offload over PCIe: the two-tier system the
#: production TieredKVCache manages.  "Threads" = the single decode stream;
#: MLP comes from DMA queue depth.
TPU_V5E_HOST = Machine("tpu-v5e-host", cores=1, near_bw_gbs=819.0,
                       far_bw_read_gbs=16.0, far_bw_write_gbs=16.0,
                       near_lat_ns=600.0, far_lat_ns=2500.0,
                       sample_us=0.05, scan_us=0.05, default_threads=1)

MACHINES: Dict[str, Machine] = {m.name: m for m in
                                (PMEM_LARGE, PMEM_SMALL, NUMA, TPU_V5E_HOST)}


def get_machine(name: str) -> Machine:
    try:
        return MACHINES[name]
    except KeyError:
        raise KeyError(f"unknown machine {name!r}; have {sorted(MACHINES)}")


# ---------------------------------------------------------------------------
# Config scaling (page-count-semantics knobs only; see module docstring).
# ---------------------------------------------------------------------------
_PAGE_SEMANTIC_KNOBS = {
    "hemem": ("cooling_pages", "hot_ring_reqs_threshold",
              "cold_ring_reqs_threshold"),
    "hmsdk": ("nr_regions",),
    "memtis": (),
    "static": (),
    "oracle": (),
}


def scale_config(engine_name: str, config: Mapping[str, Any],
                 scale: float) -> Dict[str, Any]:
    out = dict(config)
    for k in _PAGE_SEMANTIC_KNOBS.get(engine_name, ()):
        if k in out:
            out[k] = max(1, int(round(out[k] * scale)))
    return out


# ---------------------------------------------------------------------------
# Simulation result
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SimResult:
    workload: str
    engine: str
    machine: str
    config: Dict[str, Any]
    total_s: float
    epoch_wall_ms: np.ndarray       # per-epoch wall time
    cum_migrations: np.ndarray      # cumulative migrated pages over epochs
    fast_hit_rate: np.ndarray       # fraction of accesses served by fast tier
    sampling_ms: np.ndarray
    stall_ms: np.ndarray
    heatmap: Optional[np.ndarray] = None   # (epochs, heat_bins) access heat
    placement: Optional[np.ndarray] = None  # (epochs, heat_bins) frac in fast

    @property
    def total_migrations(self) -> int:
        return int(self.cum_migrations[-1]) if len(self.cum_migrations) else 0


# ---------------------------------------------------------------------------
# Core loop
# ---------------------------------------------------------------------------
def run_simulation(workload: Workload, engine_name: str,
                   config: Optional[Mapping[str, Any]] = None,
                   machine: Machine | str = PMEM_LARGE,
                   fast_slow_ratio: float = 8.0,
                   seed: int = 0,
                   record_heatmap: bool = False,
                   heat_bins: int = 128,
                   fast_capacity_pages: Optional[int] = None) -> SimResult:
    """Simulate ``workload`` under ``engine_name``/``config`` on ``machine``.

    ``fast_slow_ratio`` r sets fast-tier capacity = RSS/(1+r) (the paper's
    "1:r memory size ratio"; default 1:8, §4.1).
    """
    if isinstance(machine, str):
        machine = get_machine(machine)
    if config is None:
        config = get_space(engine_name).default_config() \
            if engine_name in ("hemem", "hmsdk", "memtis") else {}

    n = workload.n_pages
    scale = workload.scale
    if fast_capacity_pages is None:
        fast_capacity_pages = max(1, int(round(n / (1.0 + fast_slow_ratio))))
    tier = TierState(n, fast_capacity_pages)
    sim_cfg = scale_config(engine_name, config, scale)
    engine = make_engine(engine_name, sim_cfg, tier, seed=seed)

    threads = workload.threads
    # effective parallel resources shrink with scale (time stays real)
    eff_bw = scale
    eff_par = threads * workload.mlp * scale
    near_bw = machine.near_bw_gbs * 1e9 * eff_bw
    far_bw_r = machine.far_bw_read_gbs * 1e9 * eff_bw
    far_bw_w = machine.far_bw_write_gbs * 1e9 * eff_bw
    near_lat_s = machine.near_lat_ns * 1e-9
    far_lat_s = machine.far_lat_ns * 1e-9
    page_bytes = tier.page_bytes

    n_epochs = workload.n_epochs
    wall = np.zeros(n_epochs)
    cum_mig = np.zeros(n_epochs)
    hit_rate = np.zeros(n_epochs)
    sampling_ms_a = np.zeros(n_epochs)
    stall_ms_a = np.zeros(n_epochs)
    heat = np.zeros((n_epochs, heat_bins)) if record_heatmap else None
    place = np.zeros((n_epochs, heat_bins)) if record_heatmap else None
    bin_of = (np.arange(n) * heat_bins // n) if record_heatmap else None

    # probe-cost knob: engines that sample pay per-sample CPU; DAMON pays per
    # scan probe (engine reports its probes via samples_last_epoch).
    probe_us = machine.scan_us if engine_name == "hmsdk" else machine.sample_us

    est_wall_ms = workload.epoch_ms  # running estimate fed to the engine
    total_mig = 0
    for e in range(n_epochs):
        reads, writes = workload.epoch_access(e)
        touched = (reads + writes) > (1.0 / max(n, 1))
        tier.allocate_first_touch(touched)

        engine.observe(reads, writes, est_wall_ms)
        plan = engine.plan(est_wall_ms, max_pages_this_epoch=_rate_cap(
            engine, est_wall_ms, page_bytes, scale))
        mig_pages = plan.n_pages
        promote_idx, demote_idx = plan.promote, plan.demote
        tier.apply(plan)
        total_mig += mig_pages
        cum_mig[e] = total_mig

        in_fast = tier.in_fast
        acc = reads + writes
        acc_f = float(acc[in_fast].sum())
        acc_s = float(acc.sum() - acc_f)
        reads_s = float(reads[~in_fast].sum())
        writes_s = float(writes[~in_fast].sum())
        bytes_f = acc_f * CACHELINE
        promote_bytes = len(promote_idx) * page_bytes
        demote_bytes = len(demote_idx) * page_bytes
        mig_cost_free = engine.zero_cost_migrations
        if mig_cost_free:
            promote_bytes = demote_bytes = 0.0

        # bandwidth-bound terms (migration traffic shares the devices)
        t_near = (bytes_f + promote_bytes + demote_bytes) / near_bw
        t_far = ((reads_s * CACHELINE + promote_bytes) / far_bw_r
                 + (writes_s * CACHELINE + demote_bytes) / far_bw_w)
        # latency-bound term
        t_lat = (acc_f * near_lat_s + acc_s * far_lat_s) / eff_par
        t_mem = max(t_near, t_far, t_lat)

        # write-protect stalls: HeMem write-protects in-flight pages, so only
        # the writes that land *during* a page's copy window stall, each for
        # half the copy time on average.  Expected stalled writes per page =
        # page_write_rate x copy_duration; a stalled thread cannot overlap, so
        # the app-level cost divides by thread count (scale-adjusted).
        if mig_pages and not mig_cost_free:
            w_mig = float(writes[promote_idx].sum() + writes[demote_idx].sum())
            page_copy_s = page_bytes / max(min(far_bw_r, near_bw), 1.0)
            epoch_s_est = max(est_wall_ms * 1e-3, page_copy_s)
            frac_in_flight = min(page_copy_s / epoch_s_est, 1.0)
            stall_s = (w_mig * frac_in_flight * (page_copy_s / 2.0)
                       / max(threads * scale, 1e-9))
        else:
            stall_s = 0.0

        sampling_s = engine.samples_last_epoch * probe_us * 1e-6 / max(threads, 1)
        engine_s = engine.overhead_ms_last_epoch * 1e-3

        wall_ms = (max(workload.compute_ms, t_mem * 1e3)
                   + stall_s * 1e3 + sampling_s * 1e3 + engine_s * 1e3)
        wall[e] = wall_ms
        est_wall_ms = wall_ms
        hit_rate[e] = acc_f / max(acc_f + acc_s, 1e-12)
        sampling_ms_a[e] = sampling_s * 1e3
        stall_ms_a[e] = stall_s * 1e3

        if record_heatmap:
            heat[e] = np.bincount(bin_of, weights=acc, minlength=heat_bins)
            place[e] = (np.bincount(bin_of, weights=in_fast.astype(np.float64),
                                    minlength=heat_bins)
                        / np.maximum(np.bincount(bin_of, minlength=heat_bins), 1))

    return SimResult(
        workload=workload.key, engine=engine_name, machine=machine.name,
        config=dict(config), total_s=float(wall.sum() / 1e3),
        epoch_wall_ms=wall, cum_migrations=cum_mig, fast_hit_rate=hit_rate,
        sampling_ms=sampling_ms_a, stall_ms=stall_ms_a,
        heatmap=heat, placement=place)


def _rate_cap(engine: TieringEngine, epoch_ms: float, page_bytes: int,
              scale: float) -> int:
    """Scaled migration-rate cap in sim pages for this epoch."""
    rate = float(engine.config.get("max_migration_rate", 1e9))
    return max(0, int(rate * (2 ** 30) * (epoch_ms / 1e3) / page_bytes * scale))


# ---------------------------------------------------------------------------
# f(θ) for the tuner
# ---------------------------------------------------------------------------
def evaluate(engine_name: str, config: Mapping[str, Any], workload_name: str,
             input_name: str = "", machine: Machine | str = PMEM_LARGE,
             threads: Optional[int] = None, scale: float = 0.25,
             fast_slow_ratio: float = 8.0, seed: int = 0) -> float:
    """Execution time (seconds) of one workload run — the objective of §3."""
    if isinstance(machine, str):
        machine = get_machine(machine)
    t = threads if threads is not None else machine.default_threads
    wl = make_workload(workload_name, input_name, threads=t, scale=scale,
                       seed=seed)
    res = run_simulation(wl, engine_name, config, machine,
                         fast_slow_ratio=fast_slow_ratio, seed=seed)
    return res.total_s


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A fully-specified tuning target: workload × input × machine × setting."""
    workload: str
    input_name: str = ""
    machine: str = "pmem-large"
    threads: Optional[int] = None
    scale: float = 0.25
    fast_slow_ratio: float = 8.0
    seed: int = 0

    def objective(self, engine_name: str):
        def f(config: Mapping[str, Any]) -> float:
            return evaluate(engine_name, config, self.workload,
                            self.input_name, self.machine, self.threads,
                            self.scale, self.fast_slow_ratio, self.seed)
        return f

    @property
    def key(self) -> str:
        inp = f":{self.input_name}" if self.input_name else ""
        return f"{self.workload}{inp}@{self.machine}"
