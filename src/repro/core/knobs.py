"""Knob (parameter) spaces for tiering engines.

Faithful to the paper:
  * Table 2 lists HeMem's 10 knobs with defaults and [min, max] ranges; those are
    reproduced verbatim in :data:`HEMEM_SPACE`.
  * Section 4.5 tunes HMSDK (DAMON-based); the DAMON monitoring knobs
    (``nr_regions``, sampling/aggregation intervals) plus HMSDK's migration knobs
    form :data:`HMSDK_SPACE`.

A :class:`KnobSpace` is the interface between the tiering engines and the
Bayesian optimizer: it knows how to sample random configurations, encode a
configuration as a numeric feature vector for the random-forest surrogate, and
generate local neighbours for SMAC-style local search.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

import numpy as np

Config = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable parameter of a tiering engine.

    ``log`` marks knobs whose useful range spans orders of magnitude
    (e.g. ``migration_period`` in [10, 5000] ms); those are sampled and
    encoded in log-space so the optimizer explores the low end properly.
    """

    name: str
    default: float
    lo: float
    hi: float
    is_int: bool = True
    log: bool = False
    description: str = ""

    def clip(self, value: float) -> float:
        v = min(max(float(value), self.lo), self.hi)
        if self.is_int:
            v = float(int(round(v)))
        return v

    # --- unit-interval transforms (for surrogate encoding) ---------------
    def to_unit(self, value: float) -> float:
        if self.log:
            lo, hi = math.log(self.lo), math.log(self.hi)
            return (math.log(max(value, self.lo)) - lo) / (hi - lo)
        return (value - self.lo) / (self.hi - self.lo)

    def from_unit(self, u: float) -> float:
        u = min(max(u, 0.0), 1.0)
        if self.log:
            lo, hi = math.log(self.lo), math.log(self.hi)
            return self.clip(math.exp(lo + u * (hi - lo)))
        return self.clip(self.lo + u * (self.hi - self.lo))


class KnobSpace:
    """An ordered collection of knobs; the domain Θ = Θ₁ × … × Θₙ of §3."""

    def __init__(self, knobs: Sequence[Knob]):
        self.knobs: List[Knob] = list(knobs)
        self._by_name = {k.name: k for k in self.knobs}
        if len(self._by_name) != len(self.knobs):
            raise ValueError("duplicate knob names")
        # vectorized knob bounds for the batched encode/decode paths
        self._lo = np.array([k.lo for k in self.knobs], dtype=np.float64)
        self._hi = np.array([k.hi for k in self.knobs], dtype=np.float64)
        self._log = np.array([k.log for k in self.knobs])
        self._int = np.array([k.is_int for k in self.knobs])
        self._lo_t = self._lo.copy()
        self._hi_t = self._hi.copy()
        self._lo_t[self._log] = np.log(self._lo[self._log])
        self._hi_t[self._log] = np.log(self._hi[self._log])

    # -- basic access ------------------------------------------------------
    def __len__(self) -> int:
        return len(self.knobs)

    def __iter__(self):
        return iter(self.knobs)

    def __getitem__(self, name: str) -> Knob:
        return self._by_name[name]

    @property
    def names(self) -> List[str]:
        return [k.name for k in self.knobs]

    def default_config(self) -> Config:
        return {k.name: (int(k.default) if k.is_int else k.default) for k in self.knobs}

    def validate(self, config: Mapping[str, Any]) -> Config:
        """Clip a config into the domain; unknown keys are rejected."""
        unknown = set(config) - set(self._by_name)
        if unknown:
            raise KeyError(f"unknown knobs: {sorted(unknown)}")
        out = self.default_config()
        for name, value in config.items():
            k = self._by_name[name]
            v = k.clip(value)
            out[name] = int(v) if k.is_int else v
        return out

    # -- sampling / encoding -----------------------------------------------
    def sample(self, rng: np.random.Generator) -> Config:
        cfg = {}
        for k in self.knobs:
            v = k.from_unit(float(rng.uniform()))
            cfg[k.name] = int(v) if k.is_int else v
        return cfg

    def sample_batch(self, rng: np.random.Generator, n: int) -> List[Config]:
        return [self.sample(rng) for _ in range(n)]

    def encode(self, config: Mapping[str, Any]) -> np.ndarray:
        """Encode a config as a unit-interval feature vector for the surrogate."""
        return np.array(
            [k.to_unit(float(config[k.name])) for k in self.knobs], dtype=np.float64
        )

    def decode(self, x: np.ndarray) -> Config:
        cfg = {}
        for k, u in zip(self.knobs, np.asarray(x, dtype=np.float64)):
            v = k.from_unit(float(u))
            cfg[k.name] = int(v) if k.is_int else v
        return cfg

    # -- batched encoding (vectorized over configs) -------------------------
    def encode_batch(self, configs: Sequence[Mapping[str, Any]]) -> np.ndarray:
        """Encode N configs as an ``(N, len(self))`` unit-interval matrix."""
        V = np.array([[float(c[k.name]) for k in self.knobs]
                      for c in configs], dtype=np.float64)
        if V.size == 0:
            return V.reshape(len(configs), len(self.knobs))
        Vt = V.copy()
        Vt[:, self._log] = np.log(np.maximum(V[:, self._log],
                                             self._lo[self._log]))
        return (Vt - self._lo_t) / (self._hi_t - self._lo_t)

    def decode_batch(self, X: np.ndarray) -> List[Config]:
        """Decode an ``(N, len(self))`` unit matrix back into configs."""
        X = np.clip(np.asarray(X, dtype=np.float64), 0.0, 1.0)
        Vt = self._lo_t + X * (self._hi_t - self._lo_t)
        V = Vt.copy()
        V[:, self._log] = np.exp(Vt[:, self._log])
        V = np.clip(V, self._lo, self._hi)
        V = np.where(self._int, np.round(V), V)
        out: List[Config] = []
        for row in V:
            out.append({k.name: (int(v) if k.is_int else float(v))
                        for k, v in zip(self.knobs, row)})
        return out

    def validate_batch(self,
                       configs: Sequence[Mapping[str, Any]]) -> List[Config]:
        """Clip N configs into the domain; unknown keys are rejected."""
        return [self.validate(c) for c in configs]

    # -- array-native candidate generation (the BO hot path) -----------------
    def quantize_unit(self, X: np.ndarray) -> np.ndarray:
        """Snap unit-cube rows onto the knob grid: ``encode(decode(X))``
        without the per-config dict round-trip.

        Canonical rows are fixpoints, so two rows are equal iff they decode
        to the same config — which is what lets the batched optimizer dedup
        candidates in encoded space before scoring.
        """
        X = np.clip(np.asarray(X, dtype=np.float64), 0.0, 1.0)
        Vt = self._lo_t + X * (self._hi_t - self._lo_t)
        V = Vt.copy()
        V[..., self._log] = np.exp(Vt[..., self._log])
        V = np.clip(V, self._lo, self._hi)
        V = np.where(self._int, np.round(V), V)
        Ut = V.copy()
        Ut[..., self._log] = np.log(np.maximum(V[..., self._log],
                                               self._lo[self._log]))
        return (Ut - self._lo_t) / (self._hi_t - self._lo_t)

    def sample_batch_encoded(self, rng: np.random.Generator,
                             n: int) -> np.ndarray:
        """``n`` uniform random configs as canonical unit rows ``(n, d)`` —
        the encoded counterpart of :meth:`sample_batch`, decoded to dicts
        only for the suggestions the optimizer actually returns."""
        return self.quantize_unit(rng.uniform(size=(n, len(self))))

    def neighbors_batch(self, x: np.ndarray, rng: np.random.Generator,
                        n: int = 8, scale: float = 0.15) -> np.ndarray:
        """``n`` Gaussian local-search neighbours of encoded point ``x`` as
        canonical unit rows ``(n, d)`` (SMAC local search, batched)."""
        d = len(self)
        x = np.asarray(x, dtype=np.float64)
        U = rng.uniform(size=(n, d))
        mask = U < max(1.0 / d, 0.3)
        fix = rng.integers(d, size=n)
        empty = ~mask.any(axis=1)
        mask[empty, fix[empty]] = True
        Z = rng.normal(0.0, scale, size=(n, d))
        return self.quantize_unit(np.clip(x[None, :] + mask * Z, 0.0, 1.0))

    def neighbors(
        self, config: Mapping[str, Any], rng: np.random.Generator, n: int = 8,
        scale: float = 0.15,
    ) -> List[Config]:
        """Gaussian perturbations in unit space around ``config`` (SMAC local search)."""
        x = self.encode(config)
        out = []
        for _ in range(n):
            mask = rng.uniform(size=len(x)) < max(1.0 / len(x), 0.3)
            if not mask.any():
                mask[rng.integers(len(x))] = True
            xp = x + mask * rng.normal(0.0, scale, size=len(x))
            out.append(self.decode(np.clip(xp, 0.0, 1.0)))
        return out


# ---------------------------------------------------------------------------
# HeMem knob space — paper Table 2, verbatim.
# ---------------------------------------------------------------------------
HEMEM_SPACE = KnobSpace([
    Knob("sampling_period", 5000, 100, 10000, is_int=True, log=True,
         description="Number of memory load events to trigger sampling"),
    Knob("write_sampling_period", 10000, 1000, 20000, is_int=True, log=True,
         description="Number of store instructions to trigger sampling"),
    Knob("read_hot_threshold", 8, 1, 30, is_int=True,
         description="Minimum number of read access samples per page to classify it hot"),
    Knob("write_hot_threshold", 4, 1, 30, is_int=True,
         description="Minimum number of write samples per page to classify it hot"),
    Knob("cooling_threshold", 18, 4, 40, is_int=True,
         description="Number of sampled accesses to trigger page access count cooling"),
    Knob("migration_period", 10, 10, 5000, is_int=True, log=True,
         description="Interval of migration thread executions (ms)"),
    Knob("max_migration_rate", 10, 2, 20, is_int=True,
         description="Maximum migration rate allowed (GiB/s)"),
    Knob("cooling_pages", 8192, 1024, 65536, is_int=True, log=True,
         description="Number of pages cooled at a time"),
    Knob("hot_ring_reqs_threshold", 1024, 128, 4096, is_int=True, log=True,
         description="Number of hot pages processed at a time"),
    Knob("cold_ring_reqs_threshold", 32, 8, 256, is_int=True, log=True,
         description="Number of cold pages processed at a time"),
])


# ---------------------------------------------------------------------------
# HMSDK / DAMON knob space — §4.5. DAMON monitors via region sampling; HMSDK
# adds migration control. Ranges follow DAMON's documented limits.
# ---------------------------------------------------------------------------
HMSDK_SPACE = KnobSpace([
    Knob("nr_regions", 100, 10, 1000, is_int=True, log=True,
         description="Number of DAMON monitoring regions"),
    Knob("sample_us", 5000, 100, 100000, is_int=True, log=True,
         description="DAMON sampling interval (us); one page probed per region per sample"),
    Knob("aggr_us", 100000, 10000, 1000000, is_int=True, log=True,
         description="DAMON aggregation interval (us)"),
    Knob("hot_access_pct", 50, 5, 100, is_int=True,
         description="Region access rate (% of samples) to classify a region hot"),
    Knob("cold_aggr_intervals", 5, 1, 50, is_int=True,
         description="Aggregation intervals with zero accesses before a region is cold"),
    Knob("migration_period", 100, 10, 5000, is_int=True, log=True,
         description="Interval of HMSDK migration executions (ms)"),
    # HMSDK's DAMOS migration quota defaults are conservative
    Knob("max_migration_rate", 2, 1, 20, is_int=True,
         description="Maximum migration rate allowed (GiB/s, DAMOS quota)"),
])


# ---------------------------------------------------------------------------
# Memtis — §4.6. Memtis *dynamically* adapts the hot threshold; its remaining
# parameters are static in the original system. We expose them as a knob space
# too so the "tune Memtis as well" ablation is expressible, but the faithful
# MemtisEngine uses the defaults below (including the 100k write sampling
# period the paper calls out as a write-blindness cause).
# ---------------------------------------------------------------------------
MEMTIS_SPACE = KnobSpace([
    Knob("sampling_period", 4001, 100, 10000, is_int=True, log=True,
         description="PEBS sampling period for loads"),
    Knob("write_sampling_period", 100003, 1000, 200000, is_int=True, log=True,
         description="PEBS sampling period for stores (static 100k in Memtis)"),
    Knob("cooling_period_ms", 2000, 100, 10000, is_int=True, log=True,
         description="Static cooling period (ms)"),
    Knob("adaptation_period_ms", 1000, 100, 10000, is_int=True, log=True,
         description="Hot-threshold adaptation period (ms)"),
    Knob("migration_period", 100, 10, 5000, is_int=True, log=True,
         description="Interval of migration thread executions (ms)"),
    Knob("max_migration_rate", 10, 2, 20, is_int=True,
         description="Maximum migration rate allowed (GiB/s)"),
    Knob("warm_pct", 10, 0, 50, is_int=True,
         description="Percent of pages just below hot kept as 'warm' (not migrated)"),
])


SPACES: Dict[str, KnobSpace] = {
    "hemem": HEMEM_SPACE,
    "hmsdk": HMSDK_SPACE,
    "memtis": MEMTIS_SPACE,
}


def get_space(engine: str) -> KnobSpace:
    try:
        return SPACES[engine]
    except KeyError:
        raise KeyError(f"no knob space for engine {engine!r}; have {sorted(SPACES)}")
