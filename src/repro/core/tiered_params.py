"""TieredParamStore — MoE expert offload driven by the tiering engine.

kimi-k2's 384-expert layers hold ~1 T parameters; at bf16 that exceeds a
256-chip v5e pod's HBM once optimizer state is counted, so cold experts live
in host DRAM and hot experts in HBM.  The access signal is the router: every
batch's expert-selection counts are the "reads" (there are no writes during
serving; during training the gradient updates are the writes).

Mechanism reuse is verbatim HeMem: thresholds decide which experts are hot,
cooling ages the counts, and the migration thread swaps expert blocks at a
bounded rate.  Tokens routed to host-resident experts take the slow path
(host roundtrip) — the latency penalty the tuner minimizes.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import HeMemEngine
from repro.core.knobs import HEMEM_SPACE
from repro.core.pages import TierState


class TieredParamStore:
    def __init__(self, expert_weights: Dict[str, np.ndarray],
                 hbm_experts: int,
                 config: Optional[Mapping[str, Any]] = None, seed: int = 0):
        """expert_weights: dict of (E, ...) arrays sharing leading dim E."""
        first = next(iter(expert_weights.values()))
        self.n_experts = first.shape[0]
        self.hbm_experts = int(hbm_experts)
        self.host = {k: np.asarray(v, np.float32)
                     for k, v in expert_weights.items()}
        bytes_per_expert = sum(v[0].nbytes for v in self.host.values())

        self.slot_of = np.full(self.n_experts, -1, np.int64)
        self.expert_of_slot = np.full(self.hbm_experts, -1, np.int64)
        self.hbm: Dict[str, jnp.ndarray] = {
            k: jnp.zeros((self.hbm_experts,) + v.shape[1:], jnp.bfloat16)
            for k, v in self.host.items()}

        cfg = HEMEM_SPACE.validate(dict(config or {}))
        self.tier = TierState(self.n_experts, self.hbm_experts,
                              page_bytes=max(bytes_per_expert, 1))
        self.tier.allocated[:] = True
        self.engine = HeMemEngine(cfg, self.tier, seed=seed)
        self._counts = np.zeros(self.n_experts)
        self.migrations = 0
        self.slow_hits = 0
        self.fast_hits = 0

        # first-touch: most-frequently-initialized experts... start 0..cap
        for e in range(min(self.hbm_experts, self.n_experts)):
            self._promote(e)

    # -- access accounting -----------------------------------------------------
    def route(self, expert_ids: np.ndarray):
        """Record a batch's routing decisions; returns per-expert residency
        mask for the batch's experts."""
        ids, cnt = np.unique(np.asarray(expert_ids).ravel(),
                             return_counts=True)
        self._counts[ids] += cnt
        resident = self.slot_of[ids] >= 0
        self.fast_hits += int(cnt[resident].sum())
        self.slow_hits += int(cnt[~resident].sum())
        return {int(e): bool(r) for e, r in zip(ids, resident)}

    def gather(self, name: str, expert_ids: np.ndarray) -> jnp.ndarray:
        """Fetch weights for ``expert_ids``: HBM-resident from the device
        pool, the rest via host roundtrip (the measured slow path)."""
        out = []
        for e in np.asarray(expert_ids).ravel():
            slot = self.slot_of[int(e)]
            if slot >= 0:
                out.append(self.hbm[name][int(slot)])
            else:
                out.append(jnp.asarray(self.host[name][int(e)],
                                       jnp.bfloat16))
        return jnp.stack(out)

    # -- tiering ------------------------------------------------------------------
    def step_engine(self, dt_ms: float):
        reads = self._counts.copy()
        self._counts[:] = 0.0
        self.engine.observe(reads, np.zeros_like(reads), dt_ms)
        plan = self.engine.plan(dt_ms,
                                max_pages_this_epoch=self.hbm_experts)
        for e in plan.demote:
            self._demote(int(e))
        for e in plan.promote:
            if self.tier.fast_free <= 0:
                break
            self._promote(int(e))
        self.migrations += plan.n_pages

    def _promote(self, e: int):
        if self.slot_of[e] >= 0:
            return
        free = np.flatnonzero(self.expert_of_slot < 0)
        if len(free) == 0:
            return
        slot = int(free[0])
        for k in self.hbm:
            self.hbm[k] = self.hbm[k].at[slot].set(
                jnp.asarray(self.host[k][e], jnp.bfloat16))
        self.slot_of[e] = slot
        self.expert_of_slot[slot] = e
        self.tier.in_fast[e] = True

    def _demote(self, e: int):
        slot = int(self.slot_of[e])
        if slot < 0:
            return
        self.slot_of[e] = -1
        self.expert_of_slot[slot] = -1
        self.tier.in_fast[e] = False

    def hit_rate(self) -> float:
        tot = self.fast_hits + self.slow_hits
        return self.fast_hits / max(tot, 1)
