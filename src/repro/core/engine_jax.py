"""Compiled JAX epoch loop: the ``backend="jax"`` fast path of the simulator.

This module re-implements the five batch tiering engines
(:mod:`repro.core.engine`) and the monitoring samplers as **pure functions**
over a pytree of ``(B, n_pages)`` arrays, and drives them with one
``jax.lax.scan`` over epochs — observe (fused Poisson/Bernoulli sampling),
plan (packed-key selection of migration candidates), tier update and the
access-cost model all compile into a single XLA program per (engine,
workload shape).  The numpy engines remain the **bit-exact reference**: they
reproduce the historical per-page RNG streams exactly, while this path
trades stream compatibility for compilation — the *distributions* are
identical (tested statistically) but individual draws differ.

Randomness is **counter-based**: every monitoring draw is a deterministic
hash of ``(seed, batch row, epoch, draw site, page)`` — no sequential RNG
state threads through the scan, so the compiled loop, a Python epoch loop
over the same step function, and any sharding of the batch all produce
identical draws.  Setting ``crn=True`` (common random numbers,
``SimOptions(crn=True)``) drops the ``(seed_b, batch row)`` components in
favour of the batch-shared ``seeds[0]``: all B configs of a batch then see
*bitwise-identical* monitoring noise, which sharpens SMAC's within-batch
candidate comparisons (the paired-evaluation idea of the SMAC paper) at the
cost of correlated errors across the batch.

Performance notes (what made the compiled loop beat the numpy reference):

* Poisson draws fuse into the observe step as a branchless hybrid kernel —
  exact inverse-CDF below :data:`POISSON_SWITCH` (:data:`POISSON_KMAX`
  accumulated pmf terms), and above it a transcendental-free normal
  approximation whose standard normal comes from ``popcount`` of the hash
  word plus uniform smoothing (Box–Muller's log/cos are the slowest ops in
  an XLA CPU epoch).
* Migration-candidate selection avoids dense stable argsorts (the dominant
  cost of a naive port: ~13 ms per (8, 8k) argsort on CPU).
  :func:`select_top` dispatches to the exact top-k selection kernel
  (:mod:`repro.kernels.select_topk` on TPU / under ``FORCE="pallas"``,
  its pure-jnp oracle :func:`repro.kernels.ref.select_topk_ref`
  otherwise): a radix-select over the full (priority, index) key — dual
  bitwise cutoff search on order-preserving float bits plus an index-order
  boundary fill — whose selected index sets are **bit-identical** to the
  reference's stable sorts.  ``SimOptions(exact_select=False)`` restores
  the historical 8-bit log-quantized approximation
  (:func:`select_top_quantized`: exact counts, near-exact order) for
  ablations.
* DAMON's region probes reduce to ``Binomial(K, p̄)`` drawn as K masked
  Bernoullis — exactly the distribution of the numpy Monte-Carlo probe
  loop, for both sampler spellings.
* The first-touch allocation state is a single shared ``(n,)`` vector: the
  trace is shared across the batch, so rows allocate identically.

Jitted epoch functions are cached per ``(engine, n_pages, sampler)`` (plus
the remaining static shape parameters) so repeated ``Study.tune``
iterations never retrace; a one-line warning is logged when a new shape
forces a recompilation of an already-compiled engine.
"""

from __future__ import annotations

import logging
from typing import Any, Dict, List, Mapping, Sequence, Tuple

import numpy as np

log = logging.getLogger(__name__)

# jax is imported lazily on first use: merely importing this module (which
# repro.core.simulator does unconditionally) must not pull in jax — the
# numpy path stays jax-free, which also keeps the process-pool fork path
# available for numpy-only runs.
jax = None
jnp = None
lax = None
_HAVE_JAX: "bool | None" = None


def have_jax() -> bool:
    """Import jax on first call; False if it is not installed."""
    global jax, jnp, lax, _HAVE_JAX
    if _HAVE_JAX is None:
        try:
            import jax as _jax
            import jax.numpy as _jnp
            from jax import lax as _lax
            jax, jnp, lax = _jax, _jnp, _lax
            _HAVE_JAX = True
        except ImportError:  # pragma: no cover - env without jax
            _HAVE_JAX = False
    return _HAVE_JAX

#: the BUILTIN engines this module compiles end-to-end.  The live registry
#: is ``jax_engines()`` — custom engines join it through the lifted-engine
#: protocol (:func:`register_jax_engine`); anything not registered there
#: falls back to the numpy epoch loop (with the vmapped jax cost model).
JAX_ENGINES = ("hemem", "hmsdk", "memtis", "static", "oracle", "kv-hemem")
#: builtin sampler names the fused kernels cover.  "elementwise" and
#: "sparse" are *stream* variants of the same distribution in numpy, so the
#: compiled path implements them with one kernel.
JAX_SAMPLERS = ("elementwise", "sparse")

#: rate below which the fused Poisson kernel inverts the CDF exactly;
#: at/above it the popcount-normal approximation takes over
POISSON_SWITCH = 5.0
#: pmf terms accumulated by the inverse-CDF branch (tail mass beyond this
#: at lam < POISSON_SWITCH is < 1e-4)
POISSON_KMAX = 16

#: 1/sigma of (popcount(u32) - 16 + uniform - 0.5): sqrt(8 + 1/12)
_POPCOUNT_NORM = 1.0 / 2.8431203

# draw-site identifiers folded into the counter-based hash so distinct
# sampling sites never share uniforms
# (each draw also folds site+1 for its second hash word)
_S_READ = 0x11
_S_WRITE = 0x21
_S_PROBE = 0x31
_S_JITTER = 0x41


# ---------------------------------------------------------------------------
# Counter-based uniforms (lowbias32-style avalanche; works for numpy and jax
# uint32 arrays alike, which is what makes the draws backend-independent).
# ---------------------------------------------------------------------------
_GOLDEN = np.uint32(0x9E3779B9)
_MUL1 = np.uint32(0x7FEB352D)
_MUL2 = np.uint32(0x846CA68B)


def mix32(h):
    """Finalizing 32-bit avalanche (murmur3-style)."""
    h = h ^ (h >> 16)
    h = h * _MUL1
    h = h ^ (h >> 15)
    h = h * _MUL2
    h = h ^ (h >> 16)
    return h


def fold(h, w):
    """Fold word ``w`` into hash state ``h`` (boost::hash_combine-style);
    broadcasting shapes the output counter grid."""
    return mix32(h ^ (w + _GOLDEN + (h << 6) + (h >> 2)))


def counter_hash(key, *words):
    """Deterministic uint32 hash of ``key`` and the counter ``words`` (site
    id, epoch, page index, ...), broadcast over the inputs."""
    h = key
    for w in words:
        h = fold(h, w)
    return h


def hash_uniform(h):
    """Map a hash word to a float32 uniform in (0, 1)."""
    return ((h >> 8).astype(np.float32) + np.float32(0.5)) * \
        np.float32(1.0 / (1 << 24))


def counter_uniform(key, *words):
    """Counter-based uniforms in (0, 1): ``hash_uniform(counter_hash(...))``."""
    return hash_uniform(counter_hash(key, *words))


def base_keys(seeds: Sequence[int], batch_offset: int, crn: bool) -> np.ndarray:
    """Per-row base hash keys.

    ``crn=False``: fold ``(seed_b, global batch index)`` so equal-seed rows
    still draw independent noise (counter-based streams never diverge by
    consumption the way stateful RNGs do).  ``crn=True``: every row uses
    ``(seeds[0], 0)`` — all rows share every subsequent draw bitwise.
    """
    seeds = np.asarray(seeds, dtype=np.uint32)
    if crn:
        seeds = np.full_like(seeds, seeds[0])
        rows = np.zeros_like(seeds)
    else:
        rows = (np.arange(len(seeds)) + batch_offset).astype(np.uint32)
    h0 = np.full(len(seeds), 0xC0FFEE, dtype=np.uint32)
    return np.asarray(fold(fold(h0, seeds), rows), dtype=np.uint32)


# ---------------------------------------------------------------------------
# Fused samplers
# ---------------------------------------------------------------------------
def _poisson_from_hash(lam, h1, h2):
    """Branchless Poisson(lam) from two hash words per element.

    ``lam < POISSON_SWITCH``: exact inverse-CDF on ``uniform(h1)``.
    Larger rates: normal approximation ``floor(lam + sqrt(lam) z + 1/2)``
    with ``z`` from popcount(h1) + uniform(h2) smoothing — mean/variance
    match Poisson to O(1/12); no transcendentals beyond one ``exp``.
    """
    u1 = hash_uniform(h1)
    lam_s = jnp.minimum(lam, POISSON_SWITCH)
    pmf = jnp.exp(-lam_s)
    cdf = pmf
    k = (u1 > cdf).astype(jnp.float32)
    for i in range(1, POISSON_KMAX):
        pmf = pmf * (lam_s / np.float32(i))
        cdf = cdf + pmf
        k = k + (u1 > cdf)
    z = (lax.population_count(h1).astype(jnp.float32) - np.float32(16.0)
         + hash_uniform(h2) - np.float32(0.5)) * np.float32(_POPCOUNT_NORM)
    normal = jnp.maximum(0.0, jnp.floor(lam + jnp.sqrt(lam) * z + 0.5))
    return jnp.where(lam < POISSON_SWITCH, k, normal)


def _as_u32(epoch):
    return epoch.astype(jnp.uint32) if hasattr(epoch, "astype") \
        else np.uint32(epoch)


def monitor_draw(keys, epoch, site, base, period):
    """Fused PEBS monitoring draw: Poisson(base / period) for every page of
    every batch row, from counter-based hashes keyed by
    ``(row key, site, epoch, page)``."""
    n = base.shape[-1]
    pages = np.arange(n, dtype=np.uint32)[None, :]
    e = _as_u32(epoch)
    h1 = counter_hash(keys[:, None], np.uint32(site), e, pages)
    h2 = counter_hash(keys[:, None], np.uint32(site + 1), e, pages)
    lam = base[None, :].astype(jnp.float32) / period[:, None]
    return _poisson_from_hash(lam, h1, h2)


def monitor_draw2(keys, epoch, reads, writes, sp, wsp):
    """Both monitoring draws (load + store PEBS sites); returns
    ``(sampled_reads, sampled_writes)``.  Two separate (B, n) kernels fuse
    better under XLA CPU than one concatenated (2B, n) kernel."""
    sr = monitor_draw(keys, epoch, _S_READ, reads, sp)
    sw = monitor_draw(keys, epoch, _S_WRITE, writes, wsp)
    return sr, sw


# ---------------------------------------------------------------------------
# Migration-plan top-k selection.  select_top() dispatches between the exact
# (priority, index) radix-select kernel (repro.kernels.select_topk / its
# pure-jnp ref — bit-exact vs the numpy stable sorts) and the historical
# 8-bit log-quantized approximation kept for ablations
# (select_top_quantized: exact counts, near-exact order).
# ---------------------------------------------------------------------------
#: selection implementations select_top() can dispatch to
SELECT_MODES = ("pallas", "ref", "quantized")


def select_top(p_mask, p_heat, d_mask, d_heat, n_promote, n_demote,
               mode: "str | None" = None):
    """Top-``n_promote`` (by ``p_heat`` desc) and top-``n_demote`` (by
    ``d_heat`` asc) selection masks for a ``(B, n)`` batch.

    ``mode`` picks the implementation: ``"pallas"`` (the Pallas kernel,
    interpret mode off-TPU), ``"ref"`` (its pure-jnp oracle) — both
    bit-exact against the numpy reference's stable sorts, ties by page
    index — or ``"quantized"`` (the historical 8-bit log-quantized
    approximation; exact counts only).  ``None`` resolves through
    :func:`repro.kernels.ops.select_path`, honouring the kernels layer's
    ``FORCE`` switch.
    """
    if mode == "quantized":
        return select_top_quantized(p_mask, p_heat, d_mask, d_heat,
                                    n_promote, n_demote)
    if mode in (None, "pallas", "ref"):
        from ..kernels import ops as kernel_ops
        return kernel_ops.select_topk(p_mask, p_heat, d_mask, d_heat,
                                      n_promote, n_demote, mode=mode)
    raise ValueError(f"unknown selection mode {mode!r}; "
                     f"expected one of {SELECT_MODES}")


# ---------------------------------------------------------------------------
# Quantized selection (ablation path): dual bitwise cutoff search over
# log-quantized priorities + one blocked prefix-sum for the cutoff tiers.
# ---------------------------------------------------------------------------
def _quantize(heat, qbits: int):
    """Per-row LOG-scale quantization of nonnegative priorities into
    [0, 2**qbits - 1].  Log spacing preserves the ordering of magnitude
    classes even when a few very hot pages dominate the linear scale (e.g.
    Silo's 1% hot pages are ~500x hotter than the warm tier — linear
    buckets would collapse warm vs cold into one tier and turn the
    demotion order into page-index order)."""
    lg = jnp.log2(1.0 + heat)
    hi = jnp.max(lg, axis=-1, keepdims=True)
    q = lg * (np.float32((1 << qbits) - 1) / jnp.maximum(hi, 1e-30))
    return q.astype(jnp.uint32)


#: quantized-priority width of the selection search (order within
#: collisions falls back to page-index order; selection counts stay exact)
_SEL_QBITS = 8
#: block width of the matmul prefix-sum (see :func:`_blocked_cumsum`)
_CS_BLOCK = 64


def _blocked_cumsum(x):
    """Inclusive cumsum along the last axis of a (B, n) uint32 array whose
    values may pack two 16-bit counters (so row totals stay < 2**32).

    XLA CPU lowers ``jnp.cumsum`` over the minor axis to a scalar chain
    (~0.7 ms at epoch-loop shapes); a block-local cumsum expressed as a
    GEMM against a lower-triangular ones matrix plus a short cross-block
    prefix is several times faster.  Block-local sums stay below the f32
    integer range (64 * 2**16 < 2**24), so the GEMM is exact; cross-block
    accumulation happens in uint32.
    """
    B, n = x.shape
    blk = _CS_BLOCK
    pad = (-n) % blk
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    nb = (n + pad) // blk
    tri = jnp.asarray(np.tril(np.ones((blk, blk), np.float32)))
    t = xp.reshape(B * nb, blk).astype(jnp.float32)
    within = (t @ tri.T).astype(jnp.uint32)         # block-local inclusive
    within = within.reshape(B, nb, blk)
    totals = within[:, :, -1]
    offsets = jnp.cumsum(totals, axis=-1) - totals  # exclusive, (B, nb)
    out = (within + offsets[:, :, None]).reshape(B, -1)
    return out[:, :n] if pad else out


def _count_ge(v, t, ones):
    """Per-row count of ``v >= t`` via a dot product (XLA CPU's reductions
    of predicates are scalar; its GEMV is vectorized)."""
    return (v >= t).astype(jnp.float32) @ ones


def select_top_quantized(p_mask, p_heat, d_mask, d_heat, n_promote,
                         n_demote):
    """Approximate top-k selection masks over log-quantized priorities —
    the ablation path behind ``SimOptions(exact_select=False)``.

    Priorities quantize to :data:`_SEL_QBITS` bits; a dual bitwise binary
    search finds each side's cutoff priority (the k-th best), and one
    packed cumulative sum takes the exact remainder from the cutoff tier in
    page-index order.  Selection *counts* are therefore exact (capacity and
    rate caps hold precisely); only the order among pages whose priority
    collides within the quantization differs from the reference's stable
    sorts (ties there break by page index too).  ~9 fused compare-count
    passes and one blocked cumsum; the exact kernel replaces this as the
    default (see :func:`select_top`).
    """
    n = p_mask.shape[-1]
    ones = jnp.ones(n, jnp.float32)
    kp = n_promote.astype(jnp.float32)[:, None]
    kd = n_demote.astype(jnp.float32)[:, None]
    qmax = np.uint32((1 << _SEL_QBITS) - 1)
    # candidate priority in [1, qmax+1], 0 = not a candidate; larger = picked
    # earlier (promotions: hottest first; demotions: coldest first)
    vp = jnp.where(p_mask, _quantize(p_heat, _SEL_QBITS) + np.uint32(1),
                   np.uint32(0))
    vd = jnp.where(d_mask, (qmax - _quantize(d_heat, _SEL_QBITS))
                   + np.uint32(1), np.uint32(0))
    tp = jnp.zeros((kp.shape[0], 1), dtype=jnp.uint32)
    td = jnp.zeros((kd.shape[0], 1), dtype=jnp.uint32)
    for i in range(_SEL_QBITS, -1, -1):  # cutoff = k-th best priority value
        bit = np.uint32(1 << i)
        cp = _count_ge(vp, tp | bit, ones)[:, None]
        cd = _count_ge(vd, td | bit, ones)[:, None]
        tp = jnp.where(cp >= kp, tp | bit, tp)
        td = jnp.where(cd >= kd, td | bit, td)
    strict_p = vp > tp
    strict_d = vd > td
    bound_p = p_mask & (vp == tp)
    bound_d = d_mask & (vd == td)
    take_p = kp - (strict_p.astype(jnp.float32) @ ones)[:, None]
    take_d = kd - (strict_d.astype(jnp.float32) @ ones)[:, None]
    # one packed cumsum resolves both boundary tiers in page-index order
    cs = _blocked_cumsum(bound_p.astype(jnp.uint32)
                         + (bound_d.astype(jnp.uint32) << np.uint32(16)))
    pmask = strict_p | (bound_p & ((cs & np.uint32(0xFFFF)).astype(jnp.float32)
                                   <= take_p))
    dmask = strict_d | (bound_d & ((cs >> np.uint32(16)).astype(jnp.float32)
                                   <= take_d))
    return pmask & (kp > 0), dmask & (kd > 0)


def kth_largest(values, k: int):
    """Exact k-th largest value per row (k static, 1-based ... actually the
    value at ascending-sorted position ``n - 1 - k`` like ``np.partition``),
    via binary search on the order-preserving bit pattern — no dense sort."""
    bits = lax.bitcast_convert_type(values.astype(jnp.float32), jnp.uint32)
    bits = jnp.where((bits >> 31) == 0, bits | np.uint32(1 << 31), ~bits)
    n = values.shape[-1]
    ones = jnp.ones(n, jnp.float32)
    want = np.float32(k + 1)  # count of elements >= result
    t = jnp.zeros(values.shape[:-1] + (1,), dtype=jnp.uint32)
    for i in range(31, -1, -1):
        cand = t | np.uint32(1 << i)
        cnt = _count_ge(bits, cand, ones)[:, None]
        t = jnp.where(cnt >= want, cand, t)
    t = t[..., 0]
    f = lax.bitcast_convert_type(
        jnp.where((t >> 31) != 0, t & np.uint32(0x7FFFFFFF), ~t), jnp.float32)
    return f


# ---------------------------------------------------------------------------
# Engine state + step functions.  Each engine contributes:
#   knobs(configs)  -> dict of per-config vectors / static arrays
#   init(kv)        -> state pytree of (B, ...) arrays
#   observe(...)    -> (state, samples (B,))
#   plan(...)       -> (state, promote_mask, demote_mask, overhead_ms)
# ---------------------------------------------------------------------------
def _knob_vec(configs, name, default=None, dtype=np.float32):
    vals = [c.get(name, default) if default is not None else c[name]
            for c in configs]
    return np.asarray(vals, dtype=dtype)


def _runs_update(credit, period, est_wall):
    credit = credit + est_wall
    runs = jnp.floor(credit / period).astype(jnp.int32)
    credit = credit - runs.astype(jnp.float32) * period
    return credit, runs


def _rate_pages(rate_gibs, est_wall, page_bytes):
    """Unscaled per-engine migration-rate cap (pages), int-truncated —
    mirrors ``migration_rate_pages(..., scale=1.0)``."""
    return jnp.floor(rate_gibs * np.float32(2 ** 30) * (est_wall / 1e3)
                     / page_bytes)


def _truncate_to_rate(n_promote, n_d, room, rate_pages):
    """The shared promotion/demotion rate-cap truncation every numpy engine
    applies: demotions free room first, promotions take what remains."""
    n_promote = n_promote.astype(jnp.float32)
    n_d = n_d.astype(jnp.float32)
    room = room.astype(jnp.float32)
    over = (n_promote + n_d) > rate_pages
    n_d2 = jnp.where(over, jnp.minimum(n_d, rate_pages), n_d)
    n_p2 = jnp.where(
        over,
        jnp.maximum(0.0, jnp.minimum(jnp.minimum(n_promote, room + n_d2),
                                     rate_pages - n_d2)),
        n_promote)
    return n_p2, n_d2


class _EngineDef:
    """Bundle of the pure functions defining one compiled engine — the
    **lifted-engine protocol**.

    A registered engine that also registers an ``_EngineDef`` subclass via
    :func:`register_jax_engine` gets the whole ``lax.scan``/jit/CRN/pmap
    machinery for free under ``backend="jax"`` instead of the warned
    numpy-epoch-loop fallback.  The contract (all methods pure — no Python
    side effects, jax ops only, shapes fixed by ``(B, n)``):

    ``knobs(configs) -> dict``
        Per-config knob vectors / static arrays from the B config dicts
        (numpy; traced as jit inputs, so new configs never retrace).  Must
        include ``"rate"`` (GiB/s migration cap; ``super().knobs`` provides
        it).
    ``init(kv) -> state``
        Initial engine-state pytree of ``(B, ...)`` arrays.
    ``observe(state, kv, keys, e, reads, writes, est_wall)
      -> (state, samples)``
        Fold one epoch of true per-page access counts into the monitoring
        state; ``samples`` is the per-row sampling volume ``(B,)`` the cost
        model charges.  Monitoring noise must come from the counter-based
        hashes (:func:`counter_uniform` keyed on ``keys``/``e``) so scan,
        eager replay and sharding agree bitwise.
    ``plan(state, kv, keys, e, reads, writes, in_fast, allocated,
      est_wall, max_pages) -> (state, promote_mask, demote_mask,
      overhead_ms)``
        One migration-thread step: boolean ``(B, n)`` selection masks
        (use :meth:`select` for exact rate-capped top-k) plus per-row
        kernel-overhead ms.

    Class attributes: ``plans = False`` skips ``plan`` entirely (static
    placement); ``zero_cost = True`` charges no migration bandwidth
    (oracle-style analysis).  The driver overwrites ``page_bytes`` with the
    workload's page granule before building the step.
    """

    zero_cost = False
    plans = True

    def __init__(self, B, n, fast_cap, sampler, select_mode: str = "ref"):
        self.B, self.n, self.fast_cap, self.sampler = B, n, fast_cap, sampler
        self.select_mode = select_mode
        self.page_bytes = np.float32(2 ** 21)  # overwritten by the driver

    def select(self, p_mask, p_heat, d_mask, d_heat, n_promote, n_demote):
        """Migration-plan top-k selection under this engine's configured
        implementation (see :func:`select_top`)."""
        return select_top(p_mask, p_heat, d_mask, d_heat, n_promote,
                          n_demote, mode=self.select_mode)

    def knobs(self, configs) -> Dict[str, np.ndarray]:
        return {"rate": _knob_vec(configs, "max_migration_rate", default=1e9)}

    def init(self, kv):
        return {}

    def observe(self, st, kv, keys, e, reads, writes, est_wall):
        return st, jnp.zeros(self.B, dtype=jnp.float32)

    def plan(self, st, kv, keys, e, reads, writes, in_fast, allocated,
             est_wall, max_pages):
        none = jnp.zeros((self.B, self.n), dtype=bool)
        return st, none, none, jnp.zeros(self.B, dtype=jnp.float32)


class _StaticDef(_EngineDef):
    plans = False


class _OracleDef(_EngineDef):
    zero_cost = True

    def plan(self, st, kv, keys, e, reads, writes, in_fast, allocated,
             est_wall, max_pages):
        heat = (reads + writes).astype(jnp.float32)  # clairvoyant knowledge
        alloc = jnp.broadcast_to(allocated[None, :] if allocated.ndim == 1
                                 else allocated, (self.B, self.n))
        n_alloc = alloc.sum(axis=-1)
        cap = jnp.minimum(self.fast_cap, n_alloc)
        # want = the `cap` hottest allocated pages (ties by index)
        heat_b = jnp.broadcast_to(heat[None, :], (self.B, self.n))
        none = jnp.zeros((self.B, self.n), bool)
        want, _ = self.select(alloc, heat_b, none, heat_b,
                              cap.astype(jnp.float32), jnp.zeros(self.B))
        prom_c = want & ~in_fast
        dem_c = ~want & in_fast
        free = self.fast_cap - in_fast.sum(axis=1)
        need = jnp.maximum(0, prom_c.sum(axis=1) - free)
        # index-order prefixes, like the reference's flatnonzero slices;
        # one packed blocked cumsum serves both sides
        cs = _blocked_cumsum(prom_c.astype(jnp.uint32)
                             + (dem_c.astype(jnp.uint32) << np.uint32(16)))
        cs_p = (cs & np.uint32(0xFFFF)).astype(jnp.int32)
        cs_d = (cs >> np.uint32(16)).astype(jnp.int32)
        d_sel = dem_c & (cs_d <= need[:, None])
        n_d = d_sel.sum(axis=1)
        p_sel = prom_c & (cs_p <= (free + n_d)[:, None])
        return st, p_sel, d_sel, jnp.zeros(self.B, dtype=jnp.float32)


class _HeMemDef(_EngineDef):
    COOL_UNIT_PAGES = 16.0

    def knobs(self, configs):
        kv = super().knobs(configs)
        kv.update(
            sp=_knob_vec(configs, "sampling_period"),
            wsp=_knob_vec(configs, "write_sampling_period"),
            read_hot=_knob_vec(configs, "read_hot_threshold"),
            write_hot=_knob_vec(configs, "write_hot_threshold"),
            period=_knob_vec(configs, "migration_period"),
            cool_pages=np.minimum(
                _knob_vec(configs, "cooling_pages", dtype=np.int32), self.n),
            hot_ring=_knob_vec(configs, "hot_ring_reqs_threshold",
                               dtype=np.int32),
            cold_ring=_knob_vec(configs, "cold_ring_reqs_threshold",
                                dtype=np.int32),
            trigger=np.maximum(
                _knob_vec(configs, "cooling_threshold") * self.n
                / self.COOL_UNIT_PAGES, 1.0).astype(np.float32),
        )
        p = kv["cool_pages"]
        # static per config: each page's cooling chunk and chunks per sweep
        kv["cj"] = (np.arange(self.n, dtype=np.int32)[None, :]
                    // p[:, None]).astype(np.int32)
        kv["M"] = ((self.n + p - 1) // p).astype(np.int32)
        return kv

    def init(self, kv):
        B, n = self.B, self.n
        z = jnp.zeros((B, n), dtype=jnp.float32)
        zb = jnp.zeros(B, dtype=jnp.float32)
        return {"rc": z, "wc": z, "cursor": jnp.zeros(B, dtype=jnp.int32),
                "since": zb, "credit": zb}

    def _draws(self, kv, keys, e, reads, writes):
        """Monitoring-noise hook: sampled (reads, writes), both ``(B, n)``.
        The default is the fused counter-based Poisson PEBS model;
        :class:`KVHeMemDef` overrides it with deterministic means."""
        return monitor_draw2(keys, e, reads, writes, kv["sp"], kv["wsp"])

    def observe(self, st, kv, keys, e, reads, writes, est_wall):
        sr, sw = self._draws(kv, keys, e, reads, writes)
        samples = (sr + sw) @ jnp.ones(self.n, jnp.float32)
        since = st["since"] + samples
        k = jnp.floor(since / kv["trigger"]).astype(jnp.int32)
        p = kv["cool_pages"]
        k_eff = k.astype(jnp.float32) * p.astype(jnp.float32) / self.n
        factor = jnp.where(
            k > 0, (2.0 - jnp.exp2(-k_eff)) / (k_eff + 1.0), 1.0)
        # the cooling sweep: chunk c_j = j // cooling_pages, M chunks per
        # sweep; k triggers from chunk m0 halve chunk c exactly
        # k//M + [ (c - m0) mod M < k mod M ] times — the closed form of
        # the reference's per-trigger cursor loop
        M = kv["M"]
        m0 = st["cursor"] // p
        cj = kv["cj"]
        halv = (k // M)[:, None] + (
            ((cj - m0[:, None]) % M[:, None]) < (k % M)[:, None])
        decay = jnp.exp2(-halv.astype(jnp.float32))
        rc = st["rc"] * decay + sr * factor[:, None]
        wc = st["wc"] * decay + sw * factor[:, None]
        st = dict(st, rc=rc, wc=wc,
                  cursor=((m0 + k) % M) * p,
                  since=since - k.astype(jnp.float32) * kv["trigger"])
        return st, samples

    def plan(self, st, kv, keys, e, reads, writes, in_fast, allocated,
             est_wall, max_pages):
        credit, runs = _runs_update(st["credit"], kv["period"], est_wall)
        st = dict(st, credit=credit)
        run_row = runs > 0
        hot = (st["rc"] >= kv["read_hot"][:, None]) | \
            (st["wc"] >= kv["write_hot"][:, None])
        heat = st["rc"] + st["wc"]
        cand_p = hot & ~in_fast & allocated
        cand_d = ~hot & in_fast
        rate_pages = jnp.minimum(
            _rate_pages(kv["rate"], est_wall, self.page_bytes), max_pages)
        # counts first (selection masks are derived from ONE packed sort)
        n_p = jnp.minimum(cand_p.sum(axis=1), kv["hot_ring"] * runs)
        room = self.fast_cap - in_fast.sum(axis=1)
        watermark = max(1, self.fast_cap // 50)
        pressure = jnp.maximum(0, watermark - room)
        need = jnp.maximum(jnp.maximum(0, n_p - room), pressure)
        n_d = jnp.minimum(cand_d.sum(axis=1),
                          jnp.minimum(need, kv["cold_ring"] * runs))
        n_promote = jnp.minimum(n_p, room + n_d)
        n_p2, n_d2 = _truncate_to_rate(n_promote, n_d, room,
                                       jnp.maximum(0.0, rate_pages))
        gate = run_row.astype(jnp.float32)
        pmask, dmask = self.select(cand_p, heat, cand_d, heat,
                                   n_p2 * gate, n_d2 * gate)
        return st, pmask, dmask, jnp.zeros(self.B, dtype=jnp.float32)


class _MemtisDef(_EngineDef):
    KERNEL_MS_PER_PAGE = 0.02

    def knobs(self, configs):
        kv = super().knobs(configs)
        kv.update(
            sp=_knob_vec(configs, "sampling_period"),
            wsp=_knob_vec(configs, "write_sampling_period"),
            cool_period=_knob_vec(configs, "cooling_period_ms"),
            adapt_period=_knob_vec(configs, "adaptation_period_ms"),
            period=_knob_vec(configs, "migration_period"),
            warm=_knob_vec(configs, "warm_pct") / np.float32(100.0),
        )
        return kv

    def init(self, kv):
        B, n = self.B, self.n
        z = jnp.zeros((B, n), dtype=jnp.float32)
        zb = jnp.zeros(B, dtype=jnp.float32)
        return {"rc": z, "wc": z, "thr": jnp.full(B, 4.0, dtype=jnp.float32),
                "cool": zb, "adapt": zb, "credit": zb}

    def observe(self, st, kv, keys, e, reads, writes, est_wall):
        sr, sw = monitor_draw2(keys, e, reads, writes, kv["sp"], kv["wsp"])
        rc = st["rc"] + sr
        wc = st["wc"] + sw
        samples = (sr + sw) @ jnp.ones(self.n, jnp.float32)
        cool_c = st["cool"] + est_wall
        cool = cool_c >= kv["cool_period"]
        cool_c = jnp.where(cool, 0.0, cool_c)
        rc = jnp.where(cool[:, None], rc * 0.5, rc)
        wc = jnp.where(cool[:, None], wc * 0.5, wc)
        adapt_c = st["adapt"] + est_wall
        adapt = adapt_c >= kv["adapt_period"]
        adapt_c = jnp.where(adapt, 0.0, adapt_c)
        # smallest threshold whose hot set fits the fast tier: the value at
        # ascending position n-1-k of the heat row (np.partition semantics)
        part = kth_largest(rc + wc, min(self.fast_cap, self.n - 1))
        thr = jnp.where(adapt, jnp.maximum(part, 1.0), st["thr"])
        st = dict(st, rc=rc, wc=wc, thr=thr, cool=cool_c, adapt=adapt_c)
        return st, samples

    def plan(self, st, kv, keys, e, reads, writes, in_fast, allocated,
             est_wall, max_pages):
        credit, runs = _runs_update(st["credit"], kv["period"], est_wall)
        st = dict(st, credit=credit)
        run_row = runs > 0
        heat = st["rc"] + st["wc"]
        hot = heat >= st["thr"][:, None]
        warm = ~hot & (heat >= (st["thr"] * (1.0 - kv["warm"]))[:, None])
        cand_p = hot & ~in_fast & allocated
        cand_d = in_fast & ~hot & ~warm
        rate_pages = jnp.minimum(
            _rate_pages(kv["rate"], est_wall, self.page_bytes), max_pages)
        n_p = cand_p.sum(axis=1)
        room = self.fast_cap - in_fast.sum(axis=1)
        need = jnp.maximum(
            0.0, jnp.minimum(n_p.astype(jnp.float32), rate_pages) - room)
        n_d = jnp.minimum(cand_d.sum(axis=1).astype(jnp.float32), need)
        n_promote = jnp.minimum(n_p.astype(jnp.float32), room + n_d)
        n_p2, n_d2 = _truncate_to_rate(n_promote, n_d, room, rate_pages)
        gate = run_row.astype(jnp.float32)
        pmask, dmask = self.select(cand_p, heat, cand_d, heat,
                                   n_p2 * gate, n_d2 * gate)
        overhead = jnp.where(
            run_row,
            (pmask.sum(axis=1) + dmask.sum(axis=1)).astype(jnp.float32)
            * np.float32(self.KERNEL_MS_PER_PAGE), 0.0)
        return st, pmask, dmask, overhead


class _HMSDKDef(_EngineDef):
    MAX_PROBES = 64  # DAMON cost cap, as in the reference

    def knobs(self, configs):
        kv = super().knobs(configs)
        nr = np.minimum(_knob_vec(configs, "nr_regions", dtype=np.int32),
                        self.n)
        kv.update(
            nr_regions=nr,
            sample_us=_knob_vec(configs, "sample_us"),
            hot_pct=_knob_vec(configs, "hot_access_pct"),
            cold_aggr=_knob_vec(configs, "cold_aggr_intervals"),
            period=_knob_vec(configs, "migration_period"),
        )
        # ragged equal-size region maps, padded to Rmax across the batch
        Rmax = int(nr.max())
        B, n = len(nr), self.n  # kv arrays are built for the FULL batch
        region_of_page = np.zeros((B, n), dtype=np.int32)
        sizes = np.zeros((B, Rmax), dtype=np.float32)
        valid = np.zeros((B, Rmax), dtype=bool)
        for b in range(B):
            R = int(nr[b])
            bounds = np.linspace(0, n, R + 1).astype(np.int64)
            region_of_page[b] = np.searchsorted(bounds[1:], np.arange(n),
                                                side="right")
            sizes[b, :R] = (bounds[1:] - bounds[:-1])
            valid[b, :R] = True
        kv.update(region_of_page=region_of_page, sizes=sizes, valid=valid)
        self.Rmax = Rmax
        return kv

    def init(self, kv):
        B = self.B
        zr = jnp.zeros((B, self.Rmax), dtype=jnp.float32)
        return {"acc": zr, "idle": zr,
                "credit": jnp.zeros(B, dtype=jnp.float32)}

    def observe(self, st, kv, keys, e, reads, writes, est_wall):
        B, Rmax = self.B, self.Rmax
        total = (reads + writes).astype(jnp.float32)
        rate = total[None, :] / jnp.maximum(est_wall, 1e-9)[:, None]
        sample_ms = kv["sample_us"] / 1e3
        nr_samples = jnp.maximum(1.0, jnp.floor(est_wall / sample_ms))
        p_hit = 1.0 - jnp.exp(-rate * sample_ms[:, None])
        K = jnp.minimum(nr_samples, self.MAX_PROBES)
        # region-mean hit probability: a probe picks a uniform page in the
        # region then tests its accessed bit, so each probe is
        # Bernoulli(p̄) and K probes are Binomial(K, p̄) — drawn as
        # MAX_PROBES masked Bernoullis (exactly the distribution of the
        # reference's Monte-Carlo probe loop, for both sampler spellings)
        ids = kv["region_of_page"] + \
            (np.arange(B, dtype=np.int32) * Rmax)[:, None]
        pbar = jax.ops.segment_sum(p_hit.reshape(-1), ids.reshape(-1),
                                   num_segments=B * Rmax).reshape(B, Rmax)
        pbar = jnp.clip(pbar / jnp.maximum(kv["sizes"], 1.0), 0.0, 1.0)
        probes = np.arange(self.MAX_PROBES, dtype=np.uint32)[None, :, None]
        regions = np.arange(Rmax, dtype=np.uint32)[None, None, :]
        u = counter_uniform(keys[:, None, None], np.uint32(_S_PROBE),
                            _as_u32(e), probes, regions)
        active = probes.astype(np.float32) < K[:, None, None]
        hits = ((u < pbar[:, None, :]) & active).sum(axis=1)
        acc = hits.astype(jnp.float32) / K[:, None]
        acc = jnp.where(kv["valid"], acc, 0.0)
        idle = jnp.where(kv["valid"] & (acc <= 0.0), st["idle"] + 1.0, 0.0)
        samples = nr_samples * kv["nr_regions"].astype(np.float32) / 50.0
        st = dict(st, acc=acc, idle=idle)
        return st, samples

    def plan(self, st, kv, keys, e, reads, writes, in_fast, allocated,
             est_wall, max_pages):
        credit, runs = _runs_update(st["credit"], kv["period"], est_wall)
        st = dict(st, credit=credit)
        run_row = runs > 0
        hot_r = st["acc"] >= (kv["hot_pct"] / 100.0)[:, None]
        cold_r = st["idle"] >= kv["cold_aggr"][:, None]
        regions = np.arange(self.Rmax, dtype=np.uint32)[None, :]
        jitter = counter_uniform(keys[:, None], np.uint32(_S_JITTER),
                                 _as_u32(e), regions) * np.float32(1e-6)
        est = st["acc"] + jitter
        rop = kv["region_of_page"]
        hp = jnp.take_along_axis(hot_r, rop, axis=1)
        cp = jnp.take_along_axis(cold_r, rop, axis=1)
        est_p = jnp.take_along_axis(est, rop, axis=1)
        cand_p = hp & ~in_fast & allocated
        rate_pages = jnp.minimum(
            _rate_pages(kv["rate"], est_wall, self.page_bytes), max_pages)
        n_p = cand_p.sum(axis=1)
        room = self.fast_cap - in_fast.sum(axis=1)
        need = jnp.maximum(
            0.0, jnp.minimum(n_p.astype(jnp.float32), rate_pages) - room)
        # demotion preference chain (idle-cold by page index, then lukewarm
        # by estimated rate, then hot by estimated rate) as one composite
        # ascending key
        class1 = ~hp & ~cp & in_fast
        class2 = hp & in_fast
        key_d = jnp.where(cp & in_fast, 0.0,
                          jnp.where(class1, 10.0 + est_p,
                                    jnp.where(class2, 20.0 + est_p, 40.0)))
        cand_d = in_fast
        n_d = jnp.minimum(cand_d.sum(axis=1).astype(jnp.float32), need)
        n_promote = jnp.minimum(n_p.astype(jnp.float32), room + n_d)
        n_p2, n_d2 = _truncate_to_rate(n_promote, n_d, room, rate_pages)
        gate = run_row.astype(jnp.float32)
        pmask, dmask = self.select(cand_p, est_p, cand_d, key_d,
                                   n_p2 * gate, n_d2 * gate)
        return st, pmask, dmask, jnp.zeros(self.B, dtype=jnp.float32)


class KVHeMemDef(_HeMemDef):
    """The tiered-KV cache's HeMem analog — the first **lifted** engine.

    Identical cooling/threshold/ring/rate machinery to :class:`_HeMemDef`,
    but monitoring is **deterministic mean sampling**: the serving path
    measures per-page attention mass *exactly* (the paged-attention kernel
    computes it), so there is no PEBS interrupt noise to emulate —
    ``sampled = true_counts / sampling_period``.  Determinism is also what
    lets the compiled serving step be pinned bit-identical to the eager
    Python ``TieredKVCache`` loop (same jnp ops, jit vs eager).
    """

    def _draws(self, kv, keys, e, reads, writes):
        sr = reads.astype(jnp.float32)[None, :] / kv["sp"][:, None]
        sw = writes.astype(jnp.float32)[None, :] / kv["wsp"][:, None]
        return sr, sw


#: name -> _EngineDef subclass; the compiled-path registry behind
#: supports()/_build_run_fn.  Builtins are seeded here; anything else goes
#: through register_jax_engine (the lifted-engine protocol).
_ENGINE_DEFS = {
    "hemem": _HeMemDef,
    "hmsdk": _HMSDKDef,
    "memtis": _MemtisDef,
    "static": _StaticDef,
    "oracle": _OracleDef,
    "kv-hemem": KVHeMemDef,
}

#: public alias of the lifted-engine protocol base class
EngineDef = _EngineDef


def register_jax_engine(name: str, def_cls: "type | None" = None, *,
                        overwrite: bool = False):
    """Register an :class:`EngineDef` subclass as the compiled (lifted)
    implementation of engine ``name``; usable as a decorator.

    Pair it with ``@register_engine(name)`` on the numpy side: the numpy
    batch engine remains the ``backend="numpy"`` implementation and the
    lifted def compiles the same policy under ``backend="jax"`` — once both
    are registered, :func:`supports` returns True and the simulator stops
    warning/falling back to the numpy epoch loop for this engine.

        @register_jax_engine("my-policy")
        class MyPolicyDef(EngineDef):
            def plan(self, st, kv, keys, e, reads, writes, in_fast,
                     allocated, est_wall, max_pages):
                ...

    See :class:`EngineDef` for the observe/plan purity contract.
    """
    def _add(cls: type) -> type:
        if not (isinstance(cls, type) and issubclass(cls, _EngineDef)):
            raise TypeError(f"lifted engine {name!r} must be an EngineDef "
                            f"subclass, got {cls!r}")
        if name in _ENGINE_DEFS and not overwrite:
            raise ValueError(
                f"lifted engine {name!r} is already registered "
                f"(to {_ENGINE_DEFS[name]!r}); pass overwrite=True to "
                f"replace it")
        _ENGINE_DEFS[name] = cls
        return cls

    return _add if def_cls is None else _add(def_cls)


def jax_engines() -> Tuple[str, ...]:
    """Names with a registered lifted def (compiled under backend='jax')."""
    return tuple(sorted(_ENGINE_DEFS))


#: page-count ceiling of the compiled path (the packed boundary cumsum
#: carries two 16-bit counters per element)
MAX_PAGES = (1 << 16) - 1


def supports(engine_name: str, sampler: str,
             n_pages: "int | None" = None) -> bool:
    """True if the compiled path covers this (engine, sampler[, trace
    size]) combination; anything unsupported falls back to the numpy
    epoch loop."""
    if engine_name not in _ENGINE_DEFS or sampler not in JAX_SAMPLERS:
        return False
    if n_pages is not None and n_pages > MAX_PAGES:
        return False
    return have_jax()


# ---------------------------------------------------------------------------
# Scan driver + jit cache
# ---------------------------------------------------------------------------
def _build_step(edef: "_EngineDef", const, page_bytes, scale,
                record_placement):
    from .simulator import _access_cost  # late: avoids a circular import
    B, n, fast_cap = edef.B, edef.n, edef.fast_cap
    edef.page_bytes = np.float32(page_bytes)
    touch_floor = np.float32(1.0 / max(n, 1))
    zero_cost = edef.zero_cost

    def step(carry, xs, kv):
        in_fast, allocated, est_wall, eng_state, cum_mig, keys = carry
        reads, writes, e = xs
        # first-touch allocation: the trace is shared across the batch, so
        # `allocated` is one shared (n,) vector; only in_fast is per-row.
        # Most epochs touch no new pages, so the (B, n) update is gated.
        acc = reads + writes
        touched = acc > touch_floor
        new = touched & ~allocated
        room = fast_cap - in_fast.sum(axis=1)
        rank_new = jnp.cumsum(new)
        in_fast = in_fast | (new[None, :] & (rank_new[None, :]
                                             <= room[:, None]))
        allocated = allocated | new

        eng_state, samples = edef.observe(
            eng_state, kv, keys, e, reads, writes, est_wall)
        max_pages = jnp.floor(kv["rate"] * np.float32(2 ** 30)
                              * (est_wall / 1e3) / np.float32(page_bytes)
                              * np.float32(scale))
        if edef.plans:
            eng_state, pmask, dmask, overhead_ms = edef.plan(
                eng_state, kv, keys, e, reads, writes, in_fast, allocated,
                est_wall, max_pages)
        else:
            pmask = jnp.zeros((B, n), dtype=bool)
            dmask = pmask
            overhead_ms = jnp.zeros(B, dtype=jnp.float32)
        n_promote = pmask.sum(axis=1).astype(jnp.float32)
        n_demote = dmask.sum(axis=1).astype(jnp.float32)
        in_fast = (in_fast & ~dmask) | pmask
        cum_mig = cum_mig + n_promote + n_demote

        acc_sum = acc.sum()
        inf_f = in_fast.astype(jnp.float32)
        reads_f = inf_f @ reads
        writes_f = inf_f @ writes
        acc_f = reads_f + writes_f
        reads_s = reads.sum() - reads_f
        writes_s = writes.sum() - writes_f
        if zero_cost:
            pb = db = w_mig = jnp.zeros(B, dtype=jnp.float32)
        else:
            pb = n_promote * np.float32(page_bytes)
            db = n_demote * np.float32(page_bytes)
            w_mig = (pmask | dmask).astype(jnp.float32) @ writes
        wall_ms, stall_s, sampling_s, hit = _access_cost(
            jnp, acc_f, acc_sum - acc_f, reads_s, writes_s, pb, db, w_mig,
            est_wall, samples, overhead_ms, const)
        out = (wall_ms, cum_mig, hit, sampling_s * 1e3, stall_s * 1e3)
        if record_placement:
            out = out + (in_fast,)
        carry = (in_fast, allocated, wall_ms, eng_state, cum_mig, keys)
        return carry, out

    return step


def init_carry(edef: "_EngineDef", kv, keys, est0):
    """The epoch-0 scan carry: ``(in_fast (B, n), allocated (n,), est_wall
    (B,), engine state pytree, cum_migrations (B,), row keys (B,))``.

    The carry is an explicit input/output of the compiled scan driver so an
    epoch loop can be CHECKPOINTED mid-run and resumed (the tune service's
    partial-budget trials): running epochs ``[0, k)`` and then ``[k, E)``
    from the returned carry is bitwise identical to one unsegmented run,
    because every monitoring draw is keyed by the *absolute* epoch index
    carried in the ``xs`` epoch-id stream, not by scan position.
    """
    B, n = edef.B, edef.n
    return (jnp.zeros((B, n), dtype=bool), jnp.zeros(n, dtype=bool),
            jnp.asarray(est0, dtype=jnp.float32), edef.init(kv),
            jnp.zeros(B, dtype=jnp.float32), jnp.asarray(keys))


def carry_to_host(carry):
    """Materialize a scan carry as a picklable numpy pytree (checkpoint
    payload for the study journal / process-pool trial executors)."""
    return jax.tree_util.tree_map(np.asarray, carry)


def broadcast_carry_row(carry, row: int, B: int):
    """Broadcast ONE batch row of a host carry to a fresh ``B``-row carry.

    The online tuner's counterfactual hook: the deployed system's state at
    epoch ``t`` (row ``row``) becomes the shared starting state for a
    candidate batch evaluating "what if we switched configs now" over the
    next window.  The shared first-touch ``allocated`` vector has no batch
    axis and passes through.

    Only meaningful under CRN (``SimOptions(crn=True)``), where every row's
    base key is identical — broadcasting row ``row``'s key then changes no
    draw.  Without CRN the copied per-row keys would collapse the rows onto
    one noise stream, so callers must pass ``crn=True`` downstream.
    """
    in_fast, allocated, est, eng, cum, keys = carry

    def pick(a):
        a = np.asarray(a)
        return np.repeat(a[row:row + 1], B, axis=0)

    return (pick(in_fast), np.asarray(allocated), pick(est),
            jax.tree_util.tree_map(pick, eng), pick(cum), pick(keys))


def _build_run_fn(engine_name, B, n, n_epochs, fast_cap, sampler, scale,
                  page_bytes, record_placement, select_mode="ref"):
    """Compiled scan driver over ``n_epochs`` epochs (the SEGMENT length).

    ``run(kv, reads_t, writes_t, const, carry, epoch_ids)`` advances the
    carry through one segment and returns ``(final_carry, outs)``.  Epoch
    indices travel as data (``epoch_ids``, int32 ``(n_epochs,)``), so one
    compiled function per segment *length* serves any epoch offset —
    resuming a checkpointed trial never recompiles.
    """
    edef = _ENGINE_DEFS[engine_name](B, n, fast_cap, sampler, select_mode)

    def run(kv, reads_t, writes_t, const, carry, epoch_ids):
        step = _build_step(edef, const, page_bytes, scale, record_placement)
        xs = (reads_t, writes_t, epoch_ids)
        return jax.lax.scan(lambda c, x: step(c, x, kv), carry, xs)

    return edef, run


#: compiled-function cache: key -> (edef, jitted run).  The leading
#: (engine, n_pages, sampler) prefix is the contract of the small-fix
#: satellite: same prefix + same remaining shape params == no retrace.
_COMPILED: Dict[Tuple, Tuple[Any, Any]] = {}

#: shape-parameter names aligned with _get_compiled's key[3:] — used to
#: name the fields a recompile changed
_KEY_FIELDS = ("B", "n_epochs", "fast_cap", "scale", "page_bytes",
               "record_placement", "pmapped", "select_mode")

#: recompile causes already warned about, keyed ((engine, n, sampler),
#: changed-field names).  A phase-shifting study that alternates between
#: two shapes (e.g. window evaluations on two drift phases) retraces each
#: shape ONCE (the compiled functions are cached and reused when the shape
#: repeats) but used to WARN on every first-sighting of a shape; warning
#: once per cause keeps logs readable across phase switches.
_RECOMPILE_WARNED: "set[Tuple]" = set()


def reset_recompile_warnings() -> None:
    """Forget which recompile causes have warned (tests)."""
    _RECOMPILE_WARNED.clear()


def _n_devices() -> int:
    """Local XLA device count (1 unless the host is split, e.g. via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    try:
        return jax.local_device_count()
    except Exception:  # pragma: no cover - no backend initialized
        return 1


def _get_compiled(engine_name, B, n, n_epochs, fast_cap, sampler, scale,
                  page_bytes, record_placement, select_mode):
    ndev = _n_devices()
    pmapped = ndev > 1 and B % ndev == 0 and B >= ndev
    key = (engine_name, n, sampler, B, n_epochs, fast_cap, float(scale),
           int(page_bytes), bool(record_placement), pmapped, select_mode)
    hit = _COMPILED.get(key)
    if hit is not None:
        return hit
    prefix = key[:3]
    same_prefix = [k for k in _COMPILED if k[:3] == prefix]
    if same_prefix:
        # name the shape fields this recompile changed, against the
        # closest already-compiled shape (fewest differing fields)
        def _diff(k):
            return tuple(name for name, a, b
                         in zip(_KEY_FIELDS, k[3:], key[3:]) if a != b)

        changed = min((_diff(k) for k in same_prefix), key=len)
        if changed == ("n_epochs",):
            # only the segment LENGTH differs — routine for the tune
            # service's partial-epoch (ASHA rung) evaluations, not churn
            log.debug("compiling %d-epoch segment driver for %s "
                      "(n_pages=%d, B=%d)", n_epochs, engine_name, n, B)
        else:
            # warn once per CAUSE (prefix + changed-field set), not once
            # per switch: a drift study alternating between two phase
            # shapes logs one warning, then debug lines
            cause = (prefix, changed)
            msg = ("recompiling jax epoch loop for %s (n_pages=%d, "
                   "sampler=%s): %s changed to B=%d, E=%d, fast_cap=%d, "
                   "select=%s")
            fields = ("/".join(changed) or "shape", B, n_epochs, fast_cap,
                      select_mode)
            if cause in _RECOMPILE_WARNED:
                log.debug(msg + " (repeat cause)", engine_name, n, sampler,
                          *fields)
            else:
                _RECOMPILE_WARNED.add(cause)
                log.warning(msg, engine_name, n, sampler, *fields)
    if pmapped:
        # data-parallel over local XLA devices: each device runs the scan on
        # a B/ndev slice of the batch.  Per-row draws are keyed by global
        # batch index (shipped in the carry's `keys`), so device placement
        # never changes results.  The shared first-touch `allocated` vector
        # is replicated (in_axes None) and comes back identical per device.
        Bl = B // ndev
        edef, run = _build_run_fn(engine_name, Bl, n, n_epochs, fast_cap,
                                  sampler, scale, page_bytes,
                                  record_placement, select_mode)
        prun = jax.pmap(run, in_axes=(0, None, None, None,
                                      (0, None, 0, 0, 0, 0), None))

        def sharded(kv, reads_t, writes_t, const, carry, epoch_ids):
            def shard(a):
                return jnp.reshape(a, (ndev, Bl) + a.shape[1:])

            def unshard(a):
                return jnp.reshape(a, (B,) + a.shape[2:])

            kv_s = {k: shard(v) for k, v in kv.items()}
            in_fast, allocated, est, eng, cum, keys = carry
            carry_s = (shard(in_fast), allocated, shard(est),
                       jax.tree_util.tree_map(shard, eng), shard(cum),
                       shard(keys))
            fin, outs = prun(kv_s, reads_t, writes_t, const, carry_s,
                             epoch_ids)
            f_in_fast, f_alloc, f_est, f_eng, f_cum, f_keys = fin
            fin_carry = (unshard(f_in_fast), f_alloc[0], unshard(f_est),
                         jax.tree_util.tree_map(unshard, f_eng),
                         unshard(f_cum), unshard(f_keys))
            # (ndev, E, Bl, ...) -> (E, B, ...)
            outs = tuple(
                jnp.moveaxis(o, 0, 1).reshape((n_epochs, B) + o.shape[3:])
                for o in outs)
            return fin_carry, outs

        _COMPILED[key] = (edef, sharded)
        return edef, sharded
    edef, run = _build_run_fn(engine_name, B, n, n_epochs, fast_cap, sampler,
                              scale, page_bytes, record_placement,
                              select_mode)
    jitted = jax.jit(run)
    _COMPILED[key] = (edef, jitted)
    return edef, jitted


def compiled_cache_info() -> List[Tuple]:
    """Keys of the jitted-epoch-function cache (tests/debugging)."""
    return list(_COMPILED)


def run_epochs(workload, engine_name: str,
               sim_configs: Sequence[Mapping[str, Any]],
               const: Mapping[str, float], fast_cap: int, page_bytes: int,
               seeds: Sequence[int], sampler: str, crn: bool = False,
               batch_offset: int = 0, record_placement: bool = False,
               python_loop: bool = False,
               exact_select: bool = True,
               epoch_start: int = 0,
               epoch_stop: "int | None" = None,
               carry: Any = None,
               return_carry: bool = False) -> Dict[str, np.ndarray]:
    """Run the compiled epoch loop; returns per-epoch result arrays.

    ``sim_configs`` must already be scale-adjusted (``scale_config``).
    ``python_loop=True`` runs the identical step function eagerly epoch by
    epoch instead of under ``lax.scan`` — the reference the scan is tested
    against.  ``exact_select=True`` (default) plans migrations with the
    exact top-k selection kernel (Pallas or its pure-jnp ref, resolved by
    :func:`repro.kernels.ops.select_path`); ``False`` restores the
    log-quantized ablation path.

    **Segments (checkpoint/restore).**  ``epoch_start``/``epoch_stop``
    bound the evaluated epoch range ``[start, stop)`` (default: the whole
    workload).  Starting past epoch 0 requires ``carry`` — the scan carry a
    previous segment returned under ``return_carry=True`` (as ``"carry"``
    in the output dict, numpy-ified and picklable).  Segmented execution is
    bitwise identical to one unsegmented scan: draws are keyed by absolute
    epoch ids shipped as data, so segment boundaries are invisible to the
    numerics (pinned by the tune-service conformance tests).

    Output dict: ``wall_ms``/``cum_migrations``/``hit_rate``/
    ``sampling_ms``/``stall_ms`` as ``(n_epochs, B)`` float arrays (segment
    epochs only), plus ``in_fast`` ``(n_epochs, B, n)`` when
    ``record_placement`` and ``carry`` when ``return_carry``.
    """
    if not have_jax():  # pragma: no cover - env without jax
        raise RuntimeError("backend='jax' requires jax; install it or use "
                           "the default numpy backend")
    B = len(sim_configs)
    n = workload.n_pages
    if n > MAX_PAGES:  # callers route via supports(); this is the backstop
        raise ValueError(
            f"backend='jax' supports up to {MAX_PAGES} pages "
            f"(workload has {n}); use the numpy backend for larger traces")
    E = workload.n_epochs
    start = int(epoch_start)
    stop = E if epoch_stop is None else min(int(epoch_stop), E)
    if not 0 <= start < stop:
        raise ValueError(f"empty epoch segment [{start}, {stop}) "
                         f"(workload has {E} epochs)")
    if start > 0 and carry is None:
        raise ValueError("epoch_start > 0 requires the carry returned by "
                         "the previous segment (return_carry=True)")
    seg = stop - start
    trace = [workload.epoch_access(e) for e in range(start, stop)]
    reads_t = np.stack([r for r, _ in trace]).astype(np.float32)
    writes_t = np.stack([w for _, w in trace]).astype(np.float32)
    epoch_ids = np.arange(start, stop, dtype=np.int32)
    const = {k: np.float32(v) for k, v in const.items()}
    scale = workload.scale
    if exact_select:
        from ..kernels import ops as kernel_ops
        select_mode = kernel_ops.select_path()
    else:
        select_mode = "quantized"

    if python_loop:
        edef, _ = _build_run_fn(engine_name, B, n, seg, fast_cap, sampler,
                                scale, page_bytes, record_placement,
                                select_mode)
        kv = edef.knobs(sim_configs)
        step = _build_step(edef, const, page_bytes, scale, record_placement)
        if carry is None:
            keys = base_keys(seeds, batch_offset, crn)
            est0 = np.full(B, workload.epoch_ms, dtype=np.float32)
            carry = init_carry(edef, kv, keys, est0)
        else:
            carry = jax.tree_util.tree_map(jnp.asarray, carry)
        outs = []
        for i, e in enumerate(epoch_ids):
            carry, o = step(carry, (jnp.asarray(reads_t[i]),
                                    jnp.asarray(writes_t[i]),
                                    jnp.int32(int(e))), kv)
            outs.append(o)
        stacked = tuple(jnp.stack([o[i] for o in outs])
                        for i in range(len(outs[0])))
    else:
        edef, run = _get_compiled(engine_name, B, n, seg, fast_cap, sampler,
                                  scale, page_bytes, record_placement,
                                  select_mode)
        kv = edef.knobs(sim_configs)
        if carry is None:
            keys = base_keys(seeds, batch_offset, crn)
            est0 = np.full(B, workload.epoch_ms, dtype=np.float32)
            carry = init_carry(edef, kv, keys, est0)
        else:
            carry = jax.tree_util.tree_map(jnp.asarray, carry)
        carry, stacked = run(kv, reads_t, writes_t, const, carry, epoch_ids)

    names = ["wall_ms", "cum_migrations", "hit_rate", "sampling_ms",
             "stall_ms"]
    if record_placement:
        names.append("in_fast")
    out = {name: np.asarray(arr) for name, arr in zip(names, stacked)}
    if return_carry:
        out["carry"] = carry_to_host(carry)
    # hand the materialized trace back so heatmap binning in the caller
    # does not regenerate it (procedural workloads pay O(n) per epoch)
    out["trace_reads"] = reads_t
    out["trace_writes"] = writes_t
    return out
