"""Tiering engines.

:class:`HeMemEngine` is the faithful reimplementation of the mechanism the
paper tunes (§3.2): PEBS-subsampled per-page read/write counters, separate
read/write hotness thresholds, batched count cooling, and a periodic migration
thread with ring-capacity and migration-rate limits.  Every knob of paper
Table 2 is honoured.

:class:`HMSDKEngine` models HMSDK's DAMON-based region monitor (§4.5): the
address space is split into ``nr_regions`` regions, one page per region is
probed per sampling interval, and whole regions are promoted/demoted.  DAMON's
core assumption — all pages of a region share an access frequency — is kept,
which is exactly what makes it fail on GUPS (paper Fig. 12).

:class:`MemtisEngine` models the Memtis baseline (§4.6): the hot threshold is
*dynamically* adapted so the hot set matches fast-tier capacity, a warm class
is excluded from migration, but the cooling period, the migration period and
the (very high, 100k) write sampling period remain static.

:class:`StaticEngine` (first-touch, never migrates) and :class:`OracleEngine`
(clairvoyant placement, free migrations — a CH_opt-style bound [49]) are the
reference points.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional

import numpy as np

from .pages import MigrationPlan, TierState


class TieringEngine:
    """Protocol: observe true per-page access counts, plan migrations."""

    #: if True, the simulator charges no bandwidth/stall cost for migrations
    zero_cost_migrations = False

    def __init__(self, config: Mapping[str, Any], tier: TierState,
                 seed: int = 0):
        self.config = dict(config)
        self.tier = tier
        self.rng = np.random.default_rng(seed)
        # per-epoch telemetry the simulator reads back
        self.samples_last_epoch = 0.0     # PEBS-style samples taken (overhead)
        self.overhead_ms_last_epoch = 0.0  # extra engine CPU time (e.g. Memtis kernel)
        self.cooling_events = 0

    def observe(self, reads: np.ndarray, writes: np.ndarray,
                epoch_ms: float) -> None:
        raise NotImplementedError

    def plan(self, epoch_ms: float, max_pages_this_epoch: int) -> MigrationPlan:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# HeMem — faithful to §3.2 + Table 2.
# ---------------------------------------------------------------------------
class HeMemEngine(TieringEngine):
    def __init__(self, config, tier, seed: int = 0):
        super().__init__(config, tier, seed)
        c = self.config
        n = tier.n_pages
        self.read_counts = np.zeros(n, dtype=np.float64)
        self.write_counts = np.zeros(n, dtype=np.float64)
        self.sampling_period = float(c["sampling_period"])
        self.write_sampling_period = float(c["write_sampling_period"])
        self.read_hot = float(c["read_hot_threshold"])
        self.write_hot = float(c["write_hot_threshold"])
        self.cooling_threshold = float(c["cooling_threshold"])
        self.migration_period_ms = float(c["migration_period"])
        self.max_migration_rate_gibs = float(c["max_migration_rate"])
        self.cooling_pages = int(c["cooling_pages"])
        self.hot_ring = int(c["hot_ring_reqs_threshold"])
        self.cold_ring = int(c["cold_ring_reqs_threshold"])
        # cooling sweep state: cursor into the page space + samples since the
        # last cooling trigger
        self._cool_cursor = 0
        self._samples_since_cool = 0.0
        self._mig_credit_ms = 0.0

    #: normalization of the cooling trigger: one trigger fires per
    #: ``cooling_threshold * n_pages / COOL_UNIT_PAGES`` sampled accesses
    COOL_UNIT_PAGES = 16.0

    # -- monitoring (PEBS subsampling) -------------------------------------
    def observe(self, reads, writes, epoch_ms):
        # One PEBS sample per `sampling_period` load events (expected value,
        # Poisson-dispersed — the sampling noise is what makes low sampling
        # frequencies inaccurate for GUPS, §4.2).
        lam_r = reads / self.sampling_period
        lam_w = writes / self.write_sampling_period
        sr = self.rng.poisson(lam_r).astype(np.float64)
        sw = self.rng.poisson(lam_w).astype(np.float64)
        self.samples_last_epoch = float(sr.sum() + sw.sum())
        # cooling is checked while samples are processed (not by the
        # migration thread): every `cooling_threshold` worth of sampled
        # accesses (normalized per COOL_UNIT_PAGES pages of the working set)
        # fires the trigger, and each trigger cools ONE batch of
        # `cooling_pages` pages, advancing the sweep cursor.  Small
        # `cooling_pages` therefore stagger the sweep across triggers —
        # different pages observe the EMA at different phases — while
        # `cooling_pages >= n` cools everything synchronously ("all pages at
        # the same time", the Silo fix of §4.2).
        n = self.tier.n_pages
        trigger = max(self.cooling_threshold * n / self.COOL_UNIT_PAGES, 1.0)
        self._samples_since_cool += self.samples_last_epoch
        k = int(self._samples_since_cool // trigger)
        # samples and cooling interleave within the epoch: a page that gets
        # halved k_eff times mid-accumulation retains factor
        # (2 - 2^-k_eff)/(k_eff + 1) of its newly-added counts
        k_eff = k * min(self.cooling_pages, n) / n
        factor = (2.0 - 2.0 ** (-k_eff)) / (k_eff + 1.0) if k_eff > 0 else 1.0
        # old counts see the k chunked halvings; the new samples arrive
        # interleaved, so they only retain `factor` of their mass
        for _ in range(k):
            self._samples_since_cool -= trigger
            self._cool_one_batch()
        self.read_counts += sr * factor
        self.write_counts += sw * factor

    # -- classification ------------------------------------------------------
    def hot_mask(self) -> np.ndarray:
        return (self.read_counts >= self.read_hot) | (
            self.write_counts >= self.write_hot)

    # -- cooling (batched halving, §3.2) --------------------------------------
    def _cool_one_batch(self) -> None:
        n = self.tier.n_pages
        self.cooling_events += 1
        start = self._cool_cursor if 0 <= self._cool_cursor < n else 0
        end = min(start + self.cooling_pages, n)
        sl = slice(start, end)
        self.read_counts[sl] *= 0.5
        self.write_counts[sl] *= 0.5
        self._cool_cursor = 0 if end >= n else end

    # -- migration thread -------------------------------------------------------
    def plan(self, epoch_ms, max_pages_this_epoch):
        self._mig_credit_ms += epoch_ms
        runs = int(self._mig_credit_ms // self.migration_period_ms)
        if runs <= 0:
            return MigrationPlan.empty()
        self._mig_credit_ms -= runs * self.migration_period_ms

        tier = self.tier
        hot = self.hot_mask()
        heat = self.read_counts + self.write_counts

        # ring capacities scale with the number of thread runs this epoch
        hot_budget = self.hot_ring * runs
        cold_budget = self.cold_ring * runs
        # migration-rate limit (GiB/s) over the epoch
        rate_pages = int(self.max_migration_rate_gibs * (2 ** 30) *
                         (epoch_ms / 1e3) / tier.page_bytes)
        rate_pages = min(rate_pages, max_pages_this_epoch)

        cand_p = np.flatnonzero(hot & ~tier.in_fast & tier.allocated)
        if len(cand_p) > hot_budget:  # ring keeps the hottest requests
            cand_p = cand_p[np.argsort(-heat[cand_p], kind="stable")[:hot_budget]]

        # demotions: HeMem keeps a free-page watermark in DRAM; cold pages are
        # demoted (coldest first) both to satisfy pending promotions and to
        # restore the watermark.  Only *cold* pages are candidates — when the
        # whole working set is hot (e.g. Graph500 BFS), nothing is demoted and
        # migration activity quiesces.
        room = tier.fast_free
        watermark = max(1, tier.fast_capacity // 50)
        pressure = max(0, watermark - room)
        need = max(max(0, len(cand_p) - room), pressure)
        demote = np.zeros(0, dtype=np.int64)
        if need > 0:
            cand_d = np.flatnonzero(~hot & tier.in_fast)
            if len(cand_d):
                order = np.argsort(heat[cand_d], kind="stable")  # coldest first
                demote = cand_d[order[:min(need, cold_budget)]]

        # promotions bounded by (room + demotions) and the rate limit
        n_promote = min(len(cand_p), room + len(demote))
        total_allowed = max(0, rate_pages)
        if n_promote + len(demote) > total_allowed:
            # migration thread moves what the rate allows; demotions make room
            # first (HeMem frees before filling)
            n_demote = min(len(demote), total_allowed)
            demote = demote[:n_demote]
            n_promote = min(n_promote, room + n_demote,
                            total_allowed - n_demote)
        promote = cand_p[np.argsort(-heat[cand_p], kind="stable")[:n_promote]] \
            if n_promote > 0 else np.zeros(0, dtype=np.int64)
        return MigrationPlan(promote=promote, demote=demote)


# ---------------------------------------------------------------------------
# HMSDK / DAMON — region-based monitor (§4.5).
# ---------------------------------------------------------------------------
class HMSDKEngine(TieringEngine):
    def __init__(self, config, tier, seed: int = 0):
        super().__init__(config, tier, seed)
        c = self.config
        self.nr_regions = min(int(c["nr_regions"]), tier.n_pages)
        self.sample_us = float(c["sample_us"])
        self.aggr_us = float(c["aggr_us"])
        self.hot_access_pct = float(c["hot_access_pct"])
        self.cold_aggr_intervals = int(c["cold_aggr_intervals"])
        self.migration_period_ms = float(c["migration_period"])
        self.max_migration_rate_gibs = float(c["max_migration_rate"])
        # equal-size regions over the page index space
        bounds = np.linspace(0, tier.n_pages, self.nr_regions + 1).astype(np.int64)
        self.region_lo = bounds[:-1]
        self.region_hi = bounds[1:]
        self.region_of_page = np.searchsorted(bounds[1:], np.arange(tier.n_pages),
                                              side="right")
        self.nr_accesses = np.zeros(self.nr_regions, dtype=np.float64)
        self.idle_intervals = np.zeros(self.nr_regions, dtype=np.float64)
        self._mig_credit_ms = 0.0

    def observe(self, reads, writes, epoch_ms):
        # DAMON: every sample interval, probe ONE random page per region and
        # check its accessed bit.  Estimate: nr_accesses = hits per
        # aggregation interval.  P(accessed bit set) for a page with rate r
        # accesses/ms over a sample window of sample_ms: 1 - exp(-r*window).
        sample_ms = self.sample_us / 1e3
        nr_samples = max(1, int(round((epoch_ms * 1e3) / self.aggr_us *
                                      (self.aggr_us / self.sample_us))))
        # == samples per epoch (epoch_ms / sample_ms), bounded for cost
        nr_samples = max(1, int(epoch_ms / sample_ms))
        rate = (reads + writes) / max(epoch_ms, 1e-9)  # accesses per ms
        p_hit = 1.0 - np.exp(-rate * sample_ms)
        # Monte-Carlo probe: one random page per region per sample
        hits = np.zeros(self.nr_regions)
        # vectorized: sample K pages per region at once
        K = min(nr_samples, 64)  # cap probes modelled per epoch (DAMON cost cap)
        for k in range(K):
            offs = self.rng.integers(0, np.maximum(self.region_hi - self.region_lo, 1))
            pages = np.minimum(self.region_lo + offs, self.region_hi - 1)
            hits += self.rng.uniform(size=self.nr_regions) < p_hit[pages]
        self.nr_accesses = hits / K  # fraction of probes that hit
        self.idle_intervals = np.where(self.nr_accesses <= 0,
                                       self.idle_intervals + 1, 0.0)
        self.samples_last_epoch = float(nr_samples * self.nr_regions) / 50.0
        # DAMON PT-scanning is cheap vs PEBS interrupts; scale overhead down

    def plan(self, epoch_ms, max_pages_this_epoch):
        self._mig_credit_ms += epoch_ms
        runs = int(self._mig_credit_ms // self.migration_period_ms)
        if runs <= 0:
            return MigrationPlan.empty()
        self._mig_credit_ms -= runs * self.migration_period_ms
        tier = self.tier
        hot_regions = self.nr_accesses >= (self.hot_access_pct / 100.0)
        cold_regions = self.idle_intervals >= self.cold_aggr_intervals
        hot_pages = hot_regions[self.region_of_page]
        cold_pages = cold_regions[self.region_of_page]

        rate_pages = int(self.max_migration_rate_gibs * (2 ** 30) *
                         (epoch_ms / 1e3) / tier.page_bytes)
        rate_pages = min(rate_pages, max_pages_this_epoch)

        cand_p = np.flatnonzero(hot_pages & ~tier.in_fast & tier.allocated)
        # regions with higher estimated rate first; saturated estimates tie,
        # so the order among them is effectively arbitrary — which is what
        # makes the default's migrations "erroneous" (§4.5: ~10M unnecessary
        # pages for XSBench)
        jitter = self.rng.uniform(0.0, 1e-6, size=self.nr_regions)
        est = self.nr_accesses + jitter
        if len(cand_p):
            order = np.argsort(-est[self.region_of_page[cand_p]],
                               kind="stable")
            cand_p = cand_p[order]
        room = tier.fast_free
        need = max(0, min(len(cand_p), rate_pages) - room)
        demote = np.zeros(0, dtype=np.int64)
        if need > 0:
            cand_d = np.flatnonzero(cold_pages & tier.in_fast)
            if len(cand_d) < need:  # fall back to coldest estimated regions
                extra = np.flatnonzero(~hot_pages & ~cold_pages & tier.in_fast)
                order = np.argsort(est[self.region_of_page[extra]],
                                   kind="stable")
                cand_d = np.concatenate([cand_d, extra[order]])
            if len(cand_d) < need:
                # HMSDK's DAMOS demotion scheme ranks regions by estimated
                # coldness even when none is idle: under a saturated monitor
                # the ranking is noise, so pages swap between tiers with no
                # benefit.  This is the erroneous-migration mode the paper
                # observes with default knobs.
                rest = np.flatnonzero(hot_pages & tier.in_fast)
                order = np.argsort(est[self.region_of_page[rest]],
                                   kind="stable")
                cand_d = np.concatenate([cand_d, rest[order]])
            demote = cand_d[:need]
        n_promote = min(len(cand_p), room + len(demote))
        total = n_promote + len(demote)
        if total > rate_pages:
            n_demote = min(len(demote), rate_pages)
            demote = demote[:n_demote]
            n_promote = max(0, min(n_promote, room + n_demote, rate_pages - n_demote))
        return MigrationPlan(promote=cand_p[:n_promote], demote=demote)


# ---------------------------------------------------------------------------
# Memtis — dynamic hot threshold, static everything else (§4.6).
# ---------------------------------------------------------------------------
class MemtisEngine(TieringEngine):
    #: extra kernel time charged per migrated page (ms) — the paper observes
    #: Memtis "spends a significant amount of time in the kernel for page
    #: allocations, page splitting and migrations".
    KERNEL_MS_PER_PAGE = 0.02

    def __init__(self, config, tier, seed: int = 0):
        super().__init__(config, tier, seed)
        c = self.config
        n = tier.n_pages
        self.read_counts = np.zeros(n, dtype=np.float64)
        self.write_counts = np.zeros(n, dtype=np.float64)
        self.sampling_period = float(c["sampling_period"])
        self.write_sampling_period = float(c["write_sampling_period"])
        self.cooling_period_ms = float(c["cooling_period_ms"])
        self.adaptation_period_ms = float(c["adaptation_period_ms"])
        self.migration_period_ms = float(c["migration_period"])
        self.max_migration_rate_gibs = float(c["max_migration_rate"])
        self.warm_pct = float(c["warm_pct"]) / 100.0
        self.hot_threshold = 4.0  # initial; adapted dynamically
        self._cool_credit = 0.0
        self._adapt_credit = 0.0
        self._mig_credit = 0.0

    def observe(self, reads, writes, epoch_ms):
        sr = self.rng.poisson(reads / self.sampling_period).astype(np.float64)
        sw = self.rng.poisson(writes / self.write_sampling_period).astype(np.float64)
        self.read_counts += sr
        self.write_counts += sw
        self.samples_last_epoch = float(sr.sum() + sw.sum())
        self._cool_credit += epoch_ms
        self._adapt_credit += epoch_ms
        if self._cool_credit >= self.cooling_period_ms:
            self._cool_credit = 0.0
            self.read_counts *= 0.5
            self.write_counts *= 0.5
            self.cooling_events += 1
        if self._adapt_credit >= self.adaptation_period_ms:
            self._adapt_credit = 0.0
            self._adapt_threshold()

    def _adapt_threshold(self):
        """Pick the smallest threshold whose hot set fits the fast tier."""
        heat = self.read_counts + self.write_counts
        cap = self.tier.fast_capacity
        if cap <= 0 or heat.size == 0:
            return
        k = min(cap, heat.size - 1)
        part = np.partition(heat, heat.size - 1 - k)
        self.hot_threshold = max(part[heat.size - 1 - k], 1.0)

    def plan(self, epoch_ms, max_pages_this_epoch):
        self._mig_credit += epoch_ms
        runs = int(self._mig_credit // self.migration_period_ms)
        self.overhead_ms_last_epoch = 0.0
        if runs <= 0:
            return MigrationPlan.empty()
        self._mig_credit -= runs * self.migration_period_ms
        tier = self.tier
        heat = self.read_counts + self.write_counts
        hot = heat >= self.hot_threshold
        warm = (~hot) & (heat >= self.hot_threshold * (1.0 - self.warm_pct))

        rate_pages = int(self.max_migration_rate_gibs * (2 ** 30) *
                         (epoch_ms / 1e3) / tier.page_bytes)
        rate_pages = min(rate_pages, max_pages_this_epoch)

        cand_p = np.flatnonzero(hot & ~tier.in_fast & tier.allocated)
        if len(cand_p):
            cand_p = cand_p[np.argsort(-heat[cand_p], kind="stable")]
        room = tier.fast_free
        need = max(0, min(len(cand_p), rate_pages) - room)
        demote = np.zeros(0, dtype=np.int64)
        if need > 0:
            # never demote hot or warm pages (warm class, Memtis improvement #2)
            cand_d = np.flatnonzero(tier.in_fast & ~hot & ~warm)
            if len(cand_d):
                order = np.argsort(heat[cand_d], kind="stable")
                demote = cand_d[order[:need]]
        n_promote = min(len(cand_p), room + len(demote))
        total = n_promote + len(demote)
        if total > rate_pages:
            n_demote = min(len(demote), rate_pages)
            demote = demote[:n_demote]
            n_promote = max(0, min(n_promote, room + n_demote, rate_pages - n_demote))
        plan = MigrationPlan(promote=cand_p[:n_promote], demote=demote)
        self.overhead_ms_last_epoch = plan.n_pages * self.KERNEL_MS_PER_PAGE
        return plan


# ---------------------------------------------------------------------------
# Reference points.
# ---------------------------------------------------------------------------
class StaticEngine(TieringEngine):
    """First-touch placement, never migrates."""

    def observe(self, reads, writes, epoch_ms):
        self.samples_last_epoch = 0.0

    def plan(self, epoch_ms, max_pages_this_epoch):
        return MigrationPlan.empty()


class OracleEngine(TieringEngine):
    """Clairvoyant top-capacity placement with free migrations (CH_opt bound)."""

    zero_cost_migrations = True

    def __init__(self, config, tier, seed: int = 0):
        super().__init__(config, tier, seed)
        self._heat = np.zeros(tier.n_pages, dtype=np.float64)

    def observe(self, reads, writes, epoch_ms):
        self._heat = reads + writes  # perfect, instantaneous knowledge
        self.samples_last_epoch = 0.0

    def plan(self, epoch_ms, max_pages_this_epoch):
        tier = self.tier
        alloc = np.flatnonzero(tier.allocated)
        if len(alloc) == 0:
            return MigrationPlan.empty()
        cap = min(tier.fast_capacity, len(alloc))
        heat_alloc = self._heat[alloc]
        top = alloc[np.argsort(-heat_alloc, kind="stable")[:cap]]
        want = np.zeros(tier.n_pages, dtype=bool)
        want[top] = True
        promote = np.flatnonzero(want & ~tier.in_fast)
        demote = np.flatnonzero(~want & tier.in_fast)
        # keep capacity exact: demote enough to fit the promotions
        need = max(0, len(promote) - (tier.fast_capacity - tier.fast_used) )
        demote = demote[:max(need, 0)] if need > 0 else np.zeros(0, dtype=np.int64)
        return MigrationPlan(promote=promote, demote=demote)


ENGINES = {
    "hemem": HeMemEngine,
    "hmsdk": HMSDKEngine,
    "memtis": MemtisEngine,
    "static": StaticEngine,
    "oracle": OracleEngine,
}


def make_engine(name: str, config: Mapping[str, Any], tier: TierState,
                seed: int = 0) -> TieringEngine:
    try:
        cls = ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; have {sorted(ENGINES)}")
    return cls(config, tier, seed=seed)
