"""Tiering engines, batched over tuning candidates.

:class:`BatchHeMemEngine` is the faithful reimplementation of the mechanism
the paper tunes (§3.2): PEBS-subsampled per-page read/write counters, separate
read/write hotness thresholds, batched count cooling, and a periodic migration
thread with ring-capacity and migration-rate limits.  Every knob of paper
Table 2 is honoured.

:class:`BatchHMSDKEngine` models HMSDK's DAMON-based region monitor (§4.5):
the address space is split into ``nr_regions`` regions, one page per region is
probed per sampling interval, and whole regions are promoted/demoted.  DAMON's
core assumption — all pages of a region share an access frequency — is kept,
which is exactly what makes it fail on GUPS (paper Fig. 12).

:class:`BatchMemtisEngine` models the Memtis baseline (§4.6): the hot
threshold is *dynamically* adapted so the hot set matches fast-tier capacity,
a warm class is excluded from migration, but the cooling period, the migration
period and the (very high, 100k) write sampling period remain static.

:class:`BatchStaticEngine` (first-touch, never migrates) and
:class:`BatchOracleEngine` (clairvoyant placement, free migrations — a
CH_opt-style bound [49]) are the reference points.

Every engine carries a leading **batch axis**: state arrays are
``(B, n_pages)`` and per-config knobs are ``(B,)`` vectors, so one
``observe``/``plan`` round advances B tuning candidates through the same
workload trace.  The historical single-config classes (:class:`HeMemEngine`,
…) remain as thin ``B=1`` wrappers so existing callers don't change.

Two sampling backends are provided (``sampler=``):

* ``"elementwise"`` — per-page ``rng.poisson`` draws, bit-identical to the
  historical implementation (the default for single-config runs);
* ``"sparse"`` — exact-distribution Poisson via superposition: per-page draws
  only where the rate is high, plus total-count + inverse-CDF placement for
  the long cold tail.  Cost scales with *sampled events*, not pages, which is
  what makes batched tuning sweeps fast.

**Two-backend contract.**  The engines in this module are the **numpy
reference**: they consume sequential RNG streams and define the bit-exact
semantics every other path is measured against (batch == sequential, both
samplers equal in distribution).  ``backend="jax"`` swaps in the *compiled*
re-implementation of the same five engines (:mod:`repro.core.engine_jax`):
pure-functional state transitions driven by one jitted ``lax.scan`` over
epochs, with counter-based monitoring draws — equal in distribution but not
stream-compatible, so cross-backend comparisons are statistical for the
sampled engines; migration-plan *selection* itself is exact (the
``repro.kernels.select_topk`` kernel reproduces this module's stable sorts
bit-for-bit).  Changes to the migration/classification logic here must be
mirrored there (the parity tests in ``tests/test_jax_backend.py`` and the
selection conformance suite in ``tests/test_select_topk.py`` pin the two
together).

Engines and samplers are looked up through :mod:`repro.core.registry`
(``@register_engine`` / ``register_sampler``), so new policies plug into
``Study``/``make_batch_engine`` without touching any dispatch code here
(custom engines run on the numpy path; the jax path covers the builtins).
"""

from __future__ import annotations

from typing import Any, List, Mapping, Sequence, Union

import numpy as np

from .knobs import HEMEM_SPACE
from .pages import (BatchTierState, MigrationPlan, TierState,
                    migration_rate_pages)
from .registry import (ENGINES as ENGINE_REGISTRY, SAMPLERS, register_engine,
                       register_sampler)

SeedLike = Union[int, Sequence[int]]

#: rate at/above which the sparse sampler falls back to per-page draws
SPARSE_DENSE_LAM = 4.0


def sparse_poisson(rng: np.random.Generator, base: np.ndarray,
                   inv_period: float) -> np.ndarray:
    """Exact Poisson(``base * inv_period``) sample with cost ∝ events.

    Pages with rate >= :data:`SPARSE_DENSE_LAM` draw per-page Poisson; the
    cold tail draws one total count N ~ Poisson(Σλ) and places the N events
    by inverse-CDF lookup.  By Poisson superposition/splitting the joint
    distribution equals elementwise sampling exactly — only the
    random-stream consumption differs.
    """
    lam = base * inv_period
    n = lam.shape[0]
    if float(lam.sum()) > float(n):
        # not sparse for this config (aggressive sampling period): per-event
        # placement would cost more than per-page draws, so use elementwise
        # directly.  The branch depends only on this config's rates, so
        # per-config streams stay reproducible at any batch size.
        return rng.poisson(lam).astype(np.float64)
    out = np.zeros(n, dtype=np.float64)
    dense = lam >= SPARSE_DENSE_LAM
    idx_d = np.flatnonzero(dense)
    if idx_d.size:
        out[idx_d] = rng.poisson(lam[idx_d])
    lam_c = np.where(dense, 0.0, lam)
    csum = np.cumsum(lam_c)
    tot = float(csum[-1])
    if tot > 0.0:
        n_events = int(rng.poisson(tot))
        if n_events:
            u = rng.uniform(0.0, tot, size=n_events)
            pos = np.searchsorted(csum, u, side="right")
            np.clip(pos, 0, n - 1, out=pos)
            out += np.bincount(pos, minlength=n)
    return out


def _elementwise_draw(rng: np.random.Generator, base: np.ndarray,
                      period: float) -> np.ndarray:
    """Per-page Poisson draws — bit-identical to the historical sampler."""
    return rng.poisson(base / period).astype(np.float64)


def _sparse_draw(rng: np.random.Generator, base: np.ndarray,
                 period: float) -> np.ndarray:
    """Exact-distribution event-driven sampler (see :func:`sparse_poisson`)."""
    return sparse_poisson(rng, base, 1.0 / period)


register_sampler("elementwise", _elementwise_draw)
register_sampler("sparse", _sparse_draw)


def _as_vec(value, batch: int, dtype=np.float64) -> np.ndarray:
    arr = np.asarray(value, dtype=dtype)
    if arr.ndim == 0:
        return np.full(batch, arr, dtype=dtype)
    assert arr.shape == (batch,), f"expected ({batch},), got {arr.shape}"
    return arr


# ---------------------------------------------------------------------------
# Batched protocol
# ---------------------------------------------------------------------------
class BatchTieringEngine:
    """Protocol: observe true per-page access counts, plan migrations — for a
    whole batch of configurations at once."""

    #: if True, the simulator charges no bandwidth/stall cost for migrations
    zero_cost_migrations = False

    def __init__(self, configs: Sequence[Mapping[str, Any]],
                 btier: BatchTierState, seeds: SeedLike = 0,
                 sampler: str = "elementwise"):
        self.configs = [dict(c) for c in configs]
        self.batch = len(self.configs)
        assert self.batch == btier.batch, "one config per tier-state row"
        self.btier = btier
        self._draw = SAMPLERS.get(sampler)
        self.sampler = sampler
        if np.ndim(seeds) == 0:
            seeds = [int(seeds)] * self.batch
        self.rngs = [np.random.default_rng(int(s)) for s in seeds]
        # per-epoch, per-config telemetry the simulator reads back
        self.samples_last_epoch = np.zeros(self.batch)
        self.overhead_ms_last_epoch = np.zeros(self.batch)
        self.cooling_events = np.zeros(self.batch, dtype=np.int64)

    def _knob(self, name: str, dtype=np.float64) -> np.ndarray:
        return np.array([c[name] for c in self.configs], dtype=dtype)

    def max_rates_gibs(self) -> np.ndarray:
        """Per-config migration-rate caps (GiB/s) for the simulator."""
        return np.array([float(c.get("max_migration_rate", 1e9))
                         for c in self.configs])

    def observe(self, reads: np.ndarray, writes: np.ndarray,
                epoch_ms) -> None:
        raise NotImplementedError

    def plan(self, epoch_ms, max_pages_this_epoch) -> List[MigrationPlan]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# HeMem — faithful to §3.2 + Table 2.
# ---------------------------------------------------------------------------
@register_engine("hemem")
class BatchHeMemEngine(BatchTieringEngine):
    #: normalization of the cooling trigger: one trigger fires per
    #: ``cooling_threshold * n_pages / COOL_UNIT_PAGES`` sampled accesses
    COOL_UNIT_PAGES = 16.0

    def __init__(self, configs, btier, seeds: SeedLike = 0,
                 sampler: str = "elementwise"):
        super().__init__(configs, btier, seeds, sampler)
        B, n = self.batch, btier.n_pages
        self.read_counts = np.zeros((B, n), dtype=np.float64)
        self.write_counts = np.zeros((B, n), dtype=np.float64)
        self.sampling_period = self._knob("sampling_period")
        self.write_sampling_period = self._knob("write_sampling_period")
        self.read_hot = self._knob("read_hot_threshold")
        self.write_hot = self._knob("write_hot_threshold")
        self.cooling_threshold = self._knob("cooling_threshold")
        self.migration_period_ms = self._knob("migration_period")
        self.max_migration_rate_gibs = self._knob("max_migration_rate")
        self.cooling_pages = self._knob("cooling_pages", dtype=np.int64)
        self.hot_ring = self._knob("hot_ring_reqs_threshold", dtype=np.int64)
        self.cold_ring = self._knob("cold_ring_reqs_threshold", dtype=np.int64)
        # cooling sweep state: cursor into the page space + samples since the
        # last cooling trigger
        self._cool_cursor = np.zeros(B, dtype=np.int64)
        self._samples_since_cool = np.zeros(B)
        self._mig_credit_ms = np.zeros(B)
        self._trigger = np.maximum(
            self.cooling_threshold * n / self.COOL_UNIT_PAGES, 1.0)

    # -- monitoring (PEBS subsampling) -------------------------------------
    def observe(self, reads, writes, epoch_ms):
        # One PEBS sample per `sampling_period` load events (expected value,
        # Poisson-dispersed — the sampling noise is what makes low sampling
        # frequencies inaccurate for GUPS, §4.2).
        B, n = self.batch, self.btier.n_pages
        if not hasattr(self, "_sr"):
            self._sr = np.empty((B, n))
            self._sw = np.empty((B, n))
        sr, sw = self._sr, self._sw
        for b in range(B):
            rng = self.rngs[b]
            sr[b] = self._draw(rng, reads, self.sampling_period[b])
            sw[b] = self._draw(rng, writes, self.write_sampling_period[b])
        self.samples_last_epoch = sr.sum(axis=1) + sw.sum(axis=1)
        # cooling is checked while samples are processed (not by the
        # migration thread): every `cooling_threshold` worth of sampled
        # accesses (normalized per COOL_UNIT_PAGES pages of the working set)
        # fires the trigger, and each trigger cools ONE batch of
        # `cooling_pages` pages, advancing the sweep cursor.  Small
        # `cooling_pages` therefore stagger the sweep across triggers —
        # different pages observe the EMA at different phases — while
        # `cooling_pages >= n` cools everything synchronously ("all pages at
        # the same time", the Silo fix of §4.2).
        self._samples_since_cool += self.samples_last_epoch
        factor = np.ones(B)
        for b in range(B):
            k = int(self._samples_since_cool[b] // self._trigger[b])
            if k <= 0:
                continue
            # samples and cooling interleave within the epoch: a page that
            # gets halved k_eff times mid-accumulation retains factor
            # (2 - 2^-k_eff)/(k_eff + 1) of its newly-added counts
            k_eff = k * min(int(self.cooling_pages[b]), n) / n
            factor[b] = (2.0 - 2.0 ** (-k_eff)) / (k_eff + 1.0)
            # old counts see the k chunked halvings; the new samples arrive
            # interleaved, so they only retain `factor` of their mass
            for _ in range(k):
                self._samples_since_cool[b] -= self._trigger[b]
                self._cool_one_batch(b)
        if (factor != 1.0).any():  # x * 1.0 == x: skipping is exact
            sr *= factor[:, None]
            sw *= factor[:, None]
        self.read_counts += sr
        self.write_counts += sw

    # -- classification ------------------------------------------------------
    def hot_mask(self) -> np.ndarray:
        return (self.read_counts >= self.read_hot[:, None]) | (
            self.write_counts >= self.write_hot[:, None])

    # -- cooling (batched halving, §3.2) --------------------------------------
    def _cool_one_batch(self, b: int) -> None:
        n = self.btier.n_pages
        self.cooling_events[b] += 1
        cur = int(self._cool_cursor[b])
        start = cur if 0 <= cur < n else 0
        end = min(start + int(self.cooling_pages[b]), n)
        sl = slice(start, end)
        self.read_counts[b, sl] *= 0.5
        self.write_counts[b, sl] *= 0.5
        self._cool_cursor[b] = 0 if end >= n else end

    # -- migration thread -------------------------------------------------------
    def plan(self, epoch_ms, max_pages_this_epoch):
        B = self.batch
        epoch_ms = _as_vec(epoch_ms, B)
        max_pages = _as_vec(max_pages_this_epoch, B, dtype=np.int64)
        self._mig_credit_ms += epoch_ms
        runs = (self._mig_credit_ms // self.migration_period_ms).astype(
            np.int64)
        self._mig_credit_ms -= runs * self.migration_period_ms
        if not (runs > 0).any():
            return [MigrationPlan.empty() for _ in range(B)]

        tier = self.btier
        hot_all = self.hot_mask()
        heat_all = self.read_counts + self.write_counts
        fast_free = tier.fast_free
        # batch-wide candidate masks (one (B, n) pass instead of B passes)
        cand_p_mask = hot_all & ~tier.in_fast & tier.allocated
        cand_d_mask = ~hot_all & tier.in_fast
        # migration-rate limit (GiB/s) over the epoch
        rate_vec = migration_rate_pages(self.max_migration_rate_gibs,
                                        epoch_ms, tier.page_bytes)
        watermark = max(1, tier.fast_capacity // 50)
        plans = []
        for b in range(B):
            if runs[b] <= 0:
                plans.append(MigrationPlan.empty())
                continue
            heat = heat_all[b]

            # ring capacities scale with the number of thread runs this epoch
            hot_budget = int(self.hot_ring[b]) * int(runs[b])
            cold_budget = int(self.cold_ring[b]) * int(runs[b])
            rate_pages = min(int(rate_vec[b]), int(max_pages[b]))

            cand_p = np.flatnonzero(cand_p_mask[b])
            if len(cand_p) > hot_budget:  # ring keeps the hottest requests
                cand_p = cand_p[np.argsort(-heat[cand_p],
                                           kind="stable")[:hot_budget]]

            # demotions: HeMem keeps a free-page watermark in DRAM; cold pages
            # are demoted (coldest first) both to satisfy pending promotions
            # and to restore the watermark.  Only *cold* pages are candidates
            # — when the whole working set is hot (e.g. Graph500 BFS), nothing
            # is demoted and migration activity quiesces.
            room = int(fast_free[b])
            pressure = max(0, watermark - room)
            need = max(max(0, len(cand_p) - room), pressure)
            demote = np.zeros(0, dtype=np.int64)
            if need > 0:
                cand_d = np.flatnonzero(cand_d_mask[b])
                if len(cand_d):
                    order = np.argsort(heat[cand_d], kind="stable")
                    demote = cand_d[order[:min(need, cold_budget)]]

            # promotions bounded by (room + demotions) and the rate limit
            n_promote = min(len(cand_p), room + len(demote))
            total_allowed = max(0, rate_pages)
            if n_promote + len(demote) > total_allowed:
                # migration thread moves what the rate allows; demotions make
                # room first (HeMem frees before filling)
                n_demote = min(len(demote), total_allowed)
                demote = demote[:n_demote]
                n_promote = min(n_promote, room + n_demote,
                                total_allowed - n_demote)
            promote = cand_p[np.argsort(-heat[cand_p],
                                        kind="stable")[:n_promote]] \
                if n_promote > 0 else np.zeros(0, dtype=np.int64)
            plans.append(MigrationPlan(promote=promote, demote=demote))
        return plans


def _mean_draw(rng, base, period):
    """Deterministic mean 'sampler': exactly ``base / period`` accesses per
    page, no dispersion.  The monitoring model of the tiered-KV serving
    engine, whose per-page access counts (attention mass) are measured
    exactly by the attention kernel rather than PEBS-sampled."""
    return np.asarray(base, dtype=np.float64) / float(period)


# ---------------------------------------------------------------------------
# kv-hemem — the TieredKVCache's HeMem analog (serving).  Same Table-2
# machinery as HeMem; monitoring is deterministic mean sampling (see
# _mean_draw).  The compiled counterpart is the *lifted*
# engine_jax.KVHeMemDef, so backend="jax" compiles this engine instead of
# warning and falling back.
# ---------------------------------------------------------------------------
@register_engine("kv-hemem", space=HEMEM_SPACE)
class BatchKVHeMemEngine(BatchHeMemEngine):
    """Batched kv-hemem: :class:`BatchHeMemEngine` with deterministic mean
    monitoring draws (the registered ``sampler`` is accepted but unused —
    serving measures its access counts exactly)."""

    def __init__(self, configs, btier, seeds: SeedLike = 0,
                 sampler: str = "elementwise"):
        super().__init__(configs, btier, seeds, sampler)
        self._draw = _mean_draw


# ---------------------------------------------------------------------------
# HMSDK / DAMON — region-based monitor (§4.5).
# ---------------------------------------------------------------------------
@register_engine("hmsdk")
class BatchHMSDKEngine(BatchTieringEngine):
    def __init__(self, configs, btier, seeds: SeedLike = 0,
                 sampler: str = "elementwise"):
        super().__init__(configs, btier, seeds, sampler)
        if sampler not in ("elementwise", "sparse"):
            # DAMON probes are region-Bernoulli draws, not the per-page
            # Poisson protocol custom samplers implement; reject rather than
            # silently ignoring the registered sampler
            raise ValueError(
                f"hmsdk supports only the builtin 'elementwise'/'sparse' "
                f"samplers, not {sampler!r}")
        B, n = self.batch, btier.n_pages
        self.nr_regions = np.minimum(self._knob("nr_regions", dtype=np.int64),
                                     n)
        self.sample_us = self._knob("sample_us")
        self.aggr_us = self._knob("aggr_us")
        self.hot_access_pct = self._knob("hot_access_pct")
        self.cold_aggr_intervals = self._knob("cold_aggr_intervals",
                                              dtype=np.int64)
        self.migration_period_ms = self._knob("migration_period")
        self.max_migration_rate_gibs = self._knob("max_migration_rate")
        # equal-size regions over the page index space (per config: region
        # counts differ, so the region maps are ragged across the batch)
        self.region_lo: List[np.ndarray] = []
        self.region_hi: List[np.ndarray] = []
        self.region_of_page: List[np.ndarray] = []
        self.nr_accesses: List[np.ndarray] = []
        self.idle_intervals: List[np.ndarray] = []
        for b in range(B):
            R = int(self.nr_regions[b])
            bounds = np.linspace(0, n, R + 1).astype(np.int64)
            self.region_lo.append(bounds[:-1])
            self.region_hi.append(bounds[1:])
            self.region_of_page.append(
                np.searchsorted(bounds[1:], np.arange(n), side="right"))
            self.nr_accesses.append(np.zeros(R, dtype=np.float64))
            self.idle_intervals.append(np.zeros(R, dtype=np.float64))
        self._mig_credit_ms = np.zeros(B)

    def observe(self, reads, writes, epoch_ms):
        # DAMON: every sample interval, probe ONE random page per region and
        # check its accessed bit.  Estimate: nr_accesses = hits per
        # aggregation interval.  P(accessed bit set) for a page with rate r
        # accesses/ms over a sample window of sample_ms: 1 - exp(-r*window).
        B = self.batch
        epoch_ms = _as_vec(epoch_ms, B)
        total = reads + writes
        for b in range(B):
            rng = self.rngs[b]
            sample_ms = self.sample_us[b] / 1e3
            # samples per epoch (epoch_ms / sample_ms), bounded for cost
            nr_samples = max(1, int(epoch_ms[b] / sample_ms))
            rate = total / max(float(epoch_ms[b]), 1e-9)  # accesses per ms
            p_hit = 1.0 - np.exp(-rate * sample_ms)
            R = int(self.nr_regions[b])
            K = min(nr_samples, 64)  # cap probes per epoch (DAMON cost cap)
            if self.sampler == "elementwise":
                # Monte-Carlo probe: one random page per region per sample
                lo, hi = self.region_lo[b], self.region_hi[b]
                hits = np.zeros(R)
                for _ in range(K):
                    offs = rng.integers(0, np.maximum(hi - lo, 1))
                    pages = np.minimum(lo + offs, hi - 1)
                    hits += rng.uniform(size=R) < p_hit[pages]
            else:
                # A probe is Bernoulli(p_hit[U]) with U uniform in the
                # region, i.e. Bernoulli(mean p_hit over the region); K iid
                # probes are exactly Binomial(K, p̄) — one vector draw.
                sizes = self.region_hi[b] - self.region_lo[b]
                pbar = np.add.reduceat(p_hit, self.region_lo[b]) / \
                    np.maximum(sizes, 1)
                hits = rng.binomial(K, np.clip(pbar, 0.0, 1.0)).astype(
                    np.float64)
            self.nr_accesses[b] = hits / K  # fraction of probes that hit
            self.idle_intervals[b] = np.where(
                self.nr_accesses[b] <= 0, self.idle_intervals[b] + 1, 0.0)
            self.samples_last_epoch[b] = float(nr_samples * R) / 50.0
            # DAMON PT-scanning is cheap vs PEBS interrupts; overhead scaled
            # down accordingly

    def plan(self, epoch_ms, max_pages_this_epoch):
        B = self.batch
        epoch_ms = _as_vec(epoch_ms, B)
        max_pages = _as_vec(max_pages_this_epoch, B, dtype=np.int64)
        self._mig_credit_ms += epoch_ms
        runs = (self._mig_credit_ms // self.migration_period_ms).astype(
            np.int64)
        self._mig_credit_ms -= runs * self.migration_period_ms
        tier = self.btier
        fast_free = tier.fast_free
        plans = []
        for b in range(B):
            if runs[b] <= 0:
                plans.append(MigrationPlan.empty())
                continue
            rng = self.rngs[b]
            region_of_page = self.region_of_page[b]
            in_fast = tier.in_fast[b]
            hot_regions = self.nr_accesses[b] >= \
                (self.hot_access_pct[b] / 100.0)
            cold_regions = self.idle_intervals[b] >= self.cold_aggr_intervals[b]
            hot_pages = hot_regions[region_of_page]
            cold_pages = cold_regions[region_of_page]

            rate_pages = migration_rate_pages(
                float(self.max_migration_rate_gibs[b]), float(epoch_ms[b]),
                tier.page_bytes)
            rate_pages = min(rate_pages, int(max_pages[b]))

            cand_p = np.flatnonzero(hot_pages & ~in_fast & tier.allocated[b])
            # regions with higher estimated rate first; saturated estimates
            # tie, so the order among them is effectively arbitrary — which
            # is what makes the default's migrations "erroneous" (§4.5: ~10M
            # unnecessary pages for XSBench)
            jitter = rng.uniform(0.0, 1e-6, size=int(self.nr_regions[b]))
            est = self.nr_accesses[b] + jitter
            if len(cand_p):
                order = np.argsort(-est[region_of_page[cand_p]],
                                   kind="stable")
                cand_p = cand_p[order]
            room = int(fast_free[b])
            need = max(0, min(len(cand_p), rate_pages) - room)
            demote = np.zeros(0, dtype=np.int64)
            if need > 0:
                cand_d = np.flatnonzero(cold_pages & in_fast)
                if len(cand_d) < need:  # fall back to coldest regions
                    extra = np.flatnonzero(~hot_pages & ~cold_pages & in_fast)
                    order = np.argsort(est[region_of_page[extra]],
                                       kind="stable")
                    cand_d = np.concatenate([cand_d, extra[order]])
                if len(cand_d) < need:
                    # HMSDK's DAMOS demotion scheme ranks regions by estimated
                    # coldness even when none is idle: under a saturated
                    # monitor the ranking is noise, so pages swap between
                    # tiers with no benefit.  This is the erroneous-migration
                    # mode the paper observes with default knobs.
                    rest = np.flatnonzero(hot_pages & in_fast)
                    order = np.argsort(est[region_of_page[rest]],
                                       kind="stable")
                    cand_d = np.concatenate([cand_d, rest[order]])
                demote = cand_d[:need]
            n_promote = min(len(cand_p), room + len(demote))
            total = n_promote + len(demote)
            if total > rate_pages:
                n_demote = min(len(demote), rate_pages)
                demote = demote[:n_demote]
                n_promote = max(0, min(n_promote, room + n_demote,
                                       rate_pages - n_demote))
            plans.append(MigrationPlan(promote=cand_p[:n_promote],
                                       demote=demote))
        return plans


# ---------------------------------------------------------------------------
# Memtis — dynamic hot threshold, static everything else (§4.6).
# ---------------------------------------------------------------------------
@register_engine("memtis")
class BatchMemtisEngine(BatchTieringEngine):
    #: extra kernel time charged per migrated page (ms) — the paper observes
    #: Memtis "spends a significant amount of time in the kernel for page
    #: allocations, page splitting and migrations".
    KERNEL_MS_PER_PAGE = 0.02

    def __init__(self, configs, btier, seeds: SeedLike = 0,
                 sampler: str = "elementwise"):
        super().__init__(configs, btier, seeds, sampler)
        B, n = self.batch, btier.n_pages
        self.read_counts = np.zeros((B, n), dtype=np.float64)
        self.write_counts = np.zeros((B, n), dtype=np.float64)
        self.sampling_period = self._knob("sampling_period")
        self.write_sampling_period = self._knob("write_sampling_period")
        self.cooling_period_ms = self._knob("cooling_period_ms")
        self.adaptation_period_ms = self._knob("adaptation_period_ms")
        self.migration_period_ms = self._knob("migration_period")
        self.max_migration_rate_gibs = self._knob("max_migration_rate")
        self.warm_pct = self._knob("warm_pct") / 100.0
        self.hot_threshold = np.full(B, 4.0)  # initial; adapted dynamically
        self._cool_credit = np.zeros(B)
        self._adapt_credit = np.zeros(B)
        self._mig_credit = np.zeros(B)

    def observe(self, reads, writes, epoch_ms):
        B, n = self.batch, self.btier.n_pages
        epoch_ms = _as_vec(epoch_ms, B)
        if not hasattr(self, "_sr"):
            self._sr = np.empty((B, n))
            self._sw = np.empty((B, n))
        sr, sw = self._sr, self._sw
        for b in range(B):
            rng = self.rngs[b]
            sr[b] = self._draw(rng, reads, self.sampling_period[b])
            sw[b] = self._draw(rng, writes, self.write_sampling_period[b])
        self.read_counts += sr
        self.write_counts += sw
        self.samples_last_epoch = sr.sum(axis=1) + sw.sum(axis=1)
        self._cool_credit += epoch_ms
        self._adapt_credit += epoch_ms
        cool = self._cool_credit >= self.cooling_period_ms
        if cool.any():
            self._cool_credit[cool] = 0.0
            self.read_counts[cool] *= 0.5
            self.write_counts[cool] *= 0.5
            self.cooling_events[cool] += 1
        adapt = self._adapt_credit >= self.adaptation_period_ms
        if adapt.any():
            self._adapt_credit[adapt] = 0.0
            self._adapt_threshold(np.flatnonzero(adapt))

    def _adapt_threshold(self, rows: np.ndarray) -> None:
        """Pick the smallest threshold whose hot set fits the fast tier."""
        heat = self.read_counts[rows] + self.write_counts[rows]
        cap = self.btier.fast_capacity
        if cap <= 0 or heat.shape[1] == 0:
            return
        k = min(cap, heat.shape[1] - 1)
        kth = heat.shape[1] - 1 - k
        part = np.partition(heat, kth, axis=1)[:, kth]
        self.hot_threshold[rows] = np.maximum(part, 1.0)

    def plan(self, epoch_ms, max_pages_this_epoch):
        B = self.batch
        epoch_ms = _as_vec(epoch_ms, B)
        max_pages = _as_vec(max_pages_this_epoch, B, dtype=np.int64)
        self._mig_credit += epoch_ms
        runs = (self._mig_credit // self.migration_period_ms).astype(np.int64)
        self.overhead_ms_last_epoch = np.zeros(B)
        self._mig_credit -= runs * self.migration_period_ms
        if not (runs > 0).any():
            return [MigrationPlan.empty() for _ in range(B)]
        tier = self.btier
        heat_all = self.read_counts + self.write_counts
        hot_all = heat_all >= self.hot_threshold[:, None]
        warm_all = (~hot_all) & (
            heat_all >= (self.hot_threshold * (1.0 - self.warm_pct))[:, None])
        fast_free = tier.fast_free
        # batch-wide candidate masks; never demote hot or warm pages (warm
        # class, Memtis improvement #2)
        cand_p_mask = hot_all & ~tier.in_fast & tier.allocated
        cand_d_mask = tier.in_fast & ~hot_all & ~warm_all
        rate_vec = migration_rate_pages(self.max_migration_rate_gibs,
                                        epoch_ms, tier.page_bytes)
        plans = []
        for b in range(B):
            if runs[b] <= 0:
                plans.append(MigrationPlan.empty())
                continue
            heat = heat_all[b]
            rate_pages = min(int(rate_vec[b]), int(max_pages[b]))

            cand_p = np.flatnonzero(cand_p_mask[b])
            if len(cand_p):
                cand_p = cand_p[np.argsort(-heat[cand_p], kind="stable")]
            room = int(fast_free[b])
            need = max(0, min(len(cand_p), rate_pages) - room)
            demote = np.zeros(0, dtype=np.int64)
            if need > 0:
                cand_d = np.flatnonzero(cand_d_mask[b])
                if len(cand_d):
                    order = np.argsort(heat[cand_d], kind="stable")
                    demote = cand_d[order[:need]]
            n_promote = min(len(cand_p), room + len(demote))
            total = n_promote + len(demote)
            if total > rate_pages:
                n_demote = min(len(demote), rate_pages)
                demote = demote[:n_demote]
                n_promote = max(0, min(n_promote, room + n_demote,
                                       rate_pages - n_demote))
            plan = MigrationPlan(promote=cand_p[:n_promote], demote=demote)
            self.overhead_ms_last_epoch[b] = plan.n_pages * \
                self.KERNEL_MS_PER_PAGE
            plans.append(plan)
        return plans


# ---------------------------------------------------------------------------
# Reference points.
# ---------------------------------------------------------------------------
@register_engine("static")
class BatchStaticEngine(BatchTieringEngine):
    """First-touch placement, never migrates."""

    def observe(self, reads, writes, epoch_ms):
        self.samples_last_epoch = np.zeros(self.batch)

    def plan(self, epoch_ms, max_pages_this_epoch):
        return [MigrationPlan.empty() for _ in range(self.batch)]


@register_engine("oracle")
class BatchOracleEngine(BatchTieringEngine):
    """Clairvoyant top-capacity placement with free migrations (CH_opt
    bound)."""

    zero_cost_migrations = True

    def __init__(self, configs, btier, seeds: SeedLike = 0,
                 sampler: str = "elementwise"):
        super().__init__(configs, btier, seeds, sampler)
        self._heat = np.zeros(btier.n_pages, dtype=np.float64)

    def observe(self, reads, writes, epoch_ms):
        self._heat = reads + writes  # perfect, instantaneous knowledge
        self.samples_last_epoch = np.zeros(self.batch)

    def plan(self, epoch_ms, max_pages_this_epoch):
        tier = self.btier
        fast_free = tier.fast_free
        plans = []
        for b in range(self.batch):
            alloc = np.flatnonzero(tier.allocated[b])
            if len(alloc) == 0:
                plans.append(MigrationPlan.empty())
                continue
            in_fast = tier.in_fast[b]
            cap = min(tier.fast_capacity, len(alloc))
            heat_alloc = self._heat[alloc]
            top = alloc[np.argsort(-heat_alloc, kind="stable")[:cap]]
            want = np.zeros(tier.n_pages, dtype=bool)
            want[top] = True
            promote = np.flatnonzero(want & ~in_fast)
            demote = np.flatnonzero(~want & in_fast)
            # demote exactly enough to fit the promotions, then cap the
            # promotions at the post-demotion free capacity so the plan can
            # never overflow the fast tier even when too few demotion
            # candidates exist
            need = max(0, len(promote) - int(fast_free[b]))
            demote = demote[:need] if need > 0 else np.zeros(0,
                                                             dtype=np.int64)
            promote = promote[:int(fast_free[b]) + len(demote)]
            plans.append(MigrationPlan(promote=promote, demote=demote))
        return plans


#: legacy alias — the engine registry replaced this hardcoded map (PR 2).
#: Mostly dict-compatible, except bare ``.get(name)`` raises KeyError with a
#: did-you-mean hint; pass a default (``.get(name, None)``) for dict behavior.
BATCH_ENGINES = ENGINE_REGISTRY


def make_batch_engine(name: str, configs: Sequence[Mapping[str, Any]],
                      btier: BatchTierState, seeds: SeedLike = 0,
                      sampler: str = "elementwise") -> BatchTieringEngine:
    """Instantiate the registered batch engine ``name`` (registry-resolved)."""
    cls = ENGINE_REGISTRY.get(name)
    return cls(configs, btier, seeds=seeds, sampler=sampler)


# ---------------------------------------------------------------------------
# Single-config wrappers (B=1) — the historical interface.
# ---------------------------------------------------------------------------
class TieringEngine:
    """Single-config engine: a thin ``B=1`` wrapper over the batch engine."""

    batch_cls: type = None
    zero_cost_migrations = False

    def __init__(self, config: Mapping[str, Any], tier: TierState,
                 seed: int = 0, sampler: str = "elementwise"):
        self.config = dict(config)
        self.tier = tier
        self._b = self.batch_cls([self.config], tier.batch_state,
                                 seeds=seed, sampler=sampler)
        self.rng = self._b.rngs[0]

    @property
    def batch_engine(self) -> BatchTieringEngine:
        return self._b

    # per-epoch telemetry the simulator reads back
    @property
    def samples_last_epoch(self) -> float:
        return float(self._b.samples_last_epoch[0])

    @property
    def overhead_ms_last_epoch(self) -> float:
        return float(self._b.overhead_ms_last_epoch[0])

    @property
    def cooling_events(self) -> int:
        return int(self._b.cooling_events[0])

    def observe(self, reads: np.ndarray, writes: np.ndarray,
                epoch_ms: float) -> None:
        self._b.observe(reads, writes, np.array([float(epoch_ms)]))

    def plan(self, epoch_ms: float, max_pages_this_epoch: int) -> MigrationPlan:
        return self._b.plan(np.array([float(epoch_ms)]),
                            np.array([int(max_pages_this_epoch)]))[0]


class HeMemEngine(TieringEngine):
    batch_cls = BatchHeMemEngine

    @property
    def read_counts(self) -> np.ndarray:
        return self._b.read_counts[0]

    @property
    def write_counts(self) -> np.ndarray:
        return self._b.write_counts[0]

    def hot_mask(self) -> np.ndarray:
        return self._b.hot_mask()[0]


class HMSDKEngine(TieringEngine):
    batch_cls = BatchHMSDKEngine

    @property
    def nr_regions(self) -> int:
        return int(self._b.nr_regions[0])

    @property
    def nr_accesses(self) -> np.ndarray:
        return self._b.nr_accesses[0]

    @property
    def idle_intervals(self) -> np.ndarray:
        return self._b.idle_intervals[0]

    @property
    def region_of_page(self) -> np.ndarray:
        return self._b.region_of_page[0]


class MemtisEngine(TieringEngine):
    batch_cls = BatchMemtisEngine
    KERNEL_MS_PER_PAGE = BatchMemtisEngine.KERNEL_MS_PER_PAGE

    @property
    def read_counts(self) -> np.ndarray:
        return self._b.read_counts[0]

    @property
    def write_counts(self) -> np.ndarray:
        return self._b.write_counts[0]

    @property
    def hot_threshold(self) -> float:
        return float(self._b.hot_threshold[0])


class StaticEngine(TieringEngine):
    batch_cls = BatchStaticEngine


class OracleEngine(TieringEngine):
    batch_cls = BatchOracleEngine
    zero_cost_migrations = True


#: single-config (B=1) wrapper classes for the builtin engines; engines
#: registered only through :func:`~repro.core.registry.register_engine` get
#: an auto-generated wrapper from :func:`single_engine_cls`.  (Renamed from
#: the historical module-level ``ENGINES`` dict, which collided with the
#: batch-class registry of the same name in :mod:`repro.core.registry`.)
SINGLE_ENGINES = {
    "hemem": HeMemEngine,
    "hmsdk": HMSDKEngine,
    "memtis": MemtisEngine,
    "static": StaticEngine,
    "oracle": OracleEngine,
}


def single_engine_cls(name: str) -> type:
    """The ``B=1`` wrapper class for engine ``name`` (auto-generated for
    engines that registered only a batch class).  The registry is the
    source of truth: re-registering a name invalidates the cached wrapper,
    so the single-config path can never diverge from the batch path."""
    batch_cls = ENGINE_REGISTRY.get(name)
    cls = SINGLE_ENGINES.get(name)
    if cls is None or cls.batch_cls is not batch_cls:
        cls = type(f"Single{batch_cls.__name__}", (TieringEngine,), {
            "batch_cls": batch_cls,
            "zero_cost_migrations": batch_cls.zero_cost_migrations,
        })
        SINGLE_ENGINES[name] = cls
    return cls


def make_engine(name: str, config: Mapping[str, Any], tier: TierState,
                seed: int = 0, sampler: str = "elementwise") -> TieringEngine:
    """Deprecated single-config factory; resolves through the registry."""
    from ._deprecation import warn_deprecated
    warn_deprecated("repro.core.engine.make_engine",
                    "repro.core.registry.ENGINES / Study(spec).run()")
    return single_engine_cls(name)(config, tier, seed=seed, sampler=sampler)
