"""Study: the unified typed front-end over simulate / tune / sweep.

One :class:`~repro.core.specs.ExperimentSpec` in, every call pattern out:

* ``Study(spec).run()`` — simulate the spec's engine config (a single
  :class:`~repro.core.simulator.SimResult`); ``run(configs=[...])`` pushes a
  whole candidate batch through ONE shared workload trace
  (:func:`~repro.core.simulator.run_simulation_batch`);
* ``Study(spec).tune(budget, batch_size)`` — SMAC-BO knob tuning
  (:class:`~repro.core.bo.tuner.TuningSession`), batched per iteration when
  ``batch_size > 1``;
* ``Study(spec).sweep(...)`` — multi-engine × multi-workload grids, each
  (engine, workload) cell evaluated as one batched simulator pass.

Workload traces are built once per Study and shared across evaluations
(builds are deterministic in the spec, so this never changes numerics — it
only removes redundant trace generation the legacy per-call path paid).

Migration table (old call -> new call):

======================================================  ======================================================
old                                                     new
======================================================  ======================================================
``evaluate(eng, cfg, wl, inp, machine, ...)``           ``Study(ExperimentSpec(engine=EngineSpec(eng, cfg),
                                                        workload=WorkloadSpec(wl, inp), ...)).run().total_s``
``evaluate_batch(eng, cfgs, wl, ...)``                  ``Study(spec).run(configs=cfgs)``
``run_simulation(workload, eng, cfg, machine)``         ``Study(spec).run()`` (full ``SimResult``)
``tune_scenario(eng, Scenario(...), budget, ...)``      ``Study(spec).tune(budget=..., batch_size=...)``
``Scenario(workload, inp, machine, ...)``               ``ExperimentSpec`` (+ ``SimOptions`` for seeds/
                                                        sampler/workers/backend)
``make_engine(name, cfg, tier)``                        ``@register_engine(name)`` + ``Study``; the registry
                                                        resolves dispatch
sequential fig-2/fig-9 sweep loops                      ``Study(spec).sweep(engines=..., workloads=...,
                                                        configs=...)``
======================================================  ======================================================
"""

from __future__ import annotations

import dataclasses
from typing import (Any, Dict, List, Mapping, Optional, Sequence, Tuple,
                    Union)

from .bo.tuner import TuningResult, TuningSession
from .knobs import Config, KnobSpace
from .simulator import (Machine, SimResult, get_machine,
                        run_simulation_batch, run_simulation_cells)
from .specs import EngineSpec, ExperimentSpec, SimOptions, WorkloadSpec
from .workloads import Workload, make_workload


@dataclasses.dataclass
class SweepResult:
    """Results of a multi-engine × multi-workload sweep.

    ``cells`` maps ``(engine_name, workload_label)`` to the list of
    :class:`~repro.core.simulator.SimResult` for that cell's config batch
    (one entry per config, in input order).  The workload label is
    ``WorkloadSpec.key``; when a sweep contains several variants of the same
    workload (different threads/scale) the label is extended with
    ``#t<threads>/s<scale>`` so no cell is overwritten.
    """

    cells: Dict[Tuple[str, str], List[SimResult]] = \
        dataclasses.field(default_factory=dict)

    def __getitem__(self, key: Tuple[str, str]) -> List[SimResult]:
        return self.cells[key]

    def __len__(self) -> int:
        return len(self.cells)

    def items(self):
        return self.cells.items()

    def total_s(self) -> Dict[Tuple[str, str], List[float]]:
        """Execution times per cell, one per config."""
        return {k: [r.total_s for r in v] for k, v in self.cells.items()}


class Study:
    """Unified front-end: one spec, three call patterns (run/tune/sweep)."""

    def __init__(self, spec: Optional[ExperimentSpec] = None, *,
                 machine: Optional[Machine] = None, **spec_kwargs):
        if spec is None:
            spec = ExperimentSpec(**spec_kwargs)
        elif spec_kwargs:
            raise TypeError("pass either a spec or spec kwargs, not both")
        if not isinstance(spec, ExperimentSpec):
            raise TypeError(f"expected ExperimentSpec, got {type(spec)!r}")
        if machine is not None and machine.name != spec.machine:
            raise ValueError(f"machine override {machine.name!r} does not "
                             f"match spec.machine {spec.machine!r}")
        self.spec = spec
        # an explicit Machine instance overrides the registry resolution —
        # this is how the legacy shims honour ad-hoc Machine objects whose
        # name collides with a registered profile
        self.machine: Machine = machine if machine is not None \
            else get_machine(spec.machine)
        self._workloads: Dict[Tuple, Workload] = {}

    @property
    def key(self) -> str:
        return self.spec.key

    # -- workload construction (cached; builds are deterministic) ----------
    def workload(self, wspec: Optional[WorkloadSpec] = None) -> Workload:
        wspec = wspec if wspec is not None else self.spec.workload
        threads = wspec.threads if wspec.threads is not None \
            else self.machine.default_threads
        cache_key = (wspec.name, wspec.input_name, threads, wspec.scale,
                     self.spec.options.seed)
        wl = self._workloads.get(cache_key)
        if wl is None:
            wl = make_workload(wspec.name, wspec.input_name, threads=threads,
                               scale=wspec.scale,
                               seed=self.spec.options.seed)
            self._workloads[cache_key] = wl
        return wl

    # -- simulate ----------------------------------------------------------
    def run(self, configs: Optional[Sequence[Mapping[str, Any]]] = None
            ) -> "SimResult | List[SimResult]":
        """Simulate the spec (one ``SimResult``), or a candidate batch.

        With ``configs`` (a sequence of knob configs), all B candidates run
        through one shared workload trace and a list of per-config results
        is returned; configs are used as-is (the optimizer and
        :class:`~repro.core.specs.EngineSpec` produce validated configs).
        """
        opts = self.spec.options
        batch = [self.spec.engine.config] if configs is None \
            else [dict(c) for c in configs]
        results = run_simulation_batch(
            self.workload(), self.spec.engine.name, batch, self.machine,
            fast_slow_ratio=self.spec.fast_slow_ratio, seeds=opts.seed,
            sampler=opts.sampler, record_heatmap=opts.record_heatmap,
            heat_bins=opts.heat_bins,
            fast_capacity_pages=self.spec.fast_capacity_pages,
            backend=opts.backend, crn=opts.crn, workers=opts.workers,
            exact_select=opts.exact_select)
        return results[0] if configs is None else results

    # -- tune --------------------------------------------------------------
    def tune(self, budget: int = 100, batch_size: int = 1, seed: int = 0,
             optimizer: str = "smac", n_init: int = 20,
             random_prob: float = 0.20, verbose: bool = False,
             space: Optional[KnobSpace] = None,
             surrogate: Optional[str] = None,
             acquisition: Optional[str] = None,
             objective: Optional[Any] = None,
             objective_batch: Optional[Any] = None,
             executor: str = "sync", slots: int = 1,
             scheduler: Optional[str] = None,
             journal: Optional[str] = None, resume: bool = False,
             pool: str = "thread", eta: int = 4,
             window: Optional[int] = None,
             workers: Optional[int] = None, retries: int = 1,
             timeout_s: Optional[float] = None,
             faults: Optional[Any] = None,
             heartbeat_s: Optional[float] = None,
             lease_deadline: Optional[int] = None,
             max_respawns: Optional[int] = None,
             fleet_spec: Optional[Any] = None,
             online: bool = False,
             window_epochs: Optional[int] = None,
             hysteresis: float = 0.05,
             dwell_windows: int = 2) -> TuningResult:
        """SMAC-BO tuning of the spec's engine knobs (§3.1).

        ``seed`` seeds the optimizer; the simulation seed stays
        ``spec.options.seed`` (matching how the legacy ``tune_scenario``
        reused one scenario seed across evaluations).  ``batch_size=q > 1``
        evaluates each optimizer round as one vectorized simulator pass
        honouring ``spec.options`` (sampler/workers/backend).  With
        ``spec.options.crn`` set, every candidate is evaluated under common
        random numbers — the compiled backend's counter-based noise is
        shared bitwise across the whole run, so all comparisons the
        optimizer makes are paired — and ``tell_batch(crn=True)`` debiases
        any re-evaluated config against its recorded value (see
        :meth:`~repro.core.bo.smac.SMACOptimizer.tell_batch`).

        ``surrogate``/``acquisition`` select the optimizer's internal
        paths (forest builder ``"reference"|"fast"``, scoring pipeline
        ``"fused"|"legacy"``); the defaults are the compiled hot path.
        The returned :class:`~repro.core.bo.tuner.TuningResult` records a
        per-round ask/fit/eval/tell wall-clock breakdown
        (``round_times``), which ``benchmarks/bo_overhead.py`` turns into
        the BENCH_bo.json before/after receipts.

        ``objective`` (``config -> float``, lower is better) replaces the
        default simulate-the-spec objective with a custom one — e.g. the
        serving benchmark's latency+recall score over a ``TieredKVCache``
        traffic replay — while the spec keeps recording *what* is tuned
        (engine name resolves the knob space, ``self.key`` the scenario).
        ``objective_batch`` (``[config] -> [float]``) is its vectorized
        counterpart, used when ``batch_size > 1``.

        **Async tuning & resume** (``executor="async"``). The study is
        handed to :class:`~repro.core.tune_service.TuneService`: ``slots``
        evaluation slots stay saturated with trials (no per-round
        barrier — a new trial is asked the moment the ask-ahead window
        has room), results are committed in canonical creation order, and
        every decision (ask, rung, tell) happens at commit time, so the
        whole study is a deterministic function of its parameters no
        matter how completions interleave.  At ``slots=1,
        scheduler=None`` this reproduces the synchronous path's incumbent
        bit-identically.  Knobs:

        * ``slots`` — evaluation-slot count; ``pool`` picks the slot
          backend (``"thread"`` default, ``"process"`` for the
          simulator's persistent worker pool).
        * ``window`` — ask-ahead depth (default ``slots``): a window
          larger than ``slots`` chunks several asks into one
          ``ask_batch`` call (one surrogate fit per chunk, amortized
          like the sync ``batch_size=q`` path) while the slots stay
          saturated.
        * ``scheduler="asha"`` — successive-halving early stopping over
          ¼/½/full-epoch rungs (``eta`` controls the promotion
          fraction).  Trials the scheduler stops early are told their
          value extrapolated to full budget; on the compiled backend
          promoted trials resume mid-run from the epoch-loop checkpoint
          (the scan carry) instead of re-simulating.  Incompatible with
          custom ``objective=`` (partial-epoch values come from the
          simulator).
        * ``journal=<path>`` — JSON-lines study journal recording every
          ask/eval/rung/tell/fail decision with the replayable spec
          (schema: :mod:`repro.core.tune_service.journal`;
          ``tools/journal_schema.py`` validates it standalone).  With
          ``resume=True`` a killed study re-runs the control loop using
          the journal as an evaluation cache and continues exactly where
          it died — the resumed journal is byte-identical to an
          uninterrupted run's.
        * failures in the objective or shard workers mark that trial
          ``FAILED`` (config + traceback journaled), skip its tell, and
          keep the executor saturated — one bad config cannot kill a
          study.

        The async path returns an
        :class:`~repro.core.tune_service.AsyncTuningResult` (a
        ``TuningResult`` plus the trial table, slot-utilization and
        ASHA-savings receipts); ``benchmarks/study_async.py`` turns those
        into the BENCH_study.json wall-clock receipts.

        **Fault-tolerant fleet tuning** (``executor="fleet",
        workers=N``).  The same deterministic control loop, but the
        evaluation slots are N *remote worker processes* driven by a
        lease-and-commit coordinator
        (:class:`~repro.core.tune_service.FleetExecutor`) that survives
        the fleet misbehaving.  Each dispatched work unit carries a
        lease; the worker heartbeats it every ``heartbeat_s`` while the
        segment runs.  A lease silent for ``lease_deadline`` heartbeats
        (a wedged host), a dead worker (crash/SIGKILL — detected
        immediately), or a lost result message expires the lease and the
        unit is **re-issued** to another worker with backoff.  Duplicate
        execution is safe *because* the study is deterministic: a unit is
        a pure function of its canonical coordinates (seed, batch offset,
        segment bounds), so both executions return the same bits — the
        first result to commit wins, and the late twin is asserted
        bitwise-equal (a free placement-invariance check on every
        straggler).  Lease lifecycle events
        (``lease``/``expire``/``reissue``) are journaled at the unit's
        *commit* point (wall-clock-free, no worker ids), so fleet
        journals — including kill/resume byte-identity — behave exactly
        like local ones.  Knobs:

        * ``workers`` — fleet size (defaults to ``slots``); ``pool``
          picks the transport: ``"process"`` (workers spawned on this
          box) or ``"socket"`` (workers connect over TCP via ``python -m
          repro.core.tune_service.worker --connect HOST:PORT``).
        * ``fleet_spec`` — a frozen
          :class:`~repro.core.tune_service.FleetSpec` (implies
          ``pool="socket"``): ONE JSON artifact carrying the bind
          address, the shared ``auth_key``, worker count/hosts and the
          transport caps.  ``tools/fleet_launch.py`` brings up the
          matching workers (local subprocesses, or printed per-host
          commands) and health-checks every greet.  The socket transport
          is authenticated end to end: every frame is HMAC-SHA256-signed
          with the spec's key, length-capped *before* allocation,
          replay-protected by per-connection sequence numbers, and
          bounded in read time — a worker must present a signed hello
          before any unit is leased, so the old "only connect workers to
          a coordinator you trust" caveat is replaced by key possession.
          Invalid frames are journaled as ``reject`` events and drop the
          connection; a worker whose link drops re-dials with backoff
          and has its in-flight lease re-attached (``reconnect``) or
          safely expired (first-commit-wins absorbs the duplicate).  The
          auth key is a secret: it never enters the journal — keep spec
          files out of version control.
        * ``scheduler="asha"`` composes with the fleet: rung units
          re-derive their epoch prefix by re-running ``[0, hi)`` from
          scratch (bitwise-identical to the checkpointed path — partial
          carries never travel over the wire), so promotion/early-stop
          decisions, heartbeat expiry, straggler re-issue and
          kill/resume all compose unchanged, and the incumbent matches
          the async-executor ASHA run bitwise.
        * ``timeout_s`` — per-unit evaluation bound: a hung objective
          becomes an ``{"error": "timeout..."}`` result (then a retry /
          FAILED trial) instead of wedging the study.  Also honoured by
          the local async executor.
        * ``retries`` — bounded per-trial retry budget (default 1): a
          transient fault (worker crash that exhausted its lease
          attempts, timeout, flaky objective) resubmits the trial's
          segment once before the trial is journaled FAILED, as a
          deterministic journaled ``retry`` event.  Also honoured by the
          local async executor.
        * ``heartbeat_s`` / ``lease_deadline`` / ``max_respawns`` —
          heartbeat cadence, lease deadline in *missed-heartbeat counts*
          (the journal stays wall-clock-free), and the respawn budget for
          dead process workers (a respawn promotes a booted hot-spare
          worker when one is up, keeping the interpreter boot off the
          slot critical path).  When the live fleet hits zero the
          coordinator degrades gracefully to a local slot — slower,
          never wedged.
        * ``faults`` — a
          :class:`~repro.core.tune_service.FaultPlan` of injected worker
          faults (kill/stall/hang/drop/dup/delay, plus the socket
          transport's corrupt/truncate/replay/partition frame faults and
          ``net_delay_s`` link latency, keyed by unit + attempt) for
          robustness testing; see :mod:`repro.core.tune_service.faults`.

        **Online re-tuning under drift** (``online=True,
        window_epochs=W``).  For phase-shifting workloads
        (:class:`~repro.core.drift.DriftSpec`) the study becomes a
        sliding-window control loop (:mod:`repro.core.tune_online`)
        instead of a one-shot search.  The contract:

        * *window*: every ``window_epochs`` epochs, ONE compiled CRN
          segment evaluates ``[deployed] + batch_size`` candidates from
          the deployed system's checkpoint — row 0 is the system's
          actual trajectory, the rest are paired what-if-we-switched
          counterfactuals.  ``budget`` caps total candidate evaluations.
        * *warm restart*: a detected phase change (sampled-histogram
          divergence or surrogate-residual blowup) REPLACES the
          optimizer with a fresh one seeded with the prior elites
          (``SMACOptimizer(seed_configs=...)``), so re-tuning starts
          from previously good configs, not from scratch — and stale
          observations cannot mislead the new phase's forest.
        * *hysteresis*: a config switch is applied only when the best
          candidate beats the deployed config by more than
          ``hysteresis`` (relative margin) AND ``dwell_windows`` windows
          have passed since the last switch — config thrashing is
          structurally impossible, not just unlikely.

        Requires ``SimOptions(backend='jax', crn=True)`` and
        ``executor='sync'``; ``journal=``/``resume=`` give the same
        byte-identical kill/resume contract as async studies.  Returns
        an :class:`~repro.core.tune_online.OnlineTuningResult` (window
        timeline + switch/detection/thrash receipts);
        ``benchmarks/drift.py`` turns those into the BENCH_drift.json
        time-to-readapt and cumulative-slowdown receipts.
        """
        if online:
            from .tune_online import OnlineTuner
            if executor != "sync":
                raise ValueError(
                    "online=True runs its own window loop; it is "
                    "incompatible with executor='async'/'fleet'")
            if window_epochs is None:
                raise ValueError(
                    "online=True requires window_epochs=W (the re-tuning "
                    "window length in epochs)")
            if scheduler is not None or objective is not None \
                    or objective_batch is not None:
                raise ValueError(
                    "online=True is incompatible with scheduler=/"
                    "objective=: the window loop needs the simulator's "
                    "segment checkpoints")
            tuner = OnlineTuner(
                self, window_epochs=window_epochs, batch_size=batch_size,
                budget=budget, seed=seed, n_init=n_init,
                hysteresis=hysteresis, dwell_windows=dwell_windows,
                space=space, journal=journal, resume=resume,
                verbose=verbose)
            return tuner.run()
        if window_epochs is not None:
            raise ValueError("window_epochs requires online=True")
        if executor in ("async", "fleet"):
            from .tune_service import TuneService
            if batch_size != 1 or objective_batch is not None:
                raise ValueError(
                    "executor='async' replaces per-round batching with "
                    "slot saturation; use slots=N instead of batch_size")
            service = TuneService(
                self, budget=budget, slots=slots, scheduler=scheduler,
                seed=seed, optimizer=optimizer, n_init=n_init,
                random_prob=random_prob, space=space, surrogate=surrogate,
                acquisition=acquisition, objective=objective,
                journal=journal, resume=resume, pool=pool, eta=eta,
                window=window, verbose=verbose,
                executor="fleet" if executor == "fleet" else "local",
                workers=workers, retries=retries, timeout_s=timeout_s,
                faults=faults, heartbeat_s=heartbeat_s,
                lease_deadline=lease_deadline, max_respawns=max_respawns,
                fleet_spec=fleet_spec)
            return service.run()
        if executor != "sync":
            raise ValueError(f"unknown executor {executor!r}; expected "
                             f"'sync', 'async' or 'fleet'")
        if scheduler is not None or slots != 1 or journal is not None \
                or resume or window is not None or workers is not None \
                or timeout_s is not None or faults is not None \
                or heartbeat_s is not None or lease_deadline is not None \
                or max_respawns is not None or fleet_spec is not None:
            raise ValueError(
                "slots/scheduler/journal/resume/window/workers/timeout_s/"
                "faults/heartbeat_s/lease_deadline/max_respawns/fleet_spec "
                "require executor='async' or 'fleet'")
        if objective is None:
            def objective(config: Config) -> float:
                return self.run(configs=[config])[0].total_s

            if objective_batch is None:
                def objective_batch(configs: Sequence[Config]
                                    ) -> List[float]:
                    return [r.total_s for r in self.run(configs=configs)]

        session = TuningSession(
            self.spec.engine.name, objective, scenario_key=self.key,
            space=space, optimizer=optimizer, budget=budget, seed=seed,
            n_init=n_init, random_prob=random_prob, batch_size=batch_size,
            objective_batch=objective_batch if batch_size > 1 else None,
            crn=self.spec.options.crn, surrogate=surrogate,
            acquisition=acquisition)
        return session.run(verbose=verbose)

    # -- sweep -------------------------------------------------------------
    def sweep(self, grid: Optional[Mapping[str, Sequence[Any]]] = None, *,
              engines: Optional[Sequence[Union[EngineSpec, str]]] = None,
              workloads: Optional[Sequence[Union[WorkloadSpec, str]]] = None,
              configs: Optional[Sequence[Mapping[str, Any]]] = None,
              ) -> SweepResult:
        """Evaluate a multi-engine × multi-workload grid in batched passes.

        ``grid`` may bundle the axes as ``{"engines": [...], "workloads":
        [...], "configs": [...]}``; keyword arguments override.  Axes default
        to the spec's engine/workload; bare workload *names* inherit the
        spec's threads and scale (pass full ``WorkloadSpec``s to vary them).  ``configs`` (shared across engines)
        defaults to each engine spec's own config, so ``sweep(engines=[...],
        workloads=[...])`` compares engines at their spec'd settings.

        All (engine, workload) cells are submitted to ONE shared work queue
        (:func:`~repro.core.simulator.run_simulation_cells`): with
        ``workers > 1`` the process pool schedules config shards across
        cells, so it stays saturated even when individual cells are smaller
        than the worker count — nothing is evaluated sequentially per
        config and there is no per-cell barrier.
        """
        grid = dict(grid or {})
        engines = engines if engines is not None else grid.get("engines")
        workloads = workloads if workloads is not None \
            else grid.get("workloads")
        configs = configs if configs is not None else grid.get("configs")
        base_ws = self.spec.workload

        def _ws(w):
            if isinstance(w, str):  # same threads/scale, different workload
                return WorkloadSpec(w, threads=base_ws.threads,
                                    scale=base_ws.scale)
            return WorkloadSpec.coerce(w)

        espcs = [EngineSpec.coerce(e) for e in engines] \
            if engines is not None else [self.spec.engine]
        wspcs = [_ws(w) for w in workloads] \
            if workloads is not None else [base_ws]
        opts = self.spec.options
        # disambiguate same-name workload variants (threads/scale sweeps) so
        # cells never overwrite each other
        base_keys = [w.key for w in wspcs]
        labels = [w.key if base_keys.count(w.key) == 1
                  else f"{w.key}#t{w.threads}/s{w.scale}" for w in wspcs]
        if len(set(labels)) != len(labels):
            raise ValueError(f"duplicate workload specs in sweep: {labels}")
        cell_keys = []
        cells = []
        for ws, wlabel in zip(wspcs, labels):
            wl = self.workload(ws)
            for es in espcs:
                batch = [dict(c) for c in configs] if configs is not None \
                    else [es.config]
                cell_keys.append((es.name, wlabel))
                cells.append((wl, es.name, batch))
        results = run_simulation_cells(
            cells, self.machine, fast_slow_ratio=self.spec.fast_slow_ratio,
            seeds=opts.seed, sampler=opts.sampler,
            record_heatmap=opts.record_heatmap, heat_bins=opts.heat_bins,
            fast_capacity_pages=self.spec.fast_capacity_pages,
            backend=opts.backend, crn=opts.crn, workers=opts.workers,
            exact_select=opts.exact_select)
        out = SweepResult()
        for key, res in zip(cell_keys, results):
            out.cells[key] = res
        return out
