"""Array-native random-forest fit + fused EI acquisition (the BO hot path).

PRs 1-4 compiled the *evaluation* side of the paper's tuning loop; this
module compiles the *optimizer* side:

* :func:`fit_forest_fast` — level-synchronous CART growth: one vectorized
  numpy pass per depth level evaluates the exact best splits for all
  ``trees x frontier nodes x sampled features`` at once and emits flat
  ``(T, max_nodes)`` arrays (``feature/threshold/left/right/value``)
  directly — no per-node Python recursion, no ``_Node`` objects.
* :func:`predict_forest` — batched gather-based descent: every candidate
  row walks all ``T`` trees level-synchronously on the flat arrays.
* :func:`suggest_topq` — the fused acquisition: tree descent + mean/std
  moments + vectorized-erf Expected Improvement + exact top-q selection
  (via :func:`repro.kernels.ops.topk_mask`, the promote side of the PR 4
  ``select_topk`` kernel) in ONE jitted jax function, with a pure-numpy
  fallback when jax is absent.

Determinism contract (the ``surrogate="reference"|"fast"`` switch in
:mod:`repro.core.bo.rf` relies on it): both builders consume identical
randomness — the bootstrap matrix is drawn up front by the caller, and the
per-node feature subsets come from :func:`feature_subsets`, a counter-based
splitmix64 hash of ``(seed, tree, heap-node)``.  No sequential RNG state is
threaded through tree growth, so the recursive reference builder (DFS
order) and this level-synchronous builder (BFS order) draw IDENTICAL
subsets and produce bit-identical trees.

EI scores are cast to float32 before top-q selection (matching the
``select_topk`` kernel's key dtype) on BOTH backends, so ties are broken
by candidate index consistently across numpy and jax.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Optional, Tuple

import numpy as np

import importlib.util

# availability probe only — jax itself is imported lazily (inside the jax
# acquisition path), so `import repro.core` stays jax-free for numpy users
_HAS_JAX = importlib.util.find_spec("jax") is not None

#: pin the acquisition backend ("jax" | "numpy"); None dispatches like
#: ``repro.kernels.ops``: the jitted path on TPU, numpy on CPU hosts (where
#: XLA compile time dwarfs the milliseconds a paper-scale 512-candidate
#: pool costs to score eagerly — the jitted path is still fully tested on
#: CPU by pinning BACKEND)
BACKEND: Optional[str] = None

#: node-variance floor below which a node is a leaf (matches the historical
#: ``y.std() < 1e-12`` termination: var < 1e-24)
_MIN_NODE_VAR = 1e-24

_SQRT2 = math.sqrt(2.0)
_INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)


@lru_cache(maxsize=1)
def _on_tpu() -> bool:
    # cached: jax.default_backend() costs tens of ms per query on CPU, and
    # this runs on every suggestion round
    if not _HAS_JAX:
        return False
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def acquisition_backend() -> str:
    """The backend :func:`suggest_topq` resolves to right now."""
    if BACKEND in ("jax", "numpy"):
        return BACKEND
    return "jax" if _on_tpu() else "numpy"


# ---------------------------------------------------------------------------
# counter-based feature subsets (shared by both builders)
# ---------------------------------------------------------------------------

_U = np.uint64


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer on uint64 arrays (wrapping arithmetic)."""
    x = (x + _U(0x9E3779B97F4A7C15)).astype(np.uint64)
    x = (x ^ (x >> _U(30))) * _U(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> _U(27))) * _U(0x94D049BB133111EB)
    return x ^ (x >> _U(31))


def feature_subsets(feat_seed: int, tree, heap, d: int, mf: int) -> np.ndarray:
    """Deterministic feature subset for the split attempt at heap node
    ``heap`` (root = 1, children ``2h``/``2h+1``) of tree ``tree``.

    Returns the first ``mf`` positions of a pseudo-random permutation of
    ``range(d)`` — the SAME permutation regardless of the order nodes are
    visited in, which is what lets the DFS reference builder and the BFS
    fast builder agree bit-for-bit.  ``tree``/``heap`` may be scalars or
    equal-shape arrays; the result gains a trailing ``(mf,)`` axis.
    """
    tree = np.asarray(tree, dtype=np.uint64)
    heap = np.asarray(heap, dtype=np.uint64)
    j = np.arange(d, dtype=np.uint64)
    key = (_U(feat_seed)
           ^ _mix64(tree[..., None] * _U(0x9E3779B97F4A7C15)
                    + heap[..., None] * _U(0xC2B2AE3D27D4EB4F)
                    + j))
    order = np.argsort(_mix64(key), axis=-1, kind="stable")
    return order[..., :mf].astype(np.int64)


# ---------------------------------------------------------------------------
# flat forest container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FlatForest:
    """A fitted forest as flat per-tree node arrays (DFS pre-order).

    Leaves have ``feature < 0``; padding slots beyond ``n_nodes[t]`` are
    leaves too and are never reached by descent (descent starts at node 0).
    """

    feature: np.ndarray    # (T, M) int64, -1 = leaf
    threshold: np.ndarray  # (T, M) float64
    left: np.ndarray       # (T, M) int64
    right: np.ndarray      # (T, M) int64
    value: np.ndarray      # (T, M) float64 (normalized-target leaf means)
    n_nodes: np.ndarray    # (T,) int64
    max_depth: int

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]


# ---------------------------------------------------------------------------
# level-synchronous fit
# ---------------------------------------------------------------------------


def _pack_rows(mem: np.ndarray, cond: np.ndarray) -> Tuple[np.ndarray,
                                                           np.ndarray]:
    """Per row: member ids where ``cond``, packed left in order, -1 padded."""
    order = np.argsort(~cond, axis=1, kind="stable")
    packed = np.take_along_axis(mem, order, axis=1)
    sizes = cond.sum(axis=1)
    keep = np.arange(mem.shape[1])[None, :] < sizes[:, None]
    return np.where(keep, packed, -1), sizes


def fit_forest_fast(X: np.ndarray, y: np.ndarray, boot: np.ndarray,
                    feat_seed: int, max_depth: int, min_leaf: int,
                    max_features: int) -> FlatForest:
    """Grow all ``T`` trees level-synchronously from pre-drawn bootstraps.

    One vectorized pass per depth level: every frontier node of every tree
    sorts its samples along every feature, computes exact split SSE scores
    from padded sequential cumsums (bit-identical to the per-node reference
    arithmetic), picks the best (score, subset-position, position-in-sort)
    lexicographically, and partitions.  Nodes are emitted in creation (BFS)
    order and renumbered to DFS pre-order at the end so the flat arrays are
    directly comparable with the recursive reference builder's.
    """
    T, n = boot.shape
    d = X.shape[1]
    mf = min(max_features, d)
    Xb = X[boot]                      # (T, n, d)
    yb = y[boot]                      # (T, n)

    rec_tree, rec_feat, rec_thr = [], [], []
    rec_left, rec_right, rec_val = [], [], []
    next_id = T

    f_tree = np.arange(T, dtype=np.int64)
    f_heap = np.ones(T, dtype=np.uint64)
    f_mem = np.tile(np.arange(n, dtype=np.int64)[None, :], (T, 1))
    f_size = np.full(T, n, dtype=np.int64)

    depth = 0
    while f_tree.size:
        K, L = f_mem.shape
        ar = np.arange(K)
        valid = f_mem >= 0
        memc = np.maximum(f_mem, 0)
        yn = np.where(valid, yb[f_tree[:, None], memc], 0.0)
        c1 = np.cumsum(yn, axis=1)
        c2 = np.cumsum(yn * yn, axis=1)
        tot1 = c1[ar, f_size - 1]
        tot2 = c2[ar, f_size - 1]
        node_val = tot1 / f_size
        sse = tot2 - tot1 ** 2 / f_size
        attempt = ((depth < max_depth) & (f_size >= 2 * min_leaf)
                   & (sse >= f_size * _MIN_NODE_VAR))

        feat_out = np.full(K, -1, dtype=np.int64)
        thr_out = np.zeros(K)
        left_out = np.full(K, -1, dtype=np.int64)
        right_out = np.full(K, -1, dtype=np.int64)

        new_tree = new_heap = new_mem = new_size = None
        S = np.flatnonzero(attempt)
        if S.size:
            s = S.size
            sizes_s = f_size[S]
            feats = feature_subsets(feat_seed, f_tree[S], f_heap[S], d, mf)
            # gather ONLY each node's sampled feature columns: (s, L, mf)
            Xn = np.where(valid[S][:, :, None],
                          Xb[f_tree[S][:, None, None], memc[S][:, :, None],
                             feats[:, None, :]], np.inf)
            yn_s = yn[S]
            order = np.argsort(Xn, axis=1, kind="stable")
            xs = np.take_along_axis(Xn, order, axis=1)
            ys = np.take_along_axis(
                np.broadcast_to(yn_s[:, :, None], Xn.shape), order, axis=1)
            cs1 = np.cumsum(ys, axis=1)
            cs2 = np.cumsum(ys ** 2, axis=1)
            lastix = np.broadcast_to((sizes_s - 1)[:, None, None], (s, 1, mf))
            t1 = np.take_along_axis(cs1, lastix, axis=1)       # (s, 1, mf)
            t2 = np.take_along_axis(cs2, lastix, axis=1)

            kk = np.arange(1, L, dtype=np.int64)               # left counts
            nr = sizes_s[:, None] - kk[None, :]                # (s, L-1)
            nr_safe = np.maximum(nr, 1)
            left_sse = cs2[:, :-1, :] - cs1[:, :-1, :] ** 2 / kk[None, :, None]
            right_sse = ((t2 - cs2[:, :-1, :])
                         - (t1 - cs1[:, :-1, :]) ** 2
                         / nr_safe[:, :, None])
            ok = ((kk[None, :] >= min_leaf)
                  & (kk[None, :] <= sizes_s[:, None] - min_leaf))
            ok3 = ok[:, :, None] & (xs[:, :-1, :] < xs[:, 1:, :])
            scores = np.where(ok3, left_sse + right_sse, np.inf)

            jbest = np.argmin(scores, axis=1)                  # (s, mf)
            smin = np.take_along_axis(scores, jbest[:, None, :],
                                      axis=1)[:, 0, :]         # (s, mf)
            fpos = np.argmin(smin, axis=1)       # first-min in subset order
            best_score = smin[np.arange(s), fpos]
            has_split = np.isfinite(best_score)
            fbest = feats[np.arange(s), fpos]
            kbest = jbest[np.arange(s), fpos] + 1              # left count
            lo_x = xs[np.arange(s), kbest - 1, fpos]
            hi_x = xs[np.arange(s), kbest, fpos]
            thr = 0.5 * (lo_x + hi_x)

            S2 = np.flatnonzero(has_split)
            if S2.size:
                s2 = S2.size
                rowsS = S[S2]
                xf = np.take_along_axis(
                    Xn[S2], fpos[S2][:, None, None], axis=2)[:, :, 0]
                go_left = xf <= thr[S2][:, None]
                condL = valid[rowsS] & go_left
                condR = valid[rowsS] & ~go_left
                memL, nL = _pack_rows(f_mem[rowsS], condL)
                memR, nR = _pack_rows(f_mem[rowsS], condR)

                left_ids = next_id + 2 * np.arange(s2, dtype=np.int64)
                right_ids = left_ids + 1
                next_id += 2 * s2
                feat_out[rowsS] = fbest[S2]
                thr_out[rowsS] = thr[S2]
                left_out[rowsS] = left_ids
                right_out[rowsS] = right_ids

                Lnew = int(max(nL.max(), nR.max()))
                new_tree = np.repeat(f_tree[rowsS], 2)
                new_heap = np.empty(2 * s2, dtype=np.uint64)
                new_heap[0::2] = f_heap[rowsS] * _U(2)
                new_heap[1::2] = f_heap[rowsS] * _U(2) + _U(1)
                new_mem = np.empty((2 * s2, Lnew), dtype=np.int64)
                new_mem[0::2] = memL[:, :Lnew]
                new_mem[1::2] = memR[:, :Lnew]
                new_size = np.empty(2 * s2, dtype=np.int64)
                new_size[0::2] = nL
                new_size[1::2] = nR

        rec_tree.append(f_tree)
        rec_feat.append(feat_out)
        rec_thr.append(thr_out)
        rec_left.append(left_out)
        rec_right.append(right_out)
        rec_val.append(node_val)

        if new_tree is None:
            break
        f_tree, f_heap, f_mem, f_size = new_tree, new_heap, new_mem, new_size
        depth += 1

    tree_all = np.concatenate(rec_tree)
    feat_all = np.concatenate(rec_feat)
    thr_all = np.concatenate(rec_thr)
    left_all = np.concatenate(rec_left)
    right_all = np.concatenate(rec_right)
    val_all = np.concatenate(rec_val)

    # DFS pre-order renumbering, level-synchronously: subtree sizes flow
    # bottom-up, then pre-order indices top-down (left = parent + 1,
    # right = parent + 1 + size(left subtree)) — no per-node Python walk.
    level_ids = []
    start = 0
    for level in rec_tree:
        level_ids.append(np.arange(start, start + level.size))
        start += level.size
    split = feat_all >= 0
    size_all = np.ones(start, dtype=np.int64)
    for ids in reversed(level_ids):
        s = ids[split[ids]]
        size_all[s] = 1 + size_all[left_all[s]] + size_all[right_all[s]]
    dfs_all = np.zeros(start, dtype=np.int64)
    for ids in level_ids:
        s = ids[split[ids]]
        dfs_all[left_all[s]] = dfs_all[s] + 1
        dfs_all[right_all[s]] = dfs_all[s] + 1 + size_all[left_all[s]]

    counts = np.bincount(tree_all, minlength=T)
    M = int(counts.max())
    F = np.full((T, M), -1, dtype=np.int64)
    TH = np.zeros((T, M))
    LC = np.full((T, M), -1, dtype=np.int64)
    RC = np.full((T, M), -1, dtype=np.int64)
    V = np.zeros((T, M))
    F[tree_all, dfs_all] = feat_all
    TH[tree_all, dfs_all] = thr_all
    V[tree_all, dfs_all] = val_all
    LC[tree_all[split], dfs_all[split]] = dfs_all[left_all[split]]
    RC[tree_all[split], dfs_all[split]] = dfs_all[right_all[split]]
    return FlatForest(feature=F, threshold=TH, left=LC, right=RC, value=V,
                      n_nodes=counts.astype(np.int64), max_depth=max_depth)


# ---------------------------------------------------------------------------
# batched descent (numpy)
# ---------------------------------------------------------------------------


def predict_forest(forest: FlatForest, X: np.ndarray,
                   trees: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-tree predictions ``(T, N)`` via level-synchronous gather descent.

    All rows of ``X`` walk all trees at once; leaf assignment is identical
    to the per-row reference walk (the comparisons are the same).  ``trees``
    restricts descent to a subset of tree indices (used by the legacy
    per-tree scoring path kept for ablation).
    """
    F, TH = forest.feature, forest.threshold
    LC, RC, V = forest.left, forest.right, forest.value
    if trees is not None:
        F, TH = F[trees], TH[trees]
        LC, RC, V = LC[trees], RC[trees], V[trees]
    T = F.shape[0]
    N = X.shape[0]
    idx = np.zeros((T, N), dtype=np.int64)
    rows = np.arange(T)[:, None]
    cols = np.arange(N)[None, :]
    while True:
        f = F[rows, idx]
        live = f >= 0
        if not live.any():
            break
        xv = X[cols, np.maximum(f, 0)]
        nxt = np.where(xv <= TH[rows, idx], LC[rows, idx], RC[rows, idx])
        idx = np.where(live, nxt, idx)
    return V[rows, idx]


# ---------------------------------------------------------------------------
# vectorized erf / EI (numpy)
# ---------------------------------------------------------------------------


def erf(z: np.ndarray) -> np.ndarray:
    """Vectorized erf via Abramowitz-Stegun 7.1.26 (|error| <= 1.5e-7).

    Replaces the historical ``np.vectorize(math.erf)`` Python loop; the
    agreement with ``math.erf`` is pinned to <= 1e-6 in tests/test_bo.py.
    """
    z = np.asarray(z, dtype=np.float64)
    sign = np.sign(z)
    x = np.abs(z)
    t = 1.0 / (1.0 + 0.3275911 * x)
    poly = t * (0.254829592
                + t * (-0.284496736
                       + t * (1.421413741
                              + t * (-1.453152027 + t * 1.061405429))))
    return sign * (1.0 - poly * np.exp(-x * x))


def norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + erf(np.asarray(z, dtype=np.float64) / _SQRT2))


def norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) * _INV_SQRT_2PI


def expected_improvement(mean: np.ndarray, std: np.ndarray,
                         best: float) -> np.ndarray:
    """EI for *minimization* (vectorized; no Python loop per candidate)."""
    std = np.maximum(std, 1e-12)
    z = (best - mean) / std
    return (best - mean) * norm_cdf(z) + std * norm_pdf(z)


def _moments(preds: np.ndarray, y_mean: float,
             y_std: float) -> Tuple[np.ndarray, np.ndarray]:
    mean = preds.mean(axis=0) * y_std + y_mean
    std = preds.std(axis=0) * y_std
    return mean, np.maximum(std, 1e-9 * abs(y_std))


# ---------------------------------------------------------------------------
# fused acquisition: descent + moments + EI + exact top-q
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _acquire_jax(depth: int, select_mode: str):
    """Build (and cache per (tree depth, resolved select_topk dispatch))
    the jitted fused acquisition.  ``select_mode`` folds
    ``ops.select_path()`` into the cache key so flipping
    ``repro.kernels.ops.FORCE`` retraces instead of silently reusing a
    function traced for the other selection path (same contract as the
    compiled epoch loop's jit cache)."""
    import jax
    import jax.numpy as jnp
    from jax.scipy.special import erf as jerf

    from ...kernels import ops

    def impl(feature, thr, left, right, value, X, best, y_mean, y_std,
             valid, q):
        T = feature.shape[0]
        N = X.shape[0]
        idx = jnp.zeros((T, N), jnp.int32)
        rows = jnp.arange(T)[:, None]
        cols = jnp.arange(N)[None, :]
        for _ in range(depth):
            f = feature[rows, idx]
            xv = X[cols, jnp.maximum(f, 0)]
            nxt = jnp.where(xv <= thr[rows, idx],
                            left[rows, idx], right[rows, idx])
            idx = jnp.where(f >= 0, nxt, idx)
        preds = value[rows, idx]
        mean = preds.mean(axis=0) * y_std + y_mean
        std = jnp.maximum(preds.std(axis=0) * y_std, 1e-9 * jnp.abs(y_std))
        s = jnp.maximum(std, 1e-12)
        z = (best - mean) / s
        cdf = 0.5 * (1.0 + jerf(z / _SQRT2))
        pdf = jnp.exp(-0.5 * z * z) * _INV_SQRT_2PI
        # s (the floored std) in BOTH terms, matching expected_improvement
        ei = ((best - mean) * cdf + s * pdf).astype(jnp.float32)
        sel = ops.topk_mask(ei, q, valid=valid, mode=select_mode)
        return ei, sel

    return jax.jit(impl)


def _order_selected(ei32: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Order selected candidate indices by (EI desc, index asc)."""
    if idx.size == 0:
        return idx
    return idx[np.lexsort((idx, -ei32[idx].astype(np.float64)))]


def suggest_topq(forest: FlatForest, X: np.ndarray, best: float,
                 y_mean: float, y_std: float,
                 valid: Optional[np.ndarray] = None, q: int = 1,
                 backend: Optional[str] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Score a candidate pool and select the top-``q`` EI candidates.

    Returns ``(ei32, selected)`` where ``ei32`` is the float32 EI per pool
    row (the selection key) and ``selected`` are up to ``q`` row indices,
    ordered by EI descending with index tie-break — the same prefix
    ``np.argsort(-ei, kind="stable")`` would produce over ``valid`` rows.

    jax backend: one jitted function fusing descent, moments, EI and the
    exact ``select_topk`` top-q kernel.  numpy backend: the same math with
    a stable-argsort selection (also the reference the kernel is tested
    against).
    """
    backend = backend if backend in ("jax", "numpy") else acquisition_backend()
    if valid is None:
        valid = np.ones(X.shape[0], dtype=bool)
    if backend == "jax":
        # pad node and pool axes to coarse buckets so the jit cache stays
        # warm while the forest grows round over round (pad nodes are
        # unreachable leaves; pad pool rows are masked out of selection)
        N = X.shape[0]
        M = forest.feature.shape[1]
        Mp = max(64, 1 << int(M - 1).bit_length())
        Np = -(-N // 512) * 512
        pad_nodes = ((0, 0), (0, Mp - M))
        Xp = np.zeros((Np, X.shape[1]))
        Xp[:N] = X
        vp = np.zeros(Np, dtype=bool)
        vp[:N] = valid
        from ...kernels import ops
        fn = _acquire_jax(forest.max_depth, ops.select_path())
        ei, sel = fn(
            np.pad(forest.feature, pad_nodes,
                   constant_values=-1).astype(np.int32),
            np.pad(forest.threshold, pad_nodes),
            np.pad(forest.left, pad_nodes).astype(np.int32),
            np.pad(forest.right, pad_nodes).astype(np.int32),
            np.pad(forest.value, pad_nodes), Xp,
            float(best), float(y_mean), float(y_std), vp, q)
        ei32 = np.asarray(ei)[:N]
        idx = np.flatnonzero(np.asarray(sel)[:N])
        return ei32, _order_selected(ei32, idx)
    preds = predict_forest(forest, X)
    mean, std = _moments(preds, y_mean, y_std)
    ei32 = expected_improvement(mean, std, best).astype(np.float32)
    order = np.argsort(-ei32, kind="stable")
    picked = order[valid[order]][:q]
    return ei32, picked
