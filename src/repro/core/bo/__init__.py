from .forest_fast import FlatForest, suggest_topq
from .rf import RandomForest
from .smac import SMACOptimizer
from .tuner import TuningSession, TuningResult
from .importance import knob_importance

__all__ = ["FlatForest", "RandomForest", "SMACOptimizer", "TuningSession",
           "TuningResult", "knob_importance", "suggest_topq"]
