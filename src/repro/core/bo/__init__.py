from .rf import RandomForest
from .smac import SMACOptimizer
from .tuner import TuningSession, TuningResult
from .importance import knob_importance

__all__ = ["RandomForest", "SMACOptimizer", "TuningSession", "TuningResult",
           "knob_importance"]
