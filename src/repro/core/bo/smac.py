"""SMAC-style Bayesian optimizer (§3.1), with batched suggestions.

Sequential Model-based Algorithm Configuration [18]: random-forest surrogate
+ Expected-Improvement acquisition, with (1) an initial random design and
(2) periodic random interleaving, exactly as the paper configures it
(budget 100, 20 initial random, 20 % random-config probability, §4.1).

Candidate generation follows SMAC's local-search-plus-random scheme: EI is
maximized over Gaussian neighbours of the best-seen configurations plus a
pool of fresh uniform samples.

**Batch mode** (:meth:`SMACOptimizer.ask_batch` / ``tell_batch``) suggests q
configurations per round so a vectorized objective
(:func:`repro.core.simulator.run_simulation_batch`) can evaluate the whole
candidate batch in one simulator pass.  Exploration slots (the default
config, the initial random design and the random interleave) are filled
exactly as the sequential schedule would; the remaining slots take the
**top-q EI** candidates (deduplicated) from one shared candidate pool,
scored with the vectorized random-forest descent.  At ``q=1`` the batch path
delegates to :meth:`ask`, so histories are bit-identical to sequential runs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..knobs import Config, KnobSpace
from .rf import RandomForest


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return np.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    # erf-based CDF (no scipy in this environment)
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


def expected_improvement(mean: np.ndarray, std: np.ndarray,
                         best: float) -> np.ndarray:
    """EI for *minimization*."""
    std = np.maximum(std, 1e-12)
    z = (best - mean) / std
    return (best - mean) * _norm_cdf(z) + std * _norm_pdf(z)


@dataclasses.dataclass
class Observation:
    config: Config
    value: float


class SMACOptimizer:
    def __init__(self, space: KnobSpace, seed: int = 0,
                 n_init: int = 20, random_prob: float = 0.20,
                 n_candidates: int = 512, n_local_parents: int = 4,
                 n_trees: int = 24, start_with_default: bool = True):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.n_init = n_init
        self.random_prob = random_prob
        self.n_candidates = n_candidates
        self.n_local_parents = n_local_parents
        self.n_trees = n_trees
        self.start_with_default = start_with_default
        self.observations: List[Observation] = []
        self._surrogate: Optional[RandomForest] = None

    # -- bookkeeping ---------------------------------------------------------
    @property
    def best(self) -> Observation:
        return min(self.observations, key=lambda o: o.value)

    def tell(self, config: Mapping[str, Any], value: float) -> None:
        self.observations.append(
            Observation(self.space.validate(config), float(value)))
        self._surrogate = None  # invalidate

    @staticmethod
    def _config_key(config: Mapping[str, Any]):
        return tuple(sorted(config.items()))

    def tell_batch(self, configs, values, crn: bool = False) -> None:
        """Record one batched evaluation round.

        ``crn=True`` marks the round as evaluated under common random
        numbers (all configs shared one noise draw, e.g.
        ``SimOptions(crn=True)``).  If the round re-evaluated any
        already-observed config (a *control* — :meth:`ask_batch` with
        ``include_incumbent=True`` plants one), the mean difference between
        the control's new and previously recorded values estimates the
        round's shared noise offset, and the whole round is debiased by it
        before being recorded — the classic CRN paired-comparison
        variance reduction.  Without controls (or with ``crn=False``,
        the default) values are recorded unchanged.

        Note: the compiled simulator's counter-based CRN noise is fixed
        given the spec seed (re-evaluations are bitwise-deterministic), so
        there the offset is always zero and no control is worth planting;
        the debias matters for objectives that redraw their shared noise
        each round (real systems, per-round seeds).
        """
        if len(configs) != len(values):
            raise ValueError("configs and values must have equal length")
        configs = [self.space.validate(c) for c in configs]
        offset = 0.0
        if crn and self.observations:
            recorded = {}
            for o in self.observations:
                recorded.setdefault(self._config_key(o.config), o.value)
            deltas = [float(v) - recorded[self._config_key(c)]
                      for c, v in zip(configs, values)
                      if self._config_key(c) in recorded]
            if deltas:
                offset = float(np.mean(deltas))
        for cfg, val in zip(configs, values):
            self.tell(cfg, float(val) - offset)

    # -- surrogate ------------------------------------------------------------
    def surrogate(self) -> RandomForest:
        if self._surrogate is None:
            X = np.stack([self.space.encode(o.config)
                          for o in self.observations])
            y = np.array([o.value for o in self.observations])
            self._surrogate = RandomForest(
                n_trees=self.n_trees,
                seed=int(self.rng.integers(2 ** 31))).fit(X, y)
        return self._surrogate

    # -- suggestion -----------------------------------------------------------
    def ask(self) -> Config:
        n_seen = len(self.observations)
        if n_seen == 0 and self.start_with_default:
            return self.space.default_config()  # paper: start from default
        if n_seen < self.n_init:
            return self.space.sample(self.rng)
        if self.rng.uniform() < self.random_prob:
            return self.space.sample(self.rng)  # forced random interleave

        model = self.surrogate()
        best_val = self.best.value
        cands = self._candidate_pool(self.n_candidates)
        X = np.stack([self.space.encode(c) for c in cands])
        mean, std = model.predict(X)
        ei = expected_improvement(mean, std, best_val)
        return cands[int(np.argmax(ei))]

    def _candidate_pool(self, n_candidates: int) -> List[Config]:
        """Local neighbours of the best parents + fresh uniform samples."""
        parents = sorted(self.observations, key=lambda o: o.value)
        parents = parents[:self.n_local_parents]
        cands: List[Config] = []
        per_parent = max(4, n_candidates // (2 * len(parents)))
        for p in parents:
            cands.extend(self.space.neighbors(p.config, self.rng,
                                              n=per_parent, scale=0.12))
            cands.extend(self.space.neighbors(p.config, self.rng,
                                              n=per_parent // 2, scale=0.35))
        cands.extend(self.space.sample_batch(
            self.rng, max(8, n_candidates - len(cands))))
        return cands

    def ask_batch(self, q: int, include_incumbent: bool = False
                  ) -> List[Config]:
        """Suggest ``q`` configs for one batched evaluation round.

        Slots that the sequential schedule would spend on exploration
        (default config, initial random design, random interleaving) stay
        exploratory; the rest are the top-``q`` EI candidates from one
        shared pool.  ``q=1`` delegates to :meth:`ask`, preserving
        bit-identical sequential histories.

        ``include_incumbent=True`` (for CRN objectives whose shared noise
        is redrawn each round) spends slot 0 on re-evaluating the current
        best config once the model phase has begun, giving
        :meth:`tell_batch` a control for estimating the round's shared
        noise offset.
        """
        if q < 1:
            raise ValueError("q must be >= 1")
        if include_incumbent and q > 1 and \
                len(self.observations) >= self.n_init:
            rest = self.ask_batch(q - 1)
            return [dict(self.best.config)] + rest
        if q == 1:
            return [self.ask()]
        out: List[Config] = []
        n_seen = len(self.observations)
        while len(out) < q and n_seen + len(out) < self.n_init:
            if n_seen + len(out) == 0 and self.start_with_default:
                out.append(self.space.default_config())
            else:
                out.append(self.space.sample(self.rng))
        n_model = 0
        for _ in range(q - len(out)):
            if len(self.observations) < 2 or \
                    self.rng.uniform() < self.random_prob:
                # forced interleave — or nothing observed yet to model
                out.append(self.space.sample(self.rng))
            else:
                n_model += 1
        if n_model == 0:
            return out
        model = self.surrogate()
        best_val = self.best.value
        cands = self._candidate_pool(max(self.n_candidates, 64 * n_model))
        X = self.space.encode_batch(cands)
        mean, std = model.predict_batch(X)
        ei = expected_improvement(mean, std, best_val)
        seen = set()
        for i in np.argsort(-ei, kind="stable"):
            key = tuple(sorted(cands[i].items()))
            if key in seen:
                continue
            seen.add(key)
            out.append(cands[i])
            if len(seen) == n_model:
                break
        while len(out) < q:  # pool exhausted by dedup: fall back to random
            out.append(self.space.sample(self.rng))
        return out

    # -- full loop -------------------------------------------------------------
    def minimize(self, objective: Callable[[Config], float],
                 budget: int = 100,
                 callback: Optional[Callable[[int, Config, float], None]] = None,
                 ) -> Observation:
        for i in range(budget):
            cfg = self.ask()
            val = float(objective(cfg))
            self.tell(cfg, val)
            if callback is not None:
                callback(i, cfg, val)
        return self.best


class RandomSearch:
    """Unguided baseline the paper contrasts BO against (§3)."""

    def __init__(self, space: KnobSpace, seed: int = 0,
                 start_with_default: bool = True):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.start_with_default = start_with_default
        self.observations: List[Observation] = []

    @property
    def best(self) -> Observation:
        return min(self.observations, key=lambda o: o.value)

    def ask_batch(self, q: int, include_incumbent: bool = False
                  ) -> List[Config]:
        # include_incumbent is accepted for interface parity with
        # SMACOptimizer; unguided search has no model to debias for
        out = []
        for j in range(q):
            first = len(self.observations) + j == 0
            out.append(self.space.default_config()
                       if first and self.start_with_default
                       else self.space.sample(self.rng))
        return out

    def tell_batch(self, configs, values, crn: bool = False) -> None:
        if len(configs) != len(values):
            raise ValueError("configs and values must have equal length")
        for cfg, val in zip(configs, values):
            self.observations.append(Observation(dict(cfg), float(val)))

    def minimize(self, objective, budget: int = 100, callback=None):
        for i in range(budget):
            cfg = (self.space.default_config()
                   if i == 0 and self.start_with_default
                   else self.space.sample(self.rng))
            val = float(objective(cfg))
            self.observations.append(Observation(cfg, val))
            if callback is not None:
                callback(i, cfg, val)
        return self.best


def grid_search(space: KnobSpace, objective, knob_values: Dict[str, List[Any]],
                base: Optional[Config] = None
                ) -> Tuple[Config, float, Dict[Tuple, float]]:
    """Exhaustive grid over a subset of knobs (the paper's Fig-1 case study).

    Deprecated: build the grid configs explicitly and evaluate them as ONE
    batched ``Study(spec).run(configs=...)`` pass (what fig1_grid /
    smac_efficiency do now) — same numbers, one shared trace.
    """
    from .._deprecation import warn_deprecated
    warn_deprecated("repro.core.bo.smac.grid_search",
                    "Study(spec).run(configs=<grid configs>)")
    import itertools
    base = dict(base or space.default_config())
    names = list(knob_values)
    results: Dict[Tuple, float] = {}
    best_cfg, best_val = None, np.inf
    for combo in itertools.product(*(knob_values[n] for n in names)):
        cfg = dict(base)
        cfg.update(dict(zip(names, combo)))
        cfg = space.validate(cfg)
        val = float(objective(cfg))
        results[combo] = val
        if val < best_val:
            best_cfg, best_val = cfg, val
    return best_cfg, best_val, results
