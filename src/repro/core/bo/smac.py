"""SMAC-style Bayesian optimizer (§3.1), with batched suggestions.

Sequential Model-based Algorithm Configuration [18]: random-forest surrogate
+ Expected-Improvement acquisition, with (1) an initial random design and
(2) periodic random interleaving, exactly as the paper configures it
(budget 100, 20 initial random, 20 % random-config probability, §4.1).

Candidate generation follows SMAC's local-search-plus-random scheme: EI is
maximized over Gaussian neighbours of the best-seen configurations plus a
pool of fresh uniform samples.

**Batch mode** (:meth:`SMACOptimizer.ask_batch` / ``tell_batch``) suggests q
configurations per round so a vectorized objective
(:func:`repro.core.simulator.run_simulation_batch`) can evaluate the whole
candidate batch in one simulator pass.  Exploration slots (the default
config, the initial random design and the random interleave) are filled
exactly as the sequential schedule would; the remaining slots take the
**top-q EI** candidates (deduplicated) from one shared candidate pool.
At ``q=1`` the batch path delegates to :meth:`ask`, so histories are
bit-identical to sequential runs.

**Compiled hot path (PR 5).**  The default ``acquisition="fused"`` keeps
the whole model phase array-native: candidate pools are generated directly
as encoded unit-cube matrices (:meth:`KnobSpace.neighbors_batch` /
``sample_batch_encoded``), deduplicated in encoded space, and scored +
top-q-selected by ONE fused function
(:func:`repro.core.bo.forest_fast.suggest_topq`: batched tree descent,
moments, vectorized-erf EI and the exact ``select_topk`` kernel — jitted
under jax, pure numpy otherwise); only the q returned suggestions are
decoded to dicts.  ``acquisition="legacy"`` preserves the pre-PR-5
pipeline (per-config dict pools, per-tree descent, ``np.vectorize``'d erf,
dense argsort) for the before/after overhead benchmark
(``benchmarks/bo_overhead.py``) and as an oracle in tests.  Suggestion
histories changed in PR 5 (new forest-randomness and pool protocols — see
:mod:`repro.core.bo.rf`); they are identical across
``surrogate="reference"|"fast"`` and regression-tested.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..knobs import Config, KnobSpace
from . import forest_fast
from .rf import RandomForest, resolve_mode as rf_resolve_mode


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return forest_fast.norm_pdf(np.asarray(z, dtype=np.float64))


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    # vectorized erf-based CDF (no scipy in this environment); agreement
    # with math.erf is <= 1e-6 (pinned in tests/test_bo.py)
    return forest_fast.norm_cdf(z)


def _norm_cdf_ref(z: np.ndarray) -> np.ndarray:
    """Pre-PR-5 CDF: a ``np.vectorize(math.erf)`` Python loop per element.
    Kept as the numeric oracle for :func:`_norm_cdf` and for the legacy
    acquisition path's honest cost profile."""
    return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))


def expected_improvement(mean: np.ndarray, std: np.ndarray,
                         best: float) -> np.ndarray:
    """EI for *minimization* (vectorized)."""
    return forest_fast.expected_improvement(mean, std, best)


def expected_improvement_ref(mean: np.ndarray, std: np.ndarray,
                             best: float) -> np.ndarray:
    """EI via the pre-PR-5 scalar-erf loop (oracle/legacy path)."""
    std = np.maximum(std, 1e-12)
    z = (best - mean) / std
    return (best - mean) * _norm_cdf_ref(z) + std * _norm_pdf(z)


@dataclasses.dataclass
class Observation:
    config: Config
    value: float


class SMACOptimizer:
    def __init__(self, space: KnobSpace, seed: int = 0,
                 n_init: int = 20, random_prob: float = 0.20,
                 n_candidates: int = 512, n_local_parents: int = 4,
                 n_trees: int = 24, start_with_default: bool = True,
                 surrogate: Optional[str] = None,
                 acquisition: Optional[str] = None,
                 seed_configs: Optional[List[Config]] = None):
        """``surrogate`` picks the forest builder (``"reference"|"fast"``;
        None resolves via :data:`repro.core.bo.rf.FORCE`, default fast —
        both produce bit-identical forests and thus identical suggestion
        histories).  ``acquisition`` picks the scoring pipeline
        (``"fused"`` default; ``"legacy"`` is the pre-PR-5 pipeline kept
        for the overhead benchmark and oracle tests).

        ``seed_configs`` warm-starts the optimizer: the given configs are
        suggested FIRST (before the default config and the random initial
        design), in order.  This is the online tuner's warm-restart hook —
        after a detected workload phase change it re-opens a fresh
        optimizer seeded with the prior forest's elites, so the new phase's
        surrogate is fit on re-evaluations of previously good configs
        instead of starting blind."""
        if acquisition not in (None, "fused", "legacy"):
            raise ValueError(f"unknown acquisition {acquisition!r}; "
                             "expected 'fused' or 'legacy'")
        if surrogate is not None:
            # fail fast (a typo would otherwise only surface after the
            # whole n_init exploration design has been evaluated)
            rf_resolve_mode(surrogate)
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.n_init = n_init
        self.random_prob = random_prob
        self.n_candidates = n_candidates
        self.n_local_parents = n_local_parents
        self.n_trees = n_trees
        self.start_with_default = start_with_default
        self.surrogate_mode = surrogate
        self.acquisition = acquisition or "fused"
        self.observations: List[Observation] = []
        self._surrogate: Optional[RandomForest] = None
        self._seed_queue: List[Config] = [space.validate(c) for c
                                          in (seed_configs or [])]
        #: cumulative surrogate-fit wall clock (the tuner's per-round
        #: fit/acquisition breakdown reads deltas of this)
        self.fit_s = 0.0

    # -- bookkeeping ---------------------------------------------------------
    @property
    def best(self) -> Observation:
        return min(self.observations, key=lambda o: o.value)

    def tell(self, config: Mapping[str, Any], value: float) -> None:
        self.observations.append(
            Observation(self.space.validate(config), float(value)))
        self._surrogate = None  # invalidate

    @staticmethod
    def _config_key(config: Mapping[str, Any]):
        return tuple(sorted(config.items()))

    def tell_batch(self, configs, values, crn: bool = False) -> None:
        """Record one batched evaluation round.

        ``crn=True`` marks the round as evaluated under common random
        numbers (all configs shared one noise draw, e.g.
        ``SimOptions(crn=True)``).  If the round re-evaluated any
        already-observed config (a *control* — :meth:`ask_batch` with
        ``include_incumbent=True`` plants one), the mean difference between
        the control's new and previously recorded values estimates the
        round's shared noise offset, and the whole round is debiased by it
        before being recorded — the classic CRN paired-comparison
        variance reduction.  Without controls (or with ``crn=False``,
        the default) values are recorded unchanged.

        Note: the compiled simulator's counter-based CRN noise is fixed
        given the spec seed (re-evaluations are bitwise-deterministic), so
        there the offset is always zero and no control is worth planting;
        the debias matters for objectives that redraw their shared noise
        each round (real systems, per-round seeds).
        """
        if len(configs) != len(values):
            raise ValueError("configs and values must have equal length")
        configs = [self.space.validate(c) for c in configs]
        offset = 0.0
        if crn and self.observations:
            recorded = {}
            for o in self.observations:
                recorded.setdefault(self._config_key(o.config), o.value)
            deltas = [float(v) - recorded[self._config_key(c)]
                      for c, v in zip(configs, values)
                      if self._config_key(c) in recorded]
            if deltas:
                offset = float(np.mean(deltas))
        for cfg, val in zip(configs, values):
            self.tell(cfg, float(val) - offset)

    # -- surrogate ------------------------------------------------------------
    def surrogate(self) -> RandomForest:
        if self._surrogate is None:
            t0 = time.perf_counter()
            X = np.stack([self.space.encode(o.config)
                          for o in self.observations])
            y = np.array([o.value for o in self.observations])
            self._surrogate = RandomForest(
                n_trees=self.n_trees,
                seed=int(self.rng.integers(2 ** 31)),
                mode=self.surrogate_mode).fit(X, y)
            self.fit_s += time.perf_counter() - t0
        return self._surrogate

    # -- suggestion -----------------------------------------------------------
    def ask(self) -> Config:
        if self._seed_queue:  # warm-restart elites go out first
            return dict(self._seed_queue.pop(0))
        n_seen = len(self.observations)
        if n_seen == 0 and self.start_with_default:
            return self.space.default_config()  # paper: start from default
        if n_seen < self.n_init:
            return self.space.sample(self.rng)
        if self.rng.uniform() < self.random_prob:
            return self.space.sample(self.rng)  # forced random interleave

        model = self.surrogate()
        best_val = self.best.value
        if self.acquisition == "legacy":
            cands = self._candidate_pool(self.n_candidates)
            X = np.stack([self.space.encode(c) for c in cands])
            mean, std = self._predict_legacy(model, X)
            ei = expected_improvement_ref(mean, std, best_val)
            return cands[int(np.argmax(ei))]
        X = self._candidate_pool_encoded(self.n_candidates)
        _, sel = forest_fast.suggest_topq(
            model.forest, X, best_val, model._y_mean, model._y_std, q=1)
        return self.space.decode_batch(X[sel])[0]

    @staticmethod
    def _predict_legacy(model: RandomForest,
                        X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Pre-PR-5 prediction cost profile: one Python-level descent per
        tree (vs one fused descent for the whole forest).  Same numbers."""
        preds = np.stack([
            forest_fast.predict_forest(model.forest, X,
                                       trees=np.array([t]))[0]
            for t in range(model.n_trees)])
        return model._moments(preds)

    def _candidate_pool(self, n_candidates: int) -> List[Config]:
        """Pre-PR-5 pool: per-config dicts via scalar neighbour draws.
        Kept for ``acquisition="legacy"`` (different RNG protocol than the
        encoded pool, so histories differ between acquisition modes)."""
        parents = sorted(self.observations, key=lambda o: o.value)
        parents = parents[:self.n_local_parents]
        cands: List[Config] = []
        per_parent = max(4, n_candidates // (2 * len(parents)))
        for p in parents:
            cands.extend(self.space.neighbors(p.config, self.rng,
                                              n=per_parent, scale=0.12))
            cands.extend(self.space.neighbors(p.config, self.rng,
                                              n=per_parent // 2, scale=0.35))
        cands.extend(self.space.sample_batch(
            self.rng, max(8, n_candidates - len(cands))))
        return cands

    def _candidate_pool_encoded(self, n_candidates: int) -> np.ndarray:
        """Local neighbours of the best parents + fresh uniform samples,
        generated directly as canonical encoded unit rows (no dicts)."""
        parents = sorted(self.observations, key=lambda o: o.value)
        parents = parents[:self.n_local_parents]
        blocks: List[np.ndarray] = []
        count = 0
        per_parent = max(4, n_candidates // (2 * len(parents)))
        for p in parents:
            x = self.space.encode(p.config)
            blocks.append(self.space.neighbors_batch(x, self.rng,
                                                     n=per_parent,
                                                     scale=0.12))
            blocks.append(self.space.neighbors_batch(x, self.rng,
                                                     n=per_parent // 2,
                                                     scale=0.35))
            count += per_parent + per_parent // 2
        blocks.append(self.space.sample_batch_encoded(
            self.rng, max(8, n_candidates - count)))
        return np.concatenate(blocks, axis=0)

    def ask_batch(self, q: int, include_incumbent: bool = False
                  ) -> List[Config]:
        """Suggest ``q`` configs for one batched evaluation round.

        Slots that the sequential schedule would spend on exploration
        (default config, initial random design, random interleaving) stay
        exploratory; the rest are the top-``q`` EI candidates from one
        shared pool.  ``q=1`` delegates to :meth:`ask`, preserving
        bit-identical sequential histories.

        ``include_incumbent=True`` (for CRN objectives whose shared noise
        is redrawn each round) spends slot 0 on re-evaluating the current
        best config once the model phase has begun, giving
        :meth:`tell_batch` a control for estimating the round's shared
        noise offset.
        """
        if q < 1:
            raise ValueError("q must be >= 1")
        if self._seed_queue:  # warm-restart elites fill the head slots
            head = [dict(self._seed_queue.pop(0))
                    for _ in range(min(q, len(self._seed_queue)))]
            return head if len(head) == q \
                else head + self.ask_batch(q - len(head),
                                           include_incumbent=False)
        if include_incumbent and q > 1 and \
                len(self.observations) >= self.n_init:
            rest = self.ask_batch(q - 1)
            return [dict(self.best.config)] + rest
        if q == 1:
            return [self.ask()]
        out: List[Config] = []
        n_seen = len(self.observations)
        while len(out) < q and n_seen + len(out) < self.n_init:
            if n_seen + len(out) == 0 and self.start_with_default:
                out.append(self.space.default_config())
            else:
                out.append(self.space.sample(self.rng))
        n_model = 0
        for _ in range(q - len(out)):
            if len(self.observations) < 2 or \
                    self.rng.uniform() < self.random_prob:
                # forced interleave — or nothing observed yet to model
                out.append(self.space.sample(self.rng))
            else:
                n_model += 1
        if n_model == 0:
            return out
        model = self.surrogate()
        best_val = self.best.value
        if self.acquisition == "legacy":
            cands = self._candidate_pool(max(self.n_candidates,
                                             64 * n_model))
            X = self.space.encode_batch(cands)
            mean, std = self._predict_legacy(model, X)
            ei = expected_improvement_ref(mean, std, best_val)
            seen = set()
            for i in np.argsort(-ei, kind="stable"):
                key = tuple(sorted(cands[i].items()))
                if key in seen:
                    continue
                seen.add(key)
                out.append(cands[i])
                if len(seen) == n_model:
                    break
        else:
            X = self._candidate_pool_encoded(max(self.n_candidates,
                                                 64 * n_model))
            # canonical rows are config fixpoints, so deduplication is a
            # first-occurrence mask in encoded space
            _, first = np.unique(X, axis=0, return_index=True)
            valid = np.zeros(len(X), dtype=bool)
            valid[first] = True
            _, sel = forest_fast.suggest_topq(
                model.forest, X, best_val, model._y_mean, model._y_std,
                valid=valid, q=n_model)
            out.extend(self.space.decode_batch(X[sel]))
        while len(out) < q:  # pool exhausted by dedup: fall back to random
            out.append(self.space.sample(self.rng))
        return out

    # -- full loop -------------------------------------------------------------
    def minimize(self, objective: Callable[[Config], float],
                 budget: int = 100,
                 callback: Optional[Callable[[int, Config, float], None]] = None,
                 ) -> Observation:
        for i in range(budget):
            cfg = self.ask()
            val = float(objective(cfg))
            self.tell(cfg, val)
            if callback is not None:
                callback(i, cfg, val)
        return self.best


class RandomSearch:
    """Unguided baseline the paper contrasts BO against (§3)."""

    def __init__(self, space: KnobSpace, seed: int = 0,
                 start_with_default: bool = True):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.start_with_default = start_with_default
        self.observations: List[Observation] = []

    @property
    def best(self) -> Observation:
        return min(self.observations, key=lambda o: o.value)

    def ask(self) -> Config:
        # same draw schedule as minimize(): default first, then uniform
        first = len(self.observations) == 0
        return (self.space.default_config()
                if first and self.start_with_default
                else self.space.sample(self.rng))

    def tell(self, config: Mapping[str, Any], value: float) -> None:
        self.observations.append(Observation(dict(config), float(value)))

    def ask_batch(self, q: int, include_incumbent: bool = False
                  ) -> List[Config]:
        # include_incumbent is accepted for interface parity with
        # SMACOptimizer; unguided search has no model to debias for
        out = []
        for j in range(q):
            first = len(self.observations) + j == 0
            out.append(self.space.default_config()
                       if first and self.start_with_default
                       else self.space.sample(self.rng))
        return out

    def tell_batch(self, configs, values, crn: bool = False) -> None:
        if len(configs) != len(values):
            raise ValueError("configs and values must have equal length")
        for cfg, val in zip(configs, values):
            self.observations.append(Observation(dict(cfg), float(val)))

    def minimize(self, objective, budget: int = 100, callback=None):
        for i in range(budget):
            cfg = self.ask()  # same schedule: default first, then uniform
            val = float(objective(cfg))
            self.tell(cfg, val)
            if callback is not None:
                callback(i, cfg, val)
        return self.best


def grid_search(space: KnobSpace, objective, knob_values: Dict[str, List[Any]],
                base: Optional[Config] = None
                ) -> Tuple[Config, float, Dict[Tuple, float]]:
    """Exhaustive grid over a subset of knobs (the paper's Fig-1 case study).

    Deprecated: build the grid configs explicitly and evaluate them as ONE
    batched ``Study(spec).run(configs=...)`` pass (what fig1_grid /
    smac_efficiency do now) — same numbers, one shared trace.
    """
    from .._deprecation import warn_deprecated
    warn_deprecated("repro.core.bo.smac.grid_search",
                    "Study(spec).run(configs=<grid configs>)")
    import itertools
    base = dict(base or space.default_config())
    names = list(knob_values)
    results: Dict[Tuple, float] = {}
    best_cfg, best_val = None, np.inf
    for combo in itertools.product(*(knob_values[n] for n in names)):
        cfg = dict(base)
        cfg.update(dict(zip(names, combo)))
        cfg = space.validate(cfg)
        val = float(objective(cfg))
        results[combo] = val
        if val < best_val:
            best_cfg, best_val = cfg, val
    return best_cfg, best_val, results
