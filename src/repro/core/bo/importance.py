"""Knob-importance scores from the RF surrogate (§3.1, as in [5, 21]).

For each knob k: fix every other knob at its default, sweep k over its range
(via surrogate predictions), and score k by the spread of predicted execution
time.  This is the paper's "which tiering system knob(s) are more important"
analysis used to explain the Table-5 findings (e.g. that the *hidden*
``cooling_pages`` knob dominates Silo).
"""

from __future__ import annotations

from typing import Dict, List, Mapping, Optional

import numpy as np

from ..knobs import KnobSpace
from .rf import RandomForest
from .smac import Observation


def knob_importance(space: KnobSpace, observations: List[Observation],
                    n_sweep: int = 32, seed: int = 0,
                    base: Optional[Mapping[str, float]] = None,
                    surrogate: Optional[str] = None,
                    ) -> Dict[str, float]:
    """``surrogate`` picks the forest builder (``"reference"|"fast"``;
    None = the :data:`repro.core.bo.rf.FORCE` default).  All per-knob
    sweeps are stacked into ONE ``(n_knobs * n_sweep, d)`` matrix and
    scored by a single flat-forest descent pass (`predict_batch`), so the
    Table-5 analysis rides the same fast inference path as the tuner."""
    X = np.stack([space.encode(o.config) for o in observations])
    y = np.array([o.value for o in observations])
    model = RandomForest(seed=seed, mode=surrogate).fit(X, y)

    base_cfg = space.validate(dict(base)) if base else space.default_config()
    x0 = space.encode(base_cfg)

    k = len(space)
    sweeps = np.tile(x0, (k * n_sweep, 1))
    grid = np.linspace(0.0, 1.0, n_sweep)
    for i in range(k):
        sweeps[i * n_sweep:(i + 1) * n_sweep, i] = grid
    mean, _ = model.predict_batch(sweeps)
    mean = mean.reshape(k, n_sweep)
    raw = {knob.name: float(mean[i].max() - mean[i].min())
           for i, knob in enumerate(space)}
    total = sum(raw.values()) or 1.0
    return {k: v / total for k, v in sorted(raw.items(),
                                            key=lambda kv: -kv[1])}
