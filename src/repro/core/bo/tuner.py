"""Tuning pipeline (§3.1): launch session -> evaluate -> update -> repeat.

:class:`TuningSession` wires a :class:`~repro.core.simulator.Scenario` (or any
objective) to an optimizer and records the full history, the incumbent
trajectory and the iterations-to-optimum statistics the paper reports
("SMAC finds the best-performing configuration for GUPS within 10-16
iterations").
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional

import numpy as np

from ..knobs import Config, KnobSpace, get_space
from .smac import Observation, RandomSearch, SMACOptimizer


@dataclasses.dataclass
class TuningResult:
    engine: str
    scenario: str
    budget: int
    history: List[Observation]
    default_value: float
    wall_s: float

    @property
    def best(self) -> Observation:
        return min(self.history, key=lambda o: o.value)

    @property
    def best_value(self) -> float:
        return self.best.value

    @property
    def improvement(self) -> float:
        """default/best execution-time ratio (the paper's headline metric)."""
        return self.default_value / self.best_value

    def incumbent_trajectory(self) -> np.ndarray:
        vals = np.array([o.value for o in self.history])
        return np.minimum.accumulate(vals)

    def iterations_to(self, target: float, rtol: float = 0.01) -> Optional[int]:
        """First iteration whose incumbent is within rtol of ``target``."""
        traj = self.incumbent_trajectory()
        hit = np.flatnonzero(traj <= target * (1.0 + rtol))
        return int(hit[0]) + 1 if len(hit) else None


class TuningSession:
    def __init__(self, engine: str, objective: Callable[[Config], float],
                 scenario_key: str = "", space: Optional[KnobSpace] = None,
                 optimizer: str = "smac", budget: int = 100, seed: int = 0,
                 n_init: int = 20, random_prob: float = 0.20):
        self.engine = engine
        self.space = space if space is not None else get_space(engine)
        self.objective = objective
        self.scenario_key = scenario_key
        self.budget = budget
        if optimizer == "smac":
            self.optimizer = SMACOptimizer(self.space, seed=seed,
                                           n_init=n_init,
                                           random_prob=random_prob)
        elif optimizer == "random":
            self.optimizer = RandomSearch(self.space, seed=seed)
        else:
            raise ValueError(f"unknown optimizer {optimizer!r}")

    def run(self, verbose: bool = False) -> TuningResult:
        t0 = time.time()
        default_value = float(self.objective(self.space.default_config()))

        def cb(i, cfg, val):
            if verbose:
                best = min(o.value for o in self.optimizer.observations)
                print(f"  iter {i + 1:3d}/{self.budget}: f={val:9.2f}s "
                      f"best={best:9.2f}s", flush=True)

        self.optimizer.minimize(self.objective, budget=self.budget,
                                callback=cb)
        return TuningResult(
            engine=self.engine, scenario=self.scenario_key,
            budget=self.budget,
            history=list(self.optimizer.observations),
            default_value=default_value, wall_s=time.time() - t0)


def tune_scenario(engine: str, scenario, budget: int = 100, seed: int = 0,
                  optimizer: str = "smac", verbose: bool = False,
                  ) -> TuningResult:
    """Convenience wrapper used by benchmarks and examples."""
    session = TuningSession(engine, scenario.objective(engine),
                            scenario_key=scenario.key, budget=budget,
                            seed=seed, optimizer=optimizer)
    return session.run(verbose=verbose)
