"""Tuning pipeline (§3.1): launch session -> evaluate -> update -> repeat.

:class:`TuningSession` wires a :class:`~repro.core.simulator.Scenario` (or any
objective) to an optimizer and records the full history, the incumbent
trajectory and the iterations-to-optimum statistics the paper reports
("SMAC finds the best-performing configuration for GUPS within 10-16
iterations").

With ``batch_size=q > 1`` and a batched objective (a callable mapping a list
of configs to a list of values, e.g.
``Scenario.objective_batch(engine)``), each tuning iteration asks the
optimizer for a whole candidate batch and evaluates it in ONE vectorized
simulator pass — the history still contains exactly ``budget`` observations,
and ``batch_size=1`` reproduces the sequential loop bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..knobs import Config, KnobSpace, get_space
from .smac import Observation, RandomSearch, SMACOptimizer


@dataclasses.dataclass
class TuningResult:
    engine: str
    scenario: str
    budget: int
    history: List[Observation]
    default_value: float
    wall_s: float
    #: per-round wall-clock breakdown: each entry has ``ask_s`` (suggestion,
    #: including the surrogate fit), ``fit_s`` (the surrogate-fit share of
    #: ask), ``eval_s`` (objective evaluation), ``tell_s`` and ``q`` — the
    #: receipts for the BO-overhead acceptance claim (BENCH_bo.json)
    round_times: List[Dict[str, float]] = dataclasses.field(
        default_factory=list)

    @property
    def best(self) -> Observation:
        return min(self.history, key=lambda o: o.value)

    @property
    def optimizer_overhead_s(self) -> float:
        """Total ask+tell wall clock (everything that is not evaluation)."""
        return float(sum(r["ask_s"] + r["tell_s"] for r in self.round_times))

    @property
    def evaluation_s(self) -> float:
        return float(sum(r["eval_s"] for r in self.round_times))

    @property
    def overhead_fraction(self) -> float:
        """ask/tell overhead as a fraction of evaluation wall clock."""
        return self.optimizer_overhead_s / max(self.evaluation_s, 1e-12)

    @property
    def best_value(self) -> float:
        return self.best.value

    @property
    def improvement(self) -> float:
        """default/best execution-time ratio (the paper's headline metric)."""
        return self.default_value / self.best_value

    def incumbent_trajectory(self) -> np.ndarray:
        vals = np.array([o.value for o in self.history])
        return np.minimum.accumulate(vals)

    def iterations_to(self, target: float, rtol: float = 0.01) -> Optional[int]:
        """First iteration whose incumbent is within rtol of ``target``."""
        traj = self.incumbent_trajectory()
        hit = np.flatnonzero(traj <= target * (1.0 + rtol))
        return int(hit[0]) + 1 if len(hit) else None


class TuningSession:
    def __init__(self, engine: str, objective: Callable[[Config], float],
                 scenario_key: str = "", space: Optional[KnobSpace] = None,
                 optimizer: str = "smac", budget: int = 100, seed: int = 0,
                 n_init: int = 20, random_prob: float = 0.20,
                 batch_size: int = 1,
                 objective_batch: Optional[
                     Callable[[Sequence[Config]], Sequence[float]]] = None,
                 crn: bool = False, surrogate: Optional[str] = None,
                 acquisition: Optional[str] = None):
        self.engine = engine
        self.space = space if space is not None else get_space(engine)
        self.objective = objective
        self.objective_batch = objective_batch
        self.scenario_key = scenario_key
        self.budget = budget
        self.batch_size = max(1, int(batch_size))
        #: the batched objective evaluates under common random numbers, so
        #: tell_batch(crn=True) debiases any re-evaluated config against its
        #: recorded value.  No incumbent control is planted here: with the
        #: simulator's counter-based draws the noise is FIXED given the
        #: spec seed (re-evaluations are bitwise-deterministic), so a
        #: control could never measure a nonzero offset and would only burn
        #: a budget slot.  ask_batch(include_incumbent=True) remains
        #: available for objectives with fresh shared noise per round.
        self.crn = bool(crn)
        if self.batch_size > 1 and objective_batch is None:
            # fall back to mapping the scalar objective over the batch
            self.objective_batch = lambda cfgs: [float(objective(c))
                                                 for c in cfgs]
        if optimizer == "smac":
            self.optimizer = SMACOptimizer(self.space, seed=seed,
                                           n_init=n_init,
                                           random_prob=random_prob,
                                           surrogate=surrogate,
                                           acquisition=acquisition)
        elif optimizer == "random":
            self.optimizer = RandomSearch(self.space, seed=seed)
        else:
            raise ValueError(f"unknown optimizer {optimizer!r}")

    def run(self, verbose: bool = False) -> TuningResult:
        t0 = time.time()

        def cb(i, cfg, val):
            if verbose:
                best = min(o.value for o in self.optimizer.observations)
                print(f"  iter {i + 1:3d}/{self.budget}: f={val:9.2f}s "
                      f"best={best:9.2f}s", flush=True)

        def fit_s() -> float:
            return float(getattr(self.optimizer, "fit_s", 0.0))

        round_times: List[Dict[str, float]] = []
        if self.batch_size > 1:
            default_value = float(
                self.objective_batch([self.space.default_config()])[0])
            done = 0
            while done < self.budget:
                q = min(self.batch_size, self.budget - done)
                fit0, ta = fit_s(), time.perf_counter()
                cfgs = self.optimizer.ask_batch(q)
                te = time.perf_counter()
                vals = [float(v) for v in self.objective_batch(cfgs)]
                tt = time.perf_counter()
                self.optimizer.tell_batch(cfgs, vals, crn=self.crn)
                tend = time.perf_counter()
                round_times.append({
                    "ask_s": te - ta, "fit_s": fit_s() - fit0,
                    "eval_s": tt - te, "tell_s": tend - tt, "q": float(q)})
                for j, (cfg, val) in enumerate(zip(cfgs, vals)):
                    cb(done + j, cfg, val)
                done += q
        else:
            # the sequential loop, identical to optimizer.minimize() but
            # with the per-round ask/eval/tell walls recorded
            default_value = float(self.objective(self.space.default_config()))
            for i in range(self.budget):
                fit0, ta = fit_s(), time.perf_counter()
                cfg = self.optimizer.ask()
                te = time.perf_counter()
                val = float(self.objective(cfg))
                tt = time.perf_counter()
                self.optimizer.tell(cfg, val)
                tend = time.perf_counter()
                round_times.append({
                    "ask_s": te - ta, "fit_s": fit_s() - fit0,
                    "eval_s": tt - te, "tell_s": tend - tt, "q": 1.0})
                cb(i, cfg, val)
        return TuningResult(
            engine=self.engine, scenario=self.scenario_key,
            budget=self.budget,
            history=list(self.optimizer.observations),
            default_value=default_value, wall_s=time.time() - t0,
            round_times=round_times)


def tune_scenario(engine: str, scenario, budget: int = 100, seed: int = 0,
                  optimizer: str = "smac", verbose: bool = False,
                  batch_size: int = 1, workers: int = 1,
                  sampler: str = "sparse", backend: str = "numpy",
                  ) -> TuningResult:
    """Deprecated wrapper — use ``Study(spec).tune(budget, batch_size)``.

    ``batch_size=q > 1`` evaluates each optimizer round with
    :func:`~repro.core.simulator.run_simulation_batch` (``sampler``/
    ``workers``/``backend`` select the vectorized evaluation mode);
    ``batch_size=1`` is the paper-faithful sequential loop.
    """
    from .._deprecation import warn_deprecated
    from ..specs import EngineSpec, ExperimentSpec, SimOptions, WorkloadSpec
    from ..study import Study
    warn_deprecated("repro.core.bo.tuner.tune_scenario",
                    "Study(ExperimentSpec(...)).tune(budget, batch_size)")
    if batch_size <= 1 and (workers not in (1, None) or sampler != "sparse"
                            or backend != "numpy"):
        import warnings
        warnings.warn(
            "batch_size=1 runs the paper-faithful sequential loop; "
            "workers/sampler/backend only apply with batch_size > 1",
            stacklevel=2)
    if batch_size <= 1:  # the sequential loop always evaluated elementwise
        sampler, workers, backend = "elementwise", 1, "numpy"
    spec = ExperimentSpec(
        engine=EngineSpec(engine),
        workload=WorkloadSpec(scenario.workload, scenario.input_name,
                              threads=scenario.threads,
                              scale=scenario.scale),
        machine=scenario.machine, fast_slow_ratio=scenario.fast_slow_ratio,
        options=SimOptions(seed=scenario.seed, sampler=sampler,
                           workers=workers, backend=backend))
    return Study(spec).tune(budget=budget, batch_size=batch_size, seed=seed,
                            optimizer=optimizer, verbose=verbose)
