"""Random-forest regressor from scratch (numpy only).

SMAC's surrogate model [18, 22]: a forest of CART regression trees over the
unit-encoded knob space.  The across-tree spread provides the predictive
variance the EI acquisition needs.  No sklearn in this environment, so the
trees are implemented directly; with tuning-session sizes (<= a few hundred
observations, <= ~15 features) exact split search is cheap.

Two builders produce bit-identical forests (``tests/test_bo.py`` pins it):

* ``mode="fast"`` (the default) — :func:`~repro.core.bo.forest_fast.
  fit_forest_fast`, level-synchronous vectorized growth emitting flat
  ``(T, max_nodes)`` arrays directly.
* ``mode="reference"`` — the historical per-node recursive CART builder,
  kept as the executable specification (and the CI matrix leg
  ``REPRO_SURROGATE_FORCE=reference|fast`` runs the suite under both).

Shared randomness protocol (changed in PR 5 — suggestion histories differ
from earlier PRs; the delta is documented here and regression-tested):
``fit`` draws the whole bootstrap matrix up front and a single feature-hash
seed; per-node feature subsets come from the counter-based
:func:`~repro.core.bo.forest_fast.feature_subsets` hash of
``(seed, tree, heap-node)`` instead of a sequential ``rng.choice`` stream,
so build order (DFS vs BFS) cannot change the forest.  Node means and the
variance-floor termination are computed from sequential cumsums in both
builders for bit-equality.

Set :data:`FORCE` = ``"reference" | "fast"`` to pin a path globally
(mirroring ``repro.kernels.ops.FORCE``); tests/conftest.py wires the
``REPRO_SURROGATE_FORCE`` env var to it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .forest_fast import (FlatForest, _MIN_NODE_VAR, feature_subsets,
                          fit_forest_fast, predict_forest)

#: pin the surrogate builder ("reference" | "fast"); None = DEFAULT_MODE
FORCE: Optional[str] = None
DEFAULT_MODE = "fast"


def resolve_mode(mode: Optional[str] = None) -> str:
    """The builder a ``RandomForest`` (or :data:`FORCE`) resolves to."""
    mode = mode or FORCE or DEFAULT_MODE
    if mode not in ("reference", "fast"):
        raise ValueError(f"unknown surrogate mode {mode!r}; "
                         "expected 'reference' or 'fast'")
    return mode


@dataclasses.dataclass
class _Node:
    # leaf: value set, feature < 0
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class _Tree:
    """Reference CART regression tree: per-node recursion, DFS pre-order.

    Consumes NO sequential randomness — the feature subset for the split
    attempt at heap node ``h`` is ``feature_subsets(feat_seed, tree, h)``,
    the same deterministic hash the level-synchronous fast builder uses.
    """

    def __init__(self, max_depth: int, min_leaf: int, max_features: int,
                 tree_index: int, feat_seed: int):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.max_features = max_features
        self.tree_index = tree_index
        self.feat_seed = feat_seed
        self.nodes: List[_Node] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "_Tree":
        self.nodes = []
        self._build(X, y, depth=0, heap=1)
        return self

    def _build(self, X: np.ndarray, y: np.ndarray, depth: int,
               heap: int) -> int:
        idx = len(self.nodes)
        n = len(y)
        c1 = np.cumsum(y)
        c2 = np.cumsum(y * y)
        self.nodes.append(_Node(value=float(c1[-1] / n)))
        sse = c2[-1] - c1[-1] ** 2 / n
        if depth >= self.max_depth or n < 2 * self.min_leaf \
                or not (sse >= n * _MIN_NODE_VAR):
            return idx
        d = X.shape[1]
        feats = feature_subsets(self.feat_seed, self.tree_index, heap,
                                d, min(self.max_features, d))
        best = self._best_split(X, y, feats)
        if best is None:
            return idx
        f, thr, mask = best
        left = self._build(X[mask], y[mask], depth + 1, 2 * heap)
        right = self._build(X[~mask], y[~mask], depth + 1, 2 * heap + 1)
        node = self.nodes[idx]
        node.feature, node.threshold, node.left, node.right = f, thr, left, right
        return idx

    def _best_split(self, X, y, feats) -> Optional[Tuple[int, float, np.ndarray]]:
        n = len(y)
        best_score, best = np.inf, None
        for f in feats:
            xs = X[:, f]
            order = np.argsort(xs, kind="stable")
            xs_s, ys_s = xs[order], y[order]
            # candidate thresholds between distinct consecutive values
            csum = np.cumsum(ys_s)
            csum2 = np.cumsum(ys_s ** 2)
            total, total2 = csum[-1], csum2[-1]
            ks = np.arange(self.min_leaf, n - self.min_leaf + 1)
            if len(ks) == 0:
                continue
            valid = xs_s[ks - 1] < xs_s[np.minimum(ks, n - 1)]
            ks = ks[valid]
            if len(ks) == 0:
                continue
            left_sse = csum2[ks - 1] - csum[ks - 1] ** 2 / ks
            nr = n - ks
            right_sse = (total2 - csum2[ks - 1]) - (total - csum[ks - 1]) ** 2 / nr
            scores = left_sse + right_sse
            j = int(np.argmin(scores))
            if scores[j] < best_score:
                k = ks[j]
                thr = 0.5 * (xs_s[k - 1] + xs_s[k])
                best_score = scores[j]
                best = (int(f), float(thr), xs <= thr)
        return best

    def predict(self, X: np.ndarray) -> np.ndarray:
        """Per-row walk — the oracle the flat descent is tested against."""
        out = np.empty(len(X))
        for i, x in enumerate(X):
            node = self.nodes[0]
            while node.feature >= 0:
                j = node.left if x[node.feature] <= node.threshold else node.right
                node = self.nodes[j]
            out[i] = node.value
        return out


def _pack_reference_trees(trees: List[_Tree], max_depth: int) -> FlatForest:
    """Flatten reference trees (nodes already in DFS pre-order) to the same
    padded ``(T, M)`` arrays the fast builder emits."""
    T = len(trees)
    counts = np.array([len(t.nodes) for t in trees], dtype=np.int64)
    M = int(counts.max())
    F = np.full((T, M), -1, dtype=np.int64)
    TH = np.zeros((T, M))
    LC = np.full((T, M), -1, dtype=np.int64)
    RC = np.full((T, M), -1, dtype=np.int64)
    V = np.zeros((T, M))
    for t, tree in enumerate(trees):
        k = len(tree.nodes)
        F[t, :k] = [nd.feature for nd in tree.nodes]
        TH[t, :k] = [nd.threshold for nd in tree.nodes]
        LC[t, :k] = [nd.left for nd in tree.nodes]
        RC[t, :k] = [nd.right for nd in tree.nodes]
        V[t, :k] = [nd.value for nd in tree.nodes]
    return FlatForest(feature=F, threshold=TH, left=LC, right=RC, value=V,
                      n_nodes=counts, max_depth=max_depth)


class RandomForest:
    """Bagged regression forest with mean/variance prediction.

    ``mode=None`` resolves via :func:`resolve_mode` at fit time; the
    resulting :class:`~repro.core.bo.forest_fast.FlatForest` is stored on
    ``self.forest`` and all predictions run the flat batched descent.
    """

    def __init__(self, n_trees: int = 24, max_depth: int = 12,
                 min_leaf: int = 2, max_features: Optional[int] = None,
                 seed: int = 0, mode: Optional[str] = None):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.max_features = max_features
        self.rng = np.random.default_rng(seed)
        self.mode = mode
        self.trees: List[_Tree] = []   # populated in reference mode only
        self.forest: Optional[FlatForest] = None
        self._y_mean = 0.0
        self._y_std = 1.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForest":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self._y_mean = float(y.mean())
        self._y_std = float(y.std()) or 1.0
        yn = (y - self._y_mean) / self._y_std
        n, d = X.shape
        mf = self.max_features or max(1, int(np.ceil(d * 5.0 / 6.0)))
        mf = min(mf, d)
        # shared randomness protocol: bootstraps + feature-hash seed drawn
        # up front, identically for both builders
        boot = self.rng.integers(0, n, size=(self.n_trees, n))
        feat_seed = int(self.rng.integers(2 ** 63))
        mode = resolve_mode(self.mode)
        if mode == "reference":
            self.trees = []
            for t in range(self.n_trees):
                tree = _Tree(self.max_depth, self.min_leaf, mf, t, feat_seed)
                tree.fit(X[boot[t]], yn[boot[t]])
                self.trees.append(tree)
            self.forest = _pack_reference_trees(self.trees, self.max_depth)
        else:
            self.trees = []
            self.forest = fit_forest_fast(X, yn, boot, feat_seed,
                                          self.max_depth, self.min_leaf, mf)
        return self

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (mean, std) per row, de-normalized."""
        return self.predict_batch(X)

    def predict_batch(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(mean, std) via the vectorized all-trees flat descent — one
        gather loop for the whole forest, the fast path for scoring large
        batched-EI candidate pools and importance sweeps."""
        X = np.asarray(X, dtype=np.float64)
        preds = predict_forest(self.forest, X)  # (T, N)
        return self._moments(preds)

    def _moments(self, preds: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        mean = preds.mean(axis=0) * self._y_std + self._y_mean
        std = preds.std(axis=0) * self._y_std
        return mean, np.maximum(std, 1e-9 * abs(self._y_std))
