"""Random-forest regressor from scratch (numpy only).

SMAC's surrogate model [18, 22]: a forest of CART regression trees over the
unit-encoded knob space.  The across-tree spread provides the predictive
variance the EI acquisition needs.  No sklearn in this environment, so the
trees are implemented directly; with tuning-session sizes (≤ a few hundred
observations, ≤ ~15 features) exact split search is cheap.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class _Node:
    # leaf: value set, feature < 0
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class _Tree:
    """CART regression tree with random feature subsetting at each split."""

    def __init__(self, max_depth: int, min_leaf: int, max_features: int,
                 rng: np.random.Generator):
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.max_features = max_features
        self.rng = rng
        self.nodes: List[_Node] = []

    def fit(self, X: np.ndarray, y: np.ndarray) -> "_Tree":
        self.nodes = []
        if hasattr(self, "_arr"):
            del self._arr  # predict_batch cache belongs to the old nodes
        self._build(X, y, depth=0)
        return self

    def _build(self, X: np.ndarray, y: np.ndarray, depth: int) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(y.mean())))
        if depth >= self.max_depth or len(y) < 2 * self.min_leaf \
                or float(y.std()) < 1e-12:
            return idx
        d = X.shape[1]
        feats = self.rng.choice(d, size=min(self.max_features, d),
                                replace=False)
        best = self._best_split(X, y, feats)
        if best is None:
            return idx
        f, thr, mask = best
        left = self._build(X[mask], y[mask], depth + 1)
        right = self._build(X[~mask], y[~mask], depth + 1)
        node = self.nodes[idx]
        node.feature, node.threshold, node.left, node.right = f, thr, left, right
        return idx

    def _best_split(self, X, y, feats) -> Optional[Tuple[int, float, np.ndarray]]:
        n = len(y)
        best_score, best = np.inf, None
        for f in feats:
            xs = X[:, f]
            order = np.argsort(xs, kind="stable")
            xs_s, ys_s = xs[order], y[order]
            # candidate thresholds between distinct consecutive values
            csum = np.cumsum(ys_s)
            csum2 = np.cumsum(ys_s ** 2)
            total, total2 = csum[-1], csum2[-1]
            ks = np.arange(self.min_leaf, n - self.min_leaf + 1)
            if len(ks) == 0:
                continue
            valid = xs_s[ks - 1] < xs_s[np.minimum(ks, n - 1)]
            ks = ks[valid]
            if len(ks) == 0:
                continue
            left_sse = csum2[ks - 1] - csum[ks - 1] ** 2 / ks
            nr = n - ks
            right_sse = (total2 - csum2[ks - 1]) - (total - csum[ks - 1]) ** 2 / nr
            scores = left_sse + right_sse
            j = int(np.argmin(scores))
            if scores[j] < best_score:
                k = ks[j]
                thr = 0.5 * (xs_s[k - 1] + xs_s[k])
                best_score = scores[j]
                best = (int(f), float(thr), xs <= thr)
        return best

    def predict(self, X: np.ndarray) -> np.ndarray:
        out = np.empty(len(X))
        for i, x in enumerate(X):
            j = 0
            node = self.nodes[0]
            while node.feature >= 0:
                j = node.left if x[node.feature] <= node.threshold else node.right
                node = self.nodes[j]
            out[i] = node.value
        return out

    def _arrays(self):
        if not hasattr(self, "_arr"):
            self._arr = (
                np.array([n.feature for n in self.nodes], dtype=np.int64),
                np.array([n.threshold for n in self.nodes]),
                np.array([n.left for n in self.nodes], dtype=np.int64),
                np.array([n.right for n in self.nodes], dtype=np.int64),
                np.array([n.value for n in self.nodes]),
            )
        return self._arr

    def predict_batch(self, X: np.ndarray) -> np.ndarray:
        """Vectorized descent: all rows walk the tree level-synchronously.
        Same leaves (hence same values) as :meth:`predict`."""
        feat, thr, left, right, value = self._arrays()
        idx = np.zeros(len(X), dtype=np.int64)
        rows = np.arange(len(X))
        while True:
            f = feat[idx]
            live = f >= 0
            if not live.any():
                break
            li, lf = idx[live], f[live]
            go_left = X[rows[live], lf] <= thr[li]
            idx[live] = np.where(go_left, left[li], right[li])
        return value[idx]


class RandomForest:
    """Bagged regression forest with mean/variance prediction."""

    def __init__(self, n_trees: int = 24, max_depth: int = 12,
                 min_leaf: int = 2, max_features: Optional[int] = None,
                 seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.max_features = max_features
        self.rng = np.random.default_rng(seed)
        self.trees: List[_Tree] = []
        self._y_mean = 0.0
        self._y_std = 1.0

    def fit(self, X: np.ndarray, y: np.ndarray) -> "RandomForest":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        self._y_mean = float(y.mean())
        self._y_std = float(y.std()) or 1.0
        yn = (y - self._y_mean) / self._y_std
        d = X.shape[1]
        mf = self.max_features or max(1, int(np.ceil(d * 5.0 / 6.0)))
        self.trees = []
        n = len(X)
        for _ in range(self.n_trees):
            boot = self.rng.integers(0, n, size=n)
            t = _Tree(self.max_depth, self.min_leaf, mf, self.rng)
            t.fit(X[boot], yn[boot])
            self.trees.append(t)
        return self

    def predict(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (mean, std) per row, de-normalized."""
        X = np.asarray(X, dtype=np.float64)
        preds = np.stack([t.predict(X) for t in self.trees])  # (T, N)
        return self._moments(preds)

    def predict_batch(self, X: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Same (mean, std) as :meth:`predict` via vectorized tree descent —
        the fast path for scoring large batched-EI candidate pools."""
        X = np.asarray(X, dtype=np.float64)
        preds = np.stack([t.predict_batch(X) for t in self.trees])
        return self._moments(preds)

    def _moments(self, preds: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        mean = preds.mean(axis=0) * self._y_std + self._y_mean
        std = preds.std(axis=0) * self._y_std
        return mean, np.maximum(std, 1e-9 * abs(self._y_std))
