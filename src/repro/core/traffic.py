"""Request-arrival traffic for the tiered-KV serving benchmark.

A :class:`TrafficSpec` is a frozen, JSON-round-trippable description of an
arrival process; :func:`arrival_trace` expands it deterministically (spec +
seed fully determine the trace), and :func:`replay_schedule` turns it into
the per-step active/done masks a ``TieredKVCache`` decode loop replays —
hundreds of concurrent sequences arriving, decoding and completing.

Two arrival patterns ship:

* ``"poisson"`` — stationary Poisson arrivals at ``arrival_rate`` requests
  per decode step (the open-loop serving baseline);
* ``"bursty-diurnal"`` — a sinusoidal load cycle (``period``,
  ``amplitude``) with random multiplicative bursts (``burst_prob``,
  ``burst_factor``), the tail-latency stressor.

The same traffic drives the simulator: ``kv-poisson`` / ``kv-diurnal`` are
registered workloads whose per-epoch access vectors replay the serving
access profile (``step_read_counts``) over the replayed occupancy, so
``Study(ExperimentSpec(engine="kv-hemem", workload="kv-poisson"))`` tunes
the exact traffic the serving benchmark measures.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np

from .registry import register_workload
from .workloads import PAGE_BYTES, Workload

PATTERNS = ("poisson", "bursty-diurnal")


def step_read_counts(lengths, max_pages: int, page_tokens: int, scale: int,
                     xp=np):
    """Integer per-page access counts for one decode step.

    The serving access pattern (attention sink + recency + uniform base —
    the float profile of ``TieredKVCache.true_attention_mass``) quantized
    to int32 access counts: for a sequence covering ``n_p`` pages,

    * every active page gets ``scale // (20 * n_p)``        (~0.05 mass),
    * page 0 additionally ``35 * scale // 100``             (~0.35, sink),
    * the last ``min(n_p, 2)`` pages additionally
      ``45 * scale // (100 * min(n_p, 2))``                 (~0.45, recency).

    Pure integer arithmetic, so ``xp=np`` (reference loop, this module's
    workload replay) and ``xp=jnp`` (inside the fused serving jit) agree
    bitwise — the engine-input exactness the serving conformance tests
    rely on.  Returns ``(counts, active_page)``: ``(B, max_pages)`` int32
    counts and the boolean active-page mask.

    This function is deliberately jax-free (``xp`` defaults to numpy) so
    importing :mod:`repro.core` keeps the numpy-only path jax-free.
    """
    lengths = xp.asarray(lengths)
    ar = xp.arange(max_pages, dtype=xp.int32)[None, :]
    n_p = ((xp.maximum(lengths, 1).astype(xp.int32) - 1)
           // xp.int32(page_tokens) + 1)[:, None]           # (B, 1)
    active = ar < n_p
    c = xp.int32(scale) // (xp.int32(20) * n_p)
    c = c + xp.where(ar == 0, xp.int32(35 * scale // 100), xp.int32(0))
    rec = xp.int32(45 * scale) // (xp.int32(100) * xp.minimum(n_p, 2))
    c = c + xp.where(ar >= n_p - 2, rec, xp.int32(0))
    return xp.where(active, c, xp.int32(0)), active


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Replayable arrival process for one serving run."""

    pattern: str = "poisson"
    arrival_rate: float = 4.0      # mean new requests per decode step
    steps: int = 512
    decode_lo: int = 32            # per-request decode length (tokens),
    decode_hi: int = 96            # uniform in [lo, hi]
    period: int = 128              # diurnal cycle length (steps)
    amplitude: float = 0.8         # diurnal modulation depth (0..1)
    burst_prob: float = 0.02       # per-step burst probability
    burst_factor: float = 6.0      # burst rate multiplier

    def __post_init__(self):
        # validate at CONSTRUCTION, not at trace time: a bad spec used to
        # survive until arrival_trace silently clamped it (negative rates
        # -> np.maximum(lam, 0) -> an all-zero trace that looked like a
        # measurement, not a typo).  Same convention as KnobSpace/DriftSpec.
        if self.pattern not in PATTERNS:
            raise ValueError(f"unknown traffic pattern {self.pattern!r}; "
                             f"expected one of {PATTERNS}")
        if self.arrival_rate < 0:
            raise ValueError(f"arrival_rate must be >= 0, "
                             f"got {self.arrival_rate}")
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.decode_lo < 1:
            raise ValueError(f"decode_lo must be >= 1, "
                             f"got {self.decode_lo}")
        if self.decode_lo > self.decode_hi:
            raise ValueError(
                f"decode_lo must be <= decode_hi, got "
                f"decode_lo={self.decode_lo} > decode_hi={self.decode_hi}")
        if self.period < 1:
            raise ValueError(f"period must be >= 1, got {self.period}")
        if not 0.0 <= self.amplitude <= 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1] (modulation depth; >1 would "
                f"drive the diurnal rate negative), got {self.amplitude}")
        if not 0.0 <= self.burst_prob <= 1.0:
            raise ValueError(f"burst_prob must be a probability in [0, 1], "
                             f"got {self.burst_prob}")
        if self.burst_factor < 0:
            raise ValueError(f"burst_factor must be >= 0, "
                             f"got {self.burst_factor}")

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "TrafficSpec":
        known = [f.name for f in dataclasses.fields(TrafficSpec)]
        unknown = sorted(set(d) - set(known))
        if unknown:
            import difflib
            hints = []
            for k in unknown:
                close = difflib.get_close_matches(k, known, n=1, cutoff=0.5)
                hints.append(f"{k!r}" + (f" (did you mean {close[0]!r}?)"
                                         if close else ""))
            raise KeyError(f"unknown TrafficSpec keys: {', '.join(hints)} "
                           f"(known: {', '.join(known)})")
        return TrafficSpec(**d)


def arrival_trace(spec: TrafficSpec,
                  seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """Expand a spec into ``(arrivals, req_lengths)``.

    ``arrivals[t]`` is the number of requests arriving at step ``t``;
    ``req_lengths`` holds each request's decode length in arrival order.
    Deterministic in ``(spec, seed)``.
    """
    rng = np.random.default_rng(seed)
    t = np.arange(spec.steps)
    if spec.pattern == "poisson":
        lam = np.full(spec.steps, spec.arrival_rate)
    else:                                    # bursty-diurnal
        lam = spec.arrival_rate * (
            1.0 + spec.amplitude * np.sin(2.0 * np.pi * t / spec.period))
        burst = rng.random(spec.steps) < spec.burst_prob
        lam = np.where(burst, lam * spec.burst_factor, lam)
    arrivals = rng.poisson(np.maximum(lam, 0.0))
    req_lengths = rng.integers(spec.decode_lo, spec.decode_hi + 1,
                               int(arrivals.sum()))
    return arrivals, req_lengths


def replay_schedule(spec: TrafficSpec, batch: int, max_tokens: int,
                    seed: int) -> Dict[str, np.ndarray]:
    """Replay the arrival process over ``batch`` sequence slots.

    Requests queue FIFO for a free slot, decode one token per step, and
    complete when their decode length (clamped to ``max_tokens``) is
    reached.  Returns per-step boolean masks ``active`` (decode this step)
    and ``done`` (completed AFTER this step; the caller resets those
    sequences), plus ``completed``/``queued_peak`` scalars.
    """
    arrivals, req_lengths = arrival_trace(spec, seed)
    req_lengths = np.minimum(req_lengths, max_tokens)
    active = np.zeros((spec.steps, batch), bool)
    done = np.zeros((spec.steps, batch), bool)
    target = np.zeros(batch, np.int64)       # remaining tokens per slot
    queue: list = []
    nxt = 0
    completed = 0
    queued_peak = 0
    for t in range(spec.steps):
        queue.extend(req_lengths[nxt:nxt + arrivals[t]])
        nxt += arrivals[t]
        for b in range(batch):               # admit into free slots, FIFO
            if target[b] == 0 and queue:
                target[b] = queue.pop(0)
        queued_peak = max(queued_peak, len(queue))
        running = target > 0
        active[t] = running
        target[running] -= 1
        done[t] = running & (target == 0)
        completed += int(done[t].sum())
    return {"active": active, "done": done,
            "completed": np.int64(completed),
            "queued_peak": np.int64(queued_peak)}


# ---------------------------------------------------------------------------
# simulator workloads: the serving traffic as epoch access vectors, so the
# kv-hemem engine can be studied/tuned on the simulator stack too
# ---------------------------------------------------------------------------
#: page geometry of the simulated serving pool (a mid-size decode config)
_SIM_BATCH, _SIM_PAGES, _SIM_PT = 8, 32, 64
_SIM_SCALE = _SIM_PT * 8 * 4 * 64            # page_tokens*kv_heads*layers*64
_STEPS_PER_EPOCH = 8


def _kv_workload(name: str, spec: TrafficSpec, input_name: str, threads: int,
                 scale: float, seed: int) -> Workload:
    B = max(2, int(round(_SIM_BATCH * scale)))
    n = B * _SIM_PAGES
    sched = replay_schedule(spec, B, _SIM_PAGES * _SIM_PT, seed)
    active = sched["active"]
    n_epochs = spec.steps // _STEPS_PER_EPOCH
    reads = np.zeros((n_epochs, n), np.float64)
    writes = np.zeros((n_epochs, n), np.float64)
    lengths = np.zeros(B, np.int64)
    for t in range(n_epochs * _STEPS_PER_EPOCH):
        act = active[t]
        lengths[~act] = 0                    # completed slots reset
        lengths[act] += 1
        cnt, _ = step_read_counts(lengths, _SIM_PAGES, _SIM_PT, _SIM_SCALE,
                                  xp=np)
        cnt = np.where(act[:, None], cnt, 0)
        e = t // _STEPS_PER_EPOCH
        reads[e] += cnt.reshape(n)
        tail = np.minimum((np.maximum(lengths, 1) - 1) // _SIM_PT,
                          _SIM_PAGES - 1)
        pid = np.arange(B) * _SIM_PAGES + tail
        writes[e, pid[act]] += 1.0

    def epoch_access(e: int):
        return reads[e % n_epochs], writes[e % n_epochs]

    return Workload(name, input_name, n * PAGE_BYTES / 2 ** 30, n, n_epochs,
                    epoch_ms=100.0, threads=threads, mlp=4.0,
                    compute_ms=10.0, scale=scale, epoch_access=epoch_access,
                    seed=seed)


@register_workload("kv-poisson", default_input="")
def _kv_poisson(input_name: str, threads: int, scale: float,
                seed: int) -> Workload:
    return _kv_workload("kv-poisson", TrafficSpec(pattern="poisson"),
                        input_name, threads, scale, seed)


@register_workload("kv-diurnal", default_input="")
def _kv_diurnal(input_name: str, threads: int, scale: float,
                seed: int) -> Workload:
    return _kv_workload("kv-diurnal", TrafficSpec(pattern="bursty-diurnal"),
                        input_name, threads, scale, seed)
