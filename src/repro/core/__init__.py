"""Core reproduction package: typed experiment API over the tiering
simulator, engines, workloads and the SMAC tuner.

Public entry points (PR 2 redesign):

* :class:`~repro.core.study.Study` — ``run()`` / ``tune()`` / ``sweep()``
* :class:`~repro.core.specs.ExperimentSpec` (+ ``EngineSpec``,
  ``WorkloadSpec``, ``SimOptions``) — typed, JSON-round-trippable specs
* :mod:`~repro.core.registry` — ``@register_engine`` / ``@register_workload``
  / ``register_sampler`` / ``register_backend`` / ``register_machine``

The historical loose-kwargs functions (``evaluate``, ``evaluate_batch``,
``run_simulation``, ``make_engine``, ``tune_scenario``, ``Scenario``) remain
as deprecated shims with identical numerics; see the migration table in the
:mod:`repro.core.study` docstring.
"""

from .drift import DriftPhase, DriftSpec  # noqa: F401  (registers drift-*)
from .registry import (BACKENDS, ENGINES, MACHINES, SAMPLERS, WORKLOADS,
                       Registry, register_backend, register_engine,
                       register_machine, register_sampler, register_workload)
from .specs import EngineSpec, ExperimentSpec, SimOptions, WorkloadSpec
from .study import Study, SweepResult
from .traffic import TrafficSpec  # noqa: F401  (registers kv-* workloads)

__all__ = [
    "BACKENDS", "ENGINES", "MACHINES", "SAMPLERS", "WORKLOADS", "Registry",
    "register_backend", "register_engine", "register_machine",
    "register_sampler", "register_workload",
    "DriftPhase", "DriftSpec",
    "EngineSpec", "ExperimentSpec", "SimOptions", "WorkloadSpec",
    "Study", "SweepResult",
]
