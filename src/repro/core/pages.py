"""Page and tier-placement state shared by tiering engines and the simulator.

A *page* is the migration granule (2 MiB huge page, as in HeMem).  Placement is
a single boolean vector ``in_fast``: every allocated page is owned by exactly
one tier at any instant.  Migration is copy-then-flip, which by construction
avoids the migrate-vs-free race the paper had to patch in HeMem (§3.2,
deployment issue #2) — there is no intermediate state in which a page is owned
by zero or two tiers.  Property tests assert this invariant.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

PAGE_BYTES = 2 * 1024 * 1024  # 2 MiB huge pages, HeMem's migration granule


@dataclasses.dataclass
class MigrationPlan:
    """Result of one simulator epoch of migration-thread activity."""

    promote: np.ndarray  # page indices slow -> fast
    demote: np.ndarray   # page indices fast -> slow

    @staticmethod
    def empty() -> "MigrationPlan":
        z = np.zeros(0, dtype=np.int64)
        return MigrationPlan(promote=z, demote=z)

    @property
    def n_pages(self) -> int:
        return int(len(self.promote) + len(self.demote))


class TierState:
    """Two-tier placement of ``n_pages`` pages with a fixed fast-tier capacity.

    First-touch allocation mirrors HeMem: allocations land in the fast tier
    (DRAM) while it has free space, then overflow to the slow tier (NVM/CXL).
    """

    def __init__(self, n_pages: int, fast_capacity_pages: int,
                 page_bytes: int = PAGE_BYTES):
        if fast_capacity_pages < 0:
            raise ValueError("fast_capacity_pages must be >= 0")
        self.n_pages = int(n_pages)
        self.page_bytes = int(page_bytes)
        self.fast_capacity = int(fast_capacity_pages)
        self.in_fast = np.zeros(self.n_pages, dtype=bool)
        self.allocated = np.zeros(self.n_pages, dtype=bool)
        # lifetime counters (used by benchmarks / figures)
        self.total_promoted = 0
        self.total_demoted = 0

    # -- invariant helpers ---------------------------------------------------
    @property
    def fast_used(self) -> int:
        return int(self.in_fast.sum())

    @property
    def fast_free(self) -> int:
        return self.fast_capacity - self.fast_used

    def check_invariants(self) -> None:
        assert self.fast_used <= self.fast_capacity, "fast tier over capacity"
        assert not (self.in_fast & ~self.allocated).any(), "unallocated page in fast"

    # -- allocation ------------------------------------------------------------
    def allocate_first_touch(self, touched: np.ndarray) -> int:
        """Allocate newly-touched pages (fast first, then slow). Returns #new."""
        new = np.flatnonzero(touched & ~self.allocated)
        if len(new) == 0:
            return 0
        self.allocated[new] = True
        room = self.fast_free
        if room > 0:
            go_fast = new[:room]
            self.in_fast[go_fast] = True
        return int(len(new))

    # -- migration ---------------------------------------------------------------
    def apply(self, plan: MigrationPlan) -> None:
        """Apply demotions then promotions (HeMem frees room before filling it)."""
        if len(plan.demote):
            d = plan.demote
            assert self.in_fast[d].all(), "demoting a page not in fast tier"
            self.in_fast[d] = False
            self.total_demoted += len(d)
        if len(plan.promote):
            p = plan.promote
            assert self.allocated[p].all(), "promoting an unallocated page"
            assert not self.in_fast[p].any(), "promoting a page already in fast tier"
            self.in_fast[p] = True
            self.total_promoted += len(p)
        self.check_invariants()
