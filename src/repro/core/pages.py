"""Page and tier-placement state shared by tiering engines and the simulator.

A *page* is the migration granule (2 MiB huge page, as in HeMem).  Placement is
a single boolean vector ``in_fast``: every allocated page is owned by exactly
one tier at any instant.  Migration is copy-then-flip, which by construction
avoids the migrate-vs-free race the paper had to patch in HeMem (§3.2,
deployment issue #2) — there is no intermediate state in which a page is owned
by zero or two tiers.  Property tests assert this invariant.

The state is stored *batched*: :class:`BatchTierState` keeps ``(B, n_pages)``
placement arrays so one simulator pass can carry B tuning candidates through
the same workload trace.  :class:`TierState` is the single-config view —
a thin ``B=1`` wrapper kept so existing callers (engines, tests, figures)
don't change.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

PAGE_BYTES = 2 * 1024 * 1024  # 2 MiB huge pages, HeMem's migration granule


def migration_rate_pages(rate_gibs, epoch_ms, page_bytes: int,
                         scale: float = 1.0):
    """Pages movable this epoch under a GiB/s migration-rate cap.

    One shared definition for the cap every engine and the simulator used to
    compute inline: ``rate * 2**30 * epoch_s / page_bytes`` (optionally scaled
    by the simulation ``scale`` so sim-page counts stay consistent with the
    scaled bandwidth).  Accepts scalars or ``(B,)`` arrays and preserves the
    historical ``int()`` truncation semantics.
    """
    raw = rate_gibs * (2 ** 30) * (epoch_ms / 1e3) / page_bytes * scale
    if np.ndim(raw) == 0:
        return max(0, int(raw))
    return np.maximum(0, np.asarray(raw).astype(np.int64))


@dataclasses.dataclass
class MigrationPlan:
    """Result of one simulator epoch of migration-thread activity."""

    promote: np.ndarray  # page indices slow -> fast
    demote: np.ndarray   # page indices fast -> slow

    @staticmethod
    def empty() -> "MigrationPlan":
        z = np.zeros(0, dtype=np.int64)
        return MigrationPlan(promote=z, demote=z)

    @property
    def n_pages(self) -> int:
        return int(len(self.promote) + len(self.demote))


class BatchTierState:
    """Two-tier placement of ``n_pages`` pages for a batch of B configs.

    Every config in the batch sees the same workload but migrates
    independently, so placement is a ``(B, n_pages)`` boolean matrix.
    First-touch allocation mirrors HeMem: allocations land in the fast tier
    (DRAM) while it has free space, then overflow to the slow tier (NVM/CXL).
    """

    def __init__(self, batch: int, n_pages: int, fast_capacity_pages: int,
                 page_bytes: int = PAGE_BYTES):
        if batch < 1:
            raise ValueError("batch must be >= 1")
        if fast_capacity_pages < 0:
            raise ValueError("fast_capacity_pages must be >= 0")
        self.batch = int(batch)
        self.n_pages = int(n_pages)
        self.page_bytes = int(page_bytes)
        self.fast_capacity = int(fast_capacity_pages)
        self.in_fast = np.zeros((self.batch, self.n_pages), dtype=bool)
        self.allocated = np.zeros((self.batch, self.n_pages), dtype=bool)
        # True while every allocation call used a shared (n,) mask — rows are
        # then provably identical and allocation can take row-0 shortcuts
        self._alloc_rows_uniform = True
        # lifetime counters (used by benchmarks / figures)
        self.total_promoted = np.zeros(self.batch, dtype=np.int64)
        self.total_demoted = np.zeros(self.batch, dtype=np.int64)

    # -- invariant helpers ---------------------------------------------------
    @property
    def fast_used(self) -> np.ndarray:
        return self.in_fast.sum(axis=1)

    @property
    def fast_free(self) -> np.ndarray:
        return self.fast_capacity - self.fast_used

    def check_invariants(self) -> None:
        assert (self.fast_used <= self.fast_capacity).all(), \
            "fast tier over capacity"
        assert not (self.in_fast & ~self.allocated).any(), \
            "unallocated page in fast"

    # -- allocation ------------------------------------------------------------
    def allocate_first_touch(self, touched: np.ndarray) -> np.ndarray:
        """Allocate newly-touched pages (fast first, then slow).

        ``touched`` is either a shared ``(n_pages,)`` mask (the common case:
        all configs see the same trace) or a per-config ``(B, n_pages)``
        matrix.  Returns the per-config count of newly allocated pages.
        """
        touched = np.asarray(touched, dtype=bool)
        if touched.ndim == 1:
            # allocation is placement-independent, so as long as every call
            # used a shared mask all rows allocate identically — a cheap
            # row-0 check then skips the (B, n) work on the (common)
            # no-new-pages epochs
            if self._alloc_rows_uniform and \
                    not (touched & ~self.allocated[0]).any():
                return np.zeros(self.batch, dtype=np.int64)
            touched = np.broadcast_to(touched, self.in_fast.shape)
        else:
            self._alloc_rows_uniform = False
        new = touched & ~self.allocated
        counts = new.sum(axis=1)
        if not counts.any():
            return counts
        self.allocated |= new
        room = self.fast_free
        # first-touch order == page-index order: the first `room` new pages
        # of each row go fast (same selection as the historical new[:room])
        rank = np.cumsum(new, axis=1)
        self.in_fast |= new & (rank <= room[:, None])
        return counts

    # -- migration ---------------------------------------------------------------
    def apply(self, plans: Sequence[MigrationPlan]) -> None:
        """Apply per-config plans: demotions then promotions (HeMem frees room
        before filling it)."""
        assert len(plans) == self.batch, "one MigrationPlan per config"
        for b, plan in enumerate(plans):
            if len(plan.demote):
                d = plan.demote
                assert self.in_fast[b, d].all(), \
                    "demoting a page not in fast tier"
                self.in_fast[b, d] = False
                self.total_demoted[b] += len(d)
            if len(plan.promote):
                p = plan.promote
                assert self.allocated[b, p].all(), \
                    "promoting an unallocated page"
                assert not self.in_fast[b, p].any(), \
                    "promoting a page already in fast tier"
                self.in_fast[b, p] = True
                self.total_promoted[b] += len(p)
        self.check_invariants()


class TierState:
    """Single-config two-tier placement: a thin ``B=1`` view of
    :class:`BatchTierState` kept for existing callers."""

    def __init__(self, n_pages: int, fast_capacity_pages: int,
                 page_bytes: int = PAGE_BYTES):
        self.batch_state = BatchTierState(1, n_pages, fast_capacity_pages,
                                          page_bytes)
        self.n_pages = self.batch_state.n_pages
        self.page_bytes = self.batch_state.page_bytes
        self.fast_capacity = self.batch_state.fast_capacity

    # -- batched-state views --------------------------------------------------
    @property
    def in_fast(self) -> np.ndarray:
        return self.batch_state.in_fast[0]

    @property
    def allocated(self) -> np.ndarray:
        return self.batch_state.allocated[0]

    @property
    def total_promoted(self) -> int:
        return int(self.batch_state.total_promoted[0])

    @property
    def total_demoted(self) -> int:
        return int(self.batch_state.total_demoted[0])

    # -- invariant helpers ---------------------------------------------------
    @property
    def fast_used(self) -> int:
        return int(self.in_fast.sum())

    @property
    def fast_free(self) -> int:
        return self.fast_capacity - self.fast_used

    def check_invariants(self) -> None:
        self.batch_state.check_invariants()

    # -- allocation ------------------------------------------------------------
    def allocate_first_touch(self, touched: np.ndarray) -> int:
        """Allocate newly-touched pages (fast first, then slow). Returns #new."""
        return int(self.batch_state.allocate_first_touch(touched)[0])

    # -- migration ---------------------------------------------------------------
    def apply(self, plan: MigrationPlan) -> None:
        """Apply demotions then promotions (HeMem frees room before filling it)."""
        self.batch_state.apply([plan])
