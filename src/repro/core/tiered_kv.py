"""TieredKVCache — the paper's technique as a first-class serving feature.

Two-tier paged KV cache for long-context decode on TPU:
  fast tier = HBM page pool (jnp arrays, attended by the Pallas
              paged-attention kernel);
  slow tier = host-DRAM page pool (on a real v5e host this is the
              PCIe-attached host memory JAX host-offload uses).

The HeMem mechanism maps 1:1 (DESIGN.md §2):
  PEBS access sampling  -> per-page ATTENTION-MASS access counts (reads)
                           and appends (writes), subsampled by
                           sampling_period / write_sampling_period;
  hot/cold thresholds   -> the same read/write_hot_threshold knobs;
  cooling               -> identical batched halving (cooling_threshold,
                           cooling_pages);
  migration thread      -> step_engine(dt) promotes/demotes whole pages,
                           rate-limited by max_migration_rate and the ring
                           sizes; the device-side copy is the page_migrate
                           Pallas kernel.

Decode attends over the HBM-RESIDENT pages of each sequence (attention-mass
concentrates on few pages in long contexts; the engine's job — and the
tuner's — is to keep those pages resident).  ``recall()`` reports the
fraction of true attention mass that was resident, the quality metric the
serving benchmark tracks alongside latency.

Every knob keeps its Table-2 name, so the SMAC tuner drives this store
through the exact same KnobSpace as the simulator.

Compiled serving
----------------

``TieredKVCache(..., compiled=True)`` replaces the per-page Python loops
with the fused jitted step from :mod:`~repro.core.serving_jax`::

    cache = TieredKVCache(spec, batch=256, max_pages_per_seq=32,
                          hbm_pages=2048, config=cfg, compiled=True)
    out = cache.decode_step(k, v, q)           # ONE jitted call per step
    cache.step_engine(50.0)                    # batched migrations

``decode_step`` fuses append + paged attention + read recording; engine
epochs batch all page moves through one ``page_migrate`` call per
direction.  Both modes share the exact same engine arithmetic: the
decision math is the **lifted engine** ``kv-hemem``
(:class:`~repro.core.engine_jax.KVHeMemDef` — registered via
``register_jax_engine``, so ``backend="jax"`` simulations of ``kv-hemem``
compile instead of falling back to the numpy loop), compiled once per
cache geometry and invoked by the reference loop and the compiled path
alike.  Page-residency sets and migration counts are therefore
bit-identical across modes (pinned by ``tests/test_serving.py``); the
reference loop remains the readable specification, the compiled path is
the fast one.

Lifted-engine contract (what ``kv-hemem`` implements): pure
``knobs``/``init``/``observe``/``plan`` over ``(B, pages)`` arrays — see
:class:`~repro.core.engine_jax._EngineDef` for the full protocol.  Serving
uses deterministic mean sampling (``counts / period``) because the
attention kernel measures page mass exactly; the simulator twin
(``repro.core.engine.BatchKVHeMemEngine``) draws the same means.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.knobs import HEMEM_SPACE
from repro.core.serving_jax import get_serving, step_read_counts
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class KVSpec:
    n_layers: int
    kv_heads: int
    head_dim: int
    page_tokens: int = 64
    dtype: Any = jnp.bfloat16


class TieredKVCache:
    """Single-sequence-group paged KV cache (batch of B sequences that share
    a page pool).  ``compiled=False`` runs the per-page Python reference
    loop; ``compiled=True`` the fused jitted step (see module docstring)."""

    def __init__(self, spec: KVSpec, batch: int, max_pages_per_seq: int,
                 hbm_pages: int, config: Optional[Mapping[str, Any]] = None,
                 seed: int = 0, compiled: bool = False):
        self.spec = spec
        self.batch = batch
        self.max_pages = max_pages_per_seq
        n_logical = batch * max_pages_per_seq
        self.n_logical = n_logical
        self.hbm_pages = hbm_pages
        self.compiled = compiled

        s = spec
        page_shape = (s.n_layers, s.page_tokens, s.kv_heads, s.head_dim)
        self.page_elems = int(np.prod(page_shape))
        self.page_shape = page_shape

        self.config = HEMEM_SPACE.validate(dict(config or {}))
        # jitted serving functions + the shared engine-decision executable
        self._srv = get_serving(spec, batch, max_pages_per_seq, hbm_pages)
        self._kv = self._srv.edef.knobs([self.config])
        self._epoch = 0
        self._last_pages: Optional[Tuple[np.ndarray, np.ndarray]] = None

        if compiled:
            self._st = self._srv.fresh_state()
            return

        self.hbm_k = jnp.zeros((hbm_pages,) + page_shape, s.dtype)
        self.hbm_v = jnp.zeros((hbm_pages,) + page_shape, s.dtype)
        self.host_k = np.zeros((n_logical,) + page_shape, np.float32)
        self.host_v = np.zeros((n_logical,) + page_shape, np.float32)

        # logical page -> hbm slot (-1 = host-resident)
        self._slot_of = np.full(n_logical, -1, np.int64)
        self._page_of_slot = np.full(hbm_pages, -1, np.int64)
        self._lengths = np.zeros(batch, np.int64)
        self._allocated = np.zeros(n_logical, bool)

        self._eng = self._srv.edef.init(None)
        self._reads = np.zeros(n_logical, np.int64)
        self._writes = np.zeros(n_logical, np.int64)
        self._migrations = 0
        self._recall_num = 0.0
        self._recall_den = 0.0
        self._mass_fn = None

    # -- state views (identical API across modes) --------------------------
    # compiled-state reads are materialized with copy=True: the serving jits
    # donate their state pytree, so a zero-copy view of a device buffer
    # could be overwritten in place by the next step
    @property
    def lengths(self) -> np.ndarray:
        return np.array(self._st["lengths"], copy=True) if self.compiled \
            else self._lengths

    @property
    def slot_of(self) -> np.ndarray:
        return np.array(self._st["slot_of"][:self.n_logical], copy=True) \
            if self.compiled else self._slot_of

    @property
    def page_of_slot(self) -> np.ndarray:
        return np.array(self._st["page_of_slot"][:self.hbm_pages],
                        copy=True) if self.compiled else self._page_of_slot

    @property
    def migrations(self) -> int:
        return int(self._st["migrations"]) if self.compiled \
            else self._migrations

    @migrations.setter
    def migrations(self, v: int):
        if self.compiled:
            self._st = dict(self._st, migrations=jnp.int32(v))
        else:
            self._migrations = int(v)

    @property
    def last_step_pages(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(resident_pages, total_pages) per sequence for the most recent
        recorded step — the inputs of the benchmark's latency model.
        Materialized lazily: the compiled decode loop stays asynchronous
        unless the caller actually reads these."""
        if self._last_pages is None:
            return None
        return tuple(np.array(a, copy=True) for a in self._last_pages)

    # -- logical addressing ------------------------------------------------
    def _page_id(self, seq: int, page_idx: int) -> int:
        return seq * self.max_pages + page_idx

    def block_table(self) -> jnp.ndarray:
        """(B, max_pages) of HBM slots; -1 where non-resident/unused."""
        tbl = self.slot_of.reshape(self.batch, self.max_pages)
        return jnp.asarray(tbl, jnp.int32)

    def _active(self, active) -> np.ndarray:
        if active is None:
            return np.ones(self.batch, bool)
        return np.asarray(active, bool)

    # -- appends (writes) --------------------------------------------------
    def append(self, k_new: np.ndarray, v_new: np.ndarray, active=None):
        """k/v_new: (B, L, KV, D) — one token per (active) sequence.  New
        tokens land in the HBM tier first (first-touch), falling back to
        host."""
        act = self._active(active)
        if self.compiled:
            self._st = self._srv.append(self._st, jnp.asarray(k_new),
                                        jnp.asarray(v_new),
                                        jnp.asarray(act))
            return
        s = self.spec
        for b in range(self.batch):
            if not act[b]:
                continue
            t = int(self._lengths[b])
            pi, off = divmod(t, s.page_tokens)
            pid = self._page_id(b, pi)
            self._allocated[pid] = True
            self._writes[pid] += 1
            slot = self._slot_of[pid]
            if slot < 0 and off == 0:
                slot = self._grab_slot(pid)     # first touch -> fast tier
            if slot >= 0:
                self.hbm_k = self.hbm_k.at[slot, :, off].set(
                    jnp.asarray(k_new[b], s.dtype))
                self.hbm_v = self.hbm_v.at[slot, :, off].set(
                    jnp.asarray(v_new[b], s.dtype))
            else:
                self.host_k[pid, :, off] = k_new[b]
                self.host_v[pid, :, off] = v_new[b]
            self._lengths[b] = t + 1

    def _grab_slot(self, pid: int) -> int:
        free = np.flatnonzero(self._page_of_slot < 0)
        if len(free) == 0:
            return -1
        slot = int(free[0])
        self._page_of_slot[slot] = pid
        self._slot_of[pid] = slot
        return slot

    # -- attention (reads) -------------------------------------------------
    def attend(self, q: np.ndarray, active=None) -> jnp.ndarray:
        """q: (B, H, D) one decode step (single layer's query is the common
        case; for multi-layer pools q attends the layer-0 view and the
        access statistics apply to the whole page).  Returns (B, H, D).
        Records the step's attention-mass reads (see ``record_reads``)."""
        act = self._active(active)
        if self.compiled:
            self._st, out, res, tot = self._srv.attend(
                self._st, jnp.asarray(q), jnp.asarray(act))
            self._last_pages = (res, tot)   # device arrays; see property
            return out
        tbl = self.block_table()
        out = kops.paged_attention(
            jnp.asarray(q, self.spec.dtype),
            self.hbm_k[:, 0], self.hbm_v[:, 0],
            tbl, jnp.asarray(self._lengths, jnp.int32))
        self.record_reads(active=act)
        return out

    def decode_step(self, k_new, v_new, q, active=None,
                    dt_ms: Optional[float] = None) -> jnp.ndarray:
        """The fused serving step: append + attend + record (+ one engine
        epoch when ``dt_ms`` is given).  In compiled mode this is ONE
        jitted call (plus the engine pair at epochs); in reference mode the
        same operations run through the per-page Python loops."""
        act = self._active(active)
        if self.compiled:
            self._st, out, res, tot = self._srv.decode(
                self._st, jnp.asarray(k_new), jnp.asarray(v_new),
                jnp.asarray(q), jnp.asarray(act))
            self._last_pages = (res, tot)   # device arrays; see property
        else:
            self.append(k_new, v_new, active=act)
            out = self.attend(q, active=act)
        if dt_ms is not None:
            self.step_engine(dt_ms)
        return out

    #: attention-mass -> access-count scale: one decode step reads each
    #: page's tokens across kv heads and layers, so a unit of mass is worth
    #: page_tokens x kv_heads x n_layers "accesses" in PEBS-knob units
    @property
    def READ_SCALE(self) -> float:
        s = self.spec
        return float(s.page_tokens * s.kv_heads * s.n_layers * 64)

    def record_reads(self, active=None):
        """Attention-mass access accounting (the PEBS analogue).  Resident
        pages are scored by the paged-attention kernel; non-resident pages
        by the low-precision page-summary scoring pass (the cold-tier
        analogue of PEBS sampling slow-tier accesses), so the engine sees
        the whole address space like HeMem does.

        Counts are integer (``step_read_counts``) so the reference loop and
        the fused compiled step accumulate bit-identical engine inputs.  In
        compiled mode recording is fused into ``attend``/``decode_step``."""
        if self.compiled:
            raise RuntimeError(
                "compiled TieredKVCache fuses read recording into "
                "attend()/decode_step(); there is no separate record pass")
        act = self._active(active)
        scale = int(self.READ_SCALE)
        if self._mass_fn is not None:
            mass = np.asarray(self._mass_fn(), np.float64)
            counts_flat = np.rint(mass * scale).astype(np.int64)
            act_page = counts_flat.reshape(self.batch, self.max_pages) > 0
        else:
            counts, act_page = step_read_counts(
                self._lengths, self.max_pages, self.spec.page_tokens,
                scale, xp=np)
            counts = np.where(act[:, None], counts, 0)
            act_page = act_page & act[:, None]
            counts_flat = counts.reshape(self.n_logical).astype(np.int64)
            mass = counts_flat / scale
        resident = self._slot_of >= 0
        self._reads += counts_flat
        # recall bookkeeping counts only truly-resident service
        self._recall_num += float(mass[resident].sum())
        self._recall_den += float(mass.sum())
        res2 = resident.reshape(self.batch, self.max_pages)
        self._last_pages = ((res2 & act_page).sum(1), act_page.sum(1))

    def _record_reads(self):
        warnings.warn(
            "repro.core.tiered_kv.TieredKVCache._record_reads is "
            "deprecated; use the public record_reads()",
            DeprecationWarning, stacklevel=2)
        self.record_reads()

    def true_attention_mass(self) -> np.ndarray:
        """Per-logical-page attention mass for the current step (recency +
        sink-heavy profile, quantized to the integer access counts the
        engine sees).  Synthetic serving benchmarks may install a generator
        via ``set_mass_fn`` (reference mode only)."""
        counts, _ = step_read_counts(self.lengths, self.max_pages,
                                     self.spec.page_tokens,
                                     int(self.READ_SCALE), xp=np)
        return counts.reshape(self.n_logical) / self.READ_SCALE

    def set_mass_fn(self, fn):
        if self.compiled:
            raise RuntimeError("set_mass_fn is reference-mode only; the "
                               "compiled step bakes the serving profile in")
        self._mass_fn = fn

    # -- tiering (the paper's engine — the lifted kv-hemem def) ------------
    def step_engine(self, dt_ms: float):
        """One engine epoch: observe accumulated access counts, plan, and
        apply the promote/demote masks.  The decision math runs through the
        ONE jitted executable both modes share (``CompiledServing.
        engine_decide``); only the apply differs — batched ``page_migrate``
        in compiled mode vs the per-page reference loop here."""
        if self.compiled:
            self._st, _ = self._srv.engine_step(self._st, self._kv, dt_ms)
            return
        in_fast = self._slot_of >= 0
        self._eng, pmask, dmask = self._srv.engine_decide(
            self._eng, self._kv,
            self._reads.astype(np.float32), self._writes.astype(np.float32),
            in_fast, self._allocated, np.float32(dt_ms),
            np.int32(self._epoch))
        self._reads[:] = 0
        self._writes[:] = 0
        self._epoch += 1
        pmask, dmask = np.asarray(pmask), np.asarray(dmask)
        moved = 0
        for pid in np.flatnonzero(dmask):
            if self._slot_of[pid] < 0:
                continue
            self._demote(int(pid))
            moved += 1
        # promote page-ids ascending into free slots ascending — the same
        # pairing the batched compiled apply uses
        free = np.flatnonzero(self._page_of_slot < 0)
        j = 0
        for pid in np.flatnonzero(pmask):
            if self._slot_of[pid] >= 0 or not self._allocated[pid]:
                continue
            if j >= len(free):
                break
            self._promote(int(pid), int(free[j]))
            j += 1
            moved += 1
        self._migrations += moved

    def _demote(self, pid: int):
        slot = int(self._slot_of[pid])
        if slot < 0:
            return
        self.host_k[pid] = np.asarray(self.hbm_k[slot], np.float32)
        self.host_v[pid] = np.asarray(self.hbm_v[slot], np.float32)
        self._slot_of[pid] = -1
        self._page_of_slot[slot] = -1

    def _promote(self, pid: int, slot: int):
        # device-side copy via the page-migration kernel datapath
        flat = jnp.asarray(self.host_k[pid].reshape(1, -1), self.spec.dtype)
        self.hbm_k = kops.page_migrate(
            self.hbm_k.reshape(self.hbm_pages, -1), flat,
            jnp.asarray([slot]), jnp.asarray([0])).reshape(self.hbm_k.shape)
        flatv = jnp.asarray(self.host_v[pid].reshape(1, -1), self.spec.dtype)
        self.hbm_v = kops.page_migrate(
            self.hbm_v.reshape(self.hbm_pages, -1), flatv,
            jnp.asarray([slot]), jnp.asarray([0])).reshape(self.hbm_v.shape)
        self._slot_of[pid] = slot
        self._page_of_slot[slot] = pid

    # -- sequence lifecycle (traffic replay) -------------------------------
    def reset_seqs(self, done):
        """Retire finished sequences (boolean ``(B,)`` mask): zero their
        lengths, access counters and engine heat, free their HBM slots.
        Pool rows keep stale data; the next occupant overwrites them."""
        done = np.asarray(done, bool)
        if self.compiled:
            self._st = self._srv.reset_seqs(self._st, jnp.asarray(done))
            return
        kill = np.repeat(done, self.max_pages)
        for pid in np.flatnonzero(kill & (self._slot_of >= 0)):
            self._page_of_slot[self._slot_of[pid]] = -1
        self._slot_of[kill] = -1
        self._allocated[kill] = False
        self._reads[kill] = 0
        self._writes[kill] = 0
        self._lengths[done] = 0
        km = jnp.asarray(kill)[None, :]
        self._eng = dict(self._eng,
                         rc=jnp.where(km, 0.0, self._eng["rc"]),
                         wc=jnp.where(km, 0.0, self._eng["wc"]))

    # -- metrics -----------------------------------------------------------
    def recall(self) -> float:
        """Fraction of true attention mass served from the fast tier."""
        if self.compiled:
            return float(self._st["recall_num"]) / \
                max(float(self._st["recall_den"]), 1e-12)
        return self._recall_num / max(self._recall_den, 1e-12)

    def hbm_utilization(self) -> float:
        return float((self.page_of_slot >= 0).mean())
