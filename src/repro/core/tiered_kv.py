"""TieredKVCache — the paper's technique as a first-class serving feature.

Two-tier paged KV cache for long-context decode on TPU:
  fast tier = HBM page pool (jnp arrays, attended by the Pallas
              paged-attention kernel);
  slow tier = host-DRAM page pool (numpy; on a real v5e host this is the
              PCIe-attached host memory JAX host-offload uses).

The HeMem mechanism maps 1:1 (DESIGN.md §2):
  PEBS access sampling  -> sampled per-page ATTENTION MASS (reads) and
                           appends (writes), subsampled by sampling_period /
                           write_sampling_period;
  hot/cold thresholds   -> the same read/write_hot_threshold knobs;
  cooling               -> identical batched halving (cooling_threshold,
                           cooling_pages);
  migration thread      -> step_engine(dt) promotes/demotes whole pages,
                           rate-limited by max_migration_rate and the ring
                           sizes; the device-side copy is the page_migrate
                           Pallas kernel.

Decode attends over the HBM-RESIDENT pages of each sequence (attention-mass
concentrates on few pages in long contexts; the engine's job — and the
tuner's — is to keep those pages resident).  `recall()` reports the fraction
of true attention mass that was resident, the quality metric the serving
benchmark tracks alongside latency.

Every knob keeps its Table-2 name, so the SMAC tuner drives this store
through the exact same KnobSpace as the simulator.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import HeMemEngine
from repro.core.knobs import HEMEM_SPACE
from repro.core.pages import TierState
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class KVSpec:
    n_layers: int
    kv_heads: int
    head_dim: int
    page_tokens: int = 64
    dtype: Any = jnp.bfloat16


class TieredKVCache:
    """Single-sequence-group paged KV cache (batch of B sequences that share
    a page pool)."""

    def __init__(self, spec: KVSpec, batch: int, max_pages_per_seq: int,
                 hbm_pages: int, config: Optional[Mapping[str, Any]] = None,
                 seed: int = 0):
        self.spec = spec
        self.batch = batch
        self.max_pages = max_pages_per_seq
        n_logical = batch * max_pages_per_seq
        self.n_logical = n_logical
        self.hbm_pages = hbm_pages

        s = spec
        page_shape = (s.n_layers, s.page_tokens, s.kv_heads, s.head_dim)
        self.page_elems = int(np.prod(page_shape))
        self.page_shape = page_shape
        self.hbm_k = jnp.zeros((hbm_pages,) + page_shape, s.dtype)
        self.hbm_v = jnp.zeros((hbm_pages,) + page_shape, s.dtype)
        self.host_k = np.zeros((n_logical,) + page_shape, np.float32)
        self.host_v = np.zeros((n_logical,) + page_shape, np.float32)

        # logical page -> hbm slot (-1 = host-resident)
        self.slot_of = np.full(n_logical, -1, np.int64)
        self.page_of_slot = np.full(hbm_pages, -1, np.int64)
        self.lengths = np.zeros(batch, np.int64)

        # tiering engine over logical pages
        cfg = HEMEM_SPACE.validate(dict(config or {}))
        # page granule is page_bytes of KV data
        page_bytes = self.page_elems * 2
        self.tier = TierState(n_logical, hbm_pages, page_bytes=page_bytes)
        self.engine = HeMemEngine(cfg, self.tier, seed=seed)
        self._reads = np.zeros(n_logical)
        self._writes = np.zeros(n_logical)
        self.migrations = 0
        self._recall_num = 0.0
        self._recall_den = 0.0

    # -- logical addressing ----------------------------------------------------
    def _page_id(self, seq: int, page_idx: int) -> int:
        return seq * self.max_pages + page_idx

    def block_table(self) -> jnp.ndarray:
        """(B, max_pages) of HBM slots; -1 where non-resident/unused."""
        tbl = self.slot_of.reshape(self.batch, self.max_pages)
        return jnp.asarray(tbl, jnp.int32)

    # -- appends (writes) --------------------------------------------------------
    def append(self, k_new: np.ndarray, v_new: np.ndarray):
        """k/v_new: (B, L, KV, D) — one token per sequence.  New tokens land
        in the HBM tier first (first-touch), falling back to host."""
        s = self.spec
        for b in range(self.batch):
            t = int(self.lengths[b])
            pi, off = divmod(t, s.page_tokens)
            pid = self._page_id(b, pi)
            self.tier.allocated[pid] = True
            self._writes[pid] += 1.0
            slot = self.slot_of[pid]
            if slot < 0 and off == 0:
                slot = self._grab_slot(pid)     # first touch -> fast tier
            if slot >= 0:
                self.hbm_k = self.hbm_k.at[slot, :, off].set(
                    jnp.asarray(k_new[b], s.dtype))
                self.hbm_v = self.hbm_v.at[slot, :, off].set(
                    jnp.asarray(v_new[b], s.dtype))
            else:
                self.host_k[pid, :, off] = k_new[b]
                self.host_v[pid, :, off] = v_new[b]
            self.lengths[b] = t + 1

    def _grab_slot(self, pid: int) -> int:
        free = np.flatnonzero(self.page_of_slot < 0)
        if len(free) == 0:
            return -1
        slot = int(free[0])
        self.page_of_slot[slot] = pid
        self.slot_of[pid] = slot
        self.tier.in_fast[pid] = True
        return slot

    # -- attention (reads) ---------------------------------------------------------
    def attend(self, q: np.ndarray, layer_weights: Optional[np.ndarray] = None
               ) -> jnp.ndarray:
        """q: (B, H, D) one decode step (single layer's query is the common
        case; for multi-layer pools q attends the layer-0 view and the
        access statistics apply to the whole page).  Returns (B, H, D)."""
        tbl = self.block_table()
        out = kops.paged_attention(
            jnp.asarray(q, self.spec.dtype),
            self.hbm_k[:, 0], self.hbm_v[:, 0],
            tbl, jnp.asarray(self.lengths, jnp.int32))
        self._record_reads()
        return out

    #: attention-mass -> access-count scale: one decode step reads each
    #: page's tokens across kv heads and layers, so a unit of mass is worth
    #: page_tokens x kv_heads x n_layers "accesses" in PEBS-knob units
    @property
    def READ_SCALE(self) -> float:
        s = self.spec
        return float(s.page_tokens * s.kv_heads * s.n_layers * 64)

    def _record_reads(self):
        """Sampled attention-mass accounting (the PEBS analogue).  Resident
        pages are scored by the paged-attention kernel; non-resident pages by
        the low-precision page-summary scoring pass (the cold-tier analogue
        of PEBS sampling slow-tier accesses), so the engine sees the whole
        address space like HeMem does."""
        mass = self.true_attention_mass()
        resident = self.slot_of >= 0
        self._reads += mass * self.READ_SCALE
        # recall bookkeeping counts only truly-resident service
        self._recall_num += float(mass[resident].sum())
        self._recall_den += float(mass.sum())

    def true_attention_mass(self) -> np.ndarray:
        """Per-logical-page attention mass for the current step.  Synthetic
        serving benchmarks install a generator here; default = recency +
        sink-heavy profile."""
        mass = np.zeros(self.n_logical)
        s = self.spec
        for b in range(self.batch):
            n_p = math.ceil(max(int(self.lengths[b]), 1) / s.page_tokens)
            ids = np.arange(n_p)
            w = np.full(n_p, 0.05 / max(n_p, 1))
            w[0] += 0.35                       # attention sink
            w[max(0, n_p - 2):] += 0.45 / min(n_p, 2)   # recency
            mass[b * self.max_pages: b * self.max_pages + n_p] += w
        return mass

    def set_mass_fn(self, fn):
        self.true_attention_mass = fn          # type: ignore

    # -- tiering (the paper's engine, verbatim) -------------------------------------
    def step_engine(self, dt_ms: float):
        self.engine.observe(self._reads, self._writes, dt_ms)
        self._reads[:] = 0.0
        self._writes[:] = 0.0
        plan = self.engine.plan(dt_ms, max_pages_this_epoch=self.hbm_pages)
        moved = 0
        for pid in plan.demote:
            self._demote(int(pid))
            moved += 1
        for pid in plan.promote:
            if self.tier.fast_free <= 0:
                break
            self._promote(int(pid))
            moved += 1
        self.migrations += moved

    def _demote(self, pid: int):
        slot = int(self.slot_of[pid])
        if slot < 0:
            return
        self.host_k[pid] = np.asarray(self.hbm_k[slot], np.float32)
        self.host_v[pid] = np.asarray(self.hbm_v[slot], np.float32)
        self.slot_of[pid] = -1
        self.page_of_slot[slot] = -1
        self.tier.in_fast[pid] = False

    def _promote(self, pid: int):
        if self.slot_of[pid] >= 0:
            return
        free = np.flatnonzero(self.page_of_slot < 0)
        if len(free) == 0:
            return
        slot = int(free[0])
        # device-side copy via the page-migration kernel datapath
        flat = jnp.asarray(self.host_k[pid].reshape(1, -1), self.spec.dtype)
        self.hbm_k = kops.page_migrate(
            self.hbm_k.reshape(self.hbm_pages, -1), flat,
            jnp.asarray([slot]), jnp.asarray([0])).reshape(self.hbm_k.shape)
        flatv = jnp.asarray(self.host_v[pid].reshape(1, -1), self.spec.dtype)
        self.hbm_v = kops.page_migrate(
            self.hbm_v.reshape(self.hbm_pages, -1), flatv,
            jnp.asarray([slot]), jnp.asarray([0])).reshape(self.hbm_v.shape)
        self.slot_of[pid] = slot
        self.page_of_slot[slot] = pid
        self.tier.in_fast[pid] = True

    # -- metrics ----------------------------------------------------------------
    def recall(self) -> float:
        """Fraction of true attention mass served from the fast tier."""
        return self._recall_num / max(self._recall_den, 1e-12)

    def hbm_utilization(self) -> float:
        return float((self.page_of_slot >= 0).mean())
