"""Shared helper for the legacy entry-point shims (PR 2 API redesign).

Every deprecated callable warns with a message starting with its fully
qualified ``repro.`` name, so CI can escalate exactly our deprecations to
errors with ``-W "error:repro.:DeprecationWarning"`` without tripping over
third-party warnings.
"""

from __future__ import annotations

import warnings


def warn_deprecated(old: str, new: str, stacklevel: int = 3) -> None:
    """Emit a DeprecationWarning pointing at the typed-API replacement."""
    warnings.warn(f"{old} is deprecated; use {new} instead "
                  f"(see repro.core.study module docstring for the "
                  f"migration table)",
                  DeprecationWarning, stacklevel=stacklevel)
