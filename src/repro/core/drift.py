"""Phase-shifting (drifting) workloads: frozen specs + composed traces.

Every scenario the repo evaluated before this module was stationary — one
trace, one tuned config.  The related work (ARMS, Jenga, Hybrid Adaptive
Tuning) says the interesting regime is *drift*: the workload changes while
the system runs, and a tuner must re-adapt without thrashing.  This module
adds the workload half of that story; the tuner half lives in
:mod:`repro.core.tune_online`.

A :class:`DriftSpec` is a frozen, JSON-round-trippable description of a
phase-shifting trace: an ordered tuple of :class:`DriftPhase` entries (each
wrapping a registered :class:`~repro.core.specs.WorkloadSpec` plus a build
``seed_offset``) and the global ``switch_epochs`` at which each subsequent
phase takes over.  Three drift families ship as constructors:

* :meth:`DriftSpec.splice` — an A→B splice of any two registered workloads
  (e.g. gups → silo/ycsb-c): the working set and skew change wholesale;
* :meth:`DriftSpec.hotspot` — the hot set *rotates* over the address
  space: K phases of the same workload built with distinct seed offsets,
  so each phase scatters its hot pages somewhere new;
* :meth:`DriftSpec.wset` — working-set growth/shrink: phases of the
  ``wset`` workload whose touched fraction grows (or shrinks) per phase.

``spec.register()`` compiles the spec into an ordinary registered workload
(a picklable :class:`_DriftBuilder` behind the normal
:class:`~repro.core.registry.WorkloadBuilder` protocol), so a drifting
trace threads through *everything* that accepts a workload name — ``Study``
/ ``run_simulation_batch`` / ``run_simulation_segment`` / the process-pool
shard workers / both backends — with no special-casing: the composed
:class:`~repro.core.workloads.Workload` simply dispatches
``epoch_access(e)`` to the owning phase.  The numpy backend stays the
bit-exact reference and the jax epoch loop materializes the same per-epoch
vectors, so the backend-parity and segmentation contracts hold across
phase boundaries unchanged (pinned in ``tests/test_drift.py``).

Determinism: the composed trace is a pure function of ``(spec, seed)`` —
phase ``i`` builds its workload with ``seed + phases[i].seed_offset``.

Shape contract: all phases are built at the SAME ``threads``/``scale`` (the
ones the outer ``WorkloadSpec`` requests; per-phase specs contribute name +
input only) and the composed trace uses ``n_pages = max`` over phases,
padding shorter phases' access vectors with zeros.  One fixed shape means
ONE compiled jax epoch function serves the whole drifting run — phase
switches never retrace (see the jit-cache notes in
:mod:`repro.core.engine_jax`).  Machine-interaction scalars (``epoch_ms``,
``mlp``, ``compute_ms``) come from phase 0, so a splice changes the access
*pattern*, not the cost-model constants, keeping per-phase comparisons
paired.
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
import json
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from .registry import WORKLOADS, WorkloadBuilder
from .specs import WorkloadSpec
from .workloads import Workload, make_workload


def _unknown_keys(d: Mapping[str, Any], known: Sequence[str],
                  what: str) -> None:
    """KnobSpace-convention rejection of unknown spec keys, with a
    did-you-mean hint."""
    unknown = sorted(set(d) - set(known))
    if unknown:
        import difflib
        hints = []
        for k in unknown:
            close = difflib.get_close_matches(k, known, n=1, cutoff=0.5)
            hints.append(f"{k!r}" + (f" (did you mean {close[0]!r}?)"
                                     if close else ""))
        raise KeyError(f"unknown {what} keys: {', '.join(hints)} "
                       f"(known: {', '.join(known)})")


@dataclasses.dataclass(frozen=True)
class DriftPhase:
    """One phase of a drifting trace: a workload plus its build-seed offset.

    ``seed_offset`` shifts the phase's build seed (``seed + seed_offset``),
    which is how hotspot rotation gets a fresh scattered hot set per phase
    from one base workload.
    """

    workload: Union[WorkloadSpec, str, Mapping[str, Any]]
    seed_offset: int = 0

    def __post_init__(self):
        object.__setattr__(self, "workload", WorkloadSpec.coerce(self.workload))
        if int(self.seed_offset) != self.seed_offset or self.seed_offset < 0:
            raise ValueError(
                f"seed_offset must be a non-negative int, "
                f"got {self.seed_offset!r}")
        object.__setattr__(self, "seed_offset", int(self.seed_offset))

    def to_dict(self) -> Dict[str, Any]:
        return {"workload": self.workload.to_dict(),
                "seed_offset": self.seed_offset}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "DriftPhase":
        _unknown_keys(d, ("workload", "seed_offset"), "DriftPhase")
        return cls(workload=d["workload"],
                   seed_offset=d.get("seed_offset", 0))

    @classmethod
    def coerce(cls, value) -> "DriftPhase":
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            # accept the WorkloadSpec.key shorthand "name:input"
            name, _, inp = value.partition(":")
            return cls(workload=WorkloadSpec(name, inp))
        if isinstance(value, WorkloadSpec):
            return cls(workload=value)
        return cls.from_dict(value)


@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """A frozen phase-shifting trace: phases × switch epochs × total length.

    ``switch_epochs[i]`` is the GLOBAL epoch at which ``phases[i + 1]``
    takes over (strictly increasing, inside ``(0, n_epochs)``); phase 0
    starts at epoch 0 and the final phase runs to ``n_epochs``.  Within a
    phase, the base workload's trace is replayed from its local epoch 0
    (``base epoch = (global - phase_start) % base.n_epochs``).

    Validation happens at construction, matching the ``KnobSpace``
    convention: out-of-range or non-increasing switch epochs, a phase/
    switch count mismatch, and unknown JSON keys (with did-you-mean hints)
    all raise immediately rather than surfacing as silent trace anomalies
    mid-study.
    """

    phases: Tuple[DriftPhase, ...]
    switch_epochs: Tuple[int, ...]
    n_epochs: int
    name: str = ""

    def __post_init__(self):
        phases = tuple(DriftPhase.coerce(p) for p in self.phases)
        object.__setattr__(self, "phases", phases)
        if len(phases) < 2:
            raise ValueError(
                f"a drift needs at least 2 phases, got {len(phases)}; "
                "for a stationary trace use the workload directly")
        switches = tuple(int(s) for s in self.switch_epochs)
        object.__setattr__(self, "switch_epochs", switches)
        if int(self.n_epochs) <= 0:
            raise ValueError(f"n_epochs must be positive, "
                             f"got {self.n_epochs}")
        object.__setattr__(self, "n_epochs", int(self.n_epochs))
        if len(switches) != len(phases) - 1:
            raise ValueError(
                f"need exactly one switch epoch per phase transition "
                f"({len(phases)} phases -> {len(phases) - 1} switches), "
                f"got {len(switches)}")
        prev = 0
        for s in switches:
            if not prev < s < self.n_epochs:
                raise ValueError(
                    f"switch epochs must be strictly increasing inside "
                    f"(0, n_epochs={self.n_epochs}), got {switches}")
            prev = s
        if not self.name:
            object.__setattr__(self, "name", f"drift-{self._digest()}")

    def _digest(self) -> str:
        payload = {"phases": [p.to_dict() for p in self.phases],
                   "switch_epochs": list(self.switch_epochs),
                   "n_epochs": self.n_epochs}
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:10]

    # -- derived views -----------------------------------------------------
    @property
    def phase_starts(self) -> Tuple[int, ...]:
        """Global start epoch of every phase (phase 0 starts at 0)."""
        return (0,) + self.switch_epochs

    def phase_of(self, epoch: int) -> int:
        """Index of the phase that owns ``epoch``."""
        if not 0 <= epoch < self.n_epochs:
            raise ValueError(f"epoch {epoch} outside [0, {self.n_epochs})")
        return bisect.bisect_right(self.phase_starts, epoch) - 1

    # -- constructors ------------------------------------------------------
    @classmethod
    def splice(cls, a, b, switch_epoch: int, n_epochs: int,
               name: str = "") -> "DriftSpec":
        """A→B splice: workload ``a`` runs until ``switch_epoch``, then
        ``b`` takes over until ``n_epochs``."""
        return cls(phases=(DriftPhase.coerce(a), DriftPhase.coerce(b)),
                   switch_epochs=(switch_epoch,), n_epochs=n_epochs,
                   name=name)

    @classmethod
    def hotspot(cls, base: Union[WorkloadSpec, str] = "gups",
                n_phases: int = 3, phase_epochs: int = 20,
                name: str = "") -> "DriftSpec":
        """Hot-set rotation: ``n_phases`` phases of ``base``, each built
        with a distinct seed offset so the scattered hot set lands on a
        fresh page subset every ``phase_epochs`` epochs."""
        if n_phases < 2:
            raise ValueError(f"hotspot drift needs n_phases >= 2, "
                             f"got {n_phases}")
        ws = WorkloadSpec.coerce(base)
        phases = tuple(DriftPhase(ws, seed_offset=i)
                       for i in range(n_phases))
        switches = tuple(phase_epochs * (i + 1) for i in range(n_phases - 1))
        return cls(phases=phases, switch_epochs=switches,
                   n_epochs=phase_epochs * n_phases, name=name)

    @classmethod
    def wset(cls, fractions: Sequence[float] = (0.25, 0.5, 1.0),
             phase_epochs: int = 20, name: str = "") -> "DriftSpec":
        """Working-set growth (or shrink, with decreasing fractions):
        phases of the ``wset`` workload whose touched fraction steps
        through ``fractions``."""
        if len(fractions) < 2:
            raise ValueError("wset drift needs at least 2 fractions")
        phases = tuple(
            DriftPhase(WorkloadSpec("wset", f"f{int(round(f * 100))}"))
            for f in fractions)
        switches = tuple(phase_epochs * (i + 1)
                         for i in range(len(fractions) - 1))
        return cls(phases=phases, switch_epochs=switches,
                   n_epochs=phase_epochs * len(fractions), name=name)

    # -- JSON round trip ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"phases": [p.to_dict() for p in self.phases],
                "switch_epochs": list(self.switch_epochs),
                "n_epochs": self.n_epochs, "name": self.name}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "DriftSpec":
        _unknown_keys(d, ("phases", "switch_epochs", "n_epochs", "name"),
                      "DriftSpec")
        return cls(phases=tuple(DriftPhase.coerce(p) for p in d["phases"]),
                   switch_epochs=tuple(d["switch_epochs"]),
                   n_epochs=d["n_epochs"], name=d.get("name", ""))

    # -- registration ------------------------------------------------------
    def register(self, overwrite: bool = True) -> str:
        """Register the composed drifting workload under ``self.name``.

        Returns the registered name, usable anywhere a workload name is
        (``WorkloadSpec(name)``, sweeps, shard workers — the builder is
        picklable, so process pools rebuild the drifting trace from the
        spec exactly).  Registration is idempotent by default
        (``overwrite=True``): the name embeds a content digest, so the
        same spec always maps to the same builder.
        """
        WORKLOADS.register(
            self.name, WorkloadBuilder(self.name, _DriftBuilder(self)),
            overwrite=overwrite)
        return self.name


@dataclasses.dataclass(frozen=True)
class _DriftBuilder:
    """Picklable workload builder compiled from a :class:`DriftSpec`.

    Implements the registered-builder protocol ``(input_name, threads,
    scale, seed) -> Workload``; module-level and closure-free so shard
    worker processes can unpickle it and rebuild the exact trace.
    """

    spec: DriftSpec

    def __call__(self, input_name: str, threads: int, scale: float,
                 seed: int) -> Workload:
        return build_drift_workload(self.spec, input_name=input_name,
                                    threads=threads, scale=scale, seed=seed)


def build_drift_workload(spec: DriftSpec, input_name: str = "",
                         threads: int = 12, scale: float = 0.25,
                         seed: int = 0) -> Workload:
    """Compose the phase workloads into ONE drifting :class:`Workload`.

    All phases are built at the shared ``threads``/``scale`` (phase specs
    contribute name + input only) with build seed ``seed + seed_offset``;
    the composed trace is therefore deterministic in ``(spec, seed)``.
    ``n_pages``/``rss_gib`` take the max over phases and shorter phases'
    access vectors are zero-padded, so the trace shape is constant across
    every phase boundary (one compiled jax shape per run).
    """
    built = [make_workload(p.workload.name, p.workload.input_name,
                           threads=threads, scale=scale,
                           seed=seed + p.seed_offset)
             for p in spec.phases]
    n = max(w.n_pages for w in built)
    starts = spec.phase_starts

    def epoch_access(e: int):
        i = bisect.bisect_right(starts, e) - 1
        w = built[i]
        reads, writes = w.epoch_access((e - starts[i]) % w.n_epochs)
        if w.n_pages == n:
            return reads, writes
        r = np.zeros(n, dtype=np.float64)
        wr = np.zeros(n, dtype=np.float64)
        r[:w.n_pages] = reads
        wr[:w.n_pages] = writes
        return r, wr

    head = built[0]
    return Workload(spec.name, input_name,
                    rss_gib=max(w.rss_gib for w in built), n_pages=n,
                    n_epochs=spec.n_epochs, epoch_ms=head.epoch_ms,
                    threads=threads, mlp=head.mlp,
                    compute_ms=head.compute_ms, scale=scale,
                    epoch_access=epoch_access, seed=seed)


def window_histogram(workload: Workload, epoch_lo: int,
                     epoch_hi: int) -> np.ndarray:
    """Normalized per-page access histogram over ``[epoch_lo, epoch_hi)``.

    The sampled-histogram phase-change detector's observable: reads +
    writes summed over the window, normalized to unit mass.  Cheap (pure
    numpy over the procedural trace) and deterministic.
    """
    h = np.zeros(workload.n_pages, dtype=np.float64)
    for e in range(epoch_lo, min(epoch_hi, workload.n_epochs)):
        r, w = workload.epoch_access(e)
        h += np.asarray(r, dtype=np.float64)
        h += np.asarray(w, dtype=np.float64)
    s = h.sum()
    return h / s if s > 0 else h


def histogram_divergence(a: np.ndarray, b: np.ndarray) -> float:
    """Total-variation distance between two normalized histograms
    (``0.5 * L1``, in ``[0, 1]``)."""
    return float(0.5 * np.abs(np.asarray(a) - np.asarray(b)).sum())


#: builtin drift scenarios, registered on import (mirrors how traffic.py
#: registers kv-poisson/kv-diurnal): a hotspot rotation, a working-set
#: growth ramp and a gups→silo splice, each usable as a plain workload name
BUILTIN_DRIFTS: Dict[str, DriftSpec] = {}
for _spec in (
        DriftSpec.hotspot(base="gups", n_phases=3, phase_epochs=20,
                          name="drift-hotspot"),
        DriftSpec.wset(fractions=(0.25, 0.5, 1.0), phase_epochs=20,
                       name="drift-wset"),
        DriftSpec.splice(WorkloadSpec("gups"),
                         WorkloadSpec("silo", "ycsb-c"),
                         switch_epoch=30, n_epochs=60,
                         name="drift-splice"),
):
    BUILTIN_DRIFTS[_spec.register()] = _spec
del _spec
