"""Online re-tuning under workload drift: sliding-window SMAC with
phase-change detection, warm restarts and a hysteresis/dwell switch guard.

The static tuner (:class:`~repro.core.bo.tuner.TuningSession`) answers "what
is the best config for THIS trace"; under drift (:mod:`repro.core.drift`)
that question has a different answer per phase, and the related work says
the hard part is *re-adapting without thrashing* — Jenga's headline failure
mode is oscillating between configs on noisy feedback.  This module is the
tuner half of the drift story:

**The window loop.**  Time is cut into windows of ``window_epochs`` epochs.
Each window runs ONE batched compiled segment
(:func:`~repro.core.simulator.run_simulation_segment`, ``backend="jax"``,
``crn=True``) whose batch is ``[deployed] + candidates``: row 0 is the
config the system is actually running, rows 1..q are SMAC's suggestions.
All rows start from the *deployed* system's checkpoint (scan carry) at the
window start — :func:`~repro.core.engine_jax.broadcast_carry_row` row 0 —
so under common random numbers every candidate's window wall answers "what
if we had switched at this boundary" as a paired counterfactual, at zero
extra trace cost.  The deployed system always advances along row 0: a
config switch changes what row 0 *runs* next window, from the state the old
config left behind — exactly like a real system flipping knobs mid-run.
Fixed ``window_epochs`` and fixed batch width mean ONE compiled shape
serves the whole study (short budgets pad the batch with deployed copies
rather than shrink it).

**Phase-change detection.**  Two detectors, OR'd:

* *sampled-histogram divergence* (primary): the total-variation distance
  between consecutive windows' normalized per-page access histograms
  (:func:`~repro.core.drift.histogram_divergence` over the segment trace
  the compiled path hands back).  Exactly 0 between same-phase windows of
  the procedural workloads, so the default threshold has real margin.
* *surrogate-residual blowup*: the deployed config's measured window wall
  vs. the forest's prediction — a z-score above ``resid_z`` with relative
  deviation above ``resid_rel`` means the model of the current phase has
  stopped explaining reality.

**Warm restart.**  On detection the optimizer is REPLACED — a fresh
:class:`~repro.core.bo.smac.SMACOptimizer` whose ``seed_configs`` are the
prior optimizer's elites (current deployed first, then the top-``k``
distinct configs by observed value).  The new phase's forest is therefore
fit on re-evaluations of previously good configs instead of starting
blind, and stale observations from the old phase cannot mislead it.

**Hysteresis/dwell guard.**  A switch is applied only if the best
candidate beat the deployed config by more than ``hysteresis`` (relative)
AND at least ``dwell_windows`` windows have passed since the last switch.
Near-ties and noise cannot flip the config back and forth: the guard makes
config-thrashing structurally impossible rather than merely unlikely
(``guard_blocks`` counts the suppressions; ``thrash_events`` counts
A→B→A reverts within ``2 * dwell_windows`` and is asserted zero in the
drift benchmark's receipts).

**Journal & resume.**  With ``journal=<path>`` every window decision is
recorded through :class:`~repro.core.tune_service.journal.StudyJournal`.
The control loop is a deterministic function of its parameters and the
compiled simulator is bitwise-deterministic, so ``resume=True`` simply
re-runs the loop (segments are cheap; the carry is NOT journaled) while
the journal *asserts* every replayed decision matches the recorded one,
then appends past the prefix — a resumed journal is byte-identical to an
uninterrupted run's, the same contract the async tune service pins.

Entry point: ``Study.tune(online=True, window_epochs=..., ...)`` —
see :meth:`repro.core.study.Study.tune`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

from . import engine_jax
from .drift import histogram_divergence
from .bo.smac import SMACOptimizer
from .knobs import SPACES, KnobSpace
from .simulator import run_simulation_segment

Config = Dict[str, Any]

#: journal schema version for the online-tuning event stream
ONLINE_JOURNAL_VERSION = 1


def _py(value):
    """Numpy scalar -> plain Python (JSON-journalable, exact round trip)."""
    return value.item() if hasattr(value, "item") else value


def _py_config(config: Mapping[str, Any]) -> Config:
    return {k: _py(v) for k, v in config.items()}


def _config_key(config: Mapping[str, Any]):
    return tuple(sorted(config.items()))


@dataclasses.dataclass
class OnlineWindow:
    """One window's decision record (mirrors the journaled event)."""

    index: int
    epoch_lo: int
    epoch_hi: int
    deployed: Config
    candidates: List[Config]
    deployed_wall_ms: float
    candidate_walls_ms: List[float]
    divergence: Optional[float]
    residual_z: Optional[float]
    detect: bool
    cause: Optional[str]
    switched: bool
    blocked: bool
    switched_to: Optional[Config] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class OnlineTuningResult:
    """Timeline + receipts of one online-tuning run.

    ``total_wall_ms`` is the DEPLOYED system's cumulative simulated wall —
    row 0 summed over every window, including the mis-configured epochs
    before each re-adaptation — i.e. exactly the quantity the drift
    benchmark compares against the static-best and default arms.
    """

    scenario: str
    windows: List[OnlineWindow]
    total_wall_ms: float
    switches: int
    detections: int
    guard_blocks: int
    thrash_events: int
    evals_used: int
    budget: int
    final_config: Config
    wall_s: float

    @property
    def deployed_walls(self) -> np.ndarray:
        """Per-window deployed wall (ms), the readaptation timeline."""
        return np.array([w.deployed_wall_ms for w in self.windows])

    @property
    def switch_windows(self) -> List[int]:
        return [w.index for w in self.windows if w.switched]

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["windows"] = [w.to_dict() for w in self.windows]
        return d


class OnlineTuner:
    """The sliding-window control loop; drive via :meth:`run`.

    Deterministic in ``(study spec, seed, loop parameters)`` — no wall
    clock or unseeded randomness feeds any decision, which is what makes
    the journal's byte-identical kill/resume contract possible.
    """

    def __init__(self, study, *, window_epochs: int, batch_size: int = 6,
                 budget: int = 10 ** 9, seed: int = 0, n_init: int = 8,
                 hysteresis: float = 0.05, dwell_windows: int = 2,
                 div_threshold: float = 0.25, resid_z: float = 4.0,
                 resid_rel: float = 0.15, elites: int = 3,
                 space: Optional[KnobSpace] = None,
                 journal: Optional[str] = None, resume: bool = False,
                 verbose: bool = False):
        if window_epochs < 1:
            raise ValueError(
                f"window_epochs must be >= 1, got {window_epochs}")
        if batch_size < 1:
            raise ValueError(
                f"online tuning needs batch_size >= 1 candidate per "
                f"window, got {batch_size}")
        if not 0.0 <= hysteresis < 1.0:
            raise ValueError(
                f"hysteresis must be in [0, 1), got {hysteresis}")
        if dwell_windows < 1:
            raise ValueError(
                f"dwell_windows must be >= 1, got {dwell_windows}")
        opts = study.spec.options
        if opts.backend != "jax":
            raise ValueError(
                "online tuning runs candidate batches as CRN counterfactual"
                " segments, which requires the compiled backend: construct "
                "the study with SimOptions(backend='jax', crn=True)")
        self.study = study
        self.window_epochs = int(window_epochs)
        self.q = int(batch_size)
        self.budget = int(budget)
        self.seed = int(seed)
        self.n_init = int(n_init)
        self.hysteresis = float(hysteresis)
        self.dwell_windows = int(dwell_windows)
        self.div_threshold = float(div_threshold)
        self.resid_z = float(resid_z)
        self.resid_rel = float(resid_rel)
        self.n_elites = int(elites)
        self.space = space if space is not None \
            else SPACES.get(study.spec.engine.name)
        if self.space is None:
            raise ValueError(
                f"engine {study.spec.engine.name!r} has no registered knob "
                f"space; online tuning needs one (see repro.core.knobs)")
        self.journal_path = journal
        self.resume = resume
        self.verbose = verbose

    # -- optimizer lifecycle ----------------------------------------------
    def _fresh_optimizer(self, phase_idx: int,
                         prior: Optional[SMACOptimizer],
                         deployed: Config) -> SMACOptimizer:
        """Phase ``phase_idx``'s optimizer; warm-restarted from ``prior``."""
        seeds: List[Config] = []
        if prior is not None:
            seeds.append(dict(deployed))
            seen = {_config_key(deployed)}
            for obs in sorted(prior.observations, key=lambda o: o.value):
                k = _config_key(obs.config)
                if k not in seen:
                    seen.add(k)
                    seeds.append(dict(obs.config))
                if len(seeds) >= 1 + self.n_elites:
                    break
        return SMACOptimizer(
            self.space, seed=self.seed + 1000 * phase_idx,
            n_init=self.n_init if prior is None
            else min(self.n_init, 2 * self.q),
            start_with_default=prior is None,
            seed_configs=seeds or None)

    # -- detection ---------------------------------------------------------
    @staticmethod
    def _window_hist(out: Mapping[str, Any]) -> Optional[np.ndarray]:
        reads, writes = out.get("trace_reads"), out.get("trace_writes")
        if reads is None:
            return None
        h = (np.asarray(reads, dtype=np.float64).sum(axis=0)
             + np.asarray(writes, dtype=np.float64).sum(axis=0))
        s = h.sum()
        return h / s if s > 0 else h

    def _residual_z(self, opt: SMACOptimizer, deployed: Config,
                    wall: float) -> Optional[float]:
        if len(opt.observations) < max(4, self.q + 1):
            return None
        mean, std = opt.surrogate().predict(
            self.space.encode(deployed)[None, :])
        resid = float(wall) - float(mean[0])
        if abs(resid) <= self.resid_rel * max(abs(float(mean[0])), 1e-9):
            return 0.0  # inside the relative floor: never a detection
        return resid / max(float(std[0]), 1e-9)

    # -- the loop ----------------------------------------------------------
    def run(self) -> OnlineTuningResult:
        from .tune_service.journal import StudyJournal

        study, spec, opts = self.study, self.study.spec, \
            self.study.spec.options
        workload = study.workload()
        engine = spec.engine.name
        if not engine_jax.supports(engine, opts.sampler, workload.n_pages):
            raise ValueError(
                f"online tuning requires the compiled path but "
                f"engine={engine!r}, sampler={opts.sampler!r}, "
                f"n_pages={workload.n_pages} is not jax-supported "
                f"(see engine_jax.supports)")
        W = self.window_epochs
        n_epochs = workload.n_epochs
        n_windows = -(-n_epochs // W)
        journal = StudyJournal(self.journal_path, resume=self.resume) \
            if self.journal_path else None
        t0 = time.perf_counter()

        deployed = _py_config(spec.engine.config)
        prev_deployed: Optional[Config] = None
        opt = self._fresh_optimizer(0, None, deployed)
        windows: List[OnlineWindow] = []
        carry = None
        prev_hist: Optional[np.ndarray] = None
        last_switch = -self.dwell_windows  # first switch is dwell-eligible
        total_wall = 0.0
        switches = detections = guard_blocks = thrash = evals = 0

        if journal is not None:
            journal.append({
                "event": "online", "version": ONLINE_JOURNAL_VERSION,
                "spec": spec.to_dict(), "window_epochs": W,
                "q": self.q, "budget": self.budget, "seed": self.seed,
                "n_init": self.n_init, "hysteresis": self.hysteresis,
                "dwell_windows": self.dwell_windows,
                "div_threshold": self.div_threshold,
                "resid_z": self.resid_z, "resid_rel": self.resid_rel,
                "elites": self.n_elites})

        for k in range(n_windows):
            lo, hi = k * W, min((k + 1) * W, n_epochs)
            n_ask = min(self.q, max(0, self.budget - evals))
            cands = [_py_config(c) for c in opt.ask_batch(n_ask)] \
                if n_ask else []
            # pad to the fixed batch width so every full-length window
            # reuses ONE compiled segment shape
            batch = [deployed] + cands \
                + [dict(deployed)] * (self.q - len(cands))
            seg_carry = None if carry is None else \
                engine_jax.broadcast_carry_row(carry, 0, len(batch))
            out = run_simulation_segment(
                workload, engine, batch, study.machine,
                fast_slow_ratio=spec.fast_slow_ratio, seeds=opts.seed,
                sampler=opts.sampler,
                fast_capacity_pages=spec.fast_capacity_pages,
                backend="jax", crn=True, exact_select=opts.exact_select,
                epoch_start=lo, epoch_stop=hi, carry=seg_carry,
                return_carry=True)
            carry = out["carry"]
            win_wall = np.asarray(out["wall_ms"]).sum(axis=0)
            dep_wall = float(win_wall[0])
            cand_walls = [float(v) for v in win_wall[1:1 + len(cands)]]
            total_wall += dep_wall
            # the optimizer and the residual detector see PER-EPOCH walls,
            # so a short final window stays comparable to full windows;
            # the journaled/cumulative walls stay raw sums
            per_epoch = win_wall / float(hi - lo)
            dep_pe = float(per_epoch[0])
            cand_pe = [float(v) for v in per_epoch[1:1 + len(cands)]]

            # detect BEFORE telling: the residual must test the forest as
            # it stood when this window started
            z = self._residual_z(opt, deployed, dep_pe)
            hist = self._window_hist(out)
            div = None if (prev_hist is None or hist is None) \
                else histogram_divergence(prev_hist, hist)
            causes = []
            if div is not None and div > self.div_threshold:
                causes.append("histogram")
            if z is not None and abs(z) > self.resid_z:
                causes.append("residual")
            detect = bool(causes)

            opt.tell_batch([deployed] + cands, [dep_pe] + cand_pe)
            evals += len(cands)

            if detect:
                detections += 1
                opt = self._fresh_optimizer(detections, opt, deployed)

            # hysteresis/dwell switch guard
            switched = blocked = False
            switched_to: Optional[Config] = None
            if cand_walls:
                best = int(np.argmin(cand_walls))
                improves = cand_walls[best] \
                    < dep_wall * (1.0 - self.hysteresis)
                if improves and k - last_switch >= self.dwell_windows:
                    if prev_deployed is not None \
                            and _config_key(cands[best]) == \
                            _config_key(prev_deployed) \
                            and k - last_switch <= 2 * self.dwell_windows:
                        thrash += 1  # A->B->A revert inside 2*dwell
                    prev_deployed = deployed
                    deployed = dict(cands[best])
                    switched_to = deployed
                    switched, last_switch = True, k
                    switches += 1
                elif improves:
                    blocked = True
                    guard_blocks += 1

            win = OnlineWindow(
                index=k, epoch_lo=lo, epoch_hi=hi,
                deployed=dict(batch[0]), candidates=cands,
                deployed_wall_ms=dep_wall, candidate_walls_ms=cand_walls,
                divergence=None if div is None else float(div),
                residual_z=None if z is None else float(z),
                detect=detect, cause="+".join(causes) or None,
                switched=switched, blocked=blocked, switched_to=switched_to)
            windows.append(win)
            if journal is not None:
                journal.append({"event": "window", **win.to_dict()})
            if self.verbose:
                print(f"[online] window {k:3d} [{lo:3d},{hi:3d}) "
                      f"wall={dep_wall:9.1f}ms div={div if div is None else round(div, 4)} "
                      f"{'DETECT ' + win.cause if detect else ''}"
                      f"{'SWITCH' if switched else ''}"
                      f"{'BLOCKED' if blocked else ''}")
            prev_hist = hist

        result = OnlineTuningResult(
            scenario=study.key, windows=windows,
            total_wall_ms=float(total_wall), switches=switches,
            detections=detections, guard_blocks=guard_blocks,
            thrash_events=thrash, evals_used=evals, budget=self.budget,
            final_config=dict(deployed),
            wall_s=time.perf_counter() - t0)
        if journal is not None:
            journal.append({
                "event": "done", "windows": n_windows,
                "switches": switches, "detections": detections,
                "guard_blocks": guard_blocks, "thrash": thrash,
                "evals": evals, "total_wall_ms": float(total_wall),
                "final_config": dict(deployed)})
            journal.close()
        return result
