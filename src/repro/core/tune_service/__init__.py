"""Asynchronous trial-executor tuning service (deterministic, resumable,
fault-tolerant).

The package behind ``Study.tune(executor="async"|"fleet", slots=N,
scheduler="asha"|None, journal=..., resume=...)``:

* :mod:`.trial` — the PENDING/RUNNING/PAUSED/TERMINATED/FAILED trial state
  machine, carrying the frozen spec, RNG counters and the mid-run epoch
  loop checkpoint (``lax.scan`` carry);
* :mod:`.executor` — N saturated evaluation slots (thread/process) with
  results committed in canonical unit-creation order;
* :mod:`.coordinator` + :mod:`.worker` — the multi-host rung: a
  lease-and-commit coordinator serving ONE shared work queue to remote
  worker processes, with heartbeats, straggler re-issue (duplicate
  execution is safe — first commit wins, the twin is asserted bitwise
  equal), bounded respawns, worker reconnect-with-backoff and graceful
  degradation to local slots;
* :mod:`.transport` — the authenticated socket frame codec (HMAC-signed,
  length-capped, replay-protected, bounded reads) plus the frozen-JSON
  :class:`~repro.core.tune_service.transport.FleetSpec` that
  ``tools/fleet_launch.py`` deploys fleets from;
* :mod:`.faults` — the fault-injection harness (kill / stall / hang /
  drop / dup / delay, plus the network-shaped corrupt / truncate /
  replay / partition / latency injections, keyed by deterministic unit
  coordinates) driving the robustness test matrix;
* :mod:`.asha` — asynchronous successive halving over ¼/½/full epoch
  rungs;
* :mod:`.journal` — the JSON-lines study journal; a killed study resumes
  by replaying the deterministic control loop against the journal as an
  evaluation cache, byte-identically;
* :mod:`.service` — the control loop tying the above together.
"""

from .asha import ASHAScheduler, PROMOTE, RUNG_FRACTIONS, STOP
from .coordinator import FleetExecutor
from .executor import TrialExecutor
from .faults import (FailNTimes, FaultPlan, KillNTimes, NO_FAULTS,
                     SlowObjective, tear_journal)
from .journal import StudyJournal, VERSION, read_events
from .service import AsyncTuningResult, TuneService
from .transport import (FleetSpec, FrameChannel, FrameError,
                        FrameReplayError, FrameSignatureError,
                        FrameTimeoutError, FrameTooLargeError,
                        FrameTruncatedError)
from .trial import (FAILED, PAUSED, PENDING, RUNNING, TERMINATED,
                    TRANSITIONS, Trial)

__all__ = [
    "ASHAScheduler", "PROMOTE", "RUNG_FRACTIONS", "STOP",
    "FleetExecutor", "TrialExecutor",
    "FailNTimes", "FaultPlan", "KillNTimes", "NO_FAULTS",
    "SlowObjective", "tear_journal",
    "StudyJournal", "VERSION", "read_events",
    "AsyncTuningResult", "TuneService",
    "FleetSpec", "FrameChannel", "FrameError", "FrameReplayError",
    "FrameSignatureError", "FrameTimeoutError", "FrameTooLargeError",
    "FrameTruncatedError",
    "FAILED", "PAUSED", "PENDING", "RUNNING", "TERMINATED",
    "TRANSITIONS", "Trial",
]
