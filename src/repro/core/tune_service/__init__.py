"""Asynchronous trial-executor tuning service (deterministic, resumable).

The package behind ``Study.tune(executor="async", slots=N,
scheduler="asha"|None, journal=..., resume=...)``:

* :mod:`.trial` — the PENDING/RUNNING/PAUSED/TERMINATED/FAILED trial state
  machine, carrying the frozen spec, RNG counters and the mid-run epoch
  loop checkpoint (``lax.scan`` carry);
* :mod:`.executor` — N saturated evaluation slots (thread/process) with
  results committed in canonical unit-creation order;
* :mod:`.asha` — asynchronous successive halving over ¼/½/full epoch
  rungs;
* :mod:`.journal` — the JSON-lines study journal; a killed study resumes
  by replaying the deterministic control loop against the journal as an
  evaluation cache, byte-identically;
* :mod:`.service` — the control loop tying the above together.
"""

from .asha import ASHAScheduler, PROMOTE, RUNG_FRACTIONS, STOP
from .executor import TrialExecutor
from .journal import StudyJournal, VERSION, read_events
from .service import AsyncTuningResult, TuneService
from .trial import (FAILED, PAUSED, PENDING, RUNNING, TERMINATED,
                    TRANSITIONS, Trial)

__all__ = [
    "ASHAScheduler", "PROMOTE", "RUNG_FRACTIONS", "STOP",
    "TrialExecutor",
    "StudyJournal", "VERSION", "read_events",
    "AsyncTuningResult", "TuneService",
    "FAILED", "PAUSED", "PENDING", "RUNNING", "TERMINATED",
    "TRANSITIONS", "Trial",
]
