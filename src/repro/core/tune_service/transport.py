"""Authenticated, capped, replay-protected frame codec for fleet sockets.

The PR 8 socket transport moved raw ``struct``-framed pickles: any peer
that could reach the port could lease work units (pickles execute on
load), a corrupt 4-byte length header triggered an up-to-4 GiB allocation
before any validation, and a stalled peer could wedge the other endpoint
forever between a frame's header and its body.  This module replaces that
with a codec both endpoints share:

frame layout (everything big-endian)::

    magic   3 bytes   b"RFT"            \\
    version 1 byte    VERSION            | header, 16 bytes
    seq     8 bytes   per-direction counter, 0, 1, 2, ...
    length  4 bytes   payload byte count /
    sig     32 bytes  HMAC-SHA256(key, header || payload)
    payload length bytes  pickled message

and the receive path enforces, strictly in this order:

1. **magic + version** checked from the fixed-size header —
   :class:`FrameMagicError` / :class:`FrameVersionError` on mismatch
   (a stray client, an incompatible peer);
2. **length cap** checked *before any payload allocation* —
   :class:`FrameTooLargeError` (one hostile header can no longer balloon
   a 4 GiB buffer);
3. **bounded body read** — once the first header byte arrives, the rest
   of the frame must arrive within ``frame_timeout_s`` or the read fails
   with :class:`FrameTimeoutError` (a stalled or malicious peer costs a
   bounded wait, never a wedged serve loop);
4. **signature** verified (constant-time) over header+payload with the
   fleet's shared secret — :class:`FrameSignatureError` rejects unsigned,
   re-keyed or bit-flipped frames *before* the payload is unpickled;
5. **sequence** must be exactly the next expected per-direction counter —
   :class:`FrameReplayError` rejects replayed (and reordered) frames even
   though their signatures verify.

Only after all five gates does ``pickle.loads`` run, and only on bytes
authenticated by the shared key — the trust model is "anyone holding the
fleet spec's ``auth_key``", not "anyone who can reach the port".  The
coordinator journals rejected frames attributable to a leased unit as
``reject`` events and drops the connection (see
:class:`~repro.core.tune_service.coordinator.FleetExecutor`); the worker
treats any :class:`FrameError` as a lost transport and re-dials.

:class:`FleetSpec` is the frozen JSON bundle that makes a multi-host
fleet deployable from ONE artifact: the coordinator bind address, the
shared ``auth_key``, worker count / host list, heartbeat + lease
parameters and the frame caps.  ``tools/fleet_launch.py`` turns a spec
into N running workers (local subprocesses, or printed per-host
commands); ``Study.tune(executor="fleet", pool="socket",
fleet_spec=...)`` binds the coordinator to it.
"""

from __future__ import annotations

import dataclasses
import hmac
import hashlib
import json
import pickle
import secrets
import socket
import struct
import threading
import time
from typing import Any, Dict, Optional, Tuple

MAGIC = b"RFT"
VERSION = 1

#: header: magic(3) + version(1) + seq(8) + length(4)
_HEADER = struct.Struct(">3sBQI")
SIG_BYTES = 32

#: hard cap on one frame's payload (work units are small dicts: a pickled
#: module-level function reference, a spec tuple and segment bounds; result
#: frames carry one float64 per epoch) — raise via FleetSpec for exotic
#: payloads, never unbounded
DEFAULT_MAX_FRAME_BYTES = 1 << 20
#: once a frame's first byte arrives, the rest must arrive within this
DEFAULT_FRAME_TIMEOUT_S = 5.0
#: how long a just-accepted connection gets to present its signed greet
DEFAULT_GREET_TIMEOUT_S = 5.0


class FrameError(Exception):
    """A frame failed validation; the connection cannot be trusted and
    must be dropped (the stream offset is unrecoverable anyway)."""

    #: short machine-readable reason (stable: journaled in reject events)
    reason = "frame"


class FrameMagicError(FrameError):
    reason = "bad-magic"


class FrameVersionError(FrameError):
    reason = "bad-version"


class FrameTooLargeError(FrameError):
    reason = "oversize"


class FrameSignatureError(FrameError):
    reason = "bad-signature"


class FrameReplayError(FrameError):
    reason = "replay"


class FrameTimeoutError(FrameError):
    reason = "timeout"


class FrameTruncatedError(FrameError):
    reason = "truncated"


class FrameProtocolError(FrameError):
    reason = "protocol"


def reject_reason(exc: BaseException) -> str:
    """The journal-stable reason string for a rejected frame."""
    if isinstance(exc, FrameError):
        return exc.reason
    return "transport"


def _sign(key: bytes, header: bytes, payload: bytes) -> bytes:
    return hmac.new(key, header + payload, hashlib.sha256).digest()


class FrameChannel:
    """One socket wrapped in the signed frame codec.

    Each endpoint keeps independent per-direction counters: ``send``
    stamps frames 0, 1, 2, ... and ``recv`` requires exactly the next
    expected counter, so a captured frame cannot be replayed into the
    same connection.  Sends are serialized by an internal lock (the
    worker's serve loop and its evaluation thread may both send).
    """

    def __init__(self, sock: socket.socket, key: bytes, *,
                 max_frame: int = DEFAULT_MAX_FRAME_BYTES,
                 frame_timeout_s: float = DEFAULT_FRAME_TIMEOUT_S):
        if not isinstance(key, (bytes, bytearray)) or len(key) < 16:
            raise ValueError("auth key must be >= 16 bytes of shared "
                             "secret (see FleetSpec.generate)")
        self.sock = sock
        self._key = bytes(key)
        self.max_frame = int(max_frame)
        self.frame_timeout_s = float(frame_timeout_s)
        self._send_seq = 0
        self._recv_seq = 0
        self._lock = threading.Lock()

    # -- send --------------------------------------------------------------
    def encode(self, obj: Any) -> bytes:
        """Serialize + sign one frame, consuming a send sequence number.
        Exposed (rather than inlined in :meth:`send`) so the fault
        harness can mangle an otherwise-valid frame."""
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        if len(payload) > self.max_frame:
            raise FrameTooLargeError(
                f"outgoing frame payload is {len(payload)} bytes "
                f"(cap {self.max_frame})")
        with self._lock:
            seq = self._send_seq
            self._send_seq += 1
        header = _HEADER.pack(MAGIC, VERSION, seq, len(payload))
        return header + _sign(self._key, header, payload) + payload

    def send(self, obj: Any) -> None:
        self.send_bytes(self.encode(obj))

    def send_bytes(self, raw: bytes) -> None:
        with self._lock:
            self.sock.sendall(raw)

    # -- recv --------------------------------------------------------------
    def _recv_exact(self, n: int, deadline: Optional[float],
                    started: bool) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            if deadline is not None:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise FrameTimeoutError(
                        f"frame body did not arrive within "
                        f"{self.frame_timeout_s}s")
                self.sock.settimeout(left)
            else:
                self.sock.settimeout(None)
            try:
                chunk = self.sock.recv(n - len(buf))
            except socket.timeout:
                raise FrameTimeoutError(
                    f"frame body did not arrive within "
                    f"{self.frame_timeout_s}s") from None
            if not chunk:
                if buf or started:
                    raise FrameTruncatedError(
                        "connection closed mid-frame")
                raise EOFError("fleet connection closed")
            buf += chunk
        return bytes(buf)

    def recv(self, wait_timeout: Optional[float] = None) -> Optional[Any]:
        """Receive one validated frame.

        ``wait_timeout`` bounds the wait for the frame to *start*
        (``None`` blocks; on expiry with no bytes, returns ``None`` — an
        idle poll).  Once the first byte arrives the WHOLE frame must
        land within ``frame_timeout_s`` (:class:`FrameTimeoutError`
        otherwise) — a peer can no longer wedge this endpoint between a
        header and its body.  Raises a :class:`FrameError` subclass on
        any validation failure and ``EOFError`` on clean close."""
        self.sock.settimeout(wait_timeout)
        try:
            first = self.sock.recv(1)
        except (socket.timeout, BlockingIOError):
            # BlockingIOError: wait_timeout == 0 puts the socket in
            # non-blocking mode — an empty instant poll, not an error
            return None
        if not first:
            raise EOFError("fleet connection closed")
        deadline = time.monotonic() + self.frame_timeout_s
        header = first + self._recv_exact(_HEADER.size - 1, deadline, True)
        magic, version, seq, length = _HEADER.unpack(header)
        if magic != MAGIC:
            raise FrameMagicError(f"bad frame magic {magic!r}")
        if version != VERSION:
            raise FrameVersionError(
                f"frame version {version} != {VERSION}")
        # the cap gates BEFORE the payload buffer exists: a corrupt or
        # hostile length header costs nothing
        if length > self.max_frame:
            raise FrameTooLargeError(
                f"frame claims {length} bytes (cap {self.max_frame})")
        sig = self._recv_exact(SIG_BYTES, deadline, True)
        payload = self._recv_exact(length, deadline, True)
        if not hmac.compare_digest(sig,
                                   _sign(self._key, header, payload)):
            raise FrameSignatureError(
                "frame signature does not verify (wrong or missing "
                "auth key, or a corrupted frame)")
        if seq != self._recv_seq:
            raise FrameReplayError(
                f"frame sequence {seq} != expected {self._recv_seq} "
                f"(replayed or reordered frame)")
        self._recv_seq += 1
        # only authenticated bytes reach the unpickler
        return pickle.loads(payload)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


# -- the greet handshake -----------------------------------------------------
def greet(channel: FrameChannel, worker_id: int,
          timeout_s: float = DEFAULT_GREET_TIMEOUT_S) -> None:
    """Worker side: present the signed hello and await the coordinator's
    welcome.  Raises :class:`FrameProtocolError` if the coordinator does
    not accept (wrong key never gets a welcome — the connection is simply
    dropped)."""
    channel.send({"type": "hello", "worker": int(worker_id)})
    try:
        ack = channel.recv(wait_timeout=timeout_s)
    except (EOFError, OSError) as e:
        raise FrameProtocolError(
            "coordinator dropped the connection during greet (auth key "
            "mismatch?)") from e
    if not (isinstance(ack, dict) and ack.get("type") == "welcome"
            and ack.get("worker") == int(worker_id)):
        raise FrameProtocolError(f"expected a welcome frame, got {ack!r}")


def accept_greet(channel: FrameChannel,
                 timeout_s: float = DEFAULT_GREET_TIMEOUT_S) -> int:
    """Coordinator side: require a signed hello as the connection's first
    frame (authenticating ``worker_id`` before any unit can be leased)
    and acknowledge it.  Raises :class:`FrameError` on anything else."""
    hello = channel.recv(wait_timeout=timeout_s)
    if hello is None:
        raise FrameTimeoutError("connection presented no greet in time")
    if not (isinstance(hello, dict) and hello.get("type") == "hello"
            and isinstance(hello.get("worker"), int)
            and not isinstance(hello.get("worker"), bool)):
        raise FrameProtocolError(f"greet is not a hello frame: {hello!r}")
    wid = int(hello["worker"])
    channel.send({"type": "welcome", "worker": wid})
    return wid


# -- the fleet spec ----------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Frozen, JSON-round-trippable description of one deployable fleet.

    One spec file is the whole hand-off between the coordinator host and
    the worker hosts: where to connect, the shared ``auth_key`` every
    frame is signed with, how many workers to expect, and the transport
    caps.  ``hosts`` empty means the coordinator self-spawns ``workers``
    local socket workers (the test/benchmark shape); a non-empty host
    list means the workers are launched externally
    (``tools/fleet_launch.py``) and the coordinator waits up to
    ``boot_grace_s`` for them to greet before degrading.

    The ``auth_key`` is a secret: keep spec files out of version control
    and world-readable paths.  :meth:`generate` mints a fresh key.
    """

    workers: int = 2
    hosts: Tuple[str, ...] = ()
    host: str = "127.0.0.1"
    port: int = 0                     # 0 = ephemeral (self-spawned fleets)
    auth_key: str = ""                # hex-encoded shared secret
    heartbeat_s: float = 0.1
    lease_deadline: int = 30          # missed-heartbeat count, wall-clock-free
    max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES
    frame_timeout_s: float = DEFAULT_FRAME_TIMEOUT_S
    max_redials: int = 8
    redial_backoff_s: float = 0.2
    boot_grace_s: float = 60.0

    def __post_init__(self):
        object.__setattr__(self, "hosts", tuple(self.hosts))
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.hosts and len(self.hosts) != self.workers:
            raise ValueError(
                f"hosts lists {len(self.hosts)} entries for "
                f"workers={self.workers}; list one host per worker "
                f"(repeat a host to run several workers on it)")
        if self.auth_key:
            try:
                key = bytes.fromhex(self.auth_key)
            except ValueError:
                raise ValueError("auth_key must be hex-encoded") from None
            if len(key) < 16:
                raise ValueError("auth_key must be >= 16 bytes (32 hex "
                                 "chars); use FleetSpec.generate()")
        if self.max_frame_bytes < 4096:
            raise ValueError("max_frame_bytes must be >= 4096")
        if self.frame_timeout_s <= 0 or self.heartbeat_s <= 0:
            raise ValueError("frame_timeout_s and heartbeat_s must be > 0")
        if self.lease_deadline < 1:
            raise ValueError("lease_deadline must be >= 1 heartbeat")

    @classmethod
    def generate(cls, **kw) -> "FleetSpec":
        """A spec with a freshly minted 32-byte auth key."""
        kw.setdefault("auth_key", secrets.token_hex(32))
        return cls(**kw)

    @property
    def key_bytes(self) -> bytes:
        if not self.auth_key:
            raise ValueError(
                "fleet spec has no auth_key; use FleetSpec.generate() or "
                "set auth_key explicitly")
        return bytes.fromhex(self.auth_key)

    @property
    def external(self) -> bool:
        """Workers are launched outside the coordinator process."""
        return bool(self.hosts)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["hosts"] = list(self.hosts)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FleetSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown FleetSpec fields {sorted(unknown)}; expected a "
                f"subset of {sorted(known)}")
        return cls(**d)

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_dict(), fh, indent=2, sort_keys=True)
            fh.write("\n")

    @classmethod
    def load(cls, path: str) -> "FleetSpec":
        with open(path, "r", encoding="utf-8") as fh:
            return cls.from_dict(json.load(fh))
