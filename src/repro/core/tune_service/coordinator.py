"""FleetExecutor: a lease-and-commit trial queue over remote workers.

The multi-host rung of the tuning service (ROADMAP item 3).  One
coordinator owns the study — the journal, the optimizer, the canonical
commit order — and serves work units from ONE shared queue to N
:mod:`.worker` processes (``pool="process"`` on this box, ``pool="socket"``
across hosts).  The class is a drop-in for
:class:`~repro.core.tune_service.executor.TrialExecutor` (same
``submit``/``submit_ready``/``pop_next``/``outstanding`` surface), so the
:class:`~repro.core.tune_service.service.TuneService` control loop — and
every determinism property it pins — is reused unchanged.

**Lease-and-commit.**  Each dispatched unit carries a lease: the worker
must heartbeat it every ``heartbeat_s`` while the segment runs, and a
lease that goes silent for ``lease_deadline`` heartbeat intervals (or
whose worker provably died — process sentinel, socket EOF, or an idle
heartbeat proving the result was lost in flight) **expires**.  An expired
unit is **re-issued** to another worker, at most ``max_attempts`` times
with a short backoff, before it is surrendered as an error result (which
the service turns into a bounded trial ``retry``, then FAILED).
Re-issue is safe *because* the study is deterministic: a unit is a pure
function of its canonical coordinates (seed + batch offset + segment
bounds), so duplicate execution returns the same bits — the first result
to land commits, and any late twin is **asserted bitwise equal** against
the committed digest (a cheap, always-on placement-invariance check).

**Determinism of the journal.**  Lease lifecycle events
(``lease``/``expire``/``reissue``) are collected per unit and journaled
by the service at the unit's *commit* point, in canonical order — never
at wall-clock detection time.  Worker ids stay out of the journal
(placement is irrelevant to the study), deadlines are recorded as
heartbeat *counts* (wall-clock-free), and each worker runs exactly one
unit at a time, so an injected fault keyed by ``(unit, attempt)``
(:mod:`.faults`) perturbs exactly one lease no matter which worker drew
the unit.  Two runs under the same fault plan therefore write
byte-identical journals, and a coordinator SIGKILLed mid-re-issue
resumes byte-identically (the re-issue in flight simply replays).

**Graceful degradation.**  Dead process workers are respawned up to
``max_respawns`` times — each respawn first promotes a booted hot-spare
worker when one is up, so the slot refills instantly and the fresh
interpreter boot (hundreds of milliseconds under the spawn start method)
happens on the replacement spare, off the critical path.  When the live
fleet shrinks to zero, queued units run on the coordinator's local slot
instead — the study finishes slower, never wedges.
"""

from __future__ import annotations

import collections
import queue as queue_mod
import socket
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .executor import _timed_safe
from .faults import NO_FAULTS, FaultPlan
from .worker import (DEFAULT_HEARTBEAT_S, process_main, recv_frame,
                     send_frame, socket_main)

FLEET_POOLS = ("process", "socket")

#: default lease deadline, in missed-heartbeat counts (wall-clock-free)
DEFAULT_LEASE_DEADLINE = 30
#: give up re-issuing a unit after this many lease attempts
DEFAULT_MAX_ATTEMPTS = 4


def _result_digest(result: Dict[str, Any]) -> Optional[bytes]:
    """A canonical digest of a unit result for the duplicate-execution
    equality assertion (None for error results — tracebacks may differ)."""
    if "error" in result:
        return None
    if "wall_ms" in result:
        return np.ascontiguousarray(
            np.asarray(result["wall_ms"], dtype=np.float64)).tobytes()
    if "value" in result:
        return repr(float(result["value"])).encode()
    return None


class _ProcessFleet:
    """Process-transport fleet: mp workers on this box, queue messaging.

    Keeps ``spares`` hot-spare workers booted but never leased: a worker
    death promotes a spare instantly instead of paying a fresh
    interpreter boot on the critical path (under the spawn start method
    a boot costs hundreds of milliseconds of idle slot time per death —
    the replacement spare boots in the background while both promoted
    slots keep working)."""

    def __init__(self, n: int, heartbeat_s: float, faults: FaultPlan,
                 cache_dir: Optional[str], spares: int = 1):
        import multiprocessing as mp
        import sys
        # mirror the simulator pool's choice: forking once jax has
        # initialized its runtime threads is unsupported
        use_fork = "fork" in mp.get_all_start_methods() and \
            "jax" not in sys.modules
        self._ctx = mp.get_context("fork" if use_fork else "spawn")
        self._inbox = self._ctx.Queue()
        self._heartbeat_s = heartbeat_s
        self._faults = faults
        self._cache_dir = cache_dir
        self._procs: Dict[int, Any] = {}
        self._queues: Dict[int, Any] = {}
        self._reaped: set = set()
        self._spares: List[int] = []
        self.n_promotions = 0
        self._next_wid = 0
        for _ in range(n):
            self._spawn()
        for _ in range(spares):
            self._spares.append(self._spawn())

    def _spawn(self) -> int:
        wid = self._next_wid
        self._next_wid += 1
        q = self._ctx.Queue()
        p = self._ctx.Process(
            target=process_main,
            args=(wid, q, self._inbox, self._heartbeat_s, self._faults,
                  self._cache_dir),
            daemon=True, name=f"repro-fleet-w{wid}")
        p.start()
        self._procs[wid] = p
        self._queues[wid] = q
        return wid

    def spawn_worker(self) -> int:
        # promote a live hot spare if one is up: it is already booted
        # (and typically greeted), so the slot refills instantly; the
        # fresh boot happens on the NEW spare, off the critical path
        while self._spares:
            wid = self._spares.pop(0)
            if self._procs[wid].is_alive():
                self.n_promotions += 1
                self._spares.append(self._spawn())
                return wid
            self._reaped.add(wid)  # spare died while idle: skip it
        return self._spawn()

    def poll(self, timeout: float) -> Optional[Dict[str, Any]]:
        try:
            if timeout <= 0:
                return self._inbox.get_nowait()
            return self._inbox.get(timeout=timeout)
        except queue_mod.Empty:
            return None

    def send(self, wid: int, msg: Dict[str, Any]) -> None:
        self._queues[wid].put(msg)

    def dispatchable(self) -> List[int]:
        """Workers a unit can be sent to right now (spares are held in
        reserve: they only take work once promoted by a death)."""
        return [w for w, p in self._procs.items()
                if w not in self._reaped and w not in self._spares
                and p.is_alive()]

    def n_eligible(self, suspect) -> int:
        """Workers that could ever take work (degradation trigger).
        Suspects don't count: a wedged worker is alive but written off
        until it speaks again — waiting on it could wedge the study.
        Spares don't count either: with respawns exhausted they are
        never promoted, and waiting on one would wedge the study."""
        return len([w for w in self.dispatchable() if w not in suspect])

    def reap_dead(self) -> List[int]:
        # a dead hot spare held no lease and no slot: replace it
        # silently rather than reporting a worker death
        for wid in list(self._spares):
            if not self._procs[wid].is_alive():
                self._spares.remove(wid)
                self._reaped.add(wid)
                self._spares.append(self._spawn())
        dead = [w for w, p in self._procs.items()
                if w not in self._reaped and w not in self._spares
                and not p.is_alive()]
        self._reaped.update(dead)
        return dead

    def close(self) -> None:
        for wid, p in self._procs.items():
            if p.is_alive():
                try:
                    self._queues[wid].put({"type": "shutdown"})
                except Exception:
                    pass
        deadline = time.monotonic() + 2.0
        for p in self._procs.values():
            p.join(timeout=max(0.0, deadline - time.monotonic()))
            if p.is_alive():
                p.terminate()
                p.join(timeout=0.5)
                if p.is_alive():
                    p.kill()
        for q in list(self._queues.values()) + [self._inbox]:
            try:
                q.cancel_join_thread()
                q.close()
            except Exception:
                pass


class _SocketFleet:
    """Socket-transport fleet: TCP workers (spawned locally for tests and
    same-box runs; remote hosts join via ``python -m
    repro.core.tune_service.worker --connect HOST:PORT``)."""

    def __init__(self, n: int, heartbeat_s: float, faults: FaultPlan,
                 cache_dir: Optional[str], host: str = "127.0.0.1"):
        self._srv = socket.create_server((host, 0))
        self.address: Tuple[str, int] = self._srv.getsockname()[:2]
        self._inbox: "queue_mod.Queue" = queue_mod.Queue()
        self._heartbeat_s = heartbeat_s
        self._lock = threading.Lock()
        self._conns: Dict[int, socket.socket] = {}
        self._send_locks: Dict[int, threading.Lock] = {}
        self._eof: set = set()
        self._reaped: set = set()
        self._closing = False
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name="repro-fleet-accept")
        self._accept_thread.start()
        import multiprocessing as mp
        import sys
        use_fork = "fork" in mp.get_all_start_methods() and \
            "jax" not in sys.modules
        self._ctx = mp.get_context("fork" if use_fork else "spawn")
        self._faults = faults
        self._cache_dir = cache_dir
        self._procs: Dict[int, Any] = {}
        self._next_wid = 0
        for _ in range(n):
            self.spawn_worker()

    def spawn_worker(self) -> int:
        wid = self._next_wid
        self._next_wid += 1
        p = self._ctx.Process(
            target=socket_main,
            args=(self.address, wid, self._heartbeat_s, self._faults,
                  self._cache_dir),
            daemon=True, name=f"repro-fleet-w{wid}")
        p.start()
        self._procs[wid] = p
        return wid

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._reader, args=(conn,),
                             daemon=True).start()

    def _reader(self, conn: socket.socket) -> None:
        wid = None
        try:
            hello = recv_frame(conn)
            wid = int(hello["worker"])
            with self._lock:
                self._conns[wid] = conn
                self._send_locks[wid] = threading.Lock()
            self._inbox.put(hello)
            while True:
                self._inbox.put(recv_frame(conn))
        except (EOFError, OSError):
            if wid is not None:
                with self._lock:
                    self._eof.add(wid)
            try:
                conn.close()
            except OSError:
                pass

    def poll(self, timeout: float) -> Optional[Dict[str, Any]]:
        try:
            if timeout <= 0:
                return self._inbox.get_nowait()
            return self._inbox.get(timeout=timeout)
        except queue_mod.Empty:
            return None

    def send(self, wid: int, msg: Dict[str, Any]) -> None:
        with self._send_locks[wid]:
            send_frame(self._conns[wid], msg)

    def dispatchable(self) -> List[int]:
        with self._lock:
            return [w for w in self._conns
                    if w not in self._eof and w not in self._reaped]

    def n_eligible(self, suspect) -> int:
        # not-yet-connected spawned workers count: they are on their way;
        # suspects (wedged, written off until they speak) do not
        with self._lock:
            live_procs = sum(1 for w, p in self._procs.items()
                             if w not in self._reaped and w not in self._eof
                             and w not in suspect and p.is_alive())
            live_ext = sum(1 for w in self._conns
                           if w not in self._eof and w not in self._reaped
                           and w not in suspect and w not in self._procs)
        return live_procs + live_ext

    def reap_dead(self) -> List[int]:
        with self._lock:
            dead = set(self._eof) - self._reaped
            dead |= {w for w, p in self._procs.items()
                     if w not in self._reaped and not p.is_alive()}
            self._reaped.update(dead)
        return sorted(dead)

    def close(self) -> None:
        self._closing = True
        for wid in self.dispatchable():
            try:
                self.send(wid, {"type": "shutdown"})
            except OSError:
                pass
        try:
            self._srv.close()
        except OSError:
            pass
        deadline = time.monotonic() + 2.0
        for p in self._procs.values():
            p.join(timeout=max(0.0, deadline - time.monotonic()))
            if p.is_alive():
                p.terminate()
                p.join(timeout=0.5)
                if p.is_alive():
                    p.kill()
        with self._lock:
            for conn in self._conns.values():
                try:
                    conn.close()
                except OSError:
                    pass


class FleetExecutor:
    """``workers`` remote evaluation slots behind the lease-and-commit
    protocol, committed in canonical unit-creation order.  Drop-in for
    :class:`~repro.core.tune_service.executor.TrialExecutor`.

    ``busy_s`` is slot *occupancy* — wall time leases were held (issue to
    result, or to fault detection for expired leases) — not worker-side
    compute time: a coordinator doesn't control its workers' clocks, and
    occupancy is what the utilization receipt must measure (an aborted
    attempt occupied its slot; only detection/respawn/backoff gaps and
    starvation count as idle)."""

    def __init__(self, workers: int, pool: str = "process",
                 heartbeat_s: float = DEFAULT_HEARTBEAT_S,
                 lease_deadline: int = DEFAULT_LEASE_DEADLINE,
                 timeout_s: Optional[float] = None,
                 faults: FaultPlan = NO_FAULTS,
                 max_attempts: int = DEFAULT_MAX_ATTEMPTS,
                 max_respawns: Optional[int] = None,
                 backoff_s: float = 0.05):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if pool not in FLEET_POOLS:
            raise ValueError(f"unknown fleet pool {pool!r}; expected one "
                             f"of {FLEET_POOLS}")
        if lease_deadline < 1:
            raise ValueError("lease_deadline must be >= 1 heartbeat")
        self.slots = int(workers)
        self.pool_kind = pool
        self.heartbeat_s = float(heartbeat_s)
        self.lease_deadline = int(lease_deadline)
        self.timeout_s = timeout_s
        self.faults = faults if faults is not None else NO_FAULTS
        self.max_attempts = int(max_attempts)
        self.max_respawns = int(max_respawns) if max_respawns is not None \
            else int(workers)
        self.backoff_s = float(backoff_s)
        from ..simulator import compile_cache_dir
        cls = _ProcessFleet if pool == "process" else _SocketFleet
        self._fleet = cls(self.slots, self.heartbeat_s, self.faults,
                          compile_cache_dir())
        # unit state, keyed by canonical sequence number
        self._specs: Dict[int, Tuple[Callable, tuple, Optional[float]]] = {}
        self._queue: "collections.deque[Tuple[int, float]]" = \
            collections.deque()
        self._ready: Dict[int, Dict[str, Any]] = {}
        self._leases: Dict[int, Dict[str, Any]] = {}
        self._history: Dict[int, List[Dict[str, Any]]] = {}
        self._attempts: Dict[int, int] = {}
        self._digest: Dict[int, Optional[bytes]] = {}
        self._busy: Dict[int, int] = {}       # worker id -> unit seq
        self._suspect: set = set()            # wedged until they speak
        # workers that have spoken (hello or any later message).  A unit
        # is only ever leased to a greeted worker: a spawned process that
        # is still booting (interpreter start can take seconds once jax
        # forces the spawn start method) is not an issue target, and
        # leasing against it would start the silence clock on a worker
        # that cannot heartbeat yet — the lease would expire through no
        # fault of the protocol.  Booting workers still count as
        # *eligible* (they are on their way), so the coordinator does not
        # degrade to its local slot during a respawn.
        self._greeted: set = set()
        self._next_seq = 0
        self._next_commit = 0
        self.busy_s = 0.0
        # local degradation slot (lazy)
        self._local = None
        self._local_futs: Dict[int, Tuple[Any, float]] = {}
        # receipts
        self.n_reissues = 0
        self.n_expired = 0
        self.n_worker_deaths = 0
        self.n_respawns = 0
        self.n_duplicates = 0
        self.reissue_overhead_s = 0.0
        self.recover_s: List[float] = []
        self.degraded = False

    # -- submission --------------------------------------------------------
    def submit(self, fn: Callable[..., Dict[str, Any]], *args,
               timeout_s: Optional[float] = None) -> int:
        seq = self._next_seq
        self._next_seq += 1
        t = timeout_s if timeout_s is not None else self.timeout_s
        self._specs[seq] = (fn, args, t)
        self._history[seq] = []
        self._attempts[seq] = 0
        self._queue.append((seq, 0.0))
        self._pump(block=False)
        return seq

    def submit_ready(self, result: Dict[str, Any]) -> int:
        """A pre-resolved unit (journal-replay cache hit): holds its
        canonical commit slot, never touches the fleet."""
        seq = self._next_seq
        self._next_seq += 1
        self._ready[seq] = dict(result)
        return seq

    @property
    def outstanding(self) -> int:
        return self._next_seq - self._next_commit

    # -- canonical-order commits ------------------------------------------
    def pop_next(self) -> Tuple[int, Dict[str, Any]]:
        seq = self._next_commit
        while seq not in self._ready:
            self._pump(block=True)
        result = self._ready.pop(seq)
        self._digest[seq] = _result_digest(result)
        self._specs.pop(seq, None)
        self._attempts.pop(seq, None)
        self._next_commit += 1
        return seq, result

    def take_history(self, seq: int) -> List[Dict[str, Any]]:
        """The unit's lease lifecycle events, for commit-time journaling."""
        return self._history.pop(seq, [])

    # -- the pump: messages, liveness, leases, dispatch --------------------
    def _pump(self, block: bool) -> None:
        msg = self._fleet.poll(min(self.heartbeat_s, 0.05) if block else 0.0)
        while msg is not None:
            self._handle(msg)
            msg = self._fleet.poll(0.0)
        self._check_workers()
        self._check_leases()
        self._check_local()
        self._dispatch()

    def _handle(self, msg: Dict[str, Any]) -> None:
        kind = msg.get("type")
        wid = msg.get("worker")
        if wid is not None:
            self._suspect.discard(wid)
            self._greeted.add(wid)
        if kind == "hello":
            return
        if kind == "heartbeat":
            unit = msg.get("unit")
            if unit is None:
                # an idle heartbeat from a worker we believe is busy means
                # its result was lost in flight — expire the lease now
                seq = self._busy.get(wid)
                if seq is not None:
                    lease = self._leases.get(seq)
                    if lease is not None and lease["worker"] == wid and \
                            time.monotonic() - lease["issued"] > \
                            3 * self.heartbeat_s:
                        self._busy.pop(wid, None)
                        self._expire(seq, "lost")
                return
            lease = self._leases.get(unit)
            if lease is not None and lease["worker"] == wid and \
                    lease["attempt"] == msg.get("attempt"):
                lease["last_seen"] = time.monotonic()
            return
        if kind == "result":
            seq = int(msg["unit"])
            if self._busy.get(wid) == seq:
                self._busy.pop(wid)
            result = msg["result"]
            if seq < self._next_commit or seq in self._ready:
                # a duplicate or late twin: first commit won; assert the
                # twin returned the SAME bits (placement invariance).  The
                # twin's runtime is wasted occupancy: the slot was busy,
                # the work was redundant
                self._assert_twin(seq, result)
                self.n_duplicates += 1
                self.busy_s += float(result.get("slot_s", 0.0))
                self.reissue_overhead_s += float(result.get("slot_s", 0.0))
                return
            lease = self._leases.pop(seq, None)
            if lease is None and seq not in self._attempts:
                return  # unit unknown (e.g. surrendered and committed)
            # accept whichever attempt lands first; cancel any queued
            # re-issue of the same unit
            self._unqueue(seq)
            if lease is not None:
                # slot occupancy: wall time the lease was held, issue to
                # result — NOT worker-reported compute time, which a
                # coordinator doesn't control (and which shrinks under
                # less CPU contention, masking idle slots)
                self.busy_s += time.monotonic() - lease["issued"]
            self._ready[seq] = result
            return

    def _assert_twin(self, seq: int, result: Dict[str, Any]) -> None:
        want = self._digest.get(seq, _result_digest(self._ready.get(seq, {})))
        got = _result_digest(result)
        if want is not None and got is not None and want != got:
            raise RuntimeError(
                f"duplicate execution of unit {seq} returned different "
                f"bits — the evaluation is not placement-invariant (this "
                f"is a determinism bug, not a fleet fault)")

    def _unqueue(self, seq: int) -> None:
        for entry in list(self._queue):
            if entry[0] == seq:
                self._queue.remove(entry)

    def _check_workers(self) -> None:
        for wid in self._fleet.reap_dead():
            self.n_worker_deaths += 1
            self._suspect.discard(wid)
            seq = self._busy.pop(wid, None)
            if seq is not None and seq in self._leases:
                self._expire(seq, "worker-dead")
            if self.n_respawns < self.max_respawns:
                self.n_respawns += 1
                self._fleet.spawn_worker()

    def _check_leases(self) -> None:
        now = time.monotonic()
        silence = self.heartbeat_s * self.lease_deadline
        for seq, lease in list(self._leases.items()):
            if now - lease["last_seen"] > silence:
                # wedged, not provably dead: write the worker off until it
                # speaks again, but leave it marked busy (never re-booked)
                self._suspect.add(lease["worker"])
                self._expire(seq, "expired")

    def _expire(self, seq: int, reason: str) -> None:
        lease = self._leases.pop(seq, None)
        if lease is None:
            return
        now = time.monotonic()
        attempt = lease["attempt"]
        self.n_expired += 1
        self.recover_s.append(now - lease["last_seen"])
        # the doomed attempt occupied its slot from issue until the fault
        # was detected: wasted occupancy, not idle time — count it as
        # both busy and re-issue overhead so utilization measures idle
        # slots and reissue_overhead_s measures burned wall clock
        held = max(0.0, now - lease["issued"])
        self.busy_s += held
        self.reissue_overhead_s += held
        self._history[seq].append(
            {"event": "expire", "unit": seq, "attempt": attempt,
             "reason": reason})
        nxt = attempt + 1
        if nxt >= self.max_attempts:
            self._ready[seq] = {
                "error": f"lease expired {nxt} times (unit {seq}, last "
                         f"reason: {reason}); the fleet could not complete "
                         f"this unit", "slot_s": 0.0}
            return
        self._attempts[seq] = nxt
        self.n_reissues += 1
        self._history[seq].append(
            {"event": "reissue", "unit": seq, "attempt": nxt})
        # the first re-issue goes out immediately (the expiry already cost
        # detection latency); repeated failures of the SAME unit back off
        self._queue.appendleft((seq, now + self.backoff_s * (nxt - 1)))

    def _dispatch(self) -> None:
        while self._queue:
            seq, not_before = self._queue[0]
            now = time.monotonic()
            if not_before > now:
                break  # re-issue backoff; re-checked on the next pump
            wid = self._idle_worker()
            if wid is None:
                if self._fleet.n_eligible(self._suspect) == 0:
                    self._queue.popleft()
                    self._run_local(seq)
                    continue
                break
            self._queue.popleft()
            attempt = self._attempts[seq]
            fn, args, t = self._specs[seq]
            self._fleet.send(wid, {"type": "unit", "unit": seq,
                                   "attempt": attempt, "fn": fn,
                                   "args": args, "timeout_s": t})
            self._leases[seq] = {"worker": wid, "attempt": attempt,
                                 "issued": now, "last_seen": now}
            self._busy[wid] = seq
            if attempt == 0:
                self._history[seq].append(
                    {"event": "lease", "unit": seq, "attempt": 0,
                     "deadline": self.lease_deadline})

    def _idle_worker(self) -> Optional[int]:
        for wid in self._fleet.dispatchable():
            if wid not in self._busy and wid not in self._suspect \
                    and wid in self._greeted:
                return wid
        return None

    # -- graceful degradation: the coordinator's local slot ----------------
    def _run_local(self, seq: int) -> None:
        if self._local is None:
            import concurrent.futures
            self._local = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="repro-fleet-local")
        self.degraded = True
        attempt = self._attempts[seq]
        fn, args, _ = self._specs[seq]
        if attempt == 0:
            self._history[seq].append(
                {"event": "lease", "unit": seq, "attempt": 0,
                 "deadline": self.lease_deadline})
        self._local_futs[seq] = (self._local.submit(_timed_safe, fn, *args),
                                 time.monotonic())

    def _check_local(self) -> None:
        for seq, (fut, t0) in list(self._local_futs.items()):
            _, _, t = self._specs.get(seq, (None, None, None))
            if fut.done():
                del self._local_futs[seq]
                self.busy_s += time.monotonic() - t0
                self._ready[seq] = fut.result()
            elif t is not None and time.monotonic() - t0 > t:
                fut.cancel()
                del self._local_futs[seq]
                self.busy_s += time.monotonic() - t0
                self._ready[seq] = {
                    "error": f"timeout: unit {seq} exceeded {t}s on the "
                             f"local degradation slot", "timeout": True,
                    "slot_s": float(t)}

    # -- receipts / shutdown ----------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            "workers": self.slots,
            "pool": self.pool_kind,
            "n_reissues": self.n_reissues,
            "n_expired_leases": self.n_expired,
            "n_worker_deaths": self.n_worker_deaths,
            "n_respawns": self.n_respawns,
            "n_spare_promotions": getattr(self._fleet, "n_promotions", 0),
            "n_duplicate_results": self.n_duplicates,
            "reissue_overhead_s": float(self.reissue_overhead_s),
            "time_to_recover_s": [float(x) for x in self.recover_s],
            "degraded": self.degraded,
        }

    def close(self) -> None:
        self._fleet.close()
        if self._local is not None:
            self._local.shutdown(wait=False, cancel_futures=True)
