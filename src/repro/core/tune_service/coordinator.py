"""FleetExecutor: a lease-and-commit trial queue over remote workers.

The multi-host rung of the tuning service (ROADMAP item 3).  One
coordinator owns the study — the journal, the optimizer, the canonical
commit order — and serves work units from ONE shared queue to N
:mod:`.worker` processes (``pool="process"`` on this box, ``pool="socket"``
across hosts, speaking the authenticated capped-frame codec of
:mod:`.transport` and deployable from a frozen
:class:`~repro.core.tune_service.transport.FleetSpec`).  The class is a drop-in for
:class:`~repro.core.tune_service.executor.TrialExecutor` (same
``submit``/``submit_ready``/``pop_next``/``outstanding`` surface), so the
:class:`~repro.core.tune_service.service.TuneService` control loop — and
every determinism property it pins — is reused unchanged.

**Lease-and-commit.**  Each dispatched unit carries a lease: the worker
must heartbeat it every ``heartbeat_s`` while the segment runs, and a
lease that goes silent for ``lease_deadline`` heartbeat intervals (or
whose worker provably died — process sentinel, socket EOF, or an idle
heartbeat proving the result was lost in flight) **expires**.  An expired
unit is **re-issued** to another worker, at most ``max_attempts`` times
with a short backoff, before it is surrendered as an error result (which
the service turns into a bounded trial ``retry``, then FAILED).
Re-issue is safe *because* the study is deterministic: a unit is a pure
function of its canonical coordinates (seed + batch offset + segment
bounds), so duplicate execution returns the same bits — the first result
to land commits, and any late twin is **asserted bitwise equal** against
the committed digest (a cheap, always-on placement-invariance check).

**Determinism of the journal.**  Lease lifecycle events
(``lease``/``expire``/``reissue``) are collected per unit and journaled
by the service at the unit's *commit* point, in canonical order — never
at wall-clock detection time.  Worker ids stay out of the journal
(placement is irrelevant to the study), deadlines are recorded as
heartbeat *counts* (wall-clock-free), and each worker runs exactly one
unit at a time, so an injected fault keyed by ``(unit, attempt)``
(:mod:`.faults`) perturbs exactly one lease no matter which worker drew
the unit.  Two runs under the same fault plan therefore write
byte-identical journals, and a coordinator SIGKILLed mid-re-issue
resumes byte-identically (the re-issue in flight simply replays).

**Rejects and reconnects.**  On the socket transport, a frame that fails
validation (bad signature, oversize, replayed, truncated) drops its
connection and — when the sender held a live lease — journals a
``reject`` into the unit's history before expiring the lease; a worker
whose link merely dropped re-dials, re-greets under its identity and has
its live lease re-attached (journaled as ``reconnect``).  Both events
ride the same commit-time history mechanism as ``lease``/``expire``/
``reissue``, so the journal stays deterministic.

**Graceful degradation.**  Dead process workers are respawned up to
``max_respawns`` times — each respawn first promotes a booted hot-spare
worker when one is up, so the slot refills instantly and the fresh
interpreter boot (hundreds of milliseconds under the spawn start method)
happens on the replacement spare, off the critical path.  When the live
fleet shrinks to zero, queued units run on the coordinator's local slot
instead — the study finishes slower, never wedges.
"""

from __future__ import annotations

import collections
import queue as queue_mod
import socket
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .executor import _timed_safe
from .faults import NO_FAULTS, FaultPlan
from .transport import (FleetSpec, FrameChannel, FrameError, accept_greet,
                        reject_reason)
from .worker import DEFAULT_HEARTBEAT_S, process_main, socket_main

FLEET_POOLS = ("process", "socket")

#: default lease deadline, in missed-heartbeat counts (wall-clock-free)
DEFAULT_LEASE_DEADLINE = 30
#: give up re-issuing a unit after this many lease attempts
DEFAULT_MAX_ATTEMPTS = 4


def _result_digest(result: Dict[str, Any]) -> Optional[bytes]:
    """A canonical digest of a unit result for the duplicate-execution
    equality assertion (None for error results — tracebacks may differ)."""
    if "error" in result:
        return None
    if "wall_ms" in result:
        return np.ascontiguousarray(
            np.asarray(result["wall_ms"], dtype=np.float64)).tobytes()
    if "value" in result:
        return repr(float(result["value"])).encode()
    return None


class _ProcessFleet:
    """Process-transport fleet: mp workers on this box, queue messaging.

    Keeps ``spares`` hot-spare workers booted but never leased: a worker
    death promotes a spare instantly instead of paying a fresh
    interpreter boot on the critical path (under the spawn start method
    a boot costs hundreds of milliseconds of idle slot time per death —
    the replacement spare boots in the background while both promoted
    slots keep working)."""

    def __init__(self, n: int, heartbeat_s: float, faults: FaultPlan,
                 cache_dir: Optional[str], spares: int = 1):
        import multiprocessing as mp
        import sys
        # mirror the simulator pool's choice: forking once jax has
        # initialized its runtime threads is unsupported
        use_fork = "fork" in mp.get_all_start_methods() and \
            "jax" not in sys.modules
        self._ctx = mp.get_context("fork" if use_fork else "spawn")
        self._inbox = self._ctx.Queue()
        self._heartbeat_s = heartbeat_s
        self._faults = faults
        self._cache_dir = cache_dir
        self._procs: Dict[int, Any] = {}
        self._queues: Dict[int, Any] = {}
        self._reaped: set = set()
        self._spares: List[int] = []
        self.n_promotions = 0
        self._next_wid = 0
        for _ in range(n):
            self._spawn()
        for _ in range(spares):
            self._spares.append(self._spawn())

    def _spawn(self) -> int:
        wid = self._next_wid
        self._next_wid += 1
        q = self._ctx.Queue()
        p = self._ctx.Process(
            target=process_main,
            args=(wid, q, self._inbox, self._heartbeat_s, self._faults,
                  self._cache_dir),
            daemon=True, name=f"repro-fleet-w{wid}")
        p.start()
        self._procs[wid] = p
        self._queues[wid] = q
        return wid

    def spawn_worker(self) -> int:
        # promote a live hot spare if one is up: it is already booted
        # (and typically greeted), so the slot refills instantly; the
        # fresh boot happens on the NEW spare, off the critical path
        while self._spares:
            wid = self._spares.pop(0)
            if self._procs[wid].is_alive():
                self.n_promotions += 1
                self._spares.append(self._spawn())
                return wid
            self._reaped.add(wid)  # spare died while idle: skip it
        return self._spawn()

    def poll(self, timeout: float) -> Optional[Dict[str, Any]]:
        try:
            if timeout <= 0:
                return self._inbox.get_nowait()
            return self._inbox.get(timeout=timeout)
        except queue_mod.Empty:
            return None

    def send(self, wid: int, msg: Dict[str, Any]) -> None:
        self._queues[wid].put(msg)

    def dispatchable(self) -> List[int]:
        """Workers a unit can be sent to right now (spares are held in
        reserve: they only take work once promoted by a death)."""
        return [w for w, p in self._procs.items()
                if w not in self._reaped and w not in self._spares
                and p.is_alive()]

    def n_eligible(self, suspect) -> int:
        """Workers that could ever take work (degradation trigger).
        Suspects don't count: a wedged worker is alive but written off
        until it speaks again — waiting on it could wedge the study.
        Spares don't count either: with respawns exhausted they are
        never promoted, and waiting on one would wedge the study."""
        return len([w for w in self.dispatchable() if w not in suspect])

    def reap_dead(self) -> List[int]:
        # a dead hot spare held no lease and no slot: replace it
        # silently rather than reporting a worker death
        for wid in list(self._spares):
            if not self._procs[wid].is_alive():
                self._spares.remove(wid)
                self._reaped.add(wid)
                self._spares.append(self._spawn())
        dead = [w for w, p in self._procs.items()
                if w not in self._reaped and w not in self._spares
                and not p.is_alive()]
        self._reaped.update(dead)
        return dead

    def close(self) -> None:
        for wid, p in self._procs.items():
            if p.is_alive():
                try:
                    self._queues[wid].put({"type": "shutdown"})
                except Exception:
                    pass
        deadline = time.monotonic() + 2.0
        for p in self._procs.values():
            p.join(timeout=max(0.0, deadline - time.monotonic()))
            if p.is_alive():
                p.terminate()
                p.join(timeout=0.5)
                if p.is_alive():
                    p.kill()
        for q in list(self._queues.values()) + [self._inbox]:
            try:
                q.cancel_join_thread()
                q.close()
            except Exception:
                pass


class _SocketFleet:
    """Socket-transport fleet behind the authenticated frame codec
    (:mod:`.transport`): every connection must greet with a signed hello
    before its worker id exists coordinator-side, every frame is
    HMAC-verified, length-capped *before* allocation and bounded in read
    time, and a frame that fails any gate produces a ``frame_reject``
    inbox message plus a dropped connection — never a wedged reader.

    A dropped connection is a *disconnect*, not a death: workers re-dial
    (:func:`~repro.core.tune_service.worker.socket_main`) and a re-greet
    under a known id atomically swaps the connection back in.  Only a
    self-spawned worker's process sentinel proves death; external workers
    (``spec.hosts`` non-empty, launched by ``tools/fleet_launch.py``) are
    never declared dead — a silent one expires its lease and is written
    off as suspect until it speaks again."""

    def __init__(self, n: int, heartbeat_s: float, faults: FaultPlan,
                 cache_dir: Optional[str],
                 spec: Optional[FleetSpec] = None):
        if spec is None:
            # self-contained fleet: mint an ephemeral key for this run
            spec = FleetSpec.generate(workers=n, heartbeat_s=heartbeat_s)
        self.spec = spec
        self._key = spec.key_bytes
        self._srv = socket.create_server((spec.host, spec.port))
        self.address: Tuple[str, int] = self._srv.getsockname()[:2]
        self._inbox: "queue_mod.Queue" = queue_mod.Queue()
        self._heartbeat_s = heartbeat_s
        self._lock = threading.Lock()
        self._chans: Dict[int, FrameChannel] = {}
        self._dc: set = set()      # disconnected (may re-dial); not dead
        self._reaped: set = set()  # provably dead (process sentinel)
        self._closing = False
        self._boot_deadline = time.monotonic() + spec.boot_grace_s
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True,
            name="repro-fleet-accept")
        self._accept_thread.start()
        import multiprocessing as mp
        import sys
        use_fork = "fork" in mp.get_all_start_methods() and \
            "jax" not in sys.modules
        self._ctx = mp.get_context("fork" if use_fork else "spawn")
        self._faults = faults
        self._cache_dir = cache_dir
        self._procs: Dict[int, Any] = {}
        self._next_wid = 0
        if not spec.external:
            for _ in range(n):
                self.spawn_worker()

    def spawn_worker(self) -> int:
        if self.spec.external:
            return -1  # externally-launched workers cannot be respawned
        wid = self._next_wid
        self._next_wid += 1
        p = self._ctx.Process(
            target=socket_main,
            args=(self.address, wid, self._heartbeat_s, self._faults,
                  self._cache_dir),
            kwargs={"key": self._key,
                    "max_frame": self.spec.max_frame_bytes,
                    "frame_timeout_s": self.spec.frame_timeout_s,
                    "max_redials": self.spec.max_redials,
                    "redial_backoff_s": self.spec.redial_backoff_s,
                    "net_delay_s": self._faults.net_delay_s},
            daemon=True, name=f"repro-fleet-w{wid}")
        p.start()
        self._procs[wid] = p
        return wid

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            threading.Thread(target=self._reader, args=(conn,),
                             daemon=True).start()

    def _reader(self, conn: socket.socket) -> None:
        chan = FrameChannel(conn, self._key,
                            max_frame=self.spec.max_frame_bytes,
                            frame_timeout_s=self.spec.frame_timeout_s)
        try:
            wid = accept_greet(chan)
        except (FrameError, EOFError, OSError) as e:
            # an unauthenticated stranger (or a garbled greet): no worker
            # id was ever established, so nothing is leased and nothing
            # reaches the journal — count it and drop the connection
            self._inbox.put({"type": "frame_reject", "worker": None,
                             "reason": reject_reason(e)})
            chan.close()
            return
        with self._lock:
            old = self._chans.get(wid)
            self._chans[wid] = chan
            self._dc.discard(wid)
        if old is not None:
            old.close()  # a re-greet supersedes the stale connection
        self._inbox.put({"type": "hello", "worker": wid})
        try:
            while True:
                msg = chan.recv()
                if msg is not None:
                    self._inbox.put(msg)
        except (EOFError, OSError):
            pass  # a disconnect: the worker may re-dial and re-greet
        except FrameError as e:
            # an authenticated connection produced an invalid frame: the
            # stream cannot be trusted past this point — reject + drop
            self._inbox.put({"type": "frame_reject", "worker": wid,
                             "reason": reject_reason(e)})
        finally:
            with self._lock:
                if self._chans.get(wid) is chan:
                    self._dc.add(wid)
            chan.close()

    def poll(self, timeout: float) -> Optional[Dict[str, Any]]:
        try:
            if timeout <= 0:
                return self._inbox.get_nowait()
            return self._inbox.get(timeout=timeout)
        except queue_mod.Empty:
            return None

    def send(self, wid: int, msg: Dict[str, Any]) -> None:
        with self._lock:
            chan = self._chans.get(wid)
        if chan is None:
            raise OSError(f"worker {wid} has no live connection")
        chan.send(msg)

    def dispatchable(self) -> List[int]:
        with self._lock:
            return [w for w in self._chans
                    if w not in self._dc and w not in self._reaped]

    def n_eligible(self, suspect) -> int:
        # self-spawned workers count while their PROCESS is alive even if
        # the connection is down (they are redialing — that is the point
        # of reconnect); externals count while connected, plus the ones
        # still expected to greet within the boot grace window
        with self._lock:
            if self._procs:
                live = sum(1 for w, p in self._procs.items()
                           if w not in self._reaped and w not in suspect
                           and p.is_alive())
                ext = sum(1 for w in self._chans
                          if w not in self._dc and w not in self._reaped
                          and w not in suspect and w not in self._procs)
                return live + ext
            live = sum(1 for w in self._chans
                       if w not in self._dc and w not in self._reaped
                       and w not in suspect)
            if time.monotonic() < self._boot_deadline:
                live += max(0, self.spec.workers - len(self._chans))
            return live

    def reap_dead(self) -> List[int]:
        # only a process sentinel proves death now that connections
        # reconnect; a silent external worker is handled by lease expiry
        with self._lock:
            dead = {w for w, p in self._procs.items()
                    if w not in self._reaped and not p.is_alive()}
            self._reaped.update(dead)
        return sorted(dead)

    def close(self) -> None:
        self._closing = True
        for wid in self.dispatchable():
            try:
                self.send(wid, {"type": "shutdown"})
            except (OSError, FrameError):
                pass
        try:
            self._srv.close()
        except OSError:
            pass
        deadline = time.monotonic() + 2.0
        for p in self._procs.values():
            p.join(timeout=max(0.0, deadline - time.monotonic()))
            if p.is_alive():
                p.terminate()
                p.join(timeout=0.5)
                if p.is_alive():
                    p.kill()
        with self._lock:
            for chan in self._chans.values():
                chan.close()


class FleetExecutor:
    """``workers`` remote evaluation slots behind the lease-and-commit
    protocol, committed in canonical unit-creation order.  Drop-in for
    :class:`~repro.core.tune_service.executor.TrialExecutor`.

    ``busy_s`` is slot *occupancy* — wall time leases were held (issue to
    result, or to fault detection for expired leases) — not worker-side
    compute time: a coordinator doesn't control its workers' clocks, and
    occupancy is what the utilization receipt must measure (an aborted
    attempt occupied its slot; only detection/respawn/backoff gaps and
    starvation count as idle)."""

    def __init__(self, workers: int, pool: str = "process",
                 heartbeat_s: float = DEFAULT_HEARTBEAT_S,
                 lease_deadline: int = DEFAULT_LEASE_DEADLINE,
                 timeout_s: Optional[float] = None,
                 faults: FaultPlan = NO_FAULTS,
                 max_attempts: int = DEFAULT_MAX_ATTEMPTS,
                 max_respawns: Optional[int] = None,
                 backoff_s: float = 0.05,
                 fleet_spec: Optional[FleetSpec] = None):
        if fleet_spec is not None:
            if pool != "socket":
                raise ValueError(
                    f"fleet_spec describes a socket fleet; got "
                    f"pool={pool!r}")
            # the spec is the deployment artifact: the externally-launched
            # workers run with ITS heartbeat/transport parameters, so the
            # coordinator must agree with it, not with ad-hoc overrides
            workers = fleet_spec.workers
            heartbeat_s = fleet_spec.heartbeat_s
            lease_deadline = fleet_spec.lease_deadline
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if pool not in FLEET_POOLS:
            raise ValueError(f"unknown fleet pool {pool!r}; expected one "
                             f"of {FLEET_POOLS}")
        if lease_deadline < 1:
            raise ValueError("lease_deadline must be >= 1 heartbeat")
        self.slots = int(workers)
        self.pool_kind = pool
        self.heartbeat_s = float(heartbeat_s)
        self.lease_deadline = int(lease_deadline)
        self.timeout_s = timeout_s
        self.faults = faults if faults is not None else NO_FAULTS
        self.max_attempts = int(max_attempts)
        self.max_respawns = int(max_respawns) if max_respawns is not None \
            else int(workers)
        self.backoff_s = float(backoff_s)
        from ..simulator import compile_cache_dir
        if pool == "process":
            self._fleet = _ProcessFleet(self.slots, self.heartbeat_s,
                                        self.faults, compile_cache_dir())
        else:
            self._fleet = _SocketFleet(self.slots, self.heartbeat_s,
                                       self.faults, compile_cache_dir(),
                                       spec=fleet_spec)
        # unit state, keyed by canonical sequence number
        self._specs: Dict[int, Tuple[Callable, tuple, Optional[float]]] = {}
        self._queue: "collections.deque[Tuple[int, float]]" = \
            collections.deque()
        self._ready: Dict[int, Dict[str, Any]] = {}
        self._leases: Dict[int, Dict[str, Any]] = {}
        self._history: Dict[int, List[Dict[str, Any]]] = {}
        self._attempts: Dict[int, int] = {}
        self._digest: Dict[int, Optional[bytes]] = {}
        self._busy: Dict[int, int] = {}       # worker id -> unit seq
        self._suspect: set = set()            # wedged until they speak
        # workers that have spoken (hello or any later message).  A unit
        # is only ever leased to a greeted worker: a spawned process that
        # is still booting (interpreter start can take seconds once jax
        # forces the spawn start method) is not an issue target, and
        # leasing against it would start the silence clock on a worker
        # that cannot heartbeat yet — the lease would expire through no
        # fault of the protocol.  Booting workers still count as
        # *eligible* (they are on their way), so the coordinator does not
        # degrade to its local slot during a respawn.
        self._greeted: set = set()
        self._next_seq = 0
        self._next_commit = 0
        self.busy_s = 0.0
        # local degradation slot (lazy)
        self._local = None
        self._local_futs: Dict[int, Tuple[Any, float]] = {}
        # receipts
        self.n_reissues = 0
        self.n_expired = 0
        self.n_worker_deaths = 0
        self.n_respawns = 0
        self.n_duplicates = 0
        self.n_reconnects = 0
        self.n_rejected_frames = 0
        self.reissue_overhead_s = 0.0
        self.recover_s: List[float] = []
        self.degraded = False

    # -- submission --------------------------------------------------------
    def submit(self, fn: Callable[..., Dict[str, Any]], *args,
               timeout_s: Optional[float] = None) -> int:
        seq = self._next_seq
        self._next_seq += 1
        t = timeout_s if timeout_s is not None else self.timeout_s
        self._specs[seq] = (fn, args, t)
        self._history[seq] = []
        self._attempts[seq] = 0
        self._queue.append((seq, 0.0))
        self._pump(block=False)
        return seq

    def submit_ready(self, result: Dict[str, Any]) -> int:
        """A pre-resolved unit (journal-replay cache hit): holds its
        canonical commit slot, never touches the fleet."""
        seq = self._next_seq
        self._next_seq += 1
        self._ready[seq] = dict(result)
        return seq

    @property
    def outstanding(self) -> int:
        return self._next_seq - self._next_commit

    # -- canonical-order commits ------------------------------------------
    def pop_next(self) -> Tuple[int, Dict[str, Any]]:
        seq = self._next_commit
        while seq not in self._ready:
            self._pump(block=True)
        result = self._ready.pop(seq)
        self._digest[seq] = _result_digest(result)
        self._specs.pop(seq, None)
        self._attempts.pop(seq, None)
        self._next_commit += 1
        return seq, result

    def take_history(self, seq: int) -> List[Dict[str, Any]]:
        """The unit's lease lifecycle events, for commit-time journaling."""
        return self._history.pop(seq, [])

    @property
    def address(self) -> Optional[Tuple[str, int]]:
        """The socket fleet's bound (host, port); None for process pools."""
        return getattr(self._fleet, "address", None)

    # -- the pump: messages, liveness, leases, dispatch --------------------
    def _pump(self, block: bool) -> None:
        msg = self._fleet.poll(min(self.heartbeat_s, 0.05) if block else 0.0)
        while msg is not None:
            self._handle(msg)
            msg = self._fleet.poll(0.0)
        self._check_workers()
        self._check_leases()
        self._check_local()
        self._dispatch()

    def _handle(self, msg: Dict[str, Any]) -> None:
        kind = msg.get("type")
        wid = msg.get("worker")
        if kind == "frame_reject":
            # the transport rejected a frame (bad signature, oversize,
            # replayed, truncated, ...) and dropped the connection.  If
            # the sender held a live lease, the lease cannot be trusted to
            # complete — journal the reject into the unit's history (at
            # commit time, like every lease event) and expire it.  A
            # reject with no live lease (an unauthenticated stranger, or
            # a replayed frame landing after its twin committed) touches
            # stats only: journaling it would be wall-clock-dependent.
            self.n_rejected_frames += 1
            if wid is None:
                return
            seq = self._busy.get(wid)
            lease = self._leases.get(seq) if seq is not None else None
            if lease is not None and lease["worker"] == wid:
                self._busy.pop(wid, None)
                self._history[seq].append(
                    {"event": "reject", "unit": seq,
                     "attempt": lease["attempt"],
                     "reason": msg.get("reason", "frame")})
                self._expire(seq, "reject")
            return
        if wid is not None:
            self._suspect.discard(wid)
            self._greeted.add(wid)
        if kind == "hello":
            # a re-greet from a worker we believe is busy: its connection
            # dropped and it re-dialed.  If the lease is still live,
            # re-attach it (refresh the silence clock, journal the
            # reconnect at commit); if it already expired, leave the
            # worker marked busy — it is still evaluating its old unit
            # and will tell us (result, or idle heartbeat) when it frees
            seq = self._busy.get(wid)
            if seq is not None:
                lease = self._leases.get(seq)
                if lease is not None and lease["worker"] == wid:
                    lease["last_seen"] = time.monotonic()
                    self.n_reconnects += 1
                    self._history[seq].append(
                        {"event": "reconnect", "unit": seq,
                         "attempt": lease["attempt"]})
            return
        if kind == "heartbeat":
            unit = msg.get("unit")
            if unit is None:
                # an idle heartbeat from a worker we believe is busy means
                # its result was lost in flight — expire the lease now
                seq = self._busy.get(wid)
                if seq is not None:
                    lease = self._leases.get(seq)
                    if lease is None:
                        # the lease already resolved without this worker
                        # (rejected frame, expiry + late twin): the worker
                        # is demonstrably idle again — free its slot
                        self._busy.pop(wid, None)
                    elif lease["worker"] == wid and \
                            time.monotonic() - lease["issued"] > \
                            3 * self.heartbeat_s:
                        self._busy.pop(wid, None)
                        self._expire(seq, "lost")
                return
            lease = self._leases.get(unit)
            if lease is not None and lease["worker"] == wid and \
                    lease["attempt"] == msg.get("attempt"):
                lease["last_seen"] = time.monotonic()
            return
        if kind == "result":
            seq = int(msg["unit"])
            if self._busy.get(wid) == seq:
                self._busy.pop(wid)
            result = msg["result"]
            if seq < self._next_commit or seq in self._ready:
                # a duplicate or late twin: first commit won; assert the
                # twin returned the SAME bits (placement invariance).  The
                # twin's runtime is wasted occupancy: the slot was busy,
                # the work was redundant
                self._assert_twin(seq, result)
                self.n_duplicates += 1
                self.busy_s += float(result.get("slot_s", 0.0))
                self.reissue_overhead_s += float(result.get("slot_s", 0.0))
                return
            lease = self._leases.pop(seq, None)
            if lease is None and seq not in self._attempts:
                return  # unit unknown (e.g. surrendered and committed)
            # accept whichever attempt lands first; cancel any queued
            # re-issue of the same unit
            self._unqueue(seq)
            if lease is not None:
                # slot occupancy: wall time the lease was held, issue to
                # result — NOT worker-reported compute time, which a
                # coordinator doesn't control (and which shrinks under
                # less CPU contention, masking idle slots)
                self.busy_s += time.monotonic() - lease["issued"]
            self._ready[seq] = result
            return

    def _assert_twin(self, seq: int, result: Dict[str, Any]) -> None:
        want = self._digest.get(seq, _result_digest(self._ready.get(seq, {})))
        got = _result_digest(result)
        if want is not None and got is not None and want != got:
            raise RuntimeError(
                f"duplicate execution of unit {seq} returned different "
                f"bits — the evaluation is not placement-invariant (this "
                f"is a determinism bug, not a fleet fault)")

    def _unqueue(self, seq: int) -> None:
        for entry in list(self._queue):
            if entry[0] == seq:
                self._queue.remove(entry)

    def _check_workers(self) -> None:
        for wid in self._fleet.reap_dead():
            self.n_worker_deaths += 1
            self._suspect.discard(wid)
            seq = self._busy.pop(wid, None)
            if seq is not None and seq in self._leases:
                self._expire(seq, "worker-dead")
            if self.n_respawns < self.max_respawns:
                self.n_respawns += 1
                self._fleet.spawn_worker()

    def _check_leases(self) -> None:
        now = time.monotonic()
        silence = self.heartbeat_s * self.lease_deadline
        for seq, lease in list(self._leases.items()):
            if now - lease["last_seen"] > silence:
                # wedged, not provably dead: write the worker off until it
                # speaks again, but leave it marked busy (never re-booked)
                self._suspect.add(lease["worker"])
                self._expire(seq, "expired")

    def _expire(self, seq: int, reason: str) -> None:
        lease = self._leases.pop(seq, None)
        if lease is None:
            return
        now = time.monotonic()
        attempt = lease["attempt"]
        self.n_expired += 1
        self.recover_s.append(now - lease["last_seen"])
        # the doomed attempt occupied its slot from issue until the fault
        # was detected: wasted occupancy, not idle time — count it as
        # both busy and re-issue overhead so utilization measures idle
        # slots and reissue_overhead_s measures burned wall clock
        held = max(0.0, now - lease["issued"])
        self.busy_s += held
        self.reissue_overhead_s += held
        self._history[seq].append(
            {"event": "expire", "unit": seq, "attempt": attempt,
             "reason": reason})
        nxt = attempt + 1
        if nxt >= self.max_attempts:
            self._ready[seq] = {
                "error": f"lease expired {nxt} times (unit {seq}, last "
                         f"reason: {reason}); the fleet could not complete "
                         f"this unit", "slot_s": 0.0}
            return
        self._attempts[seq] = nxt
        self.n_reissues += 1
        self._history[seq].append(
            {"event": "reissue", "unit": seq, "attempt": nxt})
        # the first re-issue goes out immediately (the expiry already cost
        # detection latency); repeated failures of the SAME unit back off
        self._queue.appendleft((seq, now + self.backoff_s * (nxt - 1)))

    def _dispatch(self) -> None:
        while self._queue:
            seq, not_before = self._queue[0]
            now = time.monotonic()
            if not_before > now:
                break  # re-issue backoff; re-checked on the next pump
            wid = self._idle_worker()
            if wid is None:
                if self._fleet.n_eligible(self._suspect) == 0:
                    self._queue.popleft()
                    self._run_local(seq)
                    continue
                break
            self._queue.popleft()
            attempt = self._attempts[seq]
            fn, args, t = self._specs[seq]
            try:
                self._fleet.send(wid, {"type": "unit", "unit": seq,
                                       "attempt": attempt, "fn": fn,
                                       "args": args, "timeout_s": t})
            except (OSError, FrameError):
                # the connection dropped under us (socket transport): the
                # unit was never leased — requeue it and try other workers
                self._queue.appendleft((seq, now))
                self._greeted.discard(wid)
                continue
            self._leases[seq] = {"worker": wid, "attempt": attempt,
                                 "issued": now, "last_seen": now}
            self._busy[wid] = seq
            if attempt == 0:
                self._history[seq].append(
                    {"event": "lease", "unit": seq, "attempt": 0,
                     "deadline": self.lease_deadline})

    def _idle_worker(self) -> Optional[int]:
        for wid in self._fleet.dispatchable():
            if wid not in self._busy and wid not in self._suspect \
                    and wid in self._greeted:
                return wid
        return None

    # -- graceful degradation: the coordinator's local slot ----------------
    def _run_local(self, seq: int) -> None:
        if self._local is None:
            import concurrent.futures
            self._local = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="repro-fleet-local")
        self.degraded = True
        attempt = self._attempts[seq]
        fn, args, _ = self._specs[seq]
        if attempt == 0:
            self._history[seq].append(
                {"event": "lease", "unit": seq, "attempt": 0,
                 "deadline": self.lease_deadline})
        self._local_futs[seq] = (self._local.submit(_timed_safe, fn, *args),
                                 time.monotonic())

    def _check_local(self) -> None:
        for seq, (fut, t0) in list(self._local_futs.items()):
            _, _, t = self._specs.get(seq, (None, None, None))
            if fut.done():
                del self._local_futs[seq]
                self.busy_s += time.monotonic() - t0
                self._ready[seq] = fut.result()
            elif t is not None and time.monotonic() - t0 > t:
                fut.cancel()
                del self._local_futs[seq]
                self.busy_s += time.monotonic() - t0
                self._ready[seq] = {
                    "error": f"timeout: unit {seq} exceeded {t}s on the "
                             f"local degradation slot", "timeout": True,
                    "slot_s": float(t)}

    # -- receipts / shutdown ----------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            "workers": self.slots,
            "pool": self.pool_kind,
            "n_reissues": self.n_reissues,
            "n_expired_leases": self.n_expired,
            "n_worker_deaths": self.n_worker_deaths,
            "n_respawns": self.n_respawns,
            "n_spare_promotions": getattr(self._fleet, "n_promotions", 0),
            "n_duplicate_results": self.n_duplicates,
            "n_reconnects": self.n_reconnects,
            "n_rejected_frames": self.n_rejected_frames,
            "reissue_overhead_s": float(self.reissue_overhead_s),
            "time_to_recover_s": [float(x) for x in self.recover_s],
            "degraded": self.degraded,
        }

    def close(self) -> None:
        self._fleet.close()
        if self._local is not None:
            self._local.shutdown(wait=False, cancel_futures=True)
