"""Trial: the resumable state machine one tuning candidate moves through.

A trial is one suggested knob configuration plus everything needed to
(re-)evaluate it deterministically: the frozen
:class:`~repro.core.specs.ExperimentSpec` dict it runs under, the encoded
config row, and its RNG counters (simulation seed + global batch offset —
with the compiled backend's counter-based draws these make the trial's
evaluation placement-invariant: any executor, any slot, any segmentation
produces bitwise-identical numbers).

States (Ray Tune's ``trial.py`` shape, collapsed to what a deterministic
single-study executor needs)::

    PENDING --> RUNNING --> TERMINATED      (budget reached, or ASHA-stopped)
                   |   \\--> FAILED          (objective raised; traceback kept)
                   v
                PAUSED  --> RUNNING          (checkpointed at a rung boundary,
                                              promoted and resumed)

``TERMINATED`` covers both full-budget completion and early ASHA
termination — ``epochs_run < max_epochs`` distinguishes them.  A PAUSED
trial carries its mid-run epoch-loop checkpoint (the ``lax.scan`` carry,
numpy-ified) so promotion resumes from the rung boundary instead of epoch
0; the numpy reference backend has no checkpointable carry and re-runs
from epoch 0 (exact either way — see
:func:`repro.core.simulator.run_simulation_segment`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

PENDING = "PENDING"
RUNNING = "RUNNING"
PAUSED = "PAUSED"
TERMINATED = "TERMINATED"
FAILED = "FAILED"

#: legal state transitions (from -> allowed targets)
TRANSITIONS = {
    PENDING: (RUNNING,),
    RUNNING: (PAUSED, TERMINATED, FAILED),
    PAUSED: (RUNNING,),
    TERMINATED: (),
    FAILED: (),
}


@dataclasses.dataclass
class Trial:
    """One tuning candidate's full lifecycle state."""

    index: int                          # canonical creation-sequence id
    config: Dict[str, Any]              # validated knob config
    encoded: np.ndarray                 # KnobSpace.encode(config) unit row
    spec: Dict[str, Any]                # frozen ExperimentSpec (replayable)
    seed: int                           # simulation seed (RNG counter base)
    batch_offset: int = 0               # global batch index (RNG counter)
    group: int = 0                      # CRN ask-group id (asked together)
    state: str = PENDING
    rung: int = 0                       # current ASHA rung index
    epochs_run: int = 0                 # committed evaluated epochs
    value: Optional[float] = None       # objective over epochs_run epochs
    told_value: Optional[float] = None  # value fed to the optimizer
    error: Optional[str] = None         # traceback text (FAILED)
    attempt: int = 0                    # bounded-retry count (transients)
    checkpoint: Any = None              # scan carry at epochs_run (jax path)
    wall_s: float = 0.0                 # evaluation wall clock spent
    #: per-epoch wall_ms history (float64), appended per committed segment;
    #: rung values re-sum this array so live (carry-resumed) and replayed
    #: (from-scratch) evaluations commit bitwise-identical values
    epoch_wall_ms: List[np.ndarray] = dataclasses.field(default_factory=list)

    def advance(self, new_state: str) -> None:
        if new_state not in TRANSITIONS:
            raise ValueError(f"unknown trial state {new_state!r}")
        if new_state not in TRANSITIONS[self.state]:
            raise ValueError(
                f"illegal trial transition {self.state} -> {new_state} "
                f"(trial {self.index})")
        self.state = new_state

    @property
    def terminal(self) -> bool:
        return self.state in (TERMINATED, FAILED)

    def wall_concat(self) -> np.ndarray:
        """Per-epoch wall_ms over everything evaluated so far, one array."""
        if not self.epoch_wall_ms:
            return np.zeros(0, dtype=np.float64)
        if len(self.epoch_wall_ms) == 1:
            return self.epoch_wall_ms[0]
        return np.concatenate(self.epoch_wall_ms)

    def value_at(self, epochs: int) -> float:
        """Objective (total seconds) over the first ``epochs`` epochs,
        computed canonically from the per-epoch wall history — independent
        of how many segments produced it."""
        wall = self.wall_concat()
        if len(wall) < epochs:
            raise ValueError(
                f"trial {self.index} has {len(wall)} evaluated epochs, "
                f"needs {epochs}")
        return float(wall[:epochs].sum() / 1e3)

    def to_row(self) -> Dict[str, Any]:
        """The trial-table row (journal/result payload; checkpoint and
        per-epoch arrays omitted — both are re-derivable)."""
        return {
            "index": self.index,
            "config": dict(self.config),
            "seed": int(self.seed),
            "batch_offset": int(self.batch_offset),
            "group": int(self.group),
            "state": self.state,
            "rung": int(self.rung),
            "epochs_run": int(self.epochs_run),
            "value": self.value,
            "told_value": self.told_value,
            "error": self.error,
            "attempt": int(self.attempt),
        }
