"""JSON-lines study journal: every decision appended, replayable exactly.

The journal is the study's source of truth for resume.  Because the whole
control loop is deterministic (asks, rung decisions and tells all happen at
canonical *commit* events, never at wall-clock arrival — see
:mod:`.service`), the event sequence a study emits is a pure function of
``(spec, tune parameters)``.  Resume therefore does not reconstruct state
from the journal; it RE-RUNS the control loop and uses the journal as an
evaluation cache: events that match the recorded prefix are consumed
(asserted equal for asks — a mismatch means the study parameters changed),
recorded evaluation values substitute for simulation, and the first event
past the recorded prefix switches the journal back into append mode.  A
killed-then-resumed study thus produces a byte-identical journal to its
uninterrupted twin (pinned in tests and the ``study-resume`` CI job).

Events are deliberately wall-clock-free; timing receipts live only in the
in-memory :class:`~repro.core.tune_service.service.AsyncTuningResult`.

Event types (all objects carry ``"event"``):

``study``
    Header: schema ``version``, frozen ``spec`` dict, ``budget``,
    ``slots``, ``scheduler`` (+ rung epoch budgets), optimizer parameters.
``default``
    The default-config baseline evaluation (not told to the optimizer).
``ask``
    Trial creation: ``trial`` index, CRN ``group`` id, suggested
    ``config``.
``eval``
    A committed evaluation segment: ``trial``, cumulative ``epochs``,
    objective ``value`` over those epochs.
``rung``
    An ASHA decision: ``trial``, ``rung`` index, ``decision``
    (``"promote"``/``"stop"``).
``fail``
    A FAILED trial: ``trial``, attempted ``epochs``, ``error`` traceback.
``retry``
    A bounded trial retry (version 2): ``trial``, the retry ``attempt``
    number, attempted ``epochs``, the transient ``error`` being retried.
``lease`` / ``expire`` / ``reissue``
    Fleet lease lifecycle (version 2), journaled as the work unit's
    attempt history at its COMMIT point — never at wall-clock detection
    time — so fleet journals stay deterministic.  ``lease`` records the
    unit's first dispatch (``unit``, ``attempt`` 0, the configured
    ``deadline`` in heartbeat counts — wall-clock-free); ``expire``
    records a lost lease (``unit``, ``attempt``, ``reason``); ``reissue``
    records the straggler re-issue that followed (``unit``, the new
    ``attempt``).
``reject`` / ``reconnect``
    Socket-transport lease events (version 3), journaled through the
    same commit-time history mechanism.  ``reject`` records an invalid
    frame (bad signature, oversize, replayed, truncated — ``unit``,
    ``attempt``, the transport ``reason``) that killed a live lease; the
    matching ``expire`` (reason ``"reject"``) follows it.  ``reconnect``
    records a worker whose connection dropped mid-lease re-greeting and
    having the live lease re-attached (``unit``, ``attempt``).  Rejected
    frames not attributable to a live lease (unauthenticated strangers,
    replays landing after their twin committed) are wall-clock-dependent
    and therefore never journaled — they appear in fleet stats only.
``tell``
    An optimizer update: ``trial``, CRN ``group``, the (possibly
    extrapolated / CRN-debiased) ``value`` recorded.
``done``
    Study completion: ``best_trial``, ``best_value``, trial-state counts.

``tools/journal_schema.py`` validates these invariants standalone.
"""

from __future__ import annotations

import io
import json
import os
from typing import Any, Dict, List, Optional

#: journal schema version (bumped on incompatible event changes)
#: v2: adds ``retry`` and the fleet lease lifecycle events
#: (``lease``/``expire``/``reissue``)
#: v3: adds the socket-transport lease events ``reject``/``reconnect``
VERSION = 3


def _read_clean(path: str) -> "tuple[List[Dict[str, Any]], int]":
    """Parse a journal, tolerating a truncated final line (SIGKILL landed
    mid-append).  Returns the events plus the byte length of the clean
    prefix (torn tail excluded).  Raises on corruption anywhere else."""
    events: List[Dict[str, Any]] = []
    with io.open(path, "rb") as fh:
        raw = fh.read()
    lines = raw.split(b"\n")
    # a complete journal ends with "\n" -> last split element is b""
    tail_ok = lines and lines[-1] == b""
    body = lines[:-1] if lines else []
    clean = 0
    for i, line in enumerate(body):
        try:
            events.append(json.loads(line.decode("utf-8")))
            clean += len(line) + 1
        except ValueError:
            if i == len(body) - 1 and not tail_ok:
                break  # torn final write
            raise
    return events, clean


def read_events(path: str) -> List[Dict[str, Any]]:
    """Parse a journal, tolerating a truncated final line (SIGKILL landed
    mid-append).  Raises on corruption anywhere else."""
    return _read_clean(path)[0]


class StudyJournal:
    """Append-mode JSONL journal with deterministic-replay dedup.

    Construct with ``resume=True`` to preload the existing event prefix:
    :meth:`append` then *consumes* matching prefix events instead of
    re-writing them (returning the recorded event, which may carry the
    cached evaluation value), and only events past the prefix hit the
    file.  ``strict`` prefix checking applies to replay-deterministic
    fields; a mismatch raises — the resumed parameters differ from the
    journaled study.
    """

    def __init__(self, path: str, resume: bool = False):
        self.path = path
        self._replay: List[Dict[str, Any]] = []
        self._pos = 0
        self._fh: Optional[io.TextIOBase] = None
        if resume:
            if not os.path.exists(path):
                raise FileNotFoundError(
                    f"resume=True but journal {path!r} does not exist")
            self._replay, clean = _read_clean(path)
            if clean < os.path.getsize(path):
                # drop the torn final write so appends continue from the
                # last complete event (keeps resumed journals byte-
                # identical to an uninterrupted run's)
                os.truncate(path, clean)

    # -- replay cache ------------------------------------------------------
    @property
    def replaying(self) -> bool:
        return self._pos < len(self._replay)

    def lookup(self, event: str, **match) -> Optional[Dict[str, Any]]:
        """Find a not-yet-consumed replay event by type + field equality
        (used to pre-check cache hits without consuming)."""
        for ev in self._replay[self._pos:]:
            if ev.get("event") != event:
                continue
            if all(ev.get(k) == v for k, v in match.items()):
                return ev
        return None

    def lookup_first(self, events: "tuple", **match
                     ) -> Optional[Dict[str, Any]]:
        """Find the FIRST not-yet-consumed replay event whose type is any
        of ``events`` and whose fields match.  Order matters when a trial
        segment was retried: its ``retry`` event precedes the eventual
        ``eval``/``fail`` at the same epochs, and replay must rediscover
        them in that order."""
        for ev in self._replay[self._pos:]:
            if ev.get("event") not in events:
                continue
            if all(ev.get(k) == v for k, v in match.items()):
                return ev
        return None

    def consume_history(self, events: "tuple",
                        unit: Optional[int] = None) -> List[Dict[str, Any]]:
        """Consume the contiguous run of recorded events at the cursor
        whose type is in ``events`` (optionally pinned to one ``unit``)
        and return them.

        This is the replay path for fleet lease histories attached to a
        CACHED unit (a replay cache hit never re-executes, so nothing
        re-generates its ``lease``/``expire``/``reissue`` events — the
        recorded ones are adopted verbatim).  Live units re-generate their
        histories deterministically and go through the strict
        :meth:`append` check instead."""
        out: List[Dict[str, Any]] = []
        while self._pos < len(self._replay):
            ev = self._replay[self._pos]
            if ev.get("event") not in events:
                break
            if unit is not None and ev.get("unit") != unit:
                break
            out.append(ev)
            self._pos += 1
        return out

    # -- append ------------------------------------------------------------
    def append(self, event: Dict[str, Any],
               check: bool = True) -> Dict[str, Any]:
        """Record one event.  During replay, consume and return the
        recorded twin instead of writing; past the prefix, write through.

        ``check`` asserts the deterministic fields of the emitted event
        match the recorded one (event type always; other keys when present
        in both) — the guard that a resumed study is replaying the SAME
        study.
        """
        if self._pos < len(self._replay):
            recorded = self._replay[self._pos]
            if check:
                if recorded.get("event") != event.get("event"):
                    raise ValueError(
                        f"journal replay diverged at event {self._pos}: "
                        f"recorded {recorded.get('event')!r}, study emitted "
                        f"{event.get('event')!r} — the resumed parameters "
                        f"do not match the journaled study")
                for k, v in event.items():
                    if k in recorded and recorded[k] != v and v is not None:
                        raise ValueError(
                            f"journal replay diverged at event {self._pos} "
                            f"({event.get('event')!r}): field {k!r} recorded "
                            f"as {recorded[k]!r}, study emitted {v!r}")
            self._pos += 1
            return recorded
        self._write(event)
        return event

    def _write(self, event: Dict[str, Any]) -> None:
        if self._fh is None:
            self._fh = io.open(self.path, "a", encoding="utf-8")
        self._fh.write(json.dumps(event, sort_keys=True) + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "StudyJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
