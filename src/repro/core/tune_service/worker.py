"""Fleet worker: one remote evaluation slot speaking the lease protocol.

A worker is the execution half of the coordinator/worker control-plane
split (Ray Tune's trial-executor shape): it owns NO study state, executes
exactly ONE work unit at a time, and talks to the coordinator through
three message types::

    hello      {type, worker}                      on connect
    heartbeat  {type, worker, unit, attempt}       every ``heartbeat_s``;
                                                   ``unit`` is None while
                                                   idle (lets the
                                                   coordinator detect a
                                                   lost result message)
    result     {type, worker, unit, attempt,       when the unit finishes
                result}                            (or times out locally)

and receives::

    unit       {type, unit, attempt, fn, args, timeout_s}
    shutdown   {type}

The evaluation runs on a daemon thread so the serve loop keeps
heartbeating mid-segment — a slow epoch loop is visibly alive, a dead or
wedged worker goes silent and its lease expires coordinator-side.  A unit
whose evaluation exceeds its ``timeout_s`` is converted into an
``{"error": "timeout..."}`` result locally (the hung thread is abandoned;
the process keeps serving) so a hung objective costs one slot-timeout,
never the study.

Transports:

* **process** (:func:`process_main`) — spawned by the coordinator on the
  same box; messages over ``multiprocessing`` queues.  The worker
  self-terminates when its parent dies, so a SIGKILLed coordinator never
  leaks orphan evaluators.
* **socket** (:func:`socket_main`, or ``python -m
  repro.core.tune_service.worker --connect HOST:PORT``) — length-prefixed
  pickle frames over TCP for workers on other hosts; the connection
  dropping ends the worker.  (Frames are pickles: only connect workers to
  a coordinator you trust.)

Injected faults (:mod:`.faults`) are applied HERE, keyed by
``(unit, attempt)``, because this is where real fleets break: process
death, wedged heartbeats, lost/duplicated/late result messages, hung
evaluations.
"""

from __future__ import annotations

import os
import pickle
import queue
import select
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, Optional

from .executor import _timed_safe
from .faults import NO_FAULTS, FaultPlan

#: heartbeat cadence (seconds) while a unit is evaluating
DEFAULT_HEARTBEAT_S = 0.1


def _apply_cache_env(cache_dir: Optional[str]) -> None:
    """Point a not-yet-imported jax at the shared XLA compile cache (the
    simulator pool's warm-start behaviour, inherited by fleet workers)."""
    if cache_dir:
        os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", cache_dir)
        os.environ.setdefault(
            "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
        os.environ.setdefault(
            "JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "-1")


class _Running:
    """One in-flight evaluation: the daemon thread plus its result box.
    Completion sets an event so the serve loop wakes instantly instead of
    holding the finished slot for a transport-poll interval."""

    def __init__(self, msg: Dict[str, Any], faults: FaultPlan):
        self.unit = int(msg["unit"])
        self.attempt = int(msg["attempt"])
        self.timeout_s = msg.get("timeout_s")
        self.t0 = time.perf_counter()
        self._box: Dict[str, Any] = {}
        self._event = threading.Event()
        self._faults = faults
        self._thread = threading.Thread(
            target=self._run, args=(msg["fn"], msg["args"]), daemon=True,
            name=f"repro-fleet-eval-u{self.unit}")
        self._thread.start()

    def _run(self, fn: Callable, args) -> None:
        if self._faults.kills(self.unit, self.attempt):
            # die mid-segment: the lease is live, heartbeats have flowed
            time.sleep(0.05)
            os._exit(9)
        if self._faults.hangs(self.unit, self.attempt):
            # a hung evaluation: heartbeats continue, the result never
            # comes — only timeout_s can unwedge the unit
            while True:
                time.sleep(3600)
        self._box["result"] = _timed_safe(fn, *args)
        self._event.set()

    def wait(self, timeout: float) -> None:
        self._event.wait(timeout)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def result(self) -> Dict[str, Any]:
        return self._box["result"]

    @property
    def elapsed(self) -> float:
        return time.perf_counter() - self.t0

    @property
    def timed_out(self) -> bool:
        return self.timeout_s is not None and self.elapsed > self.timeout_s


def _serve(recv: Callable[[float], Optional[Dict[str, Any]]],
           send: Callable[[Dict[str, Any]], None],
           worker_id: int, heartbeat_s: float, faults: FaultPlan,
           parent_alive: Callable[[], bool]) -> None:
    """The worker loop shared by every transport.

    Idle: block on the transport (new units wake it immediately) and send
    an *idle* heartbeat (``unit: None``) every ``heartbeat_s`` — this is
    how the coordinator learns a result message was lost (a worker
    claiming idle while its lease is live) and that a written-off worker
    recovered.  Busy: wait on the evaluation's completion event (finished
    slots are reported instantly, not at the next poll tick), heartbeat
    the lease every ``heartbeat_s``, and poll the transport
    non-blockingly for shutdown."""
    send({"type": "hello", "worker": worker_id})
    current: Optional[_Running] = None
    wedged = False  # a fired stall fault: alive but permanently silent
    last_hb = time.monotonic()
    while True:
        if not parent_alive():
            return
        try:
            if current is not None:
                delay = max(0.0, heartbeat_s
                            - (time.monotonic() - last_hb))
                if current.timeout_s is not None:
                    delay = min(delay, max(
                        0.0, current.timeout_s - current.elapsed) + 0.01)
                current.wait(delay)
                msg = recv(0.0)
            else:
                msg = recv(min(0.25, heartbeat_s))
        except (EOFError, OSError):
            return  # transport gone: the coordinator died or hung up
        if msg is not None:
            if msg.get("type") == "shutdown":
                return
            if msg.get("type") == "unit":
                if current is not None and not current.done:
                    # the coordinator never double-books a worker; a unit
                    # arriving mid-unit means state was lost — refuse it
                    send({"type": "result", "worker": worker_id,
                          "unit": int(msg["unit"]),
                          "attempt": int(msg["attempt"]),
                          "result": {"error": "worker busy (protocol "
                                              "violation)", "slot_s": 0.0}})
                    continue
                current = _Running(msg, faults)
                continue
        now = time.monotonic()
        if current is None:
            if not wedged and now - last_hb >= heartbeat_s:
                last_hb = now
                send({"type": "heartbeat", "worker": worker_id,
                      "unit": None, "attempt": None})
            continue
        u, a = current.unit, current.attempt
        if current.done:
            result = current.result
            current = None
            last_hb = now
            if faults.stalls(u, a):
                # stall: the worker wedges — this result and every later
                # message (including idle heartbeats) are suppressed, so
                # the lease expires by heartbeat SILENCE and the worker is
                # written off as suspect until it speaks again (never)
                wedged = True
                continue
            if faults.drops(u, a):
                # drop: pure message loss — the worker stays healthy, and
                # its idle heartbeats let the coordinator detect the lost
                # result quickly (the "lost" expiry fast path)
                continue
            delay = faults.delays(u, a)
            if delay:
                time.sleep(delay)  # straggler: the late twin still arrives
            out = {"type": "result", "worker": worker_id, "unit": u,
                   "attempt": a, "result": result}
            send(out)
            if faults.dups(u, a):
                send(out)
        elif current.timed_out:
            t = current.timeout_s
            current = None  # abandon the daemon thread; keep serving
            last_hb = now
            send({"type": "result", "worker": worker_id, "unit": u,
                  "attempt": a,
                  "result": {"error": f"timeout: unit {u} exceeded "
                                      f"{t}s on worker {worker_id}",
                             "timeout": True, "slot_s": float(t)}})
        elif faults.stalls(u, a):
            continue  # wedged host: no heartbeats, no result
        elif now - last_hb >= heartbeat_s:
            last_hb = now
            send({"type": "heartbeat", "worker": worker_id, "unit": u,
                  "attempt": a})


# -- process transport (multiprocessing queues) ------------------------------
def process_main(worker_id: int, inbox, outbox, heartbeat_s: float,
                 faults: FaultPlan, cache_dir: Optional[str]) -> None:
    """Entry point for coordinator-spawned process workers."""
    _apply_cache_env(cache_dir)
    import multiprocessing as mp
    parent = mp.parent_process()

    def recv(timeout: float):
        try:
            return inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def parent_alive() -> bool:
        return parent is None or parent.is_alive()

    try:
        _serve(recv, outbox.put, worker_id, heartbeat_s, faults,
               parent_alive)
    finally:
        outbox.cancel_join_thread()


# -- socket transport (length-prefixed pickle frames) ------------------------
def send_frame(sock: socket.socket, obj: Any) -> None:
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(struct.pack(">I", len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise EOFError("fleet connection closed")
        buf += chunk
    return buf


def recv_frame(sock: socket.socket) -> Any:
    """Blocking read of one frame."""
    (n,) = struct.unpack(">I", _recv_exact(sock, 4))
    return pickle.loads(_recv_exact(sock, n))


def socket_main(addr, worker_id: int,
                heartbeat_s: float = DEFAULT_HEARTBEAT_S,
                faults: FaultPlan = NO_FAULTS,
                cache_dir: Optional[str] = None) -> None:
    """Entry point for socket workers (same-box tests spawn this in a
    process; real remote hosts use the module CLI)."""
    _apply_cache_env(cache_dir)
    sock = socket.create_connection(tuple(addr))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    lock = threading.Lock()

    def recv(timeout: float):
        ready, _, _ = select.select([sock], [], [], timeout)
        if not ready:
            return None
        return recv_frame(sock)  # header seen: the frame follows promptly

    def send(msg: Dict[str, Any]) -> None:
        with lock:
            send_frame(sock, msg)

    try:
        _serve(recv, send, worker_id, heartbeat_s, faults, lambda: True)
    finally:
        try:
            sock.close()
        except OSError:
            pass


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="repro tune-service fleet worker (socket transport)")
    p.add_argument("--connect", required=True, metavar="HOST:PORT",
                   help="coordinator address")
    p.add_argument("--id", type=int, default=0, help="worker id")
    p.add_argument("--heartbeat", type=float, default=DEFAULT_HEARTBEAT_S,
                   help="heartbeat cadence in seconds")
    args = p.parse_args(argv)
    host, port = args.connect.rsplit(":", 1)
    socket_main((host, int(port)), args.id, heartbeat_s=args.heartbeat)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
