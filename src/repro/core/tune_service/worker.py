"""Fleet worker: one remote evaluation slot speaking the lease protocol.

A worker is the execution half of the coordinator/worker control-plane
split (Ray Tune's trial-executor shape): it owns NO study state, executes
exactly ONE work unit at a time, and talks to the coordinator through
three message types::

    hello      {type, worker}                      on connect
    heartbeat  {type, worker, unit, attempt}       every ``heartbeat_s``;
                                                   ``unit`` is None while
                                                   idle (lets the
                                                   coordinator detect a
                                                   lost result message)
    result     {type, worker, unit, attempt,       when the unit finishes
                result}                            (or times out locally)

and receives::

    unit       {type, unit, attempt, fn, args, timeout_s}
    shutdown   {type}

The evaluation runs on a daemon thread so the serve loop keeps
heartbeating mid-segment — a slow epoch loop is visibly alive, a dead or
wedged worker goes silent and its lease expires coordinator-side.  A unit
whose evaluation exceeds its ``timeout_s`` is converted into an
``{"error": "timeout..."}`` result locally (the hung thread is abandoned;
the process keeps serving) so a hung objective costs one slot-timeout,
never the study.

Transports:

* **process** (:func:`process_main`) — spawned by the coordinator on the
  same box; messages over ``multiprocessing`` queues.  The worker
  self-terminates when its parent dies, so a SIGKILLed coordinator never
  leaks orphan evaluators.
* **socket** (:func:`socket_main`, or ``python -m
  repro.core.tune_service.worker --connect HOST:PORT``) — authenticated,
  length-capped frames over TCP (:mod:`.transport`) for workers on other
  hosts.  Every frame is HMAC-signed with the fleet spec's shared
  ``auth_key`` and the worker greets with a signed hello before any unit
  is leased, so reachability no longer implies trust.  A dropped
  connection does NOT end the worker: it re-dials with exponential
  backoff and re-greets under the same identity, keeping any in-flight
  evaluation alive across the gap — the coordinator re-attaches the live
  lease, or first-commit-wins absorbs the duplicate if it already
  expired.

Injected faults (:mod:`.faults`) are applied HERE, keyed by
``(unit, attempt)``, because this is where real fleets break: process
death, wedged heartbeats, lost/duplicated/late result messages, hung
evaluations, and (socket transport) corrupted / truncated / replayed
frames, partitions and link latency.
"""

from __future__ import annotations

import os
import queue
import socket
import threading
import time
from typing import Any, Callable, Dict, Optional

from .executor import _timed_safe
from .faults import NO_FAULTS, FaultPlan
from .transport import (DEFAULT_FRAME_TIMEOUT_S, DEFAULT_MAX_FRAME_BYTES,
                        FleetSpec, FrameChannel, FrameError, greet)

#: heartbeat cadence (seconds) while a unit is evaluating
DEFAULT_HEARTBEAT_S = 0.1
#: environment variables the CLI / launcher use to pass secrets and
#: injected latency without putting them on argv (visible in ``ps``)
KEY_ENV = "REPRO_FLEET_KEY"
NET_DELAY_ENV = "REPRO_FLEET_NET_DELAY_S"


def _apply_cache_env(cache_dir: Optional[str]) -> None:
    """Point a not-yet-imported jax at the shared XLA compile cache (the
    simulator pool's warm-start behaviour, inherited by fleet workers)."""
    if cache_dir:
        os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", cache_dir)
        os.environ.setdefault(
            "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
        os.environ.setdefault(
            "JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "-1")


class _Running:
    """One in-flight evaluation: the daemon thread plus its result box.
    Completion sets an event so the serve loop wakes instantly instead of
    holding the finished slot for a transport-poll interval."""

    def __init__(self, msg: Dict[str, Any], faults: FaultPlan):
        self.unit = int(msg["unit"])
        self.attempt = int(msg["attempt"])
        self.timeout_s = msg.get("timeout_s")
        self.t0 = time.perf_counter()
        self._box: Dict[str, Any] = {}
        self._event = threading.Event()
        self._faults = faults
        self._thread = threading.Thread(
            target=self._run, args=(msg["fn"], msg["args"]), daemon=True,
            name=f"repro-fleet-eval-u{self.unit}")
        self._thread.start()

    def _run(self, fn: Callable, args) -> None:
        if self._faults.kills(self.unit, self.attempt):
            # die mid-segment: the lease is live, heartbeats have flowed
            time.sleep(0.05)
            os._exit(9)
        if self._faults.hangs(self.unit, self.attempt):
            # a hung evaluation: heartbeats continue, the result never
            # comes — only timeout_s can unwedge the unit
            while True:
                time.sleep(3600)
        self._box["result"] = _timed_safe(fn, *args)
        self._event.set()

    def wait(self, timeout: float) -> None:
        self._event.wait(timeout)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def result(self) -> Dict[str, Any]:
        return self._box["result"]

    @property
    def elapsed(self) -> float:
        return time.perf_counter() - self.t0

    @property
    def timed_out(self) -> bool:
        return self.timeout_s is not None and self.elapsed > self.timeout_s


class TransportLost(Exception):
    """The socket transport failed mid-serve (raised by the socket
    transport's send/recv closures); the reconnect loop re-dials.  Any
    in-flight evaluation survives in the :class:`_ServeState`."""


class _ServeState:
    """Serve-loop state that must survive a transport loss: the in-flight
    evaluation (its daemon thread keeps computing while the worker
    re-dials) and the wedged flag (a fired stall fault outlives any
    number of reconnects)."""

    def __init__(self):
        self.current: Optional[_Running] = None
        self.wedged = False  # a fired stall fault: alive, forever silent
        #: a result whose send was cut off by a transport loss: resent
        #: first thing after the next successful re-greet, so a partition
        #: landing on the result frame costs a reconnect, not the unit
        self.pending: Optional[Dict[str, Any]] = None


def _serve(recv: Callable[[float], Optional[Dict[str, Any]]],
           send: Callable[[Dict[str, Any]], None],
           worker_id: int, heartbeat_s: float, faults: FaultPlan,
           parent_alive: Callable[[], bool],
           state: Optional[_ServeState] = None,
           hello: bool = True) -> str:
    """The worker loop shared by every transport.

    Idle: block on the transport (new units wake it immediately) and send
    an *idle* heartbeat (``unit: None``) every ``heartbeat_s`` — this is
    how the coordinator learns a result message was lost (a worker
    claiming idle while its lease is live) and that a written-off worker
    recovered.  Busy: wait on the evaluation's completion event (finished
    slots are reported instantly, not at the next poll tick), heartbeat
    the lease every ``heartbeat_s``, and poll the transport
    non-blockingly for shutdown.

    Returns ``"shutdown"`` (coordinator said so), ``"parent"`` (the
    spawning coordinator process died) or ``"transport"`` (the transport
    broke — the socket path re-dials with the same ``state``).  The
    socket closures may also raise :class:`TransportLost` out of this
    loop; ``state`` keeps that safe."""
    if state is None:
        state = _ServeState()
    if hello:
        send({"type": "hello", "worker": worker_id})
    if state.pending is not None:
        # the previous connection died between computing a result and
        # delivering it: deliver before anything else (the coordinator
        # just re-attached the lease; this resolves it)
        out, state.pending = state.pending, None
        send(out)
    last_hb = time.monotonic()
    while True:
        if not parent_alive():
            return "parent"
        try:
            if state.current is not None:
                delay = max(0.0, heartbeat_s
                            - (time.monotonic() - last_hb))
                if state.current.timeout_s is not None:
                    delay = min(delay, max(
                        0.0, state.current.timeout_s
                        - state.current.elapsed) + 0.01)
                state.current.wait(delay)
                msg = recv(0.0)
            else:
                msg = recv(min(0.25, heartbeat_s))
        except (EOFError, OSError):
            return "transport"  # the coordinator died or hung up
        if msg is not None:
            if msg.get("type") == "shutdown":
                return "shutdown"
            if msg.get("type") == "unit":
                if state.current is not None and not state.current.done:
                    # the coordinator never double-books a worker; a unit
                    # arriving mid-unit means state was lost — refuse it
                    send({"type": "result", "worker": worker_id,
                          "unit": int(msg["unit"]),
                          "attempt": int(msg["attempt"]),
                          "result": {"error": "worker busy (protocol "
                                              "violation)", "slot_s": 0.0}})
                    continue
                state.current = _Running(msg, faults)
                continue
        now = time.monotonic()
        if state.current is None:
            if not state.wedged and now - last_hb >= heartbeat_s:
                last_hb = now
                send({"type": "heartbeat", "worker": worker_id,
                      "unit": None, "attempt": None})
            continue
        current = state.current
        u, a = current.unit, current.attempt
        if current.done:
            result = current.result
            state.current = None
            last_hb = now
            if faults.stalls(u, a):
                # stall: the worker wedges — this result and every later
                # message (including idle heartbeats) are suppressed, so
                # the lease expires by heartbeat SILENCE and the worker is
                # written off as suspect until it speaks again (never)
                state.wedged = True
                continue
            if faults.drops(u, a):
                # drop: pure message loss — the worker stays healthy, and
                # its idle heartbeats let the coordinator detect the lost
                # result quickly (the "lost" expiry fast path)
                continue
            delay = faults.delays(u, a)
            if delay:
                time.sleep(delay)  # straggler: the late twin still arrives
            out = {"type": "result", "worker": worker_id, "unit": u,
                   "attempt": a, "result": result}
            state.pending = out  # survives a transport loss mid-delivery
            send(out)
            if faults.dups(u, a):
                send(out)
            state.pending = None
        elif current.timed_out:
            t = current.timeout_s
            state.current = None  # abandon the daemon thread; keep serving
            last_hb = now
            send({"type": "result", "worker": worker_id, "unit": u,
                  "attempt": a,
                  "result": {"error": f"timeout: unit {u} exceeded "
                                      f"{t}s on worker {worker_id}",
                             "timeout": True, "slot_s": float(t)}})
        elif faults.stalls(u, a):
            continue  # wedged host: no heartbeats, no result
        elif now - last_hb >= heartbeat_s:
            last_hb = now
            send({"type": "heartbeat", "worker": worker_id, "unit": u,
                  "attempt": a})


# -- process transport (multiprocessing queues) ------------------------------
def process_main(worker_id: int, inbox, outbox, heartbeat_s: float,
                 faults: FaultPlan, cache_dir: Optional[str]) -> None:
    """Entry point for coordinator-spawned process workers."""
    _apply_cache_env(cache_dir)
    import multiprocessing as mp
    parent = mp.parent_process()

    def recv(timeout: float):
        try:
            return inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def parent_alive() -> bool:
        return parent is None or parent.is_alive()

    try:
        _serve(recv, outbox.put, worker_id, heartbeat_s, faults,
               parent_alive)
    finally:
        outbox.cancel_join_thread()


# -- socket transport (authenticated frames, reconnect-with-backoff) ---------
def _dial(addr, key: bytes, worker_id: int, max_frame: int,
          frame_timeout_s: float) -> FrameChannel:
    """One connect + greet attempt; raises OSError/FrameError on failure."""
    sock = socket.create_connection(tuple(addr), timeout=5.0)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    chan = FrameChannel(sock, key, max_frame=max_frame,
                        frame_timeout_s=frame_timeout_s)
    try:
        greet(chan, worker_id)
    except BaseException:
        chan.close()
        raise
    return chan


def socket_main(addr, worker_id: int,
                heartbeat_s: float = DEFAULT_HEARTBEAT_S,
                faults: FaultPlan = NO_FAULTS,
                cache_dir: Optional[str] = None,
                key: Optional[bytes] = None,
                max_frame: int = DEFAULT_MAX_FRAME_BYTES,
                frame_timeout_s: float = DEFAULT_FRAME_TIMEOUT_S,
                max_redials: int = 8,
                redial_backoff_s: float = 0.2,
                net_delay_s: float = 0.0,
                announce: Optional[Callable[[str], None]] = None) -> None:
    """Entry point for socket workers (same-box tests and self-spawned
    fleets call this in a process; remote hosts use the module CLI).

    The outer loop is reconnect-with-backoff: dial, greet with the signed
    hello, serve until the transport breaks, then re-dial (exponential
    backoff, at most ``max_redials`` consecutive failures) and re-greet
    under the same ``worker_id``.  The :class:`_ServeState` — including
    an in-flight evaluation's daemon thread — survives the gap, so after
    re-greeting the worker resumes heartbeating its lease and the
    coordinator re-attaches it.  A greet the coordinator never answers
    (wrong auth key) fails fast instead of redialing forever.
    """
    _apply_cache_env(cache_dir)
    if key is None:
        hexkey = os.environ.get(KEY_ENV, "")
        if not hexkey:
            raise ValueError(
                f"socket workers need the fleet auth key: pass key= or "
                f"set {KEY_ENV} (see FleetSpec / tools/fleet_launch.py)")
        key = bytes.fromhex(hexkey)
    if not net_delay_s:
        net_delay_s = float(os.environ.get(NET_DELAY_ENV, "0") or 0)
    state = _ServeState()
    # frame faults fire once per (unit, attempt) per worker process —
    # a re-issued attempt has fresh coordinates, so it runs clean
    fired: set = set()
    partition_hold = [0.0]

    def run_once(chan: FrameChannel) -> str:
        def recv(timeout: float):
            try:
                return chan.recv(wait_timeout=timeout)
            except FrameError as e:
                # a garbled or hostile coordinator stream: drop + re-dial
                raise TransportLost(str(e)) from e

        def send(msg: Dict[str, Any]) -> None:
            if net_delay_s:
                time.sleep(net_delay_s)
            kind = msg.get("type")
            u, a = msg.get("unit"), msg.get("attempt")
            try:
                if kind == "result":
                    hold = faults.partitions(u, a)
                    if hold and ("partition", u, a) not in fired:
                        # the link drops mid-lease, just before the result
                        # frame, and stays down: close, hold, then re-dial
                        # and re-greet.  Keyed to the result (every unit
                        # sends exactly one) so the fault fires
                        # deterministically; the result itself survives in
                        # ``state.pending`` and is delivered after the
                        # reconnect — the coordinator re-attaches the
                        # lease, nothing is re-executed
                        fired.add(("partition", u, a))
                        partition_hold[0] = hold
                        chan.close()
                        raise TransportLost("injected partition")
                    raw = chan.encode(msg)
                    if faults.corrupts(u, a) and \
                            ("corrupt", u, a) not in fired:
                        fired.add(("corrupt", u, a))
                        # flip the last payload byte: the signature no
                        # longer verifies coordinator-side
                        raw = raw[:-1] + bytes([raw[-1] ^ 0x01])
                        chan.send_bytes(raw)
                        return
                    if faults.truncates(u, a) and \
                            ("truncate", u, a) not in fired:
                        fired.add(("truncate", u, a))
                        # half a frame then EOF: closing is what makes the
                        # fault deterministic (the coordinator always sees
                        # truncated, never a signature race with later
                        # heartbeat bytes filling the body read)
                        chan.send_bytes(raw[:len(raw) // 2])
                        chan.close()
                        raise TransportLost("injected truncated frame")
                    chan.send_bytes(raw)
                    if faults.replays(u, a) and \
                            ("replay", u, a) not in fired:
                        fired.add(("replay", u, a))
                        # the same bytes again: a stale sequence number —
                        # rejected even though the signature verifies
                        chan.send_bytes(raw)
                    return
                chan.send(msg)
            except TransportLost:
                raise
            except (OSError, FrameError) as e:
                raise TransportLost(str(e)) from e

        try:
            # greet() already presented the signed hello; the coordinator
            # reader forwards it, so the serve loop must not repeat it
            return _serve(recv, send, worker_id, heartbeat_s, faults,
                          lambda: True, state=state, hello=False)
        except TransportLost:
            return "transport"

    dials = 0
    while True:
        try:
            chan = _dial(addr, key, worker_id, max_frame, frame_timeout_s)
        except FrameError:
            return  # greeted but refused / garbled welcome: wrong key
        except OSError:
            dials += 1
            if dials > max_redials:
                return
            time.sleep(min(redial_backoff_s * (2 ** (dials - 1)), 2.0))
            continue
        dials = 0
        if announce is not None:
            announce(f"worker {worker_id} greeted")
        outcome = run_once(chan)
        chan.close()
        if outcome in ("shutdown", "parent"):
            return
        if partition_hold[0]:
            time.sleep(partition_hold[0])
            partition_hold[0] = 0.0
        dials += 1
        if dials > max_redials:
            return
        time.sleep(min(redial_backoff_s * (2 ** (dials - 1)), 2.0))


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="repro tune-service fleet worker (authenticated "
                    "socket transport)")
    p.add_argument("--connect", metavar="HOST:PORT", default=None,
                   help="coordinator address (defaults to the fleet "
                        "spec's host:port)")
    p.add_argument("--id", type=int, default=0, help="worker id")
    p.add_argument("--fleet-spec", metavar="SPEC.json", default=None,
                   help="fleet spec file: address, auth key, heartbeat "
                        "and transport caps in one artifact")
    p.add_argument("--key-file", metavar="PATH", default=None,
                   help="file holding the hex auth key (overrides the "
                        f"spec; default: ${KEY_ENV})")
    p.add_argument("--heartbeat", type=float, default=None,
                   help="heartbeat cadence in seconds")
    p.add_argument("--max-redials", type=int, default=None,
                   help="consecutive failed re-dials before giving up")
    args = p.parse_args(argv)
    spec = FleetSpec.load(args.fleet_spec) if args.fleet_spec else None
    key = None
    if args.key_file:
        with open(args.key_file, "r", encoding="utf-8") as fh:
            key = bytes.fromhex(fh.read().strip())
    elif os.environ.get(KEY_ENV):
        key = bytes.fromhex(os.environ[KEY_ENV])
    elif spec is not None and spec.auth_key:
        key = spec.key_bytes
    if args.connect:
        host, port = args.connect.rsplit(":", 1)
        addr = (host, int(port))
    elif spec is not None:
        addr = (spec.host, spec.port)
    else:
        p.error("--connect or --fleet-spec is required")
    kw: Dict[str, Any] = {}
    if spec is not None:
        kw.update(max_frame=spec.max_frame_bytes,
                  frame_timeout_s=spec.frame_timeout_s,
                  max_redials=spec.max_redials,
                  redial_backoff_s=spec.redial_backoff_s)
        if args.heartbeat is None:
            args.heartbeat = spec.heartbeat_s
    if args.max_redials is not None:
        kw["max_redials"] = args.max_redials
    socket_main(addr, args.id,
                heartbeat_s=args.heartbeat if args.heartbeat is not None
                else DEFAULT_HEARTBEAT_S,
                key=key, announce=lambda line: print(line, flush=True),
                **kw)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
