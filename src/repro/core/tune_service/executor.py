"""TrialExecutor: N saturated evaluation slots + canonical commit order.

The executor generalizes the PR 3 cross-cell sweep scheduler to tuning:
work units (trial evaluation segments) are enqueued in creation order and
run on whichever of the N slots frees first — slots never idle while work
is queued, and nothing ever waits on a per-round barrier.  What makes the
asynchrony safe is the COMMIT protocol: results are handed back strictly
in unit-creation order (:meth:`pop_next` blocks on the canonical-next
unit while later finishers buffer), so every decision the service makes —
asks, ASHA promotions, CRN-group tells — sees a deterministic state no
matter how wall-clock completion interleaved.  Combined with the
simulator's counter-based draws (placement-invariant numbers), the entire
study is a pure function of its parameters; the executor only changes how
fast it runs.

Two slot backends:

* ``"thread"`` (default) — a thread pool; the compiled jax epoch loop
  releases the GIL inside XLA executions, so segments overlap on
  multi-core hosts, and unpicklable custom ``objective=`` callables work.
* ``"process"`` — the simulator's persistent process pool
  (:func:`repro.core.simulator._get_pool`), sharing its spawn-safety and
  XLA warm-start behaviour; payload functions must be module-level
  picklables (the service's default simulator objective is).

Failures never kill a slot: unit callables are wrapped, exceptions come
back as ``{"error": <traceback>}`` results, and the service records a
FAILED trial and keeps the window full (the fault-injection satellite).
Two further slot-level faults are absorbed here rather than killing the
study:

* a hung evaluation — a per-unit ``timeout_s`` bounds the canonical-next
  wait and converts the unit into an ``{"error": "timeout..."}`` result
  (the wedged thread/process slot is abandoned);
* a dead ``pool="process"`` worker — ``BrokenProcessPool`` poisons every
  pending future in the shared pool, so the executor discards the broken
  pool, builds a fresh one and resubmits ALL outstanding units.  Results
  are deterministic, so the journal stays byte-identical to a fault-free
  run; only wall-clock suffers.  Rebuilds are bounded
  (:data:`MAX_POOL_REBUILDS`) so a poisoned objective cannot respawn
  forever.
"""

from __future__ import annotations

import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Tuple

POOLS = ("thread", "process")

#: bound on BrokenProcessPool self-heals per executor
MAX_POOL_REBUILDS = 3

try:  # BrokenExecutor subsumes BrokenProcessPool (py3.7+)
    from concurrent.futures import BrokenExecutor as BrokenPoolError
except ImportError:  # pragma: no cover
    from concurrent.futures.process import \
        BrokenProcessPool as BrokenPoolError


def _timed_safe(fn: Callable[..., Dict[str, Any]], *args
                ) -> Dict[str, Any]:
    """Run one unit: exceptions -> {"error": traceback}; always stamps the
    slot-occupancy wall clock (``slot_s``) for the utilization receipt.
    Module-level so process pools can pickle it."""
    t0 = time.perf_counter()
    try:
        out = fn(*args)
        if not isinstance(out, dict):
            out = {"value": out}
    except BaseException as e:  # noqa: BLE001 - FAILED-trial contract
        out = {"error": "".join(traceback.format_exception(
            type(e), e, e.__traceback__))}
    out["slot_s"] = time.perf_counter() - t0
    return out


class TrialExecutor:
    """``slots`` evaluation slots over a thread/process pool, with results
    committed in unit-creation order."""

    def __init__(self, slots: int, pool: str = "thread",
                 timeout_s: Optional[float] = None):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if pool not in POOLS:
            raise ValueError(f"unknown pool {pool!r}; expected one of "
                             f"{POOLS}")
        self.slots = int(slots)
        self.pool_kind = pool
        self.timeout_s = timeout_s  # default per-unit hang bound
        if pool == "process":
            from ..simulator import _get_pool
            self._pool = _get_pool(self.slots)
            self._owns_pool = False
        else:
            import concurrent.futures
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=self.slots,
                thread_name_prefix="repro-tune-slot")
            self._owns_pool = True
        self._futures: Dict[int, Any] = {}
        # (fn, args, timeout_s) per live unit — resubmission after a pool
        # heal, and the per-unit hang bound
        self._specs: Dict[int, Tuple[Callable, tuple, Optional[float]]] = {}
        self._rebuilds = 0
        self._next_seq = 0
        self._next_commit = 0
        self.busy_s = 0.0  # summed slot occupancy (utilization receipt)

    # -- submission --------------------------------------------------------
    def submit(self, fn: Callable[..., Dict[str, Any]], *args,
               timeout_s: Optional[float] = None) -> int:
        """Enqueue one unit (FIFO; the pool keeps <= slots running).
        Returns the unit's canonical sequence number."""
        seq = self._next_seq
        self._next_seq += 1
        t = timeout_s if timeout_s is not None else self.timeout_s
        self._specs[seq] = (fn, args, t)
        self._futures[seq] = self._safe_submit(fn, args)
        return seq

    def _safe_submit(self, fn: Callable, args: tuple):
        try:
            return self._pool.submit(_timed_safe, fn, *args)
        except BrokenPoolError:
            self._heal()
            return self._pool.submit(_timed_safe, fn, *args)

    def submit_ready(self, result: Dict[str, Any]) -> int:
        """Enqueue a pre-resolved unit (journal-replay cache hit): it holds
        a commit slot in canonical order but occupies no evaluation slot."""
        seq = self._next_seq
        self._next_seq += 1
        self._futures[seq] = dict(result)  # sentinel: plain dict == ready
        return seq

    # -- canonical-order commits ------------------------------------------
    @property
    def outstanding(self) -> int:
        """Units created but not yet committed (the ask-ahead window)."""
        return self._next_seq - self._next_commit

    def pop_next(self) -> Tuple[int, Dict[str, Any]]:
        """Block for the canonical-next unit's result (later finishers
        buffer inside their futures until their turn).  A unit exceeding
        its ``timeout_s`` wait comes back as an ``{"error": "timeout..."}``
        result instead of wedging the study; a dead process-pool worker
        triggers a bounded pool rebuild + resubmission of every
        outstanding unit."""
        import concurrent.futures as cf
        seq = self._next_commit
        fut = self._futures.pop(seq)
        if isinstance(fut, dict):
            result = fut
        else:
            _, _, t = self._specs.get(seq, (None, (), None))
            deadline = None if t is None else time.monotonic() + t
            while True:
                try:
                    left = None if deadline is None else \
                        max(0.0, deadline - time.monotonic())
                    result = fut.result(timeout=left)
                    break
                except cf.TimeoutError:
                    fut.cancel()  # queued: freed; running: slot abandoned
                    result = {"error": f"timeout: unit {seq} exceeded "
                                       f"{t}s in the {self.pool_kind} "
                                       f"pool", "timeout": True,
                              "slot_s": float(t)}
                    break
                except BrokenPoolError:
                    # the canonical-next unit was already popped from
                    # _futures, so _heal's resubmission loop misses it —
                    # resubmit it on the fresh pool here
                    self._heal()
                    fn, args, _ = self._specs[seq]
                    fut = self._pool.submit(_timed_safe, fn, *args)
        self._specs.pop(seq, None)
        self._next_commit += 1
        self.busy_s += float(result.get("slot_s", 0.0))
        return seq, result

    def _heal(self) -> None:
        """A broken process pool poisons every pending future: discard it,
        build a fresh pool and resubmit all outstanding units.  Unit
        results are deterministic, so re-execution changes nothing the
        journal sees — the fault costs wall clock only."""
        if self.pool_kind != "process":
            raise RuntimeError("thread pool broke — cannot self-heal")
        if self._rebuilds >= MAX_POOL_REBUILDS:
            raise RuntimeError(
                f"process pool broke {self._rebuilds + 1} times "
                f"(> MAX_POOL_REBUILDS={MAX_POOL_REBUILDS}); giving up — "
                f"the objective is likely killing its workers")
        self._rebuilds += 1
        from ..simulator import _discard_pool, _get_pool
        _discard_pool(self._pool)
        self._pool = _get_pool(self.slots)
        for seq, fut in list(self._futures.items()):
            if isinstance(fut, dict):
                continue  # replay cache hit: no evaluation to redo
            fn, args, _ = self._specs[seq]
            self._futures[seq] = self._pool.submit(_timed_safe, fn, *args)

    def take_history(self, seq: int) -> List[Dict[str, Any]]:
        """Lease lifecycle events for commit-time journaling.  Local slots
        have no leases — the fleet coordinator overrides this."""
        return []

    def close(self) -> None:
        """Shut down, cancelling queued units so an aborted study doesn't
        leave orphan segments burning slots (running units cannot be
        interrupted, but their results are dropped)."""
        for fut in self._futures.values():
            if not isinstance(fut, dict):
                fut.cancel()
        self._futures.clear()
        self._specs.clear()
        if self._owns_pool:
            self._pool.shutdown(wait=True, cancel_futures=True)
