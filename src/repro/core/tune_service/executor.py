"""TrialExecutor: N saturated evaluation slots + canonical commit order.

The executor generalizes the PR 3 cross-cell sweep scheduler to tuning:
work units (trial evaluation segments) are enqueued in creation order and
run on whichever of the N slots frees first — slots never idle while work
is queued, and nothing ever waits on a per-round barrier.  What makes the
asynchrony safe is the COMMIT protocol: results are handed back strictly
in unit-creation order (:meth:`pop_next` blocks on the canonical-next
unit while later finishers buffer), so every decision the service makes —
asks, ASHA promotions, CRN-group tells — sees a deterministic state no
matter how wall-clock completion interleaved.  Combined with the
simulator's counter-based draws (placement-invariant numbers), the entire
study is a pure function of its parameters; the executor only changes how
fast it runs.

Two slot backends:

* ``"thread"`` (default) — a thread pool; the compiled jax epoch loop
  releases the GIL inside XLA executions, so segments overlap on
  multi-core hosts, and unpicklable custom ``objective=`` callables work.
* ``"process"`` — the simulator's persistent process pool
  (:func:`repro.core.simulator._get_pool`), sharing its spawn-safety and
  XLA warm-start behaviour; payload functions must be module-level
  picklables (the service's default simulator objective is).

Failures never kill a slot: unit callables are wrapped, exceptions come
back as ``{"error": <traceback>}`` results, and the service records a
FAILED trial and keeps the window full (the fault-injection satellite).
"""

from __future__ import annotations

import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Tuple

POOLS = ("thread", "process")


def _timed_safe(fn: Callable[..., Dict[str, Any]], *args
                ) -> Dict[str, Any]:
    """Run one unit: exceptions -> {"error": traceback}; always stamps the
    slot-occupancy wall clock (``slot_s``) for the utilization receipt.
    Module-level so process pools can pickle it."""
    t0 = time.perf_counter()
    try:
        out = fn(*args)
        if not isinstance(out, dict):
            out = {"value": out}
    except BaseException as e:  # noqa: BLE001 - FAILED-trial contract
        out = {"error": "".join(traceback.format_exception(
            type(e), e, e.__traceback__))}
    out["slot_s"] = time.perf_counter() - t0
    return out


class TrialExecutor:
    """``slots`` evaluation slots over a thread/process pool, with results
    committed in unit-creation order."""

    def __init__(self, slots: int, pool: str = "thread"):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if pool not in POOLS:
            raise ValueError(f"unknown pool {pool!r}; expected one of "
                             f"{POOLS}")
        self.slots = int(slots)
        self.pool_kind = pool
        if pool == "process":
            from ..simulator import _get_pool
            self._pool = _get_pool(self.slots)
            self._owns_pool = False
        else:
            import concurrent.futures
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=self.slots,
                thread_name_prefix="repro-tune-slot")
            self._owns_pool = True
        self._futures: Dict[int, Any] = {}
        self._next_seq = 0
        self._next_commit = 0
        self.busy_s = 0.0  # summed slot occupancy (utilization receipt)

    # -- submission --------------------------------------------------------
    def submit(self, fn: Callable[..., Dict[str, Any]], *args) -> int:
        """Enqueue one unit (FIFO; the pool keeps <= slots running).
        Returns the unit's canonical sequence number."""
        seq = self._next_seq
        self._next_seq += 1
        self._futures[seq] = self._pool.submit(_timed_safe, fn, *args)
        return seq

    def submit_ready(self, result: Dict[str, Any]) -> int:
        """Enqueue a pre-resolved unit (journal-replay cache hit): it holds
        a commit slot in canonical order but occupies no evaluation slot."""
        seq = self._next_seq
        self._next_seq += 1
        self._futures[seq] = dict(result)  # sentinel: plain dict == ready
        return seq

    # -- canonical-order commits ------------------------------------------
    @property
    def outstanding(self) -> int:
        """Units created but not yet committed (the ask-ahead window)."""
        return self._next_seq - self._next_commit

    def pop_next(self) -> Tuple[int, Dict[str, Any]]:
        """Block for the canonical-next unit's result (later finishers
        buffer inside their futures until their turn)."""
        seq = self._next_commit
        fut = self._futures.pop(seq)
        result = fut if isinstance(fut, dict) else fut.result()
        self._next_commit += 1
        self.busy_s += float(result.get("slot_s", 0.0))
        return seq, result

    def close(self) -> None:
        if self._owns_pool:
            self._pool.shutdown(wait=True)
