"""ASHA successive halving on partial-epoch objectives.

Asynchronous Successive Halving (Li et al.): evaluate every trial to a
small epoch budget first, promote only the promising fraction to the next
rung, and terminate the rest — most tuning compute goes to candidates that
are already visibly doomed at a quarter of the budget, and the compiled
epoch loop's checkpointable scan carry makes the partial evaluations
cheap to extend instead of recompute.

Rung budgets default to the issue's ¼ / ½ / full epochs.  Promotion is the
asynchronous rule: when a trial lands at rung ``r`` with value ``v``, it is
promoted iff ``v`` ranks within the top ``1/eta`` of ALL rung-``r`` results
committed so far (itself included; ties break by trial index, earlier
wins); with fewer than ``eta`` results only the current best promotes.
Decisions are made at canonical journal-commit time, never at wall-clock
arrival, so the promotion sequence — like everything else in the service —
is a deterministic function of the study parameters.

Early-terminated trials still inform the optimizer: their partial value is
extrapolated to full budget (``value * E / epochs_run``) before ``tell``,
so a trial stopped at ¼ budget does not masquerade as a 4x-faster config in
the surrogate.
"""

from __future__ import annotations

import math
from typing import List, Tuple

#: default rung budgets as fractions of the full epoch budget
RUNG_FRACTIONS = (0.25, 0.5, 1.0)

PROMOTE = "promote"
STOP = "stop"


class ASHAScheduler:
    """Successive-halving rung bookkeeping for one study."""

    name = "asha"

    def __init__(self, max_epochs: int, eta: int = 4,
                 rung_fractions=RUNG_FRACTIONS):
        if max_epochs < 1:
            raise ValueError("max_epochs must be >= 1")
        if eta < 2:
            raise ValueError(f"eta must be >= 2, got {eta}")
        self.max_epochs = int(max_epochs)
        self.eta = int(eta)
        epochs: List[int] = []
        for f in rung_fractions:
            e = min(self.max_epochs, max(1, int(math.ceil(max_epochs * f))))
            if not epochs or e > epochs[-1]:  # dedupe degenerate tiny budgets
                epochs.append(e)
        if epochs[-1] != self.max_epochs:
            epochs.append(self.max_epochs)
        #: epoch budget per rung; the last rung is always the full budget
        self.rung_epochs: Tuple[int, ...] = tuple(epochs)
        #: committed (value, trial_index) pairs per rung, commit order
        self.results: List[List[Tuple[float, int]]] = \
            [[] for _ in self.rung_epochs]

    @property
    def n_rungs(self) -> int:
        return len(self.rung_epochs)

    def is_final(self, rung: int) -> bool:
        return rung >= self.n_rungs - 1

    def report(self, rung: int, trial_index: int, value: float) -> str:
        """Record a committed rung result and decide the trial's fate.

        Must be called in canonical commit order; the decision depends only
        on the results committed before this one (plus this one), which is
        what makes kill/resume replay exact.
        """
        if self.is_final(rung):
            raise ValueError(f"rung {rung} is the final budget; no decision")
        pool = self.results[rung]
        pool.append((float(value), int(trial_index)))
        k = max(1, len(pool) // self.eta)  # promotion slots so far
        me = (float(value), int(trial_index))
        rank = sum(1 for r in pool if r < me)  # ties -> earlier trial wins
        return PROMOTE if rank < k else STOP
