"""Fault-injection harness for the tune-service fleet.

Robustness claims are only as good as the faults they were tested under,
so the fleet's test matrix is driven from here: a :class:`FaultPlan` is a
frozen, picklable schedule of worker misbehaviour keyed by **(unit
sequence number, attempt)** — the canonical work-unit coordinates that are
deterministic across runs, placements and resumes.  Because every worker
executes exactly ONE unit at a time, a fault keyed this way hits exactly
one lease no matter which worker drew the unit, which is what makes the
journal-twin byte-identity tests possible: two runs with the same plan
produce the same ``lease``/``expire``/``reissue`` histories even though
wall-clock scheduling differs.

Injectors (all applied worker-side, where the fleet actually breaks):

``kill``
    The worker process dies (``os._exit``) mid-segment — the coordinator
    sees the death (process sentinel / socket EOF), expires the lease
    immediately and re-issues the unit.
``stall``
    The worker stops heartbeating and swallows the unit's result — a
    wedged host.  The lease expires after ``lease_deadline`` missed
    heartbeats and the unit is re-issued; the stalled worker is written
    off.
``hang``
    The evaluation never returns but heartbeats keep flowing — a hung
    objective, not a dead worker.  Only the per-unit ``timeout_s`` can
    convert this into a FAILED result (satellite: the study must not
    wedge).
``drop``
    The result message is computed but never sent (message loss).  The
    lease expires and the unit is re-issued — duplicate execution is safe.
``dup``
    The result message is sent twice (message duplication).  The
    coordinator commits the first and asserts the twin bitwise equal.
``delay``
    The result message is sent ``seconds`` late (straggler).  The lease
    expires, the unit is re-issued, and whichever result lands first
    commits — the late twin is asserted equal against it.

Network-shaped injectors (socket transport only — they mangle frames at
the codec layer, so the authenticated transport's reject paths are
exercised by the same deterministic (unit, attempt) coordinates):

``corrupt``
    The result frame is sent with its last payload byte flipped — the
    signature no longer verifies, the coordinator journals a
    ``reject``/``bad-signature`` and drops the connection; the worker
    re-dials and the unit is re-issued.
``truncate``
    Half the result frame is sent, then the connection is closed (a
    crashed sender / cut link mid-frame).  Closing is what makes the
    fault deterministic: the coordinator always sees EOF-mid-frame
    (``truncated``), never a signature race against later heartbeats.
``replay``
    The result frame's raw bytes are sent twice.  The second copy has a
    stale sequence number, so it is rejected as a ``replay`` even though
    its signature verifies.
``partition``
    ``(unit, attempt, seconds)``: the link drops mid-lease — just before
    the unit's result frame, so the fault fires deterministically (every
    unit sends exactly one result) — and stays down for ``seconds``: the
    reconnect-with-backoff path.  The computed result survives the gap
    worker-side; on re-dial + re-greet the coordinator re-attaches the
    live lease (journalling ``reconnect``) and the result is delivered —
    or, if the lease already expired, first-commit-wins absorbs the
    duplicate.
``net_delay_s``
    Uniform latency: every frame send sleeps this long first (the
    benchmark's socket+latency arm; also settable via the
    ``REPRO_FLEET_NET_DELAY_S`` env var for CI).

The flaky-objective callables at the bottom inject *evaluation* faults
(raise / self-SIGKILL) through the normal ``objective=`` path; they are
module-level classes so process pools can pickle them, and they use
marker files (``O_CREAT | O_EXCL`` — atomic across processes) so "fail
the first N calls" stays exact under concurrency.

``tear_journal`` truncates a journal mid-line — the torn-write fault the
resume path must absorb.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Tuple


def _pairs(spec) -> Tuple[Tuple[int, int], ...]:
    return tuple((int(u), int(a)) for u, a in spec)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of injected fleet faults.

    Every field is a tuple of ``(unit, attempt)`` pairs (``delay`` adds a
    ``seconds`` third element).  ``unit`` is the canonical work-unit
    sequence number (creation order, the executor's commit order);
    ``attempt`` is the lease attempt (0 = first issue, 1 = first
    re-issue, ...).  An empty plan injects nothing.
    """

    kill: Tuple[Tuple[int, int], ...] = ()
    stall: Tuple[Tuple[int, int], ...] = ()
    hang: Tuple[Tuple[int, int], ...] = ()
    drop: Tuple[Tuple[int, int], ...] = ()
    dup: Tuple[Tuple[int, int], ...] = ()
    delay: Tuple[Tuple[int, int, float], ...] = ()
    corrupt: Tuple[Tuple[int, int], ...] = ()
    truncate: Tuple[Tuple[int, int], ...] = ()
    replay: Tuple[Tuple[int, int], ...] = ()
    partition: Tuple[Tuple[int, int, float], ...] = ()
    #: uniform injected latency before every frame send (socket transport)
    net_delay_s: float = 0.0
    #: kill every worker whose unit satisfies ``unit % kill_every == which``
    #: on attempt 0 (the benchmark's "1-in-8 injected worker kills")
    kill_every: int = 0
    kill_phase: int = 0

    def __post_init__(self):
        object.__setattr__(self, "kill", _pairs(self.kill))
        object.__setattr__(self, "stall", _pairs(self.stall))
        object.__setattr__(self, "hang", _pairs(self.hang))
        object.__setattr__(self, "drop", _pairs(self.drop))
        object.__setattr__(self, "dup", _pairs(self.dup))
        object.__setattr__(self, "delay", tuple(
            (int(u), int(a), float(s)) for u, a, s in self.delay))
        object.__setattr__(self, "corrupt", _pairs(self.corrupt))
        object.__setattr__(self, "truncate", _pairs(self.truncate))
        object.__setattr__(self, "replay", _pairs(self.replay))
        object.__setattr__(self, "partition", tuple(
            (int(u), int(a), float(s)) for u, a, s in self.partition))

    def kills(self, unit: int, attempt: int) -> bool:
        if (unit, attempt) in self.kill:
            return True
        return bool(self.kill_every) and attempt == 0 and \
            unit % self.kill_every == self.kill_phase

    def stalls(self, unit: int, attempt: int) -> bool:
        return (unit, attempt) in self.stall

    def hangs(self, unit: int, attempt: int) -> bool:
        return (unit, attempt) in self.hang

    def drops(self, unit: int, attempt: int) -> bool:
        return (unit, attempt) in self.drop

    def dups(self, unit: int, attempt: int) -> bool:
        return (unit, attempt) in self.dup

    def delays(self, unit: int, attempt: int) -> float:
        for u, a, s in self.delay:
            if (u, a) == (unit, attempt):
                return s
        return 0.0

    def corrupts(self, unit: int, attempt: int) -> bool:
        return (unit, attempt) in self.corrupt

    def truncates(self, unit: int, attempt: int) -> bool:
        return (unit, attempt) in self.truncate

    def replays(self, unit: int, attempt: int) -> bool:
        return (unit, attempt) in self.replay

    def partitions(self, unit: int, attempt: int) -> float:
        for u, a, s in self.partition:
            if (u, a) == (unit, attempt):
                return s
        return 0.0

    @property
    def empty(self) -> bool:
        return not (self.kill or self.stall or self.hang or self.drop
                    or self.dup or self.delay or self.corrupt
                    or self.truncate or self.replay or self.partition
                    or self.net_delay_s or self.kill_every)


NO_FAULTS = FaultPlan()


def tear_journal(path: str, keep_lines: int, tail_bytes: int = 10) -> None:
    """Truncate ``path`` to ``keep_lines`` complete events plus
    ``tail_bytes`` of the next line — the torn final write a SIGKILL
    mid-append leaves behind."""
    with open(path, "rb") as fh:
        lines = fh.read().split(b"\n")
    if keep_lines >= len(lines) - 1:
        raise ValueError(f"journal has only {len(lines) - 1} events")
    torn = b"\n".join(lines[:keep_lines]) + b"\n" + \
        lines[keep_lines][:tail_bytes]
    with open(path, "wb") as fh:
        fh.write(torn)


def _claim(marker_dir: str, prefix: str, n: int) -> bool:
    """Atomically claim one of ``n`` cross-process marker slots; returns
    True while claims remain (O_CREAT|O_EXCL — exactly n callers win)."""
    for i in range(n):
        try:
            fd = os.open(os.path.join(marker_dir, f"{prefix}{i}"),
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            continue
        os.close(fd)
        return True
    return False


@dataclasses.dataclass
class FailNTimes:
    """Objective whose first ``n`` calls raise (a transient evaluation
    fault); later calls return ``config[knob]``.  Picklable; exact under
    process pools via atomic marker files in ``marker_dir``."""

    marker_dir: str
    n: int = 1
    knob: str = "sampling_period"

    def __call__(self, config) -> float:
        if _claim(self.marker_dir, "fail", self.n):
            raise RuntimeError("injected transient fault (FailNTimes)")
        return float(config[self.knob])


@dataclasses.dataclass
class KillNTimes:
    """Objective that SIGKILLs its own process on the first ``n`` calls —
    the process-pool worker-death fault.  Later calls return
    ``config[knob]``."""

    marker_dir: str
    n: int = 1
    knob: str = "sampling_period"
    grace_s: float = 0.05

    def __call__(self, config) -> float:
        if _claim(self.marker_dir, "kill", self.n):
            time.sleep(self.grace_s)  # die mid-unit, not at the boundary
            os.kill(os.getpid(), signal.SIGKILL)
        return float(config[self.knob])


@dataclasses.dataclass
class SlowObjective:
    """Objective that sleeps ``hang_s`` on selected trial values (a hung
    evaluation) — pair with ``timeout_s`` to test the un-wedge path."""

    marker_dir: str
    n: int = 1
    hang_s: float = 3600.0
    knob: str = "sampling_period"

    def __call__(self, config) -> float:
        if _claim(self.marker_dir, "hang", self.n):
            time.sleep(self.hang_s)
        return float(config[self.knob])
